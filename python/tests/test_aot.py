"""AOT pipeline tests: lowering, manifest format and init blobs."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import _manifest_entry
from compile.hlo import lower_to_hlo_text
from compile.model import catalogue
from compile.presets import PRESETS
from compile.systems import madqn

jax.config.update("jax_platform_name", "cpu")


def test_catalogue_names_unique_and_paired():
    arts = catalogue()
    names = [a.name for a in arts]
    assert len(names) == len(set(names)), "duplicate artifact names"
    policies = {n[: -len("_policy")] for n in names if n.endswith("_policy")}
    trains = {n[: -len("_train")] for n in names if n.endswith("_train")}
    assert policies == trains, "every system needs a policy+train pair"
    # every train artifact carries its init blobs
    for a in arts:
        if a.name.endswith("_train"):
            assert set(a.init) == {"params0", "opt0"}, a.name


def test_lowering_produces_parsable_hlo_text():
    arts = madqn.build(PRESETS["matrix2"])
    text = lower_to_hlo_text(arts[0].fn, *arts[0].example_args())
    assert text.startswith("HloModule")
    assert "parameter(0)" in text
    # the rust loader needs ROOT tuple outputs (return_tuple=True)
    assert "ROOT" in text


def test_lowered_policy_matches_eager():
    arts = madqn.build(PRESETS["matrix2"])
    policy = arts[0]
    params = jnp.asarray(arts[1].init["params0"])
    obs = jnp.asarray(np.random.RandomState(3).randn(1, 2, 4), jnp.float32)
    eager = policy.fn(params, obs)[0]
    jitted = jax.jit(policy.fn)(params, obs)[0]
    np.testing.assert_allclose(eager, jitted, rtol=1e-5, atol=1e-6)


def test_manifest_entry_format():
    art = madqn.build(PRESETS["matrix2"])[1]
    entry = _manifest_entry(
        art, f"{art.name}.hlo.txt", [("params0", "x.f32bin", 10)]
    )
    lines = entry.splitlines()
    assert lines[0] == f"artifact {art.name}"
    assert lines[1] == f"file {art.name}.hlo.txt"
    assert lines[-1] == "end"
    assert any(l.startswith("input params f32 ") for l in lines)
    assert any(l == "input lr f32" for l in lines), "scalars have no dims"
    assert any(l.startswith("meta params ") for l in lines)
    assert "init params0 x.f32bin 10" in lines


def test_aot_cli_writes_artifacts(tmp_path):
    out = tmp_path / "arts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--only", "matrix2",
         "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    files = os.listdir(out)
    assert "manifest.txt" in files
    assert "matrix2_madqn_policy.hlo.txt" in files
    assert "matrix2_madqn_train_params0.f32bin" in files
    blob = np.fromfile(out / "matrix2_madqn_train_params0.f32bin", "<f4")
    train = [a for a in catalogue() if a.name == "matrix2_madqn_train"][0]
    assert blob.shape == train.init["params0"].shape
    np.testing.assert_allclose(blob, train.init["params0"], rtol=1e-6)


def test_shape_metadata_consistency():
    """Manifest meta dims must match the declared tensor shapes."""
    for art in catalogue():
        n = art.meta["n_agents"]
        o = art.meta["obs_dim"]
        if art.name.endswith("_policy"):
            obs = next(t for t in art.inputs if t[0] == "obs")
            assert obs[2][-2:] == (n, o), art.name
        if art.name.endswith("_train"):
            p = art.meta["params"]
            params = next(t for t in art.inputs if t[0] == "params")
            assert params[2] == (p,), art.name
            opt = next(t for t in art.inputs if t[0] == "opt")
            assert opt[2] == (1 + 2 * p,), art.name
