"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

Hypothesis sweeps shapes (and block sizes) for both kernels; gradients of
the qmix mixer are checked against ``jax.grad`` of the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import agent_net, qmix_mixer
from compile.kernels.agent_net import agent_net_from_params
from compile.kernels.qmix_mixer import init_qmix_params
from compile.kernels import ref
from compile import networks as nets

jax.config.update("jax_platform_name", "cpu")


def _mlp_weights(key, n, o, h, a):
    ks = jax.random.split(key, 6)
    s = 0.3
    return (
        s * jax.random.normal(ks[0], (n, o, h)),
        s * jax.random.normal(ks[1], (n, h)),
        s * jax.random.normal(ks[2], (n, h, h)),
        s * jax.random.normal(ks[3], (n, h)),
        s * jax.random.normal(ks[4], (n, h, a)),
        s * jax.random.normal(ks[5], (n, a)),
    )


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 65),
    n=st.integers(1, 5),
    o=st.integers(1, 24),
    h=st.sampled_from([8, 32, 64]),
    a=st.integers(1, 10),
    block=st.sampled_from([1, 16, 128]),
    seed=st.integers(0, 2**16),
)
def test_agent_net_matches_ref(b, n, o, h, a, block, seed):
    key = jax.random.PRNGKey(seed)
    w = _mlp_weights(key, n, o, h, a)
    obs = jax.random.normal(jax.random.fold_in(key, 1), (b, n, o))
    got = agent_net(obs, *w, block_b=block)
    want = ref.agent_net_ref(obs, *w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_agent_net_from_params_matches_vmap_reference():
    key = jax.random.PRNGKey(0)
    params = nets.init_per_agent_mlp(key, 3, [14, 64, 64, 5])
    obs = jax.random.normal(jax.random.fold_in(key, 7), (32, 3, 14))
    got = agent_net_from_params(params, obs)
    want = nets.per_agent_mlp_apply(params, obs)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_agent_net_shared_weights_identical_agents():
    key = jax.random.PRNGKey(3)
    params = nets.init_per_agent_mlp(key, 4, [6, 32, 32, 2], shared=True)
    obs = jnp.broadcast_to(
        jax.random.normal(key, (8, 1, 6)), (8, 4, 6)
    )
    q = agent_net_from_params(params, obs)
    for i in range(1, 4):
        np.testing.assert_allclose(q[:, 0], q[:, i], rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 40),
    n=st.integers(2, 5),
    s=st.integers(2, 30),
    e=st.sampled_from([8, 16, 32]),
    block=st.sampled_from([4, 64]),
    seed=st.integers(0, 2**16),
)
def test_qmix_mixer_matches_ref(b, n, s, e, block, seed):
    key = jax.random.PRNGKey(seed)
    qs = jax.random.normal(key, (b, n))
    state = jax.random.normal(jax.random.fold_in(key, 1), (b, s))
    params = init_qmix_params(jax.random.fold_in(key, 2), n, s, e)
    got = qmix_mixer(qs, state, params, block_b=block)
    want = ref.qmix_mixer_ref(qs, state, params)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=6, deadline=None)
@given(
    b=st.integers(2, 24),
    n=st.integers(2, 4),
    s=st.integers(3, 16),
    seed=st.integers(0, 2**16),
)
def test_qmix_mixer_grads_match_ref(b, n, s, seed):
    key = jax.random.PRNGKey(seed)
    e = 16
    qs = jax.random.normal(key, (b, n))
    state = jax.random.normal(jax.random.fold_in(key, 1), (b, s))
    params = init_qmix_params(jax.random.fold_in(key, 2), n, s, e)

    def loss_k(qs, state, params):
        return jnp.sum(jnp.square(qmix_mixer(qs, state, params, block_b=64)))

    def loss_r(qs, state, params):
        return jnp.sum(jnp.square(ref.qmix_mixer_ref(qs, state, params)))

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(qs, state, params)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(qs, state, params)
    for a, b_ in zip(jax.tree.leaves(gk), jax.tree.leaves(gr)):
        np.testing.assert_allclose(a, b_, rtol=2e-3, atol=2e-4)


def test_qmix_monotonicity_in_agent_qs():
    """The mixer must be monotone in every agent's Q (QMIX's core
    constraint, enforced by |W|)."""
    key = jax.random.PRNGKey(5)
    n, s, e = 3, 12, 16
    params = init_qmix_params(key, n, s, e)
    state = jax.random.normal(jax.random.fold_in(key, 1), (64, s))
    qs = jax.random.normal(jax.random.fold_in(key, 2), (64, n))
    grads = jax.vmap(
        jax.grad(lambda q, st_: qmix_mixer(q[None], st_[None], params)[0])
    )(qs, state)
    assert np.all(np.asarray(grads) >= -1e-6), "dQtot/dq_i must be >= 0"


def test_qmix_mixer_under_jit_and_vjp():
    key = jax.random.PRNGKey(9)
    qs = jax.random.normal(key, (16, 3))
    state = jax.random.normal(jax.random.fold_in(key, 1), (16, 10))
    params = init_qmix_params(jax.random.fold_in(key, 2), 3, 10, 8)
    f = jax.jit(lambda q: jnp.sum(qmix_mixer(q, state, params)))
    g = jax.jit(jax.grad(f))(qs)
    assert g.shape == qs.shape
    assert np.all(np.isfinite(np.asarray(g)))


@pytest.mark.parametrize("b", [1, 17, 128, 200])
def test_agent_net_uneven_batches(b):
    """Batch sizes not divisible by the block tile still agree."""
    key = jax.random.PRNGKey(11)
    w = _mlp_weights(key, 3, 10, 32, 4)
    obs = jax.random.normal(jax.random.fold_in(key, 1), (b, 3, 10))
    got = agent_net(obs, *w, block_b=128)
    want = ref.agent_net_ref(obs, *w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
