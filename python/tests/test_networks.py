"""Unit tests for the L2 network building blocks."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import networks as nets
from compile.optim import adam_init, adam_update, clip_grads, polyak

jax.config.update("jax_platform_name", "cpu")


def test_mlp_shapes_and_activation():
    key = jax.random.PRNGKey(0)
    params = nets.init_mlp(key, [4, 8, 3])
    x = jnp.ones((5, 4))
    y = nets.mlp_apply(params, x)
    assert y.shape == (5, 3)
    t = nets.mlp_apply(params, x, final_activation=jnp.tanh)
    assert np.all(np.abs(np.asarray(t)) <= 1.0)


def test_per_agent_mlp_independent_towers():
    key = jax.random.PRNGKey(1)
    params = nets.init_per_agent_mlp(key, 3, [4, 8, 2])
    obs = jnp.zeros((7, 3, 4)).at[:, 1].set(1.0)
    out = nets.per_agent_mlp_apply(params, obs)
    assert out.shape == (7, 3, 2)
    # different towers -> different outputs for identical inputs
    same_in = jnp.ones((1, 3, 4))
    o = nets.per_agent_mlp_apply(params, same_in)
    assert not np.allclose(o[0, 0], o[0, 1])


def test_shared_weights_tie_towers():
    key = jax.random.PRNGKey(2)
    params = nets.init_per_agent_mlp(key, 3, [4, 8, 2], shared=True)
    o = nets.per_agent_mlp_apply(params, jnp.ones((1, 3, 4)))
    np.testing.assert_allclose(o[0, 0], o[0, 1], rtol=1e-6)


def test_gru_state_update_bounds():
    key = jax.random.PRNGKey(3)
    cell = nets.init_gru(key, 5, 8)
    x = jax.random.normal(key, (4, 5))
    h = jnp.zeros((4, 8))
    h1 = nets.gru_apply(cell, x, h)
    assert h1.shape == (4, 8)
    assert np.all(np.abs(np.asarray(h1)) <= 1.0), "GRU state in (-1,1)"
    # zero update gate keeps memory: with x=0 and h large, state persists
    h_big = 0.9 * jnp.ones((4, 8))
    h2 = nets.gru_apply(cell, jnp.zeros((4, 5)), h_big)
    assert h2.shape == h_big.shape


def test_per_agent_gru_vmap_consistency():
    key = jax.random.PRNGKey(4)
    cells = nets.init_per_agent_gru(key, 3, 5, 8)
    x = jax.random.normal(key, (2, 3, 5))
    h = jnp.zeros((2, 3, 8))
    out = nets.per_agent_gru_apply(cells, x, h)
    # agent 1 alone must match slicing its tower
    tower1 = jax.tree.map(lambda a: a[1], cells)
    ref = nets.gru_apply(tower1, x[:, 1], h[:, 1])
    np.testing.assert_allclose(out[:, 1], ref, rtol=1e-5, atol=1e-6)


def test_flatten_roundtrip():
    key = jax.random.PRNGKey(5)
    params = {
        "a": nets.init_mlp(key, [3, 4, 2]),
        "b": nets.init_gru(key, 3, 4),
    }
    flat, unravel = nets.flatten_params(params)
    back = unravel(flat)
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_allclose(x, y, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(p=st.integers(1, 300), seed=st.integers(0, 1000))
def test_adam_decreases_quadratic(p, seed):
    key = jax.random.PRNGKey(seed)
    target = jax.random.normal(key, (p,))
    params = jnp.zeros((p,))
    opt = adam_init(p)

    def loss(w):
        return jnp.sum(jnp.square(w - target))

    l0 = loss(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adam_update(opt, params, g, 0.05)
    assert loss(params) < 0.1 * l0


def test_clip_grads_bounds_norm():
    g = jnp.full((100,), 10.0)
    c = clip_grads(g, 5.0)
    assert np.linalg.norm(np.asarray(c)) <= 5.0 + 1e-4
    small = jnp.full((4,), 0.01)
    np.testing.assert_allclose(clip_grads(small, 5.0), small, rtol=1e-5)


def test_polyak_interpolates():
    t = jnp.zeros((4,))
    o = jnp.ones((4,))
    np.testing.assert_allclose(polyak(t, o, 0.25), 0.25 * np.ones(4))
    np.testing.assert_allclose(polyak(t, o, 1.0), np.ones(4))
