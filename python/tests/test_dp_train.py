"""Bucketed policy ladder + data-parallel train shards (DESIGN.md §11).

Two properties keep the rust side honest:

1. The bucketed ladder covers 1..=64 so `runtime/bucket.rs` can round any
   executor/eval width up to a lowered variant, and padding rows can
   never leak into real rows (the acting networks are row-independent).
2. The `_dp{D}` + `_apply` decomposition is exact: the equal-weight mean
   of per-shard gradients equals the full-batch gradient (eligible
   losses are unweighted batch means), so shard-grads -> host all-reduce
   -> `_apply` reproduces the fused `_train` step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import DP_SHARDS, POLICY_BATCHES, catalogue
from compile.presets import PRESETS
from compile.systems import madqn

jax.config.update("jax_platform_name", "cpu")

# systems whose train artifact must carry dp variants (unweighted-mean
# losses) and systems that must NOT (masked-mean losses)
DP_ELIGIBLE = ["matrix2_madqn", "matrix2_vdn", "matrix2_qmix",
               "smac3m_madqn", "spread3_maddpg_dec"]
DP_INELIGIBLE = ["switch3_madqn_rec", "switch3_dial"]


def _arts():
    if not hasattr(_arts, "cache"):
        _arts.cache = {a.name: a for a in catalogue()}
    return _arts.cache


def test_ladder_covers_1_to_64():
    assert POLICY_BATCHES[0] == 1 and POLICY_BATCHES[-1] == 64
    assert list(POLICY_BATCHES) == sorted(POLICY_BATCHES)
    # round-up gap bound: every n in 1..=64 has a bucket within 2x
    for n in range(1, 65):
        b = min(x for x in POLICY_BATCHES if x >= n)
        assert b < 2 * n or b == 1, (n, b)


def test_dp_variants_exist_exactly_for_mean_loss_systems():
    arts = _arts()
    for tag in DP_ELIGIBLE:
        assert f"{tag}_train_apply" in arts, tag
        base = arts[f"{tag}_train"]
        B = base.inputs[3][2][0]
        for d in DP_SHARDS:
            if B % d != 0:
                continue
            v = arts[f"{tag}_train_dp{d}"]
            assert v.meta["dp_shards"] == d
            assert v.meta["shard_batch"] == B // d
            # (params, target, *shard_batch) -> (grads, loss)
            assert v.inputs[0][0] == "params" and v.inputs[1][0] == "target"
            assert all(s[2][0] == B // d for s in v.inputs[2:])
            assert v.outputs[0] == ("grads", "float32", base.inputs[0][2])
            assert v.outputs[1][2] == tuple(base.outputs[3][2])
            assert not v.init, "dp variants carry no init blobs"
    for tag in DP_INELIGIBLE:
        assert f"{tag}_train_apply" not in arts, tag
        assert not any(n.startswith(f"{tag}_train_dp") for n in arts), tag


def _train_batch(rng, art):
    """Random full-batch inputs for every batch input (between opt and lr)."""
    out = []
    for (_, dt, shape) in art.inputs[3:-2]:
        if dt == "int32":
            out.append(jnp.asarray(rng.randint(0, 2, shape), jnp.int32))
        else:
            out.append(jnp.asarray(rng.randn(*shape), jnp.float32))
    return out


@pytest.mark.parametrize("tag", ["matrix2_madqn", "matrix2_qmix"])
def test_shard_gradient_mean_equals_full_batch_gradient(tag):
    arts = _arts()
    base = arts[f"{tag}_train"]
    rng = np.random.RandomState(11)
    P = base.inputs[0][2][0]
    params = jnp.asarray(rng.randn(P) * 0.1, jnp.float32)
    target = jnp.asarray(rng.randn(P) * 0.1, jnp.float32)
    batch = _train_batch(rng, base)
    B = batch[0].shape[0]
    g_full, loss_full = base.grad_fn(params, target, *batch)
    for d in DP_SHARDS:
        if B % d != 0:
            continue
        shard_fn = arts[f"{tag}_train_dp{d}"].fn
        shard = B // d
        gs, losses = [], []
        for k in range(d):
            rows = [x[k * shard:(k + 1) * shard] for x in batch]
            g_k, l_k = shard_fn(params, target, *rows)
            gs.append(g_k)
            losses.append(l_k)
        np.testing.assert_allclose(
            np.mean(np.stack(gs), axis=0), np.asarray(g_full),
            rtol=1e-4, atol=1e-5, err_msg=f"{tag} dp{d} gradient mean"
        )
        np.testing.assert_allclose(
            np.mean(np.stack(losses), axis=0), np.asarray(loss_full),
            rtol=1e-5, atol=1e-6, err_msg=f"{tag} dp{d} loss mean"
        )


def test_dp_pipeline_matches_fused_train_step():
    """shard grads -> host mean all-reduce -> _apply == fused _train."""
    arts = _arts()
    base = arts["matrix2_madqn_train"]
    apply_fn = arts["matrix2_madqn_train_apply"].fn
    rng = np.random.RandomState(5)
    P = base.inputs[0][2][0]
    params = jnp.asarray(rng.randn(P) * 0.1, jnp.float32)
    target = jnp.asarray(rng.randn(P) * 0.1, jnp.float32)
    opt = jnp.asarray(base.init["opt0"])
    batch = _train_batch(rng, base)
    lr, tau = jnp.float32(1e-3), jnp.float32(0.01)

    fused = base.fn(params, target, opt, *batch, lr, tau)

    d = 2
    shard_fn = arts[f"matrix2_madqn_train_dp{d}"].fn
    shard = batch[0].shape[0] // d
    gs = [
        shard_fn(params, target,
                 *[x[k * shard:(k + 1) * shard] for x in batch])[0]
        for k in range(d)
    ]
    reduced = jnp.mean(jnp.stack(gs), axis=0)
    applied = apply_fn(params, target, opt, reduced, lr, tau)

    for (got, want, name) in zip(applied, fused, ("params", "target", "opt")):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-6,
            err_msg=f"dp pipeline diverged on {name}"
        )


def test_padding_rows_never_affect_real_rows():
    """Bitwise: garbage in pad rows of a bucket call leaves real rows
    untouched (the property rust's bucket masking relies on)."""
    arts = _arts()
    pol = arts["matrix2_madqn_policy_b8"]
    rng = np.random.RandomState(3)
    P = pol.inputs[0][2][0]
    params = jnp.asarray(rng.randn(P) * 0.1, jnp.float32)
    n, B = 5, 8
    obs_shape = pol.inputs[1][2]
    real = rng.randn(n, *obs_shape[1:]).astype(np.float32)
    padded_zero = np.zeros(obs_shape, np.float32)
    padded_zero[:n] = real
    padded_junk = rng.randn(*obs_shape).astype(np.float32) * 100.0
    padded_junk[:n] = real
    fn = jax.jit(pol.fn)
    q_zero = np.asarray(fn(params, jnp.asarray(padded_zero))[0])
    q_junk = np.asarray(fn(params, jnp.asarray(padded_junk))[0])
    np.testing.assert_array_equal(
        q_zero[:n], q_junk[:n],
        err_msg="pad-row contents leaked into real rows"
    )


def test_dp_shard_artifacts_lower_to_hlo():
    from compile.hlo import lower_to_hlo_text

    art = _arts()["matrix2_madqn_train_dp2"]
    text = lower_to_hlo_text(art.fn, *art.example_args())
    assert text.startswith("HloModule")
    assert "ROOT" in text
    art = _arts()["matrix2_madqn_train_apply"]
    text = lower_to_hlo_text(art.fn, *art.example_args())
    assert text.startswith("HloModule")


def test_grad_fn_and_clip_norm_recorded():
    arts = madqn.build(PRESETS["matrix2"])
    train = arts[1]
    assert train.grad_fn is not None
    assert train.clip_norm == 40.0
    # the policy artifact carries neither
    assert arts[0].grad_fn is None and arts[0].clip_norm == 0.0
