"""System-level tests: every train-step artifact must run at its lowered
shapes, produce finite losses, and *learn* (loss decreases on a fixed
batch). Policy artifacts must be consistent with the training forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.presets import PRESETS, Preset
from compile.systems import dial, madqn, maddpg, value_decomp

jax.config.update("jax_platform_name", "cpu")


def make_args(art, seed=0):
    """Concrete random inputs at an artifact's declared shapes."""
    rng = np.random.RandomState(seed)
    args = []
    for (name, dt, shape) in art.inputs:
        if name == "params" and "params0" in art.init:
            args.append(jnp.asarray(art.init["params0"]))
        elif name == "target" and "params0" in art.init:
            args.append(jnp.asarray(art.init["params0"]))
        elif name == "opt" and "opt0" in art.init:
            args.append(jnp.asarray(art.init["opt0"]))
        elif name == "lr":
            args.append(jnp.float32(1e-3))
        elif name == "tau":
            args.append(jnp.float32(0.01))
        elif dt == "int32":
            hi = art.meta["act_dim"]
            args.append(jnp.asarray(rng.randint(0, hi, shape), jnp.int32))
        elif name == "disc":
            args.append(jnp.asarray(rng.rand(*shape), jnp.float32))
        elif name == "mask":
            args.append(jnp.ones(shape, jnp.float32))
        else:
            args.append(
                jnp.asarray(rng.randn(*shape) * 0.5, jnp.float32)
            )
    return args


def run_train_repeatedly(arts, steps=30, lr=3e-3):
    """Run a (policy, train) artifact pair on a fixed batch; return the
    loss trajectory."""
    train = next(a for a in arts if a.name.endswith("_train"))
    args = make_args(train)
    fn = jax.jit(train.fn)
    names = [n for (n, _, _) in train.inputs]
    losses = []
    params, target, opt = args[0], args[1], args[2]
    rest = args[3:-2]
    for _ in range(steps):
        out = fn(params, target, opt, *rest, jnp.float32(lr), jnp.float32(0.01))
        params, target, opt = out[0], out[1], out[2]
        losses.append(float(jnp.sum(out[3])))
    del names
    return losses


tiny = PRESETS["matrix2"]


@pytest.mark.parametrize(
    "arts,label",
    [
        (madqn.build(tiny), "madqn"),
        (value_decomp.build(tiny, mixer="vdn"), "vdn"),
        (value_decomp.build(tiny, mixer="qmix"), "qmix"),
    ],
    ids=["madqn", "vdn", "qmix"],
)
def test_discrete_train_losses_decrease(arts, label):
    losses = run_train_repeatedly(arts, steps=40)
    assert all(np.isfinite(losses)), losses[:5]
    assert losses[-1] < 0.5 * losses[0], f"{label}: {losses[0]} -> {losses[-1]}"


def test_madqn_policy_matches_training_forward():
    arts = madqn.build(tiny)
    policy = next(a for a in arts if a.name.endswith("_policy"))
    train = next(a for a in arts if a.name.endswith("_train"))
    params = jnp.asarray(train.init["params0"])
    obs = jnp.asarray(np.random.RandomState(0).randn(1, 2, 4), jnp.float32)
    (q,) = policy.fn(params, obs)
    assert q.shape == (1, 2, 3)
    assert np.all(np.isfinite(np.asarray(q)))


def test_madqn_recurrent_unroll_and_policy():
    p = PRESETS["switch3"]
    arts = madqn.build_recurrent(p)
    policy = next(a for a in arts if a.name.endswith("_policy"))
    train = next(a for a in arts if a.name.endswith("_train"))
    params = jnp.asarray(train.init["params0"])
    obs = jnp.asarray(np.random.RandomState(0).randn(1, 3, 5), jnp.float32)
    h = jnp.zeros((1, 3, 64))
    q1, h1 = policy.fn(params, obs, h)
    assert q1.shape == (1, 3, 2) and h1.shape == (1, 3, 64)
    # hidden state must influence the next step
    q2, _ = policy.fn(params, obs, h1)
    assert not np.allclose(np.asarray(q1), np.asarray(q2))
    # training reduces loss on a fixed batch
    losses = run_train_repeatedly(arts, steps=25)
    assert losses[-1] < losses[0]


class TestDial:
    p = PRESETS["switch3"]
    arts = dial.build(p)

    def _policy(self):
        return next(a for a in self.arts if a.name.endswith("_policy"))

    def test_policy_messages_are_binary_and_routed(self):
        policy = self._policy()
        train = next(a for a in self.arts if a.name.endswith("_train"))
        params = jnp.asarray(train.init["params0"])
        obs = jnp.asarray(
            np.random.RandomState(1).randn(1, 3, 5), jnp.float32
        )
        h = jnp.zeros((1, 3, 64))
        inbox = jnp.zeros((1, 3, 1))
        q, h2, inbox2 = policy.fn(params, obs, h, inbox)
        assert q.shape == (1, 3, 2)
        # routed inbox values are means of others' hard bits -> in [0,1]
        arr = np.asarray(inbox2)
        assert np.all((arr >= 0.0) & (arr <= 1.0))

    def test_messages_affect_qvalues(self):
        policy = self._policy()
        train = next(a for a in self.arts if a.name.endswith("_train"))
        params = jnp.asarray(train.init["params0"])
        obs = jnp.zeros((1, 3, 5))
        h = jnp.zeros((1, 3, 64))
        q0, _, _ = policy.fn(params, obs, h, jnp.zeros((1, 3, 1)))
        q1, _, _ = policy.fn(params, obs, h, jnp.ones((1, 3, 1)))
        assert not np.allclose(np.asarray(q0), np.asarray(q1)), (
            "the communication channel must reach the Q-network"
        )

    def test_train_loss_decreases(self):
        losses = run_train_repeatedly(self.arts, steps=25)
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0]

    def test_line_topology_routing(self):
        r = dial._routing_matrix(3, "line")
        arr = np.asarray(r)
        assert arr[0, 1] == 1.0 and arr[0, 2] == 0.0
        np.testing.assert_allclose(arr[1], [0.5, 0.0, 0.5])


class TestMaddpg:
    p = PRESETS["matrix2"]

    # continuous variant of the tiny preset for speed
    tiny_cont = Preset(
        name="tinyc", env="matrix", n_agents=2, obs_dim=4, act_dim=2,
        discrete=False, state_dim=8, hidden=32, batch=16,
        atoms=11, vmin=-5.0, vmax=5.0,
    )

    def test_arch_masks(self):
        np.testing.assert_allclose(
            maddpg.arch_mask(3, "decentralised"), np.eye(3)
        )
        np.testing.assert_allclose(
            maddpg.arch_mask(3, "centralised"), np.ones((3, 3))
        )
        net = np.asarray(maddpg.arch_mask(4, "networked"))
        assert net[0, 1] == 1 and net[0, 2] == 0 and net[1, 2] == 1

    def test_critic_inputs_masking(self):
        mask = maddpg.arch_mask(2, "decentralised")
        obs = jnp.ones((3, 2, 4))
        act = 2.0 * jnp.ones((3, 2, 2))
        x = maddpg.critic_inputs(mask, obs, act)
        assert x.shape == (3, 2, 12)
        arr = np.asarray(x)
        # critic 0 sees its own slot, zeros for agent 1
        assert np.all(arr[:, 0, :6] != 0)
        assert np.all(arr[:, 0, 6:] == 0)

    @pytest.mark.parametrize("distributional", [False, True],
                             ids=["maddpg", "mad4pg"])
    @pytest.mark.parametrize("arch", ["decentralised", "centralised"])
    def test_train_losses_finite_and_critic_learns(self, distributional, arch):
        arts = maddpg.build(
            self.tiny_cont, arch=arch, distributional=distributional
        )
        train = next(a for a in arts if a.name.endswith("_train"))
        args = make_args(train)
        fn = jax.jit(train.fn)
        params, target, opt = args[0], args[1], args[2]
        rest = args[3:-2]
        critic_losses = []
        for _ in range(40):
            out = fn(params, target, opt, *rest, jnp.float32(3e-3),
                     jnp.float32(0.01))
            params, target, opt = out[0], out[1], out[2]
            critic_losses.append(float(out[3][0]))
        assert all(np.isfinite(critic_losses))
        assert critic_losses[-1] < critic_losses[0]

    def test_policy_outputs_bounded(self):
        arts = maddpg.build(self.tiny_cont, arch="decentralised")
        policy = next(a for a in arts if a.name.endswith("_policy"))
        train = next(a for a in arts if a.name.endswith("_train"))
        params = jnp.asarray(train.init["params0"])
        obs = jnp.asarray(
            np.random.RandomState(2).randn(1, 2, 4) * 3, jnp.float32
        )
        (act,) = policy.fn(params, obs)
        assert act.shape == (1, 2, 2)
        assert np.all(np.abs(np.asarray(act)) <= 1.0)

    def test_projection_preserves_probability_mass(self):
        arts = maddpg.build(
            self.tiny_cont, arch="decentralised", distributional=True
        )
        # the projection is internal; verify via the train fn running with
        # extreme rewards without NaNs
        train = next(a for a in arts if a.name.endswith("_train"))
        args = make_args(train)
        # blow up rewards beyond [vmin, vmax]
        names = [n for (n, _, _) in train.inputs]
        i_rew = names.index("rew")
        args[i_rew] = 100.0 * jnp.ones_like(args[i_rew])
        out = train.fn(*args)
        assert np.all(np.isfinite(np.asarray(out[3])))


def test_param_counts_match_meta():
    for arts in (
        madqn.build(tiny),
        value_decomp.build(tiny, mixer="qmix"),
        maddpg.build(TestMaddpg.tiny_cont, arch="centralised"),
    ):
        train = next(a for a in arts if a.name.endswith("_train"))
        p = train.meta["params"]
        assert train.init["params0"].shape == (p,)
        assert train.init["opt0"].shape == (1 + 2 * p,)
        # all architectures share the same P for the same preset (maddpg)


def test_maddpg_arch_swap_preserves_param_count():
    arts_dec = maddpg.build(TestMaddpg.tiny_cont, arch="decentralised")
    arts_cen = maddpg.build(TestMaddpg.tiny_cont, arch="centralised")
    arts_net = maddpg.build(TestMaddpg.tiny_cont, arch="networked")
    ps = {a[1].meta["params"] for a in (arts_dec, arts_cen, arts_net)}
    assert len(ps) == 1, "architecture swap must not change P"
