"""Batched policy artifacts (the vectorized executor hot path).

The rust ``VecExecutor`` replaces B separate ``[1, N, O]`` policy calls
with one ``[B, N, O]`` call. That is only sound if the batched lowering
is row-equivalent to B independent B=1 calls — exactly what these tests
check, per system family (feedforward Q, recurrent Q, DIAL, continuous
actors), including the recurrent-carry outputs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import POLICY_BATCHES, catalogue
from compile.systems.base import batched_policy_variants

jax.config.update("jax_platform_name", "cpu")

CASES = [
    "matrix2_madqn_policy",
    "smac3m_vdn_policy",
    "switch3_madqn_rec_policy",
    "switch3_dial_policy",
    "spread3_maddpg_dec_policy",
]


def _arts():
    if not hasattr(_arts, "cache"):
        _arts.cache = {a.name: a for a in catalogue()}
    return _arts.cache


def _rand_inputs(art, rng):
    return [
        jnp.asarray(rng.randn(*[int(d) for d in shape]), jnp.float32)
        if dt == "float32"
        else jnp.asarray(rng.randint(0, 2, shape), jnp.int32)
        for (_, dt, shape) in art.inputs
    ]


def test_every_policy_has_batched_variants():
    arts = _arts()
    for name, art in list(arts.items()):
        if not name.endswith("_policy"):
            continue
        for b in POLICY_BATCHES:
            if b <= 1:
                continue  # B=1 bucket IS the base `*_policy` artifact
            vname = f"{name}_b{b}"
            assert vname in arts, f"missing batched variant {vname}"
            v = arts[vname]
            assert v.meta["env_batch"] == b
            obs = next(t for t in v.inputs if t[0] == "obs")
            assert obs[2][0] == b, vname
            for (base_out, v_out) in zip(art.outputs, v.outputs):
                assert v_out[2][0] == b, f"{vname} output {v_out[0]}"
            assert not v.init, "policy variants carry no init blobs"


def test_batched_variants_do_not_touch_train_artifacts():
    arts = catalogue()
    variants = batched_policy_variants(arts, (4,))
    assert all(v.name.endswith("_policy_b4") for v in variants)


@pytest.mark.parametrize("name", CASES)
@pytest.mark.parametrize("b", [4])
def test_batched_policy_matches_stacked_single_calls(name, b):
    arts = _arts()
    base = arts[name]
    batched = arts[f"{name}_b{b}"]
    rng = np.random.RandomState(7)
    params = jnp.asarray(
        rng.randn(int(base.inputs[0][2][0])) * 0.1, jnp.float32
    )
    # random [B, ...] inputs for every non-param input of the batched fn
    binputs = _rand_inputs(batched, rng)[1:]
    stacked = batched.fn(params, *binputs)
    for i in range(b):
        row = [x[i : i + 1] for x in binputs]
        single = base.fn(params, *row)
        assert len(single) == len(stacked)
        for (got, want) in zip(stacked, single):
            np.testing.assert_allclose(
                np.asarray(got[i : i + 1]),
                np.asarray(want),
                rtol=1e-5,
                atol=1e-5,
                err_msg=f"{name} b={b} row {i}",
            )


def test_batched_policy_lowers_to_hlo():
    from compile.hlo import lower_to_hlo_text

    art = _arts()["matrix2_madqn_policy_b4"]
    text = lower_to_hlo_text(art.fn, *art.example_args())
    assert text.startswith("HloModule")
    assert "ROOT" in text
