"""AOT driver: lower every artifact in the catalogue to HLO text.

Emits, under ``--out-dir`` (default ../artifacts):
  <name>.hlo.txt        — HLO text (NOT a serialized proto: the runtime's
                          xla_extension 0.5.1 rejects jax>=0.5's 64-bit
                          instruction ids; the text parser reassigns ids)
  <name>_params0.f32bin — raw little-endian f32 initial parameters
  <name>_opt0.f32bin    — raw little-endian f32 initial Adam state
  manifest.txt          — line-based artifact index parsed by
                          rust/src/runtime/manifest.rs

Usage: (from python/) python -m compile.aot [--out-dir DIR] [--only SUBSTR]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

from .hlo import lower_to_hlo_text
from .model import catalogue

_DT = {"float32": "f32", "int32": "i32"}


def _manifest_entry(art, fname: str, inits: list) -> str:
    lines = [f"artifact {art.name}", f"file {fname}"]
    for (name, dt, shape) in art.inputs:
        dims = " ".join(str(d) for d in shape)
        lines.append(f"input {name} {_DT[dt]} {dims}".rstrip())
    for (name, dt, shape) in art.outputs:
        dims = " ".join(str(d) for d in shape)
        lines.append(f"output {name} {_DT[dt]} {dims}".rstrip())
    for k, v in art.meta.items():
        lines.append(f"meta {k} {v}")
    for (name, f, n) in inits:
        lines.append(f"init {name} {f} {n}")
    lines.append("end")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None,
                    help="only lower artifacts whose name contains SUBSTR")
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    t_all = time.time()
    for art in catalogue():
        if args.only and args.only not in art.name:
            continue
        t0 = time.time()
        text = lower_to_hlo_text(art.fn, *art.example_args())
        fname = f"{art.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        inits = []
        for init_name, arr in art.init.items():
            arr = np.asarray(arr, dtype=np.float32)
            bin_name = f"{art.name}_{init_name}.f32bin"
            arr.tofile(os.path.join(out_dir, bin_name))
            inits.append((init_name, bin_name, arr.size))
        entries.append(_manifest_entry(art, fname, inits))
        print(f"  lowered {art.name:<40s} {len(text):>9d} chars "
              f"({time.time() - t0:.1f}s)", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(entries) + "\n")
    print(f"wrote {len(entries)} artifacts to {out_dir} "
          f"in {time.time() - t_all:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
