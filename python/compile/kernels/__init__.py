"""Pallas kernels (L1) — the compute hot-spots of mava-rs.

* ``agent_net``  — fused per-agent MLP forward (every system's acting path)
* ``qmix_mixer`` — QMIX monotonic mixing network with hypernetwork weight
  generation, differentiable via a custom_vjp whose forward AND backward
  are pallas kernels (used inside the QMIX train-step artifact)

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so real-TPU lowering is treated as a compile-only
target and numerics are validated through the interpret path (see
DESIGN.md §7 (Hardware adaptation)).
"""

from .agent_net import agent_net, agent_net_from_params
from .qmix_mixer import qmix_mixer

__all__ = ["agent_net", "agent_net_from_params", "qmix_mixer"]
