"""Fused per-agent MLP forward as a pallas kernel.

This is the acting hot-spot shared by every mava-rs system: all N agents'
3-layer MLP towers evaluated in a single kernel launch instead of N
separate network calls (or one call + N-way vmap dispatch).

TPU mapping (DESIGN.md §7 (Hardware adaptation)): the grid is
(batch-tiles, agents); for each grid step one agent's full weight set is
resident in VMEM (< 1 MiB for hidden <= 256, far under the ~16 MiB budget)
while a 128-row activation tile streams HBM->VMEM. The three matmuls use
``preferred_element_type=float32`` so they target the MXU with f32
accumulation. On CPU we run interpret=True; correctness is asserted
against ``ref.agent_net_ref`` (pure jnp) by the pytest/hypothesis suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# batch tile: one VPU-aligned block of rows (8x128 lanes). For acting
# (B == 1) the tile degenerates to a single row, which interpret mode and
# the TPU grid both handle (the block is padded internally).
DEFAULT_BLOCK_B = 128


def _kernel(obs_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, out_ref):
    x = obs_ref[:, 0, :]  # [Bt, O]
    w1, b1 = w1_ref[0], b1_ref[0]
    w2, b2 = w2_ref[0], b2_ref[0]
    w3, b3 = w3_ref[0], b3_ref[0]
    h = jnp.maximum(
        jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1, 0.0
    )
    h = jnp.maximum(
        jnp.dot(h, w2, preferred_element_type=jnp.float32) + b2, 0.0
    )
    out_ref[:, 0, :] = (
        jnp.dot(h, w3, preferred_element_type=jnp.float32) + b3
    )


@functools.partial(jax.jit, static_argnames=("block_b",))
def agent_net(obs, w1, b1, w2, b2, w3, b3, *, block_b: int = DEFAULT_BLOCK_B):
    """Per-agent 3-layer MLP: relu(relu(x@W1+b1)@W2+b2)@W3+b3, fused.

    Args:
      obs: [B, N, O] observations.
      w1/b1: [N, O, H] / [N, H]   first-layer weights per agent.
      w2/b2: [N, H, H] / [N, H]   second layer.
      w3/b3: [N, H, A] / [N, A]   output head (no activation).
      block_b: batch tile size.

    Returns: [B, N, A].
    """
    batch, n_agents, obs_dim = obs.shape
    hidden = w1.shape[-1]
    out_dim = w3.shape[-1]
    bt = min(block_b, batch)
    grid = (pl.cdiv(batch, bt), n_agents)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, 1, obs_dim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, obs_dim, hidden), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, hidden), lambda i, j: (j, 0)),
            pl.BlockSpec((1, hidden, hidden), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, hidden), lambda i, j: (j, 0)),
            pl.BlockSpec((1, hidden, out_dim), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, out_dim), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, 1, out_dim), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n_agents, out_dim), jnp.float32),
        interpret=True,
    )(obs, w1, b1, w2, b2, w3, b3)


def agent_net_from_params(params, obs, *, block_b: int = DEFAULT_BLOCK_B):
    """Call ``agent_net`` from a stacked per-agent MLP pytree.

    ``params`` is the output of ``networks.init_per_agent_mlp`` with
    exactly three layers: a list of {"w": [N, in, out], "b": [N, out]}.
    """
    assert len(params) == 3, "agent_net kernel is specialised to 3 layers"
    (l1, l2, l3) = params
    return agent_net(
        obs, l1["w"], l1["b"], l2["w"], l2["b"], l3["w"], l3["b"],
        block_b=block_b,
    )
