"""Pure-jnp oracles for the pallas kernels.

These are the CORE correctness references: the pytest/hypothesis suite
asserts ``assert_allclose(kernel(...), ref(...))`` across shape/dtype
sweeps, and grads of ``qmix_mixer`` against ``jax.grad`` of
``qmix_mixer_ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def agent_net_ref(obs, w1, b1, w2, b2, w3, b3):
    """Per-agent 3-layer MLP, reference implementation.

    obs [B, N, O]; w1 [N, O, H]; w2 [N, H, H]; w3 [N, H, A].
    """
    h = jax.nn.relu(jnp.einsum("bno,noh->bnh", obs, w1) + b1)
    h = jax.nn.relu(jnp.einsum("bnh,nhg->bng", h, w2) + b2)
    return jnp.einsum("bnh,nha->bna", h, w3) + b3


def qmix_mixer_ref(qs, state, params):
    """QMIX monotonic mixer, reference implementation.

    qs [B, N]; state [B, S]; params as in kernels.qmix_mixer.
    Returns q_tot [B].
    """
    batch, n_agents = qs.shape
    embed = params["hb1"].shape[1]
    w1 = jnp.abs(state @ params["hw1"] + params["hw1b"]).reshape(
        batch, n_agents, embed
    )
    b1 = state @ params["hb1"] + params["hb1b"]
    hid = jax.nn.elu(jnp.einsum("bn,bne->be", qs, w1) + b1)
    w2 = jnp.abs(state @ params["hw2"] + params["hw2b"])
    v = jax.nn.relu(state @ params["vw1"] + params["vb1"]) @ params["vw2"]
    v = v[:, 0] + params["vb2"][0]
    return jnp.sum(hid * w2, axis=-1) + v
