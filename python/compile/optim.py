"""Adam on a flat parameter vector.

The optimiser state is a single flat f32 vector ``[t, m(P), v(P)]`` so the
rust trainer can treat it as an opaque buffer threaded through the
functional ``train_step`` artifact.
"""

from __future__ import annotations

import jax.numpy as jnp


def adam_init(n_params: int) -> jnp.ndarray:
    return jnp.zeros((1 + 2 * n_params,), jnp.float32)


def adam_update(opt_state, flat_params, flat_grads, lr, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step. Returns (new_params, new_opt_state)."""
    p = flat_params.shape[0]
    t = opt_state[0] + 1.0
    m = opt_state[1 : 1 + p]
    v = opt_state[1 + p :]
    m = b1 * m + (1.0 - b1) * flat_grads
    v = b2 * v + (1.0 - b2) * jnp.square(flat_grads)
    mhat = m / (1.0 - jnp.power(b1, t))
    vhat = v / (1.0 - jnp.power(b2, t))
    new_params = flat_params - lr * mhat / (jnp.sqrt(vhat) + eps)
    new_state = jnp.concatenate([t[None], m, v])
    return new_params, new_state


def clip_grads(flat_grads, max_norm: float):
    """Global-norm gradient clipping (Acme/Mava default: 40.0 for DQN)."""
    norm = jnp.sqrt(jnp.sum(jnp.square(flat_grads)) + 1e-12)
    scale = jnp.minimum(1.0, max_norm / norm)
    return flat_grads * scale


def polyak(target, online, tau: float):
    """Soft target-network update."""
    return (1.0 - tau) * target + tau * online
