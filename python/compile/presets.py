"""Artifact presets: the fixed shapes every AOT artifact is lowered at.

AOT compilation freezes shapes, so each (environment, system) pair gets a
preset pinning agent count, observation/action dims, global-state dim,
batch size and network width.  The rust side reads the same numbers back
from ``artifacts/manifest.txt`` and its environments must produce matching
shapes (checked at startup).

Heterogeneous agent specs (speaker-listener) are padded to the per-preset
max dims — Mava supports per-agent specs natively; padding is the
fixed-shape AOT equivalent (DESIGN.md §4).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Preset:
    name: str
    env: str
    n_agents: int
    obs_dim: int
    act_dim: int          # discrete: number of actions; continuous: dim
    discrete: bool
    state_dim: int = 0    # global state (mixers / centralised critics)
    hidden: int = 64
    embed: int = 32       # QMIX mixing embed dim
    msg_dim: int = 0      # DIAL message size
    seq_len: int = 0      # recurrent training sequence length
    batch: int = 128
    atoms: int = 51       # MAD4PG distributional critic
    vmin: float = -50.0
    vmax: float = 10.0
    extras: dict = field(default_factory=dict)


PRESETS = {
    # tiny 2-agent repeated matrix game — fast integration tests
    "matrix2": Preset(
        name="matrix2", env="matrix", n_agents=2, obs_dim=4, act_dim=3,
        discrete=True, state_dim=8, hidden=32, embed=16, batch=16,
    ),
    # switch riddle (Foerster et al. 2016), 3 agents — Fig 4 top
    "switch3": Preset(
        name="switch3", env="switch", n_agents=3, obs_dim=5, act_dim=2,
        discrete=True, hidden=64, msg_dim=1, seq_len=8, batch=32,
    ),
    # smac_lite 3 marines vs 3 marines — Fig 4 bottom
    "smac3m": Preset(
        name="smac3m", env="smac_lite", n_agents=3, obs_dim=30, act_dim=9,
        discrete=True, state_dim=90, hidden=64, embed=32, batch=128,
    ),
    # smac_lite with replay-stabilisation fingerprint ([eps, step]) appended
    "smac3m_fp": Preset(
        name="smac3m_fp", env="smac_lite", n_agents=3, obs_dim=32, act_dim=9,
        discrete=True, state_dim=96, hidden=64, embed=32, batch=128,
    ),
    # MPE simple_spread, 3 agents — Fig 6 top-right
    "spread3": Preset(
        name="spread3", env="mpe_spread", n_agents=3, obs_dim=14, act_dim=2,
        discrete=False, state_dim=42, hidden=64, batch=128,
        vmin=-50.0, vmax=0.0,
    ),
    # MPE simple_speaker_listener (padded hetero specs) — Fig 6 top-right
    "speaker2": Preset(
        name="speaker2", env="mpe_speaker_listener", n_agents=2, obs_dim=11,
        act_dim=3, discrete=False, state_dim=22, hidden=64, batch=128,
        vmin=-40.0, vmax=0.0,
    ),
    # simplified multi-walker, 3 walkers — Fig 6 mid/bottom-right
    "walker3": Preset(
        name="walker3", env="multiwalker", n_agents=3, obs_dim=20, act_dim=4,
        discrete=False, state_dim=60, hidden=64, batch=128,
        vmin=-60.0, vmax=60.0,
    ),
}
