"""System definitions (L2): policy-forward + fused train-step per system.

Each module exposes ``build(preset, **variant) -> list[ArtifactDef]``.
``aot.py`` lowers every ArtifactDef to HLO text + a manifest entry.
"""

from .base import ArtifactDef
from . import madqn, dial, value_decomp, maddpg

__all__ = ["ArtifactDef", "madqn", "dial", "value_decomp", "maddpg"]
