"""Shared plumbing for system artifact builders."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

import zlib

from ..networks import flatten_params
from ..optim import adam_init, adam_update, clip_grads, polyak


@dataclass
class ArtifactDef:
    """One AOT artifact: a pure jax function + the shapes it is lowered at.

    ``inputs``/``outputs`` are (name, dtype, shape) triples recorded in the
    manifest so the rust runtime can type-check its calls. ``init`` maps
    name -> concrete initial array (parameters, optimiser state) that
    aot.py serialises alongside the HLO so rust starts from the same init.

    Train artifacts whose loss is an unweighted batch mean additionally
    carry ``grad_fn`` — ``(params, target, *batch) -> (grads[P], loss[L])``
    with UNCLIPPED gradients — plus the ``clip_norm`` the fused step
    applies. ``dp_train_variants`` lowers those into per-device-shard
    gradient artifacts for data-parallel training; systems with
    mask-weighted losses (recurrent MADQN, DIAL) leave ``grad_fn`` unset
    because the mean of their per-shard gradients is not the full-batch
    gradient (the masked-mean denominator differs per shard).
    """

    name: str
    fn: Callable
    inputs: Sequence[tuple]          # (name, dtype_str, shape_tuple)
    outputs: Sequence[tuple]         # (name, dtype_str, shape_tuple)
    meta: dict = field(default_factory=dict)
    init: dict = field(default_factory=dict)  # name -> np/jnp array
    grad_fn: Callable | None = None  # (params, target, *batch) -> (g, loss)
    clip_norm: float = 0.0           # global-norm clip the fused step uses

    def example_args(self):
        return [
            jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dt))
            for (_, dt, shape) in self.inputs
        ]


def batched_policy_variants(arts, batches=(4, 16)):
    """Batched (vectorized-executor) clones of every policy artifact.

    The acting networks are pure over the leading batch axis, so the same
    jax function lowers at ``[B, N, O]`` for any ``B``; only the example
    shapes change. For each ``*_policy`` artifact this returns
    ``{name}_b{B}`` variants whose leading input/output dims of 1 become
    ``B`` and whose meta gains ``env_batch`` — the artifacts
    ``rust/src/systems/executor.rs``'s ``VecExecutor`` acts through
    (DESIGN.md §6). ``b <= 1`` entries of the ladder are skipped (the base
    ``*_policy`` artifact IS the B=1 bucket); train artifacts are
    untouched.
    """

    def rebatch(specs, b):
        out = []
        for (name, dt, shape) in specs:
            shape = tuple(shape)
            if len(shape) >= 2 and shape[0] == 1:
                shape = (b,) + shape[1:]
            out.append((name, dt, shape))
        return out

    variants = []
    for art in arts:
        if not art.name.endswith("_policy"):
            continue
        for b in batches:
            if b <= 1:
                continue
            variants.append(ArtifactDef(
                f"{art.name}_b{b}",
                art.fn,
                rebatch(art.inputs, b),
                rebatch(art.outputs, b),
                dict(art.meta, env_batch=b),
            ))
    return variants


def dp_train_variants(arts, shards=(2, 4)):
    """Data-parallel shards of every gradient-decomposable train artifact.

    For each ``*_train`` artifact carrying a ``grad_fn`` this returns, per
    shard count ``D`` (with ``B % D == 0``), a ``{name}_dp{D}`` artifact
    computing UNCLIPPED gradients + loss on a ``B/D``-row batch shard:

      (params, target, *shard_batch) -> (grads[P], loss[L])

    plus ONE ``{name}_apply`` artifact performing the post-all-reduce
    update (clip -> adam -> polyak) on already-reduced gradients:

      (params, target, opt, grads, lr, tau) -> (params', target', opt')

    The rust trainer calls the ``_dp{D}`` variant once per device lane,
    mean-reduces the gradient vectors on the host in fixed lane order, and
    runs the identical ``_apply`` step on every lane — so replicas stay in
    bitwise lock-step (DESIGN.md §11). The decomposition is exact because
    the eligible losses are unweighted batch means: the full-batch
    gradient equals the equal-weight mean of the per-shard gradients.
    Clipping happens inside ``_apply`` (after the reduce), matching the
    fused step's clip-of-full-batch-gradient semantics.
    """
    f = "float32"
    variants = []
    for art in arts:
        if not art.name.endswith("_train") or art.grad_fn is None:
            continue
        params_spec, target_spec, opt_spec = art.inputs[0], art.inputs[1], art.inputs[2]
        lr_spec, tau_spec = art.inputs[-2], art.inputs[-1]
        batch_specs = list(art.inputs[3:-2])
        P = int(params_spec[2][0])
        B = int(batch_specs[0][2][0])
        loss_spec = art.outputs[3]
        made_any = False
        for d in shards:
            if d < 2 or B % d != 0:
                continue
            made_any = True
            shard = B // d
            resharded = [
                (n, dt, (shard,) + tuple(s)[1:]) for (n, dt, s) in batch_specs
            ]
            variants.append(ArtifactDef(
                f"{art.name}_dp{d}",
                art.grad_fn,
                [params_spec, target_spec] + resharded,
                [("grads", f, (P,)), ("loss", f, tuple(loss_spec[2]))],
                dict(art.meta, dp_shards=d, shard_batch=shard),
            ))
        if not made_any:
            continue

        def make_apply(clip):
            def apply(params, target, opt, grads, lr, tau):
                g = clip_grads(grads, clip)
                new_params, new_opt = adam_update(opt, params, g, lr)
                new_target = polyak(target, new_params, tau)
                return new_params, new_target, new_opt
            return apply

        variants.append(ArtifactDef(
            f"{art.name}_apply",
            make_apply(art.clip_norm),
            [params_spec, target_spec, opt_spec,
             ("grads", f, (P,)), lr_spec, tau_spec],
            list(art.outputs[:3]),
            dict(art.meta, clip_norm=art.clip_norm),
        ))
    return variants


def huber(x, delta: float = 1.0):
    absx = jnp.abs(x)
    return jnp.where(absx <= delta, 0.5 * x * x, delta * (absx - 0.5 * delta))


def flat_init(params):
    """(flat0, unravel, P) for a parameter pytree."""
    flat0, unravel = flatten_params(params)
    return flat0, unravel, int(flat0.shape[0])


def std_meta(preset, P: int, **extra) -> dict:
    m = {
        "n_agents": preset.n_agents,
        "obs_dim": preset.obs_dim,
        "act_dim": preset.act_dim,
        "discrete": int(preset.discrete),
        "state_dim": preset.state_dim,
        "hidden": preset.hidden,
        "msg_dim": preset.msg_dim,
        "seq_len": preset.seq_len,
        "batch": preset.batch,
        "params": P,
        "opt": 1 + 2 * P,
    }
    m.update(extra)
    return m


def opt0(P: int):
    return adam_init(P)


def stable_seed(s: str) -> int:
    """Deterministic string seed (``hash()`` is per-process randomised)."""
    return zlib.crc32(s.encode()) & 0x7FFFFFFF
