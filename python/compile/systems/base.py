"""Shared plumbing for system artifact builders."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

import zlib

from ..networks import flatten_params
from ..optim import adam_init


@dataclass
class ArtifactDef:
    """One AOT artifact: a pure jax function + the shapes it is lowered at.

    ``inputs``/``outputs`` are (name, dtype, shape) triples recorded in the
    manifest so the rust runtime can type-check its calls. ``init`` maps
    name -> concrete initial array (parameters, optimiser state) that
    aot.py serialises alongside the HLO so rust starts from the same init.
    """

    name: str
    fn: Callable
    inputs: Sequence[tuple]          # (name, dtype_str, shape_tuple)
    outputs: Sequence[tuple]         # (name, dtype_str, shape_tuple)
    meta: dict = field(default_factory=dict)
    init: dict = field(default_factory=dict)  # name -> np/jnp array

    def example_args(self):
        return [
            jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dt))
            for (_, dt, shape) in self.inputs
        ]


def batched_policy_variants(arts, batches=(4, 16)):
    """Batched (vectorized-executor) clones of every policy artifact.

    The acting networks are pure over the leading batch axis, so the same
    jax function lowers at ``[B, N, O]`` for any ``B``; only the example
    shapes change. For each ``*_policy`` artifact this returns
    ``{name}_b{B}`` variants whose leading input/output dims of 1 become
    ``B`` and whose meta gains ``env_batch`` — the artifacts
    ``rust/src/systems/executor.rs``'s ``VecExecutor`` acts through
    (DESIGN.md §6). Train artifacts are untouched.
    """

    def rebatch(specs, b):
        out = []
        for (name, dt, shape) in specs:
            shape = tuple(shape)
            if len(shape) >= 2 and shape[0] == 1:
                shape = (b,) + shape[1:]
            out.append((name, dt, shape))
        return out

    variants = []
    for art in arts:
        if not art.name.endswith("_policy"):
            continue
        for b in batches:
            variants.append(ArtifactDef(
                f"{art.name}_b{b}",
                art.fn,
                rebatch(art.inputs, b),
                rebatch(art.outputs, b),
                dict(art.meta, env_batch=b),
            ))
    return variants


def huber(x, delta: float = 1.0):
    absx = jnp.abs(x)
    return jnp.where(absx <= delta, 0.5 * x * x, delta * (absx - 0.5 * delta))


def flat_init(params):
    """(flat0, unravel, P) for a parameter pytree."""
    flat0, unravel = flatten_params(params)
    return flat0, unravel, int(flat0.shape[0])


def std_meta(preset, P: int, **extra) -> dict:
    m = {
        "n_agents": preset.n_agents,
        "obs_dim": preset.obs_dim,
        "act_dim": preset.act_dim,
        "discrete": int(preset.discrete),
        "state_dim": preset.state_dim,
        "hidden": preset.hidden,
        "msg_dim": preset.msg_dim,
        "seq_len": preset.seq_len,
        "batch": preset.batch,
        "params": P,
        "opt": 1 + 2 * P,
    }
    m.update(extra)
    return m


def opt0(P: int):
    return adam_init(P)


def stable_seed(s: str) -> int:
    """Deterministic string seed (``hash()`` is per-process randomised)."""
    return zlib.crc32(s.encode()) & 0x7FFFFFFF
