"""MADQN: independent multi-agent deep Q-networks (Tampuu et al., 2017).

Feedforward variant: the acting path is the fused pallas ``agent_net``
kernel; the train-step is a single HLO module computing the per-agent TD
loss, global-norm-clipped Adam update and Polyak target update.

Recurrent variant (paper: "feed-forward or recurrent actors"): per-agent
GRU + MLP head, trained on stored sequences (burn-in-free unroll from a
zero initial state, as in Mava's recurrent MADQN).

Artifact contracts (all params are ONE flat f32[P] vector):
  {p}_madqn_policy : (params, obs[1,N,O])                  -> (q[1,N,A],)
  {p}_madqn_train  : (params, target, opt, obs[B,N,O], act[B,N]i32,
                      rew[B,N], disc[B], next_obs[B,N,O], lr[], tau[])
                     -> (params', target', opt', loss[1])
  {p}_madqn_rec_policy : (params, obs[1,N,O], h[1,N,H]) -> (q, h')
  {p}_madqn_rec_train  : (params, target, opt, obs[B,T+1,N,O],
                          act[B,T,N]i32, rew[B,T,N], disc[B,T], mask[B,T],
                          lr[], tau[]) -> (params', target', opt', loss[1])
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import networks as nets
from ..kernels import agent_net_from_params
from ..optim import adam_update, clip_grads, polyak
from .base import ArtifactDef, flat_init, huber, opt0, std_meta, stable_seed


def _q_apply(params, obs):
    return nets.per_agent_mlp_apply(params, obs)


def build(preset, *, gamma: float = 0.99, shared_weights: bool = False):
    """Feedforward MADQN artifacts for ``preset``."""
    p = preset
    key = jax.random.PRNGKey(stable_seed(p.name))
    qnet = nets.init_per_agent_mlp(
        key, p.n_agents, [p.obs_dim, p.hidden, p.hidden, p.act_dim],
        shared=shared_weights,
    )
    flat0, unravel, P = flat_init(qnet)

    def policy(params, obs):
        return (agent_net_from_params(unravel(params), obs),)

    def grads(params, target, obs, act, rew, disc, next_obs):
        """Unclipped full/shard-batch gradients + loss (loss is a plain
        batch mean, so shard gradients average exactly — DESIGN.md §11)."""
        def loss_fn(flat):
            q = _q_apply(unravel(flat), obs)                       # [B,N,A]
            chosen = jnp.take_along_axis(q, act[..., None], -1)[..., 0]
            tq = _q_apply(unravel(target), next_obs).max(-1)       # [B,N]
            y = rew + gamma * disc[:, None] * tq
            return jnp.mean(huber(chosen - jax.lax.stop_gradient(y)))

        loss, g = jax.value_and_grad(loss_fn)(params)
        return g, loss[None]

    def train(params, target, opt, obs, act, rew, disc, next_obs, lr, tau):
        g, loss = grads(params, target, obs, act, rew, disc, next_obs)
        g = clip_grads(g, 40.0)
        new_params, new_opt = adam_update(opt, params, g, lr)
        new_target = polyak(target, new_params, tau)
        return new_params, new_target, new_opt, loss

    B, N, O, A = p.batch, p.n_agents, p.obs_dim, p.act_dim
    f, i = "float32", "int32"
    meta = std_meta(p, P, gamma=gamma)
    return [
        ArtifactDef(
            f"{p.name}_madqn_policy", policy,
            [("params", f, (P,)), ("obs", f, (1, N, O))],
            [("q", f, (1, N, A))], meta,
        ),
        ArtifactDef(
            f"{p.name}_madqn_train", train,
            [("params", f, (P,)), ("target", f, (P,)),
             ("opt", f, (1 + 2 * P,)), ("obs", f, (B, N, O)),
             ("act", i, (B, N)), ("rew", f, (B, N)), ("disc", f, (B,)),
             ("next_obs", f, (B, N, O)), ("lr", f, ()), ("tau", f, ())],
            [("params", f, (P,)), ("target", f, (P,)),
             ("opt", f, (1 + 2 * P,)), ("loss", f, (1,))],
            meta, init={"params0": flat0, "opt0": opt0(P)},
            grad_fn=grads, clip_norm=40.0,
        ),
    ]


def _rec_init(key, p):
    k1, k2 = jax.random.split(key)
    return {
        "gru": nets.init_per_agent_gru(k1, p.n_agents, p.obs_dim, p.hidden),
        "head": nets.init_per_agent_mlp(
            k2, p.n_agents, [p.hidden, p.hidden, p.act_dim]
        ),
    }


def _rec_step(params, obs_t, h):
    """One recurrent step: obs_t [B,N,O], h [B,N,H] -> (q [B,N,A], h')."""
    h = nets.per_agent_gru_apply(params["gru"], obs_t, h)
    q = nets.per_agent_mlp_apply(params["head"], h)
    return q, h


def _rec_unroll(params, obs_seq, h0):
    """Unroll over time: obs_seq [B,T,N,O] -> qs [B,T,N,A]."""

    def step(h, obs_t):
        q, h = _rec_step(params, obs_t, h)
        return h, q

    obs_tmajor = jnp.moveaxis(obs_seq, 1, 0)  # [T,B,N,O]
    _, qs = jax.lax.scan(step, h0, obs_tmajor)
    return jnp.moveaxis(qs, 0, 1)  # [B,T,N,A]


def build_recurrent(preset, *, gamma: float = 1.0):
    """Recurrent MADQN artifacts (switch uses undiscounted returns)."""
    p = preset
    key = jax.random.PRNGKey(stable_seed(p.name + "rec"))
    params0 = _rec_init(key, p)
    flat0, unravel, P = flat_init(params0)
    B, T = p.batch, p.seq_len
    N, O, A, H = p.n_agents, p.obs_dim, p.act_dim, p.hidden

    def policy(params, obs, h):
        q, h2 = _rec_step(unravel(params), obs, h)
        return q, h2

    # No grad_fn: the masked-mean loss denominator (sum of the padding
    # mask) differs per batch shard, so mean-of-shard-gradients is NOT
    # the full-batch gradient — recurrent MADQN is dp-ineligible.
    def train(params, target, opt, obs, act, rew, disc, mask, lr, tau):
        h0 = jnp.zeros((B, N, H), jnp.float32)

        def loss_fn(flat):
            qs = _rec_unroll(unravel(flat), obs[:, :T], h0)        # [B,T,N,A]
            chosen = jnp.take_along_axis(qs, act[..., None], -1)[..., 0]
            tqs = _rec_unroll(unravel(target), obs, h0)            # [B,T+1,...]
            tmax = tqs[:, 1:].max(-1)                              # [B,T,N]
            y = rew + gamma * disc[..., None] * tmax
            err = huber(chosen - jax.lax.stop_gradient(y))
            m = mask[..., None]
            return jnp.sum(err * m) / jnp.maximum(jnp.sum(m) * N, 1.0)

        loss, g = jax.value_and_grad(loss_fn)(params)
        g = clip_grads(g, 40.0)
        new_params, new_opt = adam_update(opt, params, g, lr)
        new_target = polyak(target, new_params, tau)
        return new_params, new_target, new_opt, loss[None]

    f, i = "float32", "int32"
    meta = std_meta(p, P, gamma=gamma, recurrent=1)
    return [
        ArtifactDef(
            f"{p.name}_madqn_rec_policy", policy,
            [("params", f, (P,)), ("obs", f, (1, N, O)),
             ("hidden", f, (1, N, H))],
            [("q", f, (1, N, A)), ("hidden", f, (1, N, H))], meta,
        ),
        ArtifactDef(
            f"{p.name}_madqn_rec_train", train,
            [("params", f, (P,)), ("target", f, (P,)),
             ("opt", f, (1 + 2 * P,)), ("obs", f, (B, T + 1, N, O)),
             ("act", i, (B, T, N)), ("rew", f, (B, T, N)),
             ("disc", f, (B, T)), ("mask", f, (B, T)),
             ("lr", f, ()), ("tau", f, ())],
            [("params", f, (P,)), ("target", f, (P,)),
             ("opt", f, (1 + 2 * P,)), ("loss", f, (1,))],
            meta, init={"params0": flat0, "opt0": opt0(P)},
        ),
    ]
