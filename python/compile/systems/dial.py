"""DIAL: differentiable inter-agent learning (Foerster et al., 2016).

Agents exchange messages through a differentiable channel during
centralised training; gradients flow across agents through the channel.
The discretise/regularise unit (DRU) adds Gaussian noise + sigmoid during
training and hard-thresholds at execution.

Message routing is part of the architecture and is baked into the
artifact: with the broadcast architecture each agent's inbox at t+1 is the
mean of the *other* agents' messages at t (channel noise optional, paper
§5 "Modules"); with the networked architecture the mean is taken over the
adjacency neighbourhood only.

Artifact contracts:
  {p}_dial_policy : (params, obs[1,N,O], h[1,N,H], inbox[1,N,M])
                    -> (q[1,N,A], h', inbox')     # inbox' already routed,
                                                  # messages hard DRU
  {p}_dial_train  : (params, target, opt, obs[B,T+1,N,O], act[B,T,N]i32,
                     rew[B,T], disc[B,T], mask[B,T], noise[B,T+1,N,M],
                     lr[], tau[]) -> (params', target', opt', loss[1])
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import networks as nets
from ..optim import adam_update, clip_grads, polyak
from .base import ArtifactDef, flat_init, huber, opt0, std_meta, stable_seed

DRU_SIGMA = 2.0  # channel noise std during training (DIAL paper value)


def _routing_matrix(n_agents: int, topology: str) -> jnp.ndarray:
    """R[i, j] = weight with which agent i receives agent j's message."""
    if topology == "broadcast":
        mask = 1.0 - jnp.eye(n_agents)
    elif topology == "line":
        idx = jnp.arange(n_agents)
        mask = (jnp.abs(idx[:, None] - idx[None, :]) == 1).astype(jnp.float32)
    else:
        raise ValueError(f"unknown topology {topology!r}")
    return mask / jnp.maximum(mask.sum(-1, keepdims=True), 1.0)


def _init(key, p):
    k1, k2 = jax.random.split(key)
    return {
        "gru": nets.init_per_agent_gru(
            k1, p.n_agents, p.obs_dim + p.msg_dim, p.hidden
        ),
        "head": nets.init_per_agent_mlp(
            k2, p.n_agents, [p.hidden, p.hidden, p.act_dim + p.msg_dim]
        ),
    }


def _step(params, route, obs_t, h, inbox, act_dim):
    """One comm step. Returns (q, h', msg_pre). inbox routing is external."""
    x = jnp.concatenate([obs_t, inbox], axis=-1)
    h = nets.per_agent_gru_apply(params["gru"], x, h)
    out = nets.per_agent_mlp_apply(params["head"], h)
    q, msg_pre = out[..., :act_dim], out[..., act_dim:]
    del route
    return q, h, msg_pre


def build(preset, *, gamma: float = 1.0, topology: str = "broadcast",
          channel_noise: float = 0.0):
    p = preset
    route = _routing_matrix(p.n_agents, topology)
    key = jax.random.PRNGKey(stable_seed(p.name + "dial" + topology))
    params0 = _init(key, p)
    flat0, unravel, P = flat_init(params0)
    B, T = p.batch, p.seq_len
    N, O, A, H, M = p.n_agents, p.obs_dim, p.act_dim, p.hidden, p.msg_dim

    def policy(params, obs, h, inbox):
        q, h2, msg_pre = _step(unravel(params), route, obs, h, inbox, A)
        msg = (msg_pre > 0.0).astype(jnp.float32)      # hard DRU (execution)
        inbox2 = jnp.einsum("ij,bjm->bim", route, msg)  # routed
        return q, h2, inbox2

    def _unroll(params, obs, noise, steps):
        """Soft-DRU unroll: returns qs [B,steps,N,A]."""
        h = jnp.zeros((B, N, H), jnp.float32)
        inbox = jnp.zeros((B, N, M), jnp.float32)

        def step(carry, inp):
            h, inbox = carry
            obs_t, noise_t = inp
            q, h, msg_pre = _step(params, route, obs_t, h, inbox, A)
            msg = jax.nn.sigmoid(msg_pre + DRU_SIGMA * noise_t)
            inbox = jnp.einsum("ij,bjm->bim", route, msg)
            if channel_noise > 0.0:
                inbox = inbox + channel_noise * noise_t
            return (h, inbox), q

        xs = (
            jnp.moveaxis(obs[:, :steps], 1, 0),
            jnp.moveaxis(noise[:, :steps], 1, 0),
        )
        _, qs = jax.lax.scan(step, (h, inbox), xs)
        return jnp.moveaxis(qs, 0, 1)

    # No grad_fn: the masked-mean loss denominator (sum of the padding
    # mask) differs per batch shard, so mean-of-shard-gradients is NOT
    # the full-batch gradient — DIAL is dp-ineligible (DESIGN.md §11).
    def train(params, target, opt, obs, act, rew, disc, mask, noise, lr, tau):
        def loss_fn(flat):
            qs = _unroll(unravel(flat), obs, noise, T)          # [B,T,N,A]
            chosen = jnp.take_along_axis(qs, act[..., None], -1)[..., 0]
            tqs = _unroll(unravel(target), obs, noise, T + 1)
            tmax = tqs[:, 1:].max(-1)                           # [B,T,N]
            y = rew[..., None] + gamma * disc[..., None] * tmax
            err = huber(chosen - jax.lax.stop_gradient(y))
            m = mask[..., None]
            return jnp.sum(err * m) / jnp.maximum(jnp.sum(m) * N, 1.0)

        loss, g = jax.value_and_grad(loss_fn)(params)
        g = clip_grads(g, 40.0)
        new_params, new_opt = adam_update(opt, params, g, lr)
        new_target = polyak(target, new_params, tau)
        return new_params, new_target, new_opt, loss[None]

    f, i = "float32", "int32"
    meta = std_meta(p, P, gamma=gamma, recurrent=1, topology=topology)
    suffix = "" if topology == "broadcast" else f"_{topology}"
    return [
        ArtifactDef(
            f"{p.name}_dial{suffix}_policy", policy,
            [("params", f, (P,)), ("obs", f, (1, N, O)),
             ("hidden", f, (1, N, H)), ("inbox", f, (1, N, M))],
            [("q", f, (1, N, A)), ("hidden", f, (1, N, H)),
             ("inbox", f, (1, N, M))], meta,
        ),
        ArtifactDef(
            f"{p.name}_dial{suffix}_train", train,
            [("params", f, (P,)), ("target", f, (P,)),
             ("opt", f, (1 + 2 * P,)), ("obs", f, (B, T + 1, N, O)),
             ("act", i, (B, T, N)), ("rew", f, (B, T)),
             ("disc", f, (B, T)), ("mask", f, (B, T)),
             ("noise", f, (B, T + 1, N, M)), ("lr", f, ()), ("tau", f, ())],
            [("params", f, (P,)), ("target", f, (P,)),
             ("opt", f, (1 + 2 * P,)), ("loss", f, (1,))],
            meta, init={"params0": flat0, "opt0": opt0(P)},
        ),
    ]
