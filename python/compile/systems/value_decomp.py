"""Value decomposition systems: VDN (Sunehag 2017) and QMIX (Rashid 2018).

Both share the MADQN per-agent Q-network for acting; training decomposes a
joint (team) value.  VDN mixes by summation ("additive mixing" module in
Mava); QMIX mixes monotonically through the pallas ``qmix_mixer`` kernel
with state-conditioned hypernetworks — the kernel is differentiable
(custom_vjp, forward and backward both pallas), so it sits directly inside
the lowered train step.

Artifact contracts:
  {p}_{vdn|qmix}_policy : (params, obs[1,N,O]) -> (q[1,N,A],)
  {p}_{vdn|qmix}_train  : (params, target, opt, obs[B,N,O], state[B,S],
                           act[B,N]i32, rew[B], disc[B],
                           next_obs[B,N,O], next_state[B,S], lr[], tau[])
                          -> (params', target', opt', loss[1])
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import networks as nets
from ..kernels import agent_net_from_params
from ..kernels.qmix_mixer import init_qmix_params, qmix_mixer
from ..optim import adam_update, clip_grads, polyak
from .base import ArtifactDef, flat_init, opt0, std_meta, stable_seed


def build(preset, *, mixer: str = "vdn", gamma: float = 0.99,
          double_q: bool = True):
    """Artifacts for VDN (``mixer='vdn'``) or QMIX (``mixer='qmix'``)."""
    assert mixer in ("vdn", "qmix")
    p = preset
    key = jax.random.PRNGKey(stable_seed(p.name + mixer))
    k1, k2 = jax.random.split(key)
    params0 = {
        "qnet": nets.init_per_agent_mlp(
            k1, p.n_agents, [p.obs_dim, p.hidden, p.hidden, p.act_dim]
        )
    }
    if mixer == "qmix":
        params0["mixer"] = init_qmix_params(
            k2, p.n_agents, p.state_dim, p.embed
        )
    flat0, unravel, P = flat_init(params0)

    def mix(params, chosen_q, state):
        if mixer == "vdn":
            return jnp.sum(chosen_q, axis=-1)
        return qmix_mixer(chosen_q, state, params["mixer"])

    def policy(params, obs):
        return (agent_net_from_params(unravel(params)["qnet"], obs),)

    def grads(params, target, obs, state, act, rew, disc, next_obs,
              next_state):
        """Unclipped gradients + loss; the TD loss is an unweighted batch
        mean, so per-shard gradients average exactly (DESIGN.md §11)."""
        def loss_fn(flat):
            ps = unravel(flat)
            tps = unravel(target)
            q = nets.per_agent_mlp_apply(ps["qnet"], obs)          # [B,N,A]
            chosen = jnp.take_along_axis(q, act[..., None], -1)[..., 0]
            q_tot = mix(ps, chosen, state)                         # [B]

            tq_next = nets.per_agent_mlp_apply(tps["qnet"], next_obs)
            if double_q:
                # online net selects, target net evaluates
                sel = nets.per_agent_mlp_apply(ps["qnet"], next_obs)
                amax = jnp.argmax(sel, axis=-1)
                next_best = jnp.take_along_axis(
                    tq_next, amax[..., None], -1
                )[..., 0]
            else:
                next_best = tq_next.max(-1)
            y_tot = rew + gamma * disc * mix(tps, next_best, next_state)
            td = q_tot - jax.lax.stop_gradient(y_tot)
            return jnp.mean(jnp.square(td))

        loss, g = jax.value_and_grad(loss_fn)(params)
        return g, loss[None]

    def train(params, target, opt, obs, state, act, rew, disc, next_obs,
              next_state, lr, tau):
        g, loss = grads(params, target, obs, state, act, rew, disc,
                        next_obs, next_state)
        g = clip_grads(g, 10.0)
        new_params, new_opt = adam_update(opt, params, g, lr)
        new_target = polyak(target, new_params, tau)
        return new_params, new_target, new_opt, loss

    B, N, O, A, S = p.batch, p.n_agents, p.obs_dim, p.act_dim, p.state_dim
    f, i = "float32", "int32"
    meta = std_meta(p, P, gamma=gamma, mixer=mixer, embed=p.embed)
    return [
        ArtifactDef(
            f"{p.name}_{mixer}_policy", policy,
            [("params", f, (P,)), ("obs", f, (1, N, O))],
            [("q", f, (1, N, A))], meta,
        ),
        ArtifactDef(
            f"{p.name}_{mixer}_train", train,
            [("params", f, (P,)), ("target", f, (P,)),
             ("opt", f, (1 + 2 * P,)), ("obs", f, (B, N, O)),
             ("state", f, (B, S)), ("act", i, (B, N)), ("rew", f, (B,)),
             ("disc", f, (B,)), ("next_obs", f, (B, N, O)),
             ("next_state", f, (B, S)), ("lr", f, ()), ("tau", f, ())],
            [("params", f, (P,)), ("target", f, (P,)),
             ("opt", f, (1 + 2 * P,)), ("loss", f, (1,))],
            meta, init={"params0": flat0, "opt0": opt0(P)},
            grad_fn=grads, clip_norm=10.0,
        ),
    ]
