"""MADDPG (Lowe et al., 2017) and MAD4PG (its distributional scale-up,
Barth-Maron et al., 2018 applied per Mava §4).

Actor-critic systems for continuous control.  The *architecture* — which
agents' observations/actions each agent's critic may condition on — is a
row-normalised mask matrix baked into the lowered graph:

  decentralised : identity mask        (independent DDPG agents)
  centralised   : all-ones mask        (CentralisedQValueCritic)
  networked     : line-adjacency mask  (NetworkedQValueCritic)

All three variants share the same parameter count (masked inputs are
zeroed, their first-layer weights receive zero gradient), so the rust
coordinator can swap architectures by swapping artifacts only.

MAD4PG replaces the scalar critic with a C51 categorical distribution over
``preset.atoms`` fixed atoms in [vmin, vmax]; targets are projected with
the standard distributional projection.  N-step returns are produced by
the rust n-step adder: ``rew`` arrives already summed/discounted and
``disc`` is gamma^n * (1 - done).

Artifact contracts:
  {p}_{sys}_{arch}_policy : (params, obs[1,N,O]) -> (act[1,N,A],)  # tanh
  {p}_{sys}_{arch}_train  : (params, target, opt, obs[B,N,O], act[B,N,A],
                             rew[B,N], disc[B], next_obs[B,N,O], lr[],
                             tau[]) -> (params', target', opt',
                                        loss[2]=[critic, actor])
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import networks as nets
from ..kernels import agent_net_from_params
from ..optim import adam_update, clip_grads, polyak
from .base import ArtifactDef, flat_init, opt0, std_meta, stable_seed

ARCHS = ("decentralised", "centralised", "networked")


def arch_mask(n_agents: int, arch: str) -> jnp.ndarray:
    if arch == "decentralised":
        return jnp.eye(n_agents, dtype=jnp.float32)
    if arch == "centralised":
        return jnp.ones((n_agents, n_agents), jnp.float32)
    if arch == "networked":
        idx = jnp.arange(n_agents)
        adj = (jnp.abs(idx[:, None] - idx[None, :]) <= 1).astype(jnp.float32)
        return adj
    raise ValueError(f"unknown architecture {arch!r}")


def critic_inputs(mask, obs, act):
    """Masked joint critic input per agent: [B, N, N*(O+A)].

    Row i of ``mask`` selects which agents' (obs, action) pairs critic i
    conditions on; de-selected slots are zeroed so every architecture
    shares one input layout (and parameter count).
    """
    b = obs.shape[0]
    n = mask.shape[0]
    joint = jnp.concatenate([obs, act], axis=-1)          # [B, N, F]
    f = joint.shape[-1]
    masked = mask[None, :, :, None] * joint[:, None, :, :]  # [B, Nc, Na, F]
    return masked.reshape(b, n, n * f)


def build(preset, *, arch: str = "decentralised", distributional: bool = False,
          gamma: float = 0.99, sys_name: str | None = None):
    """MADDPG (``distributional=False``) / MAD4PG (``True``) artifacts."""
    assert arch in ARCHS
    p = preset
    sys_name = sys_name or ("mad4pg" if distributional else "maddpg")
    mask = arch_mask(p.n_agents, arch)
    critic_out = p.atoms if distributional else 1
    critic_in = p.n_agents * (p.obs_dim + p.act_dim)
    key = jax.random.PRNGKey(stable_seed(p.name + sys_name + arch))
    k1, k2 = jax.random.split(key)
    params0 = {
        "actor": nets.init_per_agent_mlp(
            k1, p.n_agents, [p.obs_dim, p.hidden, p.hidden, p.act_dim]
        ),
        "critic": nets.init_per_agent_mlp(
            k2, p.n_agents, [critic_in, p.hidden, p.hidden, critic_out]
        ),
    }
    flat0, unravel, P = flat_init(params0)
    atoms = jnp.linspace(p.vmin, p.vmax, p.atoms)

    def actor_apply(ps, obs):
        return jnp.tanh(nets.per_agent_mlp_apply(ps["actor"], obs))

    def critic_apply(ps, obs, act):
        """Returns scalar Q [B,N] (maddpg) or logits [B,N,atoms] (mad4pg)."""
        x = critic_inputs(mask, obs, act)
        out = nets.per_agent_mlp_apply(ps["critic"], x)
        return out[..., 0] if not distributional else out

    def expected_q(logits):
        return jnp.sum(jax.nn.softmax(logits, -1) * atoms, -1)

    def project(rew, disc, next_probs):
        """C51 categorical projection. rew [B,N], disc [B], probs [B,N,K]."""
        z = rew[..., None] + (gamma * disc)[:, None, None] * atoms
        z = jnp.clip(z, p.vmin, p.vmax)
        dz = (p.vmax - p.vmin) / (p.atoms - 1)
        bj = (z - p.vmin) / dz                           # [B,N,K]
        lo = jnp.floor(bj)
        hi = jnp.ceil(bj)
        lo_w = next_probs * (hi - bj + (lo == hi))
        hi_w = next_probs * (bj - lo)
        proj = jnp.zeros_like(next_probs)
        lo_i = lo.astype(jnp.int32)
        hi_i = jnp.minimum(hi, p.atoms - 1).astype(jnp.int32)
        # scatter-add along the atom axis
        onehot_lo = jax.nn.one_hot(lo_i, p.atoms)        # [B,N,K,K]
        onehot_hi = jax.nn.one_hot(hi_i, p.atoms)
        proj = jnp.einsum("bnk,bnkj->bnj", lo_w, onehot_lo) + jnp.einsum(
            "bnk,bnkj->bnj", hi_w, onehot_hi
        )
        return proj

    def policy(params, obs):
        ps = unravel(params)
        pre = agent_net_from_params(ps["actor"], obs)
        return (jnp.tanh(pre),)

    def grads(params, target, obs, act, rew, disc, next_obs):
        """Unclipped gradients + [critic, actor] losses; both terms are
        unweighted batch means, so shard gradients average exactly
        (DESIGN.md §11)."""
        tps = unravel(target)

        def loss_fn(flat):
            ps = unravel(flat)
            ps_sg = jax.lax.stop_gradient(ps)

            # --- critic loss ---
            next_act = actor_apply(tps, next_obs)
            if distributional:
                t_logits = critic_apply(tps, next_obs, next_act)
                t_proj = project(rew, disc, jax.nn.softmax(t_logits, -1))
                logits = critic_apply(ps, obs, act)
                logp = jax.nn.log_softmax(logits, -1)
                critic_loss = -jnp.mean(
                    jnp.sum(jax.lax.stop_gradient(t_proj) * logp, -1)
                )
            else:
                tq = critic_apply(tps, next_obs, next_act)       # [B,N]
                y = rew + gamma * disc[:, None] * tq
                q = critic_apply(ps, obs, act)
                critic_loss = jnp.mean(
                    jnp.square(q - jax.lax.stop_gradient(y))
                )

            # --- actor loss: own action from policy, others from replay;
            # critic params frozen so actor grads don't reshape the critic.
            pi = actor_apply(ps, obs)                            # [B,N,A]
            n = p.n_agents
            eye = jnp.eye(n)[None, :, :, None]                   # [1,N,N,1]
            # for critic of agent i: action matrix with row i replaced by pi_i
            act_b = jnp.broadcast_to(act[:, None], (act.shape[0], n) + act.shape[1:])
            pi_b = jnp.broadcast_to(pi[:, None], act_b.shape)
            mixed = eye * pi_b + (1.0 - eye) * act_b             # [B,N,N,A]

            # evaluate critic for each agent's own-action substitution
            qs = []
            for i in range(n):
                out = critic_apply(ps_sg, obs, mixed[:, i])
                if distributional:
                    qs.append(expected_q(out)[:, i])
                else:
                    qs.append(out[:, i])
            actor_loss = -jnp.mean(jnp.stack(qs, -1))
            return critic_loss + actor_loss, (critic_loss, actor_loss)

        # NOTE on gradient flow: ps_sg freezes critic params in the actor
        # term; the critic term's own grads flow normally. The actor term
        # still differentiates through `pi` (actor params) because `mixed`
        # uses the non-frozen `pi`.
        (loss, (cl, al)), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        del loss
        return g, jnp.stack([cl, al])

    def train(params, target, opt, obs, act, rew, disc, next_obs, lr, tau):
        g, losses = grads(params, target, obs, act, rew, disc, next_obs)
        g = clip_grads(g, 40.0)
        new_params, new_opt = adam_update(opt, params, g, lr)
        new_target = polyak(target, new_params, tau)
        return new_params, new_target, new_opt, losses

    B, N, O, A = p.batch, p.n_agents, p.obs_dim, p.act_dim
    f = "float32"
    short = {"decentralised": "dec", "centralised": "cen", "networked": "net"}
    tag = f"{p.name}_{sys_name}_{short[arch]}"
    meta = std_meta(
        p, P, gamma=gamma, arch=arch, distributional=int(distributional),
        atoms=p.atoms if distributional else 0, vmin=p.vmin, vmax=p.vmax,
    )
    return [
        ArtifactDef(
            f"{tag}_policy", policy,
            [("params", f, (P,)), ("obs", f, (1, N, O))],
            [("act", f, (1, N, A))], meta,
        ),
        ArtifactDef(
            f"{tag}_train", train,
            [("params", f, (P,)), ("target", f, (P,)),
             ("opt", f, (1 + 2 * P,)), ("obs", f, (B, N, O)),
             ("act", f, (B, N, A)), ("rew", f, (B, N)), ("disc", f, (B,)),
             ("next_obs", f, (B, N, O)), ("lr", f, ()), ("tau", f, ())],
            [("params", f, (P,)), ("target", f, (P,)),
             ("opt", f, (1 + 2 * P,)), ("loss", f, (2,))],
            meta, init={"params0": flat0, "opt0": opt0(P)},
            grad_fn=grads, clip_norm=40.0,
        ),
    ]
