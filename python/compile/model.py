"""L2 entry point: the full catalogue of AOT artifacts.

``catalogue()`` assembles every (preset, system, architecture) artifact the
rust coordinator can run.  ``aot.py`` lowers them to HLO text; the pytest
suite executes them directly (pre-lowering) against hand-written checks.
"""

from __future__ import annotations

from .presets import PRESETS
from .systems import dial, madqn, maddpg, value_decomp
from .systems.base import batched_policy_variants, dp_train_variants

# The bucketed policy-batch ladder lowered for the vectorized executor /
# evaluator hot paths. Rust's `runtime/bucket.rs` rounds ANY requested
# width 1..=max up to the nearest bucket and masks the padding rows, so
# the ladder only has to cover the range, not every width (DESIGN.md
# §11). B=1 is the plain `*_policy` artifact.
POLICY_BATCHES = (1, 2, 4, 8, 16, 32, 64)

# Device-shard counts lowered for data-parallel training: each eligible
# `*_train` also gets `_dp{D}` per-shard gradient variants plus one
# `_apply` post-all-reduce update step (systems/base.py
# `dp_train_variants`; consumed by rust `Trainer` dp lanes).
DP_SHARDS = (2, 4)


def catalogue():
    """All artifacts, grouped exactly as DESIGN.md §4 specifies."""
    arts = []
    # tiny preset for fast rust integration tests (all three Q families)
    arts += madqn.build(PRESETS["matrix2"])
    arts += value_decomp.build(PRESETS["matrix2"], mixer="vdn")
    arts += value_decomp.build(PRESETS["matrix2"], mixer="qmix")
    # Fig 4 top: switch riddle — recurrent MADQN baseline vs DIAL
    arts += madqn.build_recurrent(PRESETS["switch3"])
    arts += dial.build(PRESETS["switch3"])
    # Fig 4 bottom: smac_lite — independent MADQN vs VDN (+ QMIX)
    arts += madqn.build(PRESETS["smac3m"])
    arts += madqn.build(PRESETS["smac3m_fp"])       # fingerprint module
    arts += value_decomp.build(PRESETS["smac3m"], mixer="vdn")
    arts += value_decomp.build(PRESETS["smac3m"], mixer="qmix")
    # Fig 6 top-right: MPE — MADDPG vs MAD4PG
    arts += maddpg.build(PRESETS["spread3"], arch="decentralised")
    arts += maddpg.build(PRESETS["spread3"], arch="decentralised",
                         distributional=True)
    arts += maddpg.build(PRESETS["speaker2"], arch="centralised")
    arts += maddpg.build(PRESETS["speaker2"], arch="centralised",
                         distributional=True)
    # Fig 6 mid-right: multi-walker — decentralised vs centralised MAD4PG
    arts += maddpg.build(PRESETS["walker3"], arch="decentralised",
                         distributional=True)
    arts += maddpg.build(PRESETS["walker3"], arch="centralised",
                         distributional=True)
    # architecture sweep on spread3 (ablation bench): cen + networked
    arts += maddpg.build(PRESETS["spread3"], arch="centralised",
                         distributional=True)
    arts += maddpg.build(PRESETS["spread3"], arch="networked",
                         distributional=True)
    # batched policy clones for the vectorized executor (DESIGN.md §6):
    # every `*_policy` also lowers at [B, N, O] for B in POLICY_BATCHES
    arts += batched_policy_variants(arts, POLICY_BATCHES)
    # data-parallel train shards (DESIGN.md §11): per-shard gradient
    # variants + the post-all-reduce apply step for every train artifact
    # whose loss decomposes over the batch (grad_fn set)
    arts += dp_train_variants(arts, DP_SHARDS)
    return arts
