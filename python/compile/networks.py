"""Neural network building blocks (pure jnp, params as pytrees).

Every network here is a pair of functions:

* ``init_*(key, ...) -> params``   — a pytree of arrays
* ``*_apply(params, x, ...) -> y`` — pure forward pass

Systems flatten the full parameter pytree with
``jax.flatten_util.ravel_pytree`` so the rust coordinator only ever sees a
single flat ``f32[P]`` vector; the unravel closure is baked into the lowered
HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def glorot(key, shape):
    fan_in, fan_out = shape[-2], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def init_mlp(key, sizes):
    """MLP params: sizes = [in, h1, ..., out]."""
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for k, (n_in, n_out) in zip(keys, zip(sizes[:-1], sizes[1:])):
        params.append(
            {"w": glorot(k, (n_in, n_out)), "b": jnp.zeros((n_out,), jnp.float32)}
        )
    return params


def mlp_apply(params, x, activation=jax.nn.relu, final_activation=None):
    """Apply an MLP; hidden layers use ``activation``."""
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = activation(x)
        elif final_activation is not None:
            x = final_activation(x)
    return x


def init_per_agent_mlp(key, n_agents, sizes, shared=False):
    """Per-agent MLP towers, stacked on a leading agent axis.

    With ``shared=True`` a single tower is initialised and broadcast —
    Mava's parameter-sharing option (RLlib-style) — but the stacked layout
    is kept so downstream code (and the pallas ``agent_net`` kernel) is
    identical either way.
    """
    if shared:
        tower = init_mlp(key, sizes)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_agents,) + a.shape), tower
        )
    keys = jax.random.split(key, n_agents)
    towers = [init_mlp(k, sizes) for k in keys]
    return jax.tree.map(lambda *a: jnp.stack(a), *towers)


def per_agent_mlp_apply(params, obs, final_activation=None):
    """Reference per-agent MLP forward: obs [..., N, O] -> [..., N, out].

    vmaps each agent's tower over the agent axis.  The pallas kernel
    ``kernels.agent_net`` computes the same function fused; this is the
    oracle / training-path version (XLA fuses it well under jit).
    """

    def one_agent(tower, x):
        return mlp_apply(tower, x, final_activation=final_activation)

    # move agent axis to front of both params (already leading) and obs
    obs_a = jnp.moveaxis(obs, -2, 0)  # [N, ..., O]
    out = jax.vmap(one_agent)(params, obs_a)  # [N, ..., out]
    return jnp.moveaxis(out, 0, -2)


def init_gru(key, in_dim, hidden):
    """GRU cell params (fused gate matrices)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": glorot(k1, (in_dim, 3 * hidden)),
        "wh": glorot(k2, (hidden, 3 * hidden)),
        "bi": jnp.zeros((3 * hidden,), jnp.float32),
        "bh": jnp.zeros((3 * hidden,), jnp.float32),
    }


def gru_apply(params, x, h):
    """GRU cell: returns new hidden state. x [..., I], h [..., H]."""
    hidden = h.shape[-1]
    gi = x @ params["wi"] + params["bi"]
    gh = h @ params["wh"] + params["bh"]
    i_r, i_z, i_n = jnp.split(gi, 3, axis=-1)
    h_r, h_z, h_n = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(i_r + h_r)
    z = jax.nn.sigmoid(i_z + h_z)
    n = jnp.tanh(i_n + r * h_n)
    del hidden
    return (1.0 - z) * n + z * h


def init_per_agent_gru(key, n_agents, in_dim, hidden, shared=False):
    if shared:
        cell = init_gru(key, in_dim, hidden)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_agents,) + a.shape), cell
        )
    keys = jax.random.split(key, n_agents)
    cells = [init_gru(k, in_dim, hidden) for k in keys]
    return jax.tree.map(lambda *a: jnp.stack(a), *cells)


def per_agent_gru_apply(params, x, h):
    """Per-agent GRU: x [..., N, I], h [..., N, H] -> [..., N, H]."""
    x_a = jnp.moveaxis(x, -2, 0)
    h_a = jnp.moveaxis(h, -2, 0)
    out = jax.vmap(gru_apply)(params, x_a, h_a)
    return jnp.moveaxis(out, 0, -2)


def flatten_params(params):
    """ravel_pytree wrapper: returns (flat f32[P], unravel closure)."""
    from jax.flatten_util import ravel_pytree

    flat, unravel = ravel_pytree(params)
    return flat.astype(jnp.float32), unravel
