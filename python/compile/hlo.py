"""AOT lowering helpers: jax function -> HLO *text* for the rust runtime.

HLO text (not ``lowered.compile().serialize()`` / HloModuleProto bytes) is
the interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the ``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``).  The text parser on the rust side reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import jax
from jax._src.lib import xla_client as xc


def lower_to_hlo_text(fn, *example_args) -> str:
    """Lower ``jax.jit(fn)`` at the example args' shapes and return HLO text.

    The computation is lowered with ``return_tuple=True`` so the rust side
    always unwraps a tuple (``Literal::to_tuple``), regardless of arity,
    and with ``keep_unused=True`` so the parameter list always matches the
    manifest even when a system ignores an input (e.g. VDN's global
    state, which only QMIX consumes).
    """
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def abstract(shape, dtype="float32"):
    """Shorthand for a ShapeDtypeStruct example arg."""
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))
