# mava-rs build entry points. `make artifacts` must run before any rust
# target that touches the PJRT runtime (training, integration tests,
# benches) — see README.md quickstart.

PYTHON ?= python
ARTIFACTS_DIR ?= artifacts

.PHONY: artifacts build test test-dist test-serve test-fault serve experiment check-bench-schema bench-vector bench-trainer bench-serve bench-build check fmt clippy lint doc

# lower every AOT artifact: policies (the full POLICY_BATCHES bucket
# ladder 1..64), fused train steps, and the _dp{2,4}/_apply
# data-parallel splits for mean-loss systems (DESIGN.md §4, §11)
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

build:
	cargo build --release

test:
	cargo test -q

# the distributed wire-layer suites alone: hermetic loopback +
# fault-injection tests (dist_net) and the frame-codec property tests
# (DESIGN.md §10). A subset of `make test`; no artifacts needed.
test-dist:
	cargo test -q --test dist_net --test properties

# the fault-tolerance tier alone (DESIGN.md §13): the chaos scenarios
# (SIGKILLed executor restarted, trainer checkpoint resume, restart
# budget exhaustion -> degraded run) plus the supervisor, retry/backoff
# and heartbeat tests in the lib + property suites. A subset of `make
# test`; hermetic (loopback TCP + self-exec'd child processes), no
# artifacts needed.
test-fault:
	cargo test -q --test dist_net chaos_
	cargo test -q --test properties prop_backoff prop_heartbeat
	cargo test -q --lib launch::supervise:: net::retry:: net::control::

# the serve suites alone: hermetic clock-driven batching/hot-reload
# tests plus the loopback TCP fault-injection tier (DESIGN.md §12).
# A subset of `make test`; no artifacts needed (the one EngineBackend
# test self-skips without artifacts/).
test-serve:
	cargo test -q --test serve

# policy inference service on the lowered artifacts (DESIGN.md §12;
# needs `make artifacts`). Prints its address; runs until killed.
serve:
	cargo run --release -- serve

# multi-seed experiment harness -> BENCH_<scenario>.json (EXPERIMENTS.md;
# needs `make artifacts`). Override e.g. SEEDS=5.
SEEDS ?= 3
experiment:
	cargo run --release -- experiment --seeds $(SEEDS)

# validate every emitted BENCH_*.json against the versioned schema
# (ISSUE 3 CI gate; passes trivially when no reports exist yet)
check-bench-schema:
	cargo run --release --quiet -- check-bench .

# the vectorized-executor scaling curve (ISSUE 1 acceptance bench);
# also writes BENCH_executor_hotpath.json — legacy vs SoA acting
# throughput at B ∈ {4,16} (ISSUE 4) — validated by `make
# check-bench-schema` like every BENCH_*.json
bench-vector:
	cargo bench --bench vector_scaling

# trainer hot path: host vs device-resident vs +prefetch steps/s
# (ISSUE 2 acceptance bench)
bench-trainer:
	cargo bench --bench trainer_throughput

# serve request-latency distribution across offered loads; writes
# BENCH_serve_latency.json (latency schema kind, gated by `make
# check-bench-schema`). Mock policy — no artifacts needed.
bench-serve:
	cargo bench --bench serve_latency

# compile-gate every bench harness without running it (CI)
bench-build:
	cargo bench --no-run

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings

# the repo's own invariant checker (DESIGN.md §14): six mechanical
# rules over rust/src + rust/tests (config-registry coherence, frame
# registry, clock seam, panic-free wire decode, engine-per-thread, no
# timing sleeps in tests). Named exceptions live in lint.allow; stale
# entries fail the gate. Runs the checker's own fixture tests first.
lint:
	cargo test -q -p xtask
	cargo xtask lint

# doc gate: -D warnings turns rustdoc lints (missing docs on the
# public System API surface — systems/{spec,nodes,builder}.rs — broken
# intra-doc links) into failures; CI runs this same target
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

check: fmt clippy lint test doc
