# mava-rs build entry points. `make artifacts` must run before any rust
# target that touches the PJRT runtime (training, integration tests,
# benches) — see README.md quickstart.

PYTHON ?= python
ARTIFACTS_DIR ?= artifacts

.PHONY: artifacts build test bench-vector check fmt clippy doc

# lower every AOT artifact (policy, batched policy variants, train steps)
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

build:
	cargo build --release

test:
	cargo test -q

# the vectorized-executor scaling curve (ISSUE 1 acceptance bench)
bench-vector:
	cargo bench --bench vector_scaling

fmt:
	cargo fmt --check

clippy:
	cargo clippy -- -D warnings

doc:
	cargo doc --no-deps

check: fmt clippy test doc
