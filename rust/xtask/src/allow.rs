//! The `lint.allow` baseline: named, justified exceptions.
//!
//! Format, one entry per line (`#` comments and blanks ignored):
//!
//! ```text
//! R3 rust/src/net/control.rs "Instant::now" supervision deadline is wall-clock by design
//! ```
//!
//! `rule` and `file` must match the finding exactly; the quoted needle
//! must be a substring of the finding's *text* (the trimmed source
//! line), which keeps entries stable across unrelated line-number
//! churn. The trailing free text is the mandatory justification —
//! entries without one are rejected, and entries that no longer match
//! any finding are reported as `R0` so the baseline cannot rot.

use crate::findings::Finding;
use std::fs;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: String,
    pub file: String,
    pub needle: String,
    pub reason: String,
    /// 1-based line in lint.allow, for R0 reporting.
    pub line_no: usize,
}

pub struct AllowList {
    pub entries: Vec<AllowEntry>,
    pub path: String,
}

impl AllowList {
    /// Parse baseline text; malformed entries are hard errors so a bad
    /// baseline cannot silently allow everything.
    pub fn parse(text: &str, path: &str) -> Result<AllowList, String> {
        let mut entries = Vec::new();
        for (i, raw_line) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = raw_line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let err = |what: &str| format!("{path}:{line_no}: {what}: `{line}`");
            let mut head = line.splitn(3, char::is_whitespace);
            let rule = head.next().unwrap_or("").to_string();
            let file = head.next().unwrap_or("").to_string();
            let rest = head.next().unwrap_or("").trim_start();
            if rule.len() < 2
                || !rule.starts_with('R')
                || !rule[1..].chars().all(|c| c.is_ascii_digit())
            {
                return Err(err("entry must start with a rule id like R3"));
            }
            if file.is_empty() {
                return Err(err("missing file path"));
            }
            if !rest.starts_with('"') {
                return Err(err("missing quoted needle after the file path"));
            }
            let close = match rest[1..].rfind('"') {
                Some(p) if p > 0 => p + 1,
                _ => return Err(err("unterminated needle quote")),
            };
            let needle = rest[1..close].to_string();
            let reason = rest[close + 1..]
                .trim()
                .trim_start_matches(['-', '—'])
                .trim()
                .to_string();
            if needle.is_empty() {
                return Err(err("empty needle"));
            }
            if reason.is_empty() {
                return Err(err("missing justification (every exception must say why)"));
            }
            entries.push(AllowEntry { rule, file, needle, reason, line_no });
        }
        Ok(AllowList { entries, path: path.to_string() })
    }

    /// Load from disk; a missing file is an empty baseline.
    pub fn load(path: &Path) -> Result<AllowList, String> {
        let shown = path.to_string_lossy().to_string();
        match fs::read_to_string(path) {
            Ok(text) => Self::parse(&text, &shown),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                Ok(AllowList { entries: Vec::new(), path: shown })
            }
            Err(e) => Err(format!("{shown}: {e}")),
        }
    }

    /// Split findings into (remaining, baselined); stale entries that
    /// matched nothing come back as R0 findings appended to remaining
    /// by the caller.
    pub fn apply(&self, findings: Vec<Finding>) -> (Vec<Finding>, Vec<Finding>, Vec<Finding>) {
        let mut used = vec![false; self.entries.len()];
        let mut remaining = Vec::new();
        let mut baselined = Vec::new();
        for f in findings {
            let hit = self.entries.iter().enumerate().find(|(_, e)| {
                e.rule == f.rule && e.file == f.file && f.text.contains(&e.needle)
            });
            match hit {
                Some((i, _)) => {
                    used[i] = true;
                    baselined.push(f);
                }
                None => remaining.push(f),
            }
        }
        let stale = self
            .entries
            .iter()
            .zip(used.iter())
            .filter(|(_, &u)| !u)
            .map(|(e, _)| {
                Finding::new(
                    "R0",
                    &self.path,
                    e.line_no,
                    format!("stale baseline entry: {} {} \"{}\"", e.rule, e.file, e.needle),
                    "the exception no longer matches any finding; delete the entry \
                     (or fix its needle if the flagged line merely moved)",
                )
            })
            .collect();
        (remaining, baselined, stale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, file: &str, text: &str) -> Finding {
        Finding::new(rule, file, 3, text.to_string(), "h")
    }

    #[test]
    fn entry_suppresses_matching_finding_only() {
        let al = AllowList::parse(
            "# comment\nR3 rust/src/a.rs \"Instant::now\" wall-clock by design\n",
            "lint.allow",
        )
        .unwrap();
        let fs = vec![
            finding("R3", "rust/src/a.rs", "let t = Instant::now();"),
            finding("R3", "rust/src/b.rs", "let t = Instant::now();"),
            finding("R6", "rust/src/a.rs", "let t = Instant::now();"),
        ];
        let (remaining, baselined, stale) = al.apply(fs);
        assert_eq!(baselined.len(), 1);
        assert_eq!(remaining.len(), 2);
        assert!(stale.is_empty());
    }

    #[test]
    fn stale_entries_surface_as_r0() {
        let al = AllowList::parse("R6 rust/tests/x.rs \"sleep(99)\" gone\n", "lint.allow").unwrap();
        let (remaining, baselined, stale) = al.apply(vec![]);
        assert!(remaining.is_empty() && baselined.is_empty());
        assert_eq!(stale.len(), 1);
        assert_eq!(stale[0].rule, "R0");
        assert_eq!(stale[0].line, 1);
    }

    #[test]
    fn malformed_entries_are_rejected() {
        assert!(AllowList::parse("R3 a.rs \"x\"\n", "l").is_err()); // no reason
        assert!(AllowList::parse("R3 a.rs x reason\n", "l").is_err()); // no needle
        assert!(AllowList::parse("X3 a.rs \"x\" reason\n", "l").is_err()); // bad rule
        assert!(AllowList::parse("R3 \"x\" reason\n", "l").is_err()); // no file
        assert!(AllowList::parse("", "l").unwrap().entries.is_empty());
    }

    #[test]
    fn one_entry_can_cover_repeated_sites_in_one_file() {
        let al =
            AllowList::parse("R3 rust/src/a.rs \"Instant::now\" deadline\n", "lint.allow").unwrap();
        let fs = vec![
            finding("R3", "rust/src/a.rs", "a Instant::now() b"),
            finding("R3", "rust/src/a.rs", "c Instant::now() d"),
        ];
        let (remaining, baselined, _) = al.apply(fs);
        assert!(remaining.is_empty());
        assert_eq!(baselined.len(), 2);
    }
}
