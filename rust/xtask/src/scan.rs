//! Masked-source scanning: the parsing substrate shared by every lint
//! rule (DESIGN.md §14).
//!
//! `syn` is unavailable in the offline container, so this module does
//! what the hand-rolled TOML subset in `rust/src/config/raw.rs` does
//! for config files: a small, deterministic, dependency-free scanner
//! that is exactly strong enough for the invariants we check. The core
//! trick is *masking* — comments and string contents are blanked to
//! spaces (newlines preserved) so that token searches, brace matching
//! and span extraction never trip over `"thread::sleep"` inside a doc
//! comment. String contents are kept separately for rules that need
//! them (R1 matches config keys that appear as literals).

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// A string literal in the original source. `start..end` spans the
/// delimiters; `inner_start..inner_end` spans the content only.
#[derive(Debug, Clone)]
pub struct StrLit {
    pub start: usize,
    pub end: usize,
    pub inner_start: usize,
    pub inner_end: usize,
}

/// A `fn` item with a body. `sig_start` is the offset of the `fn`
/// keyword, `body_start..body_end` the byte span between (and
/// including) the body braces.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub sig_start: usize,
    pub body_start: usize,
    pub body_end: usize,
}

/// An `impl` block: the header text (between `impl` and `{`) and the
/// body span.
#[derive(Debug, Clone)]
pub struct ImplSpan {
    pub header: String,
    pub body_start: usize,
    pub body_end: usize,
}

/// One parsed source file.
pub struct SourceFile {
    /// Path relative to the repo root, `/`-separated.
    pub rel: String,
    /// Original text.
    pub raw: String,
    /// Comment- and string-masked text (same length as `raw`).
    pub masked: String,
    /// All string literals, in source order.
    pub strings: Vec<StrLit>,
    /// `#[cfg(test)]` item spans.
    pub test_regions: Vec<(usize, usize)>,
    /// All `fn` items that have a body, in source order.
    pub fns: Vec<FnSpan>,
    /// All `impl` blocks, in source order.
    pub impls: Vec<ImplSpan>,
    line_starts: Vec<usize>,
}

pub fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

fn prev_is_ident(b: &[u8], i: usize) -> bool {
    i > 0 && is_ident_byte(b[i - 1])
}

/// Blank comments and string contents; return the masked text plus the
/// extracted string literals.
pub fn mask(raw: &str) -> (String, Vec<StrLit>) {
    let b = raw.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let mut strings = Vec::new();
    let blank = |out: &mut Vec<u8>, lo: usize, hi: usize| {
        for p in lo..hi.min(out.len()) {
            if out[p] != b'\n' {
                out[p] = b' ';
            }
        }
    };
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            blank(&mut out, start, i);
        } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start = i;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            blank(&mut out, start, i);
        } else if c == b'"' {
            let start = i;
            i += 1;
            while i < n {
                if b[i] == b'\\' && i + 1 < n {
                    i += 2;
                    continue;
                }
                if b[i] == b'"' {
                    break;
                }
                i += 1;
            }
            let inner_end = i.min(n);
            blank(&mut out, start + 1, inner_end);
            if i < n {
                i += 1; // consume the closing quote
            }
            strings.push(StrLit { start, end: i, inner_start: start + 1, inner_end });
        } else if c == b'r' && !prev_is_ident(b, i) && raw_string_at(b, i).is_some() {
            let (inner_start, inner_end, end) = raw_string_at(b, i).unwrap();
            blank(&mut out, inner_start, inner_end);
            strings.push(StrLit { start: i, end, inner_start, inner_end });
            i = end;
        } else if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                // escaped char literal: '\n', '\'', '\u{1F600}', ...
                let start = i;
                let mut j = i + 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                let close = if j < n { j + 1 } else { n };
                blank(&mut out, start + 1, close.saturating_sub(1));
                i = close;
            } else if i + 2 < n && b[i + 2] == b'\'' && b[i + 1] != b'\'' {
                // simple char literal 'x' — blank the payload so it is
                // not mistaken for an identifier
                out[i + 1] = b' ';
                i += 3;
            } else {
                // lifetime — leave intact
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    let masked = String::from_utf8(out).expect("masking preserves utf-8");
    (masked, strings)
}

/// If `b[i]` starts a raw string (`r"…"` / `r#"…"#`), return
/// `(inner_start, inner_end, end)`.
fn raw_string_at(b: &[u8], i: usize) -> Option<(usize, usize, usize)> {
    let n = b.len();
    let mut j = i + 1;
    let mut hashes = 0usize;
    while j < n && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || b[j] != b'"' {
        return None;
    }
    let inner_start = j + 1;
    let mut k = inner_start;
    while k < n {
        if b[k] == b'"' {
            let mut h = 0usize;
            let mut m = k + 1;
            while m < n && h < hashes && b[m] == b'#' {
                h += 1;
                m += 1;
            }
            if h == hashes {
                return Some((inner_start, k, m));
            }
        }
        k += 1;
    }
    Some((inner_start, n, n))
}

/// Index of the `}` matching the `{` at `open`, if any.
pub fn match_brace(masked: &str, open: usize) -> Option<usize> {
    match_delim(masked, open, b'{', b'}')
}

/// Generic delimiter matcher over masked text.
pub fn match_delim(masked: &str, open: usize, oc: u8, cc: u8) -> Option<usize> {
    let b = masked.as_bytes();
    if open >= b.len() || b[open] != oc {
        return None;
    }
    let mut depth = 0usize;
    let mut i = open;
    while i < b.len() {
        if b[i] == oc {
            depth += 1;
        } else if b[i] == cc {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
        i += 1;
    }
    None
}

/// All identifiers in `masked[lo..hi]` as `(offset, text)` pairs.
pub fn idents(masked: &str, lo: usize, hi: usize) -> Vec<(usize, &str)> {
    let b = masked.as_bytes();
    let hi = hi.min(b.len());
    let mut v = Vec::new();
    let mut i = lo;
    while i < hi {
        if is_ident_byte(b[i]) && !b[i].is_ascii_digit() && !prev_is_ident(b, i) {
            let start = i;
            while i < hi && is_ident_byte(b[i]) {
                i += 1;
            }
            v.push((start, &masked[start..i]));
        } else {
            i += 1;
        }
    }
    v
}

/// First occurrence of `w` in `s` with identifier boundaries on both
/// sides (so `find_word("sleep_ms", "sleep")` is `None`).
pub fn find_word(s: &str, w: &str) -> Option<usize> {
    find_word_from(s, w, 0)
}

/// As [`find_word`], starting the search at byte offset `from`.
pub fn find_word_from(s: &str, w: &str, mut from: usize) -> Option<usize> {
    let sb = s.as_bytes();
    while from <= s.len() {
        let p = s[from..].find(w)?;
        let at = from + p;
        let after = at + w.len();
        let before_ok = at == 0 || !is_ident_byte(sb[at - 1]);
        let after_ok = after >= sb.len() || !is_ident_byte(sb[after]);
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + 1;
    }
    None
}

/// Whether `s` contains `w` as a whole identifier.
pub fn has_word(s: &str, w: &str) -> bool {
    find_word(s, w).is_some()
}

impl SourceFile {
    pub fn parse(rel: String, raw: String) -> SourceFile {
        let (masked, strings) = mask(&raw);
        let mut line_starts = vec![0usize];
        for (i, c) in raw.bytes().enumerate() {
            if c == b'\n' {
                line_starts.push(i + 1);
            }
        }
        let test_regions = find_test_regions(&masked);
        let fns = find_fns(&masked);
        let impls = find_impls(&masked);
        SourceFile { rel, raw, masked, strings, test_regions, fns, impls, line_starts }
    }

    /// 1-based line number of byte offset `off`.
    pub fn line_of(&self, off: usize) -> usize {
        match self.line_starts.binary_search(&off) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// Trimmed original text of 1-based line `line`.
    pub fn line_text(&self, line: usize) -> &str {
        let lo = self.line_starts[line - 1];
        let hi = self
            .line_starts
            .get(line)
            .map(|&h| h.saturating_sub(1))
            .unwrap_or(self.raw.len());
        self.raw[lo..hi.max(lo)].trim()
    }

    /// Whether `off` falls inside a `#[cfg(test)]` item.
    pub fn in_test(&self, off: usize) -> bool {
        self.test_regions.iter().any(|&(lo, hi)| off >= lo && off < hi)
    }

    /// Contents of every string literal that starts in `[lo, hi)`.
    pub fn strings_in(&self, lo: usize, hi: usize) -> Vec<&str> {
        self.strings
            .iter()
            .filter(|s| s.start >= lo && s.start < hi)
            .map(|s| &self.raw[s.inner_start..s.inner_end])
            .collect()
    }

    /// Innermost `fn` whose body contains `off`.
    pub fn enclosing_fn(&self, off: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| off >= f.body_start && off < f.body_end)
            .min_by_key(|f| f.body_end - f.body_start)
    }

    /// Whether `off` is lexically inside a `while`/`for`/`loop` block
    /// that opened at or after `from`. Used by R6: a sleep that paces a
    /// polling loop is fine, a bare sleep that stands in for a
    /// condition is not.
    pub fn inside_loop(&self, from: usize, off: usize) -> bool {
        let b = self.masked.as_bytes();
        let mut stack: Vec<bool> = Vec::new();
        let mut i = from;
        while i < off && i < b.len() {
            match b[i] {
                b'{' => stack.push(self.is_loop_brace(i)),
                b'}' => {
                    stack.pop();
                }
                _ => {}
            }
            i += 1;
        }
        stack.iter().any(|&l| l)
    }

    /// Whether the `{` at `open` begins a loop body: scan back to the
    /// previous statement boundary and look for a loop keyword.
    fn is_loop_brace(&self, open: usize) -> bool {
        let b = self.masked.as_bytes();
        let mut j = open;
        while j > 0 {
            j -= 1;
            if matches!(b[j], b';' | b'{' | b'}') {
                j += 1;
                break;
            }
        }
        let head = &self.masked[j..open];
        has_word(head, "while") || has_word(head, "for") || has_word(head, "loop")
    }
}

/// Spans of `#[cfg(test)]` items (attribute through closing brace or
/// semicolon).
fn find_test_regions(masked: &str) -> Vec<(usize, usize)> {
    let b = masked.as_bytes();
    let mut regions = Vec::new();
    let mut from = 0usize;
    while let Some(p) = masked[from..].find("#[cfg(test)]") {
        let at = from + p;
        let mut j = at + "#[cfg(test)]".len();
        // skip whitespace and any further attributes
        loop {
            while j < b.len() && b[j].is_ascii_whitespace() {
                j += 1;
            }
            if j + 1 < b.len() && b[j] == b'#' && b[j + 1] == b'[' {
                match match_delim(masked, j + 1, b'[', b']') {
                    Some(close) => j = close + 1,
                    None => break,
                }
            } else {
                break;
            }
        }
        // the item ends at the first top-level `{…}` or `;`
        let mut depth = 0i32;
        let mut end = masked.len();
        let mut k = j;
        while k < b.len() {
            match b[k] {
                b'(' | b'[' | b'<' => depth += 1,
                b')' | b']' => depth -= 1,
                b'>' => {
                    if k > 0 && b[k - 1] != b'-' && b[k - 1] != b'=' {
                        depth -= 1;
                    }
                }
                b';' if depth <= 0 => {
                    end = k + 1;
                    break;
                }
                b'{' => {
                    end = match_brace(masked, k).map(|c| c + 1).unwrap_or(masked.len());
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        regions.push((at, end));
        from = end.max(at + 1);
    }
    regions
}

/// All `fn` items that have a body.
fn find_fns(masked: &str) -> Vec<FnSpan> {
    let b = masked.as_bytes();
    let mut out = Vec::new();
    for (off, word) in idents(masked, 0, masked.len()) {
        if word != "fn" {
            continue;
        }
        // the name is the next identifier; `fn(` pointer types have none
        let mut j = off + 2;
        while j < b.len() && b[j].is_ascii_whitespace() {
            j += 1;
        }
        if j >= b.len() || !is_ident_byte(b[j]) || b[j].is_ascii_digit() {
            continue;
        }
        let name_start = j;
        while j < b.len() && is_ident_byte(b[j]) {
            j += 1;
        }
        let name = masked[name_start..j].to_string();
        // find the body `{`, tracking (), [], <> so that a `{` inside a
        // where-clause bound or default argument never fools us
        let mut depth = 0i32;
        let mut body = None;
        let mut k = j;
        while k < b.len() {
            match b[k] {
                b'(' | b'[' | b'<' => depth += 1,
                b')' | b']' => depth -= 1,
                b'>' => {
                    if k > 0 && b[k - 1] != b'-' && b[k - 1] != b'=' {
                        depth -= 1;
                    }
                }
                b';' if depth <= 0 => break, // bodyless declaration
                b'{' if depth <= 0 => {
                    body = Some(k);
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        if let Some(open) = body {
            if let Some(close) = match_brace(masked, open) {
                out.push(FnSpan { name, sig_start: off, body_start: open, body_end: close + 1 });
            }
        }
    }
    out
}

/// All `impl` blocks.
fn find_impls(masked: &str) -> Vec<ImplSpan> {
    let b = masked.as_bytes();
    let mut out = Vec::new();
    for (off, word) in idents(masked, 0, masked.len()) {
        if word != "impl" {
            continue;
        }
        // `impl` in type position (`-> impl Iterator`) is preceded by
        // non-item context; a real block follows `;`, `}`, `{`, `]`,
        // start-of-file, or the `unsafe` keyword
        let mut j = off;
        while j > 0 && b[j - 1].is_ascii_whitespace() {
            j -= 1;
        }
        let item_pos = j == 0
            || matches!(b[j - 1], b';' | b'}' | b'{' | b']')
            || masked[..j].ends_with("unsafe");
        if !item_pos {
            continue;
        }
        let mut depth = 0i32;
        let mut k = off + 4;
        while k < b.len() {
            match b[k] {
                b'(' | b'[' | b'<' => depth += 1,
                b')' | b']' => depth -= 1,
                b'>' => {
                    if k > 0 && b[k - 1] != b'-' && b[k - 1] != b'=' {
                        depth -= 1;
                    }
                }
                b';' if depth <= 0 => break,
                b'{' if depth <= 0 => {
                    if let Some(close) = match_brace(masked, k) {
                        out.push(ImplSpan {
                            header: masked[off..k].trim().to_string(),
                            body_start: k,
                            body_end: close + 1,
                        });
                    }
                    break;
                }
                _ => {}
            }
            k += 1;
        }
    }
    out
}

/// The scanned `rust/src` + `rust/tests` tree.
pub struct Tree {
    pub files: Vec<SourceFile>,
}

impl Tree {
    /// Scan every `.rs` file under `rust/src` and `rust/tests`,
    /// sorted so output ordering is deterministic.
    pub fn load(root: &Path) -> io::Result<Tree> {
        let mut paths = Vec::new();
        for sub in ["rust/src", "rust/tests"] {
            let dir = root.join(sub);
            if dir.is_dir() {
                walk(&dir, &mut paths)?;
            }
        }
        let mut files = Vec::new();
        for p in paths {
            let raw = fs::read_to_string(&p)?;
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            files.push(SourceFile::parse(rel, raw));
        }
        Ok(Tree { files })
    }

    /// Look up a file by exact repo-relative path.
    pub fn file(&self, rel: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.rel == rel)
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Build an in-memory tree from `(rel_path, source)` pairs — the
/// fixture harness used by every rule's tests.
#[cfg(test)]
pub fn fixture_tree(files: &[(&str, &str)]) -> Tree {
    Tree {
        files: files
            .iter()
            .map(|(rel, src)| SourceFile::parse(rel.to_string(), src.to_string()))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masking_blanks_comments_and_strings_preserving_layout() {
        let src = "let a = \"thread::sleep\"; // thread::sleep\nlet b = 1;\n";
        let (masked, strings) = mask(src);
        assert_eq!(masked.len(), src.len());
        assert!(!masked.contains("thread::sleep"));
        assert!(masked.contains("let b = 1;"));
        assert_eq!(strings.len(), 1);
        assert_eq!(&src[strings[0].inner_start..strings[0].inner_end], "thread::sleep");
    }

    #[test]
    fn masking_handles_char_literals_and_lifetimes() {
        let (masked, _) = mask("fn f<'a>(x: &'a str) -> char { '\"' }");
        // the quote char literal must not open a string
        assert!(masked.contains("str"));
        let (masked2, strings2) = mask("let c = 'x'; let s = \"ab\";");
        assert!(!masked2.contains('x'));
        assert_eq!(strings2.len(), 1);
    }

    #[test]
    fn masking_handles_nested_block_comments_and_raw_strings() {
        let (masked, _) = mask("/* outer /* inner */ still */ fn ok() {}");
        assert!(masked.contains("fn ok"));
        assert!(!masked.contains("outer"));
        let (masked2, strings2) = mask("let r = r#\"panic!(\"x\")\"#; fn g() {}");
        assert!(!masked2.contains("panic"));
        assert_eq!(strings2.len(), 1);
        assert!(masked2.contains("fn g"));
    }

    #[test]
    fn fn_and_test_region_spans() {
        let src = "fn prod() { a(); }\n#[cfg(test)]\nmod tests {\n    fn t() { b(); }\n}\n";
        let f = SourceFile::parse("x.rs".into(), src.into());
        assert_eq!(f.fns.len(), 2);
        assert_eq!(f.fns[0].name, "prod");
        assert_eq!(f.test_regions.len(), 1);
        let t_off = f.masked.find("b()").unwrap();
        assert!(f.in_test(t_off));
        assert!(!f.in_test(f.masked.find("a()").unwrap()));
    }

    #[test]
    fn fn_body_found_past_return_types_and_where_clauses() {
        let src = "fn g<F>(f: F) -> Vec<u8> where F: FnMut() -> bool { body() }";
        let f = SourceFile::parse("x.rs".into(), src.into());
        assert_eq!(f.fns.len(), 1);
        let span = &f.fns[0];
        assert!(f.masked[span.body_start..span.body_end].contains("body()"));
    }

    #[test]
    fn loop_detection_is_lexical() {
        let src = "fn f() { while go() { step(); } after(); for x in v { y(); } }";
        let f = SourceFile::parse("x.rs".into(), src.into());
        let body = f.fns[0].body_start;
        assert!(f.inside_loop(body, f.masked.find("step").unwrap()));
        assert!(!f.inside_loop(body, f.masked.find("after").unwrap()));
        assert!(f.inside_loop(body, f.masked.find("y()").unwrap()));
    }

    #[test]
    fn word_boundaries_respected() {
        assert!(has_word("thread::sleep(d)", "sleep"));
        assert!(!has_word("sleep_interruptible(d)", "sleep"));
        assert!(find_word("max_train_steps", "train_steps").is_none());
    }
}
