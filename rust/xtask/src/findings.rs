//! Lint findings and their two output forms: human-readable
//! `file:line [rule] text — hint` lines and machine-readable JSON
//! (hand-rolled, like `rust/src/bench/report.rs`).

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule id: "R1".."R6", or "R0" for baseline hygiene.
    pub rule: &'static str,
    /// Repo-relative path.
    pub file: String,
    /// 1-based line; 0 for file-level findings.
    pub line: usize,
    /// Trimmed source line (or a synthesized description). `lint.allow`
    /// needles match against this text, so it is line-number stable.
    pub text: String,
    /// How to fix it.
    pub hint: String,
}

impl Finding {
    pub fn new(rule: &'static str, file: &str, line: usize, text: String, hint: &str) -> Finding {
        Finding { rule, file: file.to_string(), line, text, hint: hint.to_string() }
    }
}

/// Deterministic ordering: file, then line, then rule.
pub fn sort(findings: &mut [Finding]) {
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
}

/// `file:line [rule] text` with an indented fix hint, one finding per
/// block.
pub fn render_human(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{} [{}] {}\n    fix: {}\n", f.file, f.line, f.rule, f.text, f.hint));
    }
    out
}

/// A JSON array of finding objects.
pub fn render_json(findings: &[Finding]) -> String {
    let mut out = String::from("[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"rule\": {}, \"file\": {}, \"line\": {}, \"text\": {}, \"hint\": {}}}",
            json_str(f.rule),
            json_str(&f.file),
            f.line,
            json_str(&f.text),
            json_str(&f.hint)
        ));
    }
    out.push_str(if findings.is_empty() { "]" } else { "\n]" });
    out.push('\n');
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_output_has_location_rule_and_hint() {
        let f = vec![Finding::new("R3", "rust/src/a.rs", 7, "x".into(), "use Clock")];
        let h = render_human(&f);
        assert!(h.contains("rust/src/a.rs:7 [R3] x"));
        assert!(h.contains("fix: use Clock"));
    }

    #[test]
    fn json_output_escapes_and_is_wellformed() {
        let f = vec![Finding::new("R1", "a.rs", 1, "say \"hi\"\t".into(), "h")];
        let j = render_json(&f);
        assert!(j.contains("\\\"hi\\\""));
        assert!(j.contains("\\t"));
        assert!(j.trim_end().starts_with('['));
        assert!(j.trim_end().ends_with(']'));
        assert_eq!(render_json(&[]).trim_end(), "[]");
    }

    #[test]
    fn sort_is_by_file_line_rule() {
        let mut f = vec![
            Finding::new("R6", "b.rs", 1, String::new(), ""),
            Finding::new("R3", "a.rs", 9, String::new(), ""),
            Finding::new("R1", "a.rs", 2, String::new(), ""),
        ];
        sort(&mut f);
        assert_eq!(f[0].file, "a.rs");
        assert_eq!(f[0].line, 2);
        assert_eq!(f[2].file, "b.rs");
    }
}
