//! `cargo xtask lint` — the repo's invariant checker (DESIGN.md §14).
//!
//! Scans `rust/src` + `rust/tests` and enforces rules R1–R6 against
//! the `lint.allow` baseline. Exit codes: 0 clean, 1 findings, 2
//! usage or I/O error.

mod allow;
mod findings;
mod rules;
mod scan;

use findings::Finding;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const USAGE: &str = "\
usage: cargo xtask lint [--json] [--allow PATH] [--root PATH]

Checks the DESIGN.md \u{a7}14 invariants over rust/src and rust/tests:
  R1 config-registry coherence   R2 frame-kind registry
  R3 clock-seam                  R4 panic-free wire decode
  R5 engine-per-thread           R6 no timing sleeps in tests
plus R0, baseline hygiene (stale lint.allow entries).

  --json        machine-readable findings on stdout
  --allow PATH  baseline file (default: <root>/lint.allow)
  --root PATH   repo root (default: the workspace this xtask belongs to)
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("lint") => lint(&args[1..]),
        Some("--help") | Some("-h") | Some("help") => {
            print!("{USAGE}");
            ExitCode::SUCCESS
        }
        _ => {
            eprint!("{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn lint(args: &[String]) -> ExitCode {
    let mut json = false;
    let mut root = default_root();
    let mut allow_path: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--json" => json = true,
            "--allow" => match it.next() {
                Some(p) => allow_path = Some(PathBuf::from(p)),
                None => return usage_err("--allow needs a path"),
            },
            "--root" => match it.next() {
                Some(p) => root = PathBuf::from(p),
                None => return usage_err("--root needs a path"),
            },
            other => return usage_err(&format!("unknown argument `{other}`")),
        }
    }
    let allow_path = allow_path.unwrap_or_else(|| root.join("lint.allow"));
    match run_lint(&root, &allow_path) {
        Ok((remaining, baselined)) => {
            if json {
                print!("{}", findings::render_json(&remaining));
            } else {
                print!("{}", findings::render_human(&remaining));
            }
            eprintln!(
                "xtask lint: {} finding(s), {} baselined ({})",
                remaining.len(),
                baselined,
                allow_path.display()
            );
            if remaining.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("xtask lint: error: {e}");
            ExitCode::from(2)
        }
    }
}

fn usage_err(msg: &str) -> ExitCode {
    eprintln!("xtask: {msg}");
    eprint!("{USAGE}");
    ExitCode::from(2)
}

/// Repo root = two levels above this crate (rust/xtask → repo).
fn default_root() -> PathBuf {
    let mani = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    mani.parent()
        .and_then(|p| p.parent())
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| PathBuf::from("."))
}

/// Scan, rule-check, baseline-filter. Returns the actionable findings
/// (rule violations plus R0 stale-baseline entries, sorted) and the
/// count of baselined ones.
fn run_lint(root: &Path, allow_path: &Path) -> Result<(Vec<Finding>, usize), String> {
    let tree = scan::Tree::load(root).map_err(|e| format!("scanning {}: {e}", root.display()))?;
    if tree.files.is_empty() {
        return Err(format!("no .rs files under {}/rust/{{src,tests}}", root.display()));
    }
    let raw = rules::run_all(&tree);
    let allow = allow::AllowList::load(allow_path)?;
    let (mut remaining, baselined, stale) = allow.apply(raw);
    remaining.extend(stale);
    findings::sort(&mut remaining);
    Ok((remaining, baselined.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

    /// A throwaway on-disk repo with the minimal coherent R1/R2 core
    /// plus the given extra files.
    fn scratch_repo(extra: &[(&str, &str)]) -> PathBuf {
        let seq = DIR_SEQ.fetch_add(1, Ordering::SeqCst);
        let root =
            std::env::temp_dir().join(format!("xtask-lint-{}-{seq}", std::process::id()));
        let base: &[(&str, &str)] = &[
            (
                "rust/src/config/mod.rs",
                "pub struct TrainConfig { pub lr: f64 }\n\
                 impl TrainConfig {\n\
                 pub fn from_raw(&mut self) { self.lr = 0.0; }\n\
                 pub fn set(&mut self) { self.lr = 1.0; }\n\
                 pub fn to_cli_args(&self) { kv(\"lr\"); }\n\
                 pub fn validate(&self) {}\n}\n",
            ),
            ("rust/src/main.rs", "fn usage() { print(\"keys: lr\"); }\nfn main() {}\n"),
            (
                "rust/src/net/frame.rs",
                "pub enum FrameKind { Hello = 0 }\n\
                 impl FrameKind {\n\
                 pub const ALL: [FrameKind; 1] = [FrameKind::Hello];\n\
                 pub fn from_byte(b: u8) -> Option<FrameKind> { ALL.get(b as usize).copied() }\n\
                 }\n",
            ),
            ("rust/src/net/wire.rs", "fn go() { let _ = FrameKind::Hello; }\n"),
        ];
        for (rel, body) in base.iter().chain(extra.iter()) {
            let p = root.join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(p, body).unwrap();
        }
        root
    }

    #[test]
    fn end_to_end_clean_tree_is_clean() {
        let root = scratch_repo(&[]);
        let (remaining, baselined) = run_lint(&root, &root.join("lint.allow")).unwrap();
        assert!(remaining.is_empty(), "{remaining:?}");
        assert_eq!(baselined, 0);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn end_to_end_finding_then_baseline_then_stale() {
        let root = scratch_repo(&[(
            "rust/src/net/control.rs",
            "fn tick() { let t = Instant::now(); }\n",
        )]);
        // 1. the violation is reported
        let (remaining, _) = run_lint(&root, &root.join("lint.allow")).unwrap();
        assert_eq!(remaining.len(), 1, "{remaining:?}");
        assert_eq!(remaining[0].rule, "R3");
        // 2. a justified baseline entry suppresses it
        fs::write(
            root.join("lint.allow"),
            "R3 rust/src/net/control.rs \"Instant::now\" heartbeat is wall-clock by design\n",
        )
        .unwrap();
        let (remaining, baselined) = run_lint(&root, &root.join("lint.allow")).unwrap();
        assert!(remaining.is_empty(), "{remaining:?}");
        assert_eq!(baselined, 1);
        // 3. fixing the code makes the entry stale -> R0
        fs::write(root.join("rust/src/net/control.rs"), "fn tick() {}\n").unwrap();
        let (remaining, _) = run_lint(&root, &root.join("lint.allow")).unwrap();
        assert_eq!(remaining.len(), 1, "{remaining:?}");
        assert_eq!(remaining[0].rule, "R0");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn end_to_end_deleting_a_usage_row_fails_r1() {
        let root = scratch_repo(&[]);
        fs::write(root.join("rust/src/main.rs"), "fn usage() { print(\"keys:\"); }\n").unwrap();
        let (remaining, _) = run_lint(&root, &root.join("lint.allow")).unwrap();
        assert_eq!(remaining.len(), 1, "{remaining:?}");
        assert_eq!(remaining[0].rule, "R1");
        assert!(remaining[0].text.contains("`lr`"));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn malformed_baseline_is_a_hard_error() {
        let root = scratch_repo(&[]);
        fs::write(root.join("lint.allow"), "R3 rust/src/a.rs \"x\"\n").unwrap();
        assert!(run_lint(&root, &root.join("lint.allow")).is_err());
        let _ = fs::remove_dir_all(&root);
    }
}
