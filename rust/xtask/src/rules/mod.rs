//! The lint rules (DESIGN.md §14). Each rule is a pure function from
//! the scanned [`Tree`] to findings, with its own fixture tests.

pub mod clock_seam;
pub mod config_registry;
pub mod engine_thread;
pub mod frame_registry;
pub mod panic_free_decode;
pub mod test_sleeps;

use crate::findings::Finding;
use crate::scan::Tree;

/// Run every rule in id order.
pub fn run_all(tree: &Tree) -> Vec<Finding> {
    let mut out = Vec::new();
    out.extend(config_registry::check(tree)); // R1
    out.extend(frame_registry::check(tree)); // R2
    out.extend(clock_seam::check(tree)); // R3
    out.extend(panic_free_decode::check(tree)); // R4
    out.extend(engine_thread::check(tree)); // R5
    out.extend(test_sleeps::check(tree)); // R6
    out
}
