//! R1 — config-registry coherence.
//!
//! `TrainConfig` keys live in five hand-maintained places: the struct
//! itself, the file parser (`from_raw`), the CLI override parser
//! (`set`), the re-serializer (`to_cli_args`) and the usage text in
//! `main.rs`. PRs 6–9 each re-maintained that quintuple by memory;
//! this rule makes the struct the source of truth and flags any key
//! missing from the other four. (The reverse direction — a registry
//! naming a field that does not exist — is already a compile error,
//! and `validate()` is only required to exist: not every key has an
//! invariant worth validating.)

use crate::findings::Finding;
use crate::scan::{self, SourceFile, Tree};

const CONFIG: &str = "rust/src/config/mod.rs";
const MAIN: &str = "rust/src/main.rs";

pub fn check(tree: &Tree) -> Vec<Finding> {
    let mut out = Vec::new();
    let cfg = match tree.file(CONFIG) {
        Some(f) => f,
        None => {
            out.push(missing_file(CONFIG));
            return out;
        }
    };
    let fields = struct_fields(cfg, "TrainConfig");
    if fields.is_empty() {
        out.push(Finding::new(
            "R1",
            CONFIG,
            0,
            "struct TrainConfig not found (or has no pub fields)".into(),
            "R1 treats TrainConfig's pub fields as the key registry of record",
        ));
        return out;
    }
    for (fn_name, label, hint) in [
        ("from_raw", "the file parser", "parse the key in TrainConfig::from_raw"),
        ("set", "the CLI override parser", "add a match arm for the key in TrainConfig::set"),
        (
            "to_cli_args",
            "to_cli_args",
            "emit the key in TrainConfig::to_cli_args so launch re-serializes it for workers",
        ),
    ] {
        check_registry(cfg, fn_name, label, hint, &fields, &mut out);
    }
    if fn_bodies(cfg, "validate").is_empty() {
        out.push(Finding::new(
            "R1",
            CONFIG,
            0,
            "fn validate not found in config/mod.rs".into(),
            "TrainConfig::validate is a required registry place; do not delete it",
        ));
    }
    match tree.file(MAIN) {
        Some(main) => check_registry(
            main,
            "usage",
            "the usage text",
            "list the key in the usage() text in main.rs",
            &fields,
            &mut out,
        ),
        None => out.push(missing_file(MAIN)),
    }
    out
}

fn missing_file(rel: &str) -> Finding {
    Finding::new(
        "R1",
        rel,
        0,
        format!("expected file {rel} is missing"),
        "R1 needs both config/mod.rs and main.rs to cross-check the key registry",
    )
}

/// Flag every struct field not mentioned (as identifier or inside a
/// string literal) in any same-named non-test fn of `file`.
fn check_registry(
    file: &SourceFile,
    fn_name: &str,
    label: &str,
    hint: &str,
    fields: &[String],
    out: &mut Vec<Finding>,
) {
    let bodies = fn_bodies(file, fn_name);
    if bodies.is_empty() {
        out.push(Finding::new(
            "R1",
            &file.rel,
            0,
            format!("fn {fn_name} not found in {}", file.rel),
            hint,
        ));
        return;
    }
    let line = file.line_of(bodies[0].0);
    for key in fields {
        let seen = bodies.iter().any(|&(lo, hi)| mentions(file, lo, hi, key));
        if !seen {
            out.push(Finding::new(
                "R1",
                &file.rel,
                line,
                format!("config key `{key}` is missing from {label} (fn {fn_name})"),
                hint,
            ));
        }
    }
}

/// `(sig_start, body_end)` spans of all non-test fns named `name`.
fn fn_bodies(file: &SourceFile, name: &str) -> Vec<(usize, usize)> {
    file.fns
        .iter()
        .filter(|f| f.name == name && !file.in_test(f.sig_start))
        .map(|f| (f.sig_start, f.body_end))
        .collect()
}

/// Does the span mention `key`, either as a code identifier or as a
/// whole word inside a string literal (match arms and usage text name
/// keys as strings)?
fn mentions(file: &SourceFile, lo: usize, hi: usize, key: &str) -> bool {
    scan::has_word(&file.masked[lo..hi], key)
        || file.strings_in(lo, hi).iter().any(|s| scan::has_word(s, key))
}

/// Ordered pub field names of `struct <name>`.
fn struct_fields(file: &SourceFile, name: &str) -> Vec<String> {
    let b = file.masked.as_bytes();
    let ids = scan::idents(&file.masked, 0, file.masked.len());
    for w in ids.windows(2) {
        if w[0].1 != "struct" || w[1].1 != name {
            continue;
        }
        let mut k = w[1].0 + name.len();
        while k < b.len() && b[k] != b'{' && b[k] != b';' {
            k += 1;
        }
        if k >= b.len() || b[k] != b'{' {
            return Vec::new();
        }
        let close = scan::match_brace(&file.masked, k).unwrap_or(file.masked.len());
        let inner = scan::idents(&file.masked, k, close);
        let mut fields = Vec::new();
        let mut i = 0usize;
        while i + 1 < inner.len() {
            if inner[i].1 == "pub" {
                let mut fi = i + 1;
                if inner[fi].1 == "crate" && fi + 1 < inner.len() {
                    fi += 1; // pub(crate) visibility
                }
                let (off, fname) = inner[fi];
                let mut j = off + fname.len();
                while j < b.len() && b[j].is_ascii_whitespace() {
                    j += 1;
                }
                if j < b.len() && b[j] == b':' {
                    fields.push(fname.to_string());
                }
                i = fi + 1;
            } else {
                i += 1;
            }
        }
        return fields;
    }
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allow::AllowList;
    use crate::scan::fixture_tree;

    const GOOD_CONFIG: &str = r#"
pub struct TrainConfig { pub lr: f64, pub seed: u64 }
impl TrainConfig {
    pub fn from_raw(&mut self) { self.lr = 0.0; self.seed = 1; }
    pub fn set(&mut self, k: &str) { match k { "lr" => {}, "seed" => {}, _ => {} } }
    pub fn to_cli_args(&self) -> Vec<String> { vec![kv("lr"), kv("seed")] }
    pub fn validate(&self) {}
}
"#;

    #[test]
    fn passes_when_every_key_is_in_every_registry() {
        let tree = fixture_tree(&[
            ("rust/src/config/mod.rs", GOOD_CONFIG),
            ("rust/src/main.rs", "fn usage() { print(\"keys: lr seed\"); }"),
        ]);
        assert!(check(&tree).is_empty());
    }

    #[test]
    fn fires_on_key_missing_from_usage_text() {
        let tree = fixture_tree(&[
            ("rust/src/config/mod.rs", GOOD_CONFIG),
            ("rust/src/main.rs", "fn usage() { print(\"keys: lr\"); }"),
        ]);
        let f = check(&tree);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "R1");
        assert_eq!(f[0].file, "rust/src/main.rs");
        assert!(f[0].text.contains("`seed`"));
        assert!(f[0].text.contains("usage"));
    }

    #[test]
    fn fires_on_key_missing_from_to_cli_args() {
        let cfg = GOOD_CONFIG.replace(", kv(\"seed\")", "");
        let tree = fixture_tree(&[
            ("rust/src/config/mod.rs", cfg.as_str()),
            ("rust/src/main.rs", "fn usage() { print(\"keys: lr seed\"); }"),
        ]);
        let f = check(&tree);
        assert_eq!(f.len(), 1);
        assert!(f[0].text.contains("to_cli_args"));
    }

    #[test]
    fn substring_keys_do_not_mask_each_other() {
        // `max_train_steps` present must not satisfy a `train_steps` key
        let cfg = "pub struct TrainConfig { pub train_steps: u64 }\n\
                   impl TrainConfig {\n\
                   pub fn from_raw(&mut self) { self.train_steps = 1; }\n\
                   pub fn set(&mut self) { self.train_steps = 2; }\n\
                   pub fn to_cli_args(&self) { kv(\"train_steps\"); }\n\
                   pub fn validate(&self) {}\n}\n";
        let tree = fixture_tree(&[
            ("rust/src/config/mod.rs", cfg),
            ("rust/src/main.rs", "fn usage() { print(\"keys: max_train_steps\"); }"),
        ]);
        let f = check(&tree);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].text.contains("`train_steps`"));
    }

    #[test]
    fn baselined_fixture_is_suppressed() {
        let tree = fixture_tree(&[
            ("rust/src/config/mod.rs", GOOD_CONFIG),
            ("rust/src/main.rs", "fn usage() { print(\"keys: lr\"); }"),
        ]);
        let al = AllowList::parse(
            "R1 rust/src/main.rs \"missing from the usage text\" legacy key, hidden on purpose\n",
            "lint.allow",
        )
        .unwrap();
        let (remaining, baselined, stale) = al.apply(check(&tree));
        assert!(remaining.is_empty());
        assert_eq!(baselined.len(), 1);
        assert!(stale.is_empty());
    }
}
