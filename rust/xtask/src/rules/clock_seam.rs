//! R3 — the clock seam.
//!
//! Deadline logic must stay deterministic under `MockClock`, so
//! production code reads time through `serve::clock::Clock` and paces
//! polls with `net::frame::POLL_INTERVAL`. Raw `Instant::now`,
//! `SystemTime::now` and ad-hoc `thread::sleep` durations are flagged
//! outside the sanctioned seams:
//!
//! - `rust/src/serve/clock.rs` (the seam itself: `SystemClock`)
//! - `rust/src/net/retry.rs` (backoff/pacing primitives built on it)
//! - `thread::sleep(POLL_INTERVAL)` / `thread::sleep(POLL)` pacing
//! - `#[cfg(test)]` code (R6 governs tests instead)
//!
//! Anything else needs a one-line justification in `lint.allow`.

use crate::findings::Finding;
use crate::scan::{self, SourceFile, Tree};

const SEAM_FILES: [&str; 2] = ["rust/src/serve/clock.rs", "rust/src/net/retry.rs"];

pub fn check(tree: &Tree) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &tree.files {
        if !f.rel.starts_with("rust/src/") || SEAM_FILES.contains(&f.rel.as_str()) {
            continue;
        }
        for pat in ["Instant::now", "SystemTime::now"] {
            let mut from = 0usize;
            while let Some(at) = scan::find_word_from(&f.masked, pat, from) {
                from = at + 1;
                if f.in_test(at) {
                    continue;
                }
                out.push(Finding::new(
                    "R3",
                    &f.rel,
                    f.line_of(at),
                    f.line_text(f.line_of(at)).to_string(),
                    "read time through serve::clock::Clock (now_us) so MockClock can \
                     drive it; if wall-clock is genuinely required, baseline it with \
                     a reason in lint.allow",
                ));
            }
        }
        let mut from = 0usize;
        while let Some(at) = scan::find_word_from(&f.masked, "thread::sleep", from) {
            from = at + 1;
            if f.in_test(at) || sleep_arg_is_poll(f, at) {
                continue;
            }
            out.push(Finding::new(
                "R3",
                &f.rel,
                f.line_of(at),
                f.line_text(f.line_of(at)).to_string(),
                "pace polls with net::frame::POLL_INTERVAL (or \
                 net::retry::sleep_interruptible for computed delays); baseline with \
                 a reason if a raw sleep is inherent",
            ));
        }
    }
    out
}

/// `thread::sleep(POLL_INTERVAL)` (any path to it) and the `POLL`
/// re-export are the sanctioned poll cadence.
fn sleep_arg_is_poll(f: &SourceFile, at: usize) -> bool {
    let b = f.masked.as_bytes();
    let mut k = at + "thread::sleep".len();
    while k < b.len() && b[k].is_ascii_whitespace() {
        k += 1;
    }
    if k >= b.len() || b[k] != b'(' {
        return false;
    }
    let close = match scan::match_delim(&f.masked, k, b'(', b')') {
        Some(c) => c,
        None => return false,
    };
    let arg = f.masked[k + 1..close].trim();
    arg == "POLL" || arg == "POLL_INTERVAL" || arg.ends_with("::POLL_INTERVAL")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allow::AllowList;
    use crate::scan::fixture_tree;

    #[test]
    fn fires_on_raw_instant_and_ad_hoc_sleep() {
        let src = "fn f() { let t = Instant::now(); \
                   std::thread::sleep(Duration::from_millis(10)); }";
        let tree = fixture_tree(&[("rust/src/net/control.rs", src)]);
        let f = check(&tree);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "R3"));
    }

    #[test]
    fn passes_on_poll_interval_pacing_seam_files_and_tests() {
        let paced = "fn f() { std::thread::sleep(POLL_INTERVAL); \
                     std::thread::sleep(crate::net::frame::POLL_INTERVAL); \
                     std::thread::sleep(POLL); }";
        let seam = "pub fn new() -> SystemClock { SystemClock { start: Instant::now() } }";
        let test = "fn p() {}\n#[cfg(test)]\nmod tests { fn t() { \
                    std::thread::sleep(Duration::from_millis(1)); } }";
        let tree = fixture_tree(&[
            ("rust/src/net/param.rs", paced),
            ("rust/src/serve/clock.rs", seam),
            ("rust/src/serve/service.rs", test),
        ]);
        assert!(check(&tree).is_empty(), "{:?}", check(&tree));
    }

    #[test]
    fn masked_strings_do_not_fire() {
        let src = "fn f() { log(\"Instant::now is banned\"); }";
        let tree = fixture_tree(&[("rust/src/metrics/mod.rs", src)]);
        assert!(check(&tree).is_empty());
    }

    #[test]
    fn baselined_fixture_is_suppressed() {
        let src = "fn f() { let started = Instant::now(); }";
        let tree = fixture_tree(&[("rust/src/launch/mod.rs", src)]);
        let al = AllowList::parse(
            "R3 rust/src/launch/mod.rs \"Instant::now\" supervising real OS processes\n",
            "lint.allow",
        )
        .unwrap();
        let (remaining, baselined, stale) = al.apply(check(&tree));
        assert!(remaining.is_empty());
        assert_eq!(baselined.len(), 1);
        assert!(stale.is_empty());
    }
}
