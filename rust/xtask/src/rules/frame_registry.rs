//! R2 — frame-kind registry coherence.
//!
//! `FrameKind::from_byte` decodes by indexing `ALL` with the wire
//! discriminant, so four things must stay true at once: discriminants
//! are exactly `0..n-1` in declaration order, `ALL` lists every
//! variant in that same order, `from_byte` actually decodes via the
//! registry, and every kind is referenced somewhere outside the
//! registry file (a kind nobody sends or handles is silent drift).

use crate::findings::Finding;
use crate::scan::{self, SourceFile, Tree};

const FRAME: &str = "rust/src/net/frame.rs";

pub fn check(tree: &Tree) -> Vec<Finding> {
    let mut out = Vec::new();
    let f = match tree.file(FRAME) {
        Some(f) => f,
        None => {
            out.push(Finding::new(
                "R2",
                FRAME,
                0,
                "net/frame.rs is missing".into(),
                "the FrameKind registry lives in net/frame.rs",
            ));
            return out;
        }
    };
    let variants = enum_variants(f, "FrameKind");
    if variants.is_empty() {
        out.push(Finding::new(
            "R2",
            FRAME,
            0,
            "enum FrameKind not found".into(),
            "net/frame.rs must declare the FrameKind wire registry",
        ));
        return out;
    }
    // discriminants: 0..n-1 in declaration order (from_byte indexes ALL)
    let mut next = 0u64;
    for (i, (off, name, disc)) in variants.iter().enumerate() {
        let v = disc.unwrap_or(next);
        next = v + 1;
        if v != i as u64 {
            out.push(Finding::new(
                "R2",
                FRAME,
                f.line_of(*off),
                format!("FrameKind::{name} has discriminant {v}, expected {i}"),
                "from_byte indexes ALL by discriminant; keep discriminants dense, \
                 ascending and in declaration order",
            ));
        }
    }
    let names: Vec<&str> = variants.iter().map(|(_, n, _)| n.as_str()).collect();
    match all_array(f) {
        Some((all_off, declared_len, items)) => {
            let line = f.line_of(all_off);
            if declared_len != names.len() || items.len() != names.len() {
                out.push(Finding::new(
                    "R2",
                    FRAME,
                    line,
                    format!(
                        "ALL registry has {} entries (declared {declared_len}) for {} variants",
                        items.len(),
                        names.len()
                    ),
                    "every FrameKind variant must appear in ALL exactly once",
                ));
            }
            for (i, it) in items.iter().enumerate() {
                if names.get(i) != Some(&it.as_str()) {
                    out.push(Finding::new(
                        "R2",
                        FRAME,
                        line,
                        format!(
                            "ALL[{i}] is {it}, but declaration order says {}",
                            names.get(i).copied().unwrap_or("<nothing>")
                        ),
                        "ALL must list the variants in declaration order so indexing \
                         by discriminant round-trips",
                    ));
                    break; // one ordering finding, not a cascade
                }
            }
        }
        None => out.push(Finding::new(
            "R2",
            FRAME,
            0,
            "const ALL: [FrameKind; N] registry not found".into(),
            "declare the ALL registry next to the enum; from_byte decodes through it",
        )),
    }
    let from_byte: Vec<&scan::FnSpan> =
        f.fns.iter().filter(|s| s.name == "from_byte" && !f.in_test(s.sig_start)).collect();
    match from_byte.first() {
        Some(fb) => {
            let body = &f.masked[fb.body_start..fb.body_end];
            let via_registry = scan::has_word(body, "ALL");
            let names_all = names.iter().all(|n| scan::has_word(body, n));
            if !via_registry && !names_all {
                out.push(Finding::new(
                    "R2",
                    FRAME,
                    f.line_of(fb.sig_start),
                    "from_byte does not decode via the ALL registry".into(),
                    "decode with ALL.get(byte) (or handle every variant explicitly) so \
                     new kinds cannot be silently undecodable",
                ));
            }
        }
        None => out.push(Finding::new(
            "R2",
            FRAME,
            0,
            "fn from_byte not found".into(),
            "FrameKind::from_byte is the only sanctioned wire decoder for kinds",
        )),
    }
    // every kind must be referenced outside the registry file
    for (off, name, _) in &variants {
        let pat = format!("FrameKind::{name}");
        let used = tree.files.iter().any(|g| {
            g.rel != FRAME && g.rel.starts_with("rust/src/") && scan::has_word(&g.masked, &pat)
        });
        if !used {
            out.push(Finding::new(
                "R2",
                FRAME,
                f.line_of(*off),
                format!("FrameKind::{name} is never referenced outside net/frame.rs"),
                "a kind nobody sends or handles is dead wire surface: wire it into \
                 net/wire.rs / its subsystem, or delete the variant",
            ));
        }
    }
    out
}

/// `(offset, name, explicit discriminant)` for each variant of
/// `enum <name>`.
fn enum_variants(f: &SourceFile, name: &str) -> Vec<(usize, String, Option<u64>)> {
    let b = f.masked.as_bytes();
    let ids = scan::idents(&f.masked, 0, f.masked.len());
    for w in ids.windows(2) {
        if w[0].1 != "enum" || w[1].1 != name {
            continue;
        }
        let mut k = w[1].0 + name.len();
        while k < b.len() && b[k] != b'{' {
            k += 1;
        }
        let close = match scan::match_brace(&f.masked, k) {
            Some(c) => c,
            None => return Vec::new(),
        };
        let mut out = Vec::new();
        let mut i = k + 1;
        while i < close {
            if b[i].is_ascii_whitespace() || b[i] == b',' {
                i += 1;
            } else if b[i] == b'#' && b.get(i + 1) == Some(&b'[') {
                i = scan::match_delim(&f.masked, i + 1, b'[', b']').map(|c| c + 1).unwrap_or(close);
            } else if scan::is_ident_byte(b[i]) && !b[i].is_ascii_digit() {
                let start = i;
                while i < close && scan::is_ident_byte(b[i]) {
                    i += 1;
                }
                let vname = f.masked[start..i].to_string();
                let mut j = i;
                while j < close && b[j].is_ascii_whitespace() {
                    j += 1;
                }
                let mut disc = None;
                if j < close && b[j] == b'=' {
                    j += 1;
                    while j < close && b[j].is_ascii_whitespace() {
                        j += 1;
                    }
                    let ds = j;
                    while j < close && b[j].is_ascii_digit() {
                        j += 1;
                    }
                    if j > ds {
                        disc = f.masked[ds..j].parse::<u64>().ok();
                    }
                }
                out.push((start, vname, disc));
                // skip to the variant-separating comma (robust to tuple
                // or struct payloads, though FrameKind has neither)
                while j < close && b[j] != b',' {
                    match b[j] {
                        b'(' => {
                            j = scan::match_delim(&f.masked, j, b'(', b')')
                                .map(|c| c + 1)
                                .unwrap_or(close)
                        }
                        b'{' => {
                            j = scan::match_brace(&f.masked, j).map(|c| c + 1).unwrap_or(close)
                        }
                        _ => j += 1,
                    }
                }
                i = j;
            } else {
                i += 1;
            }
        }
        return out;
    }
    Vec::new()
}

/// The `const ALL: [FrameKind; N] = [...]` registry:
/// `(offset, declared_len, item names)`.
fn all_array(f: &SourceFile) -> Option<(usize, usize, Vec<String>)> {
    let b = f.masked.as_bytes();
    let mut from = 0usize;
    while let Some(off) = scan::find_word_from(&f.masked, "ALL", from) {
        from = off + 1;
        let mut k = off + 3;
        while k < b.len() && b[k].is_ascii_whitespace() {
            k += 1;
        }
        if k >= b.len() || b[k] != b':' {
            continue; // a use like `Self::ALL.get(..)`, not the declaration
        }
        while k < b.len() && b[k] != b'[' && b[k] != b';' {
            k += 1;
        }
        if k >= b.len() || b[k] != b'[' {
            continue;
        }
        let ty_close = scan::match_delim(&f.masked, k, b'[', b']')?;
        let declared_len: usize =
            f.masked[k + 1..ty_close].rsplit(';').next()?.trim().parse().ok()?;
        let mut m = ty_close + 1;
        while m < b.len() && b[m] != b'[' && b[m] != b';' {
            m += 1;
        }
        if m >= b.len() || b[m] != b'[' {
            continue;
        }
        let lit_close = scan::match_delim(&f.masked, m, b'[', b']')?;
        let items = scan::idents(&f.masked, m, lit_close)
            .into_iter()
            .map(|(_, w)| w.to_string())
            .filter(|w| w != "FrameKind" && w != "Self")
            .collect();
        return Some((off, declared_len, items));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allow::AllowList;
    use crate::scan::fixture_tree;

    const GOOD_FRAME: &str = "pub enum FrameKind { Hello = 0, Data = 1 }\n\
        impl FrameKind {\n\
        pub const ALL: [FrameKind; 2] = [FrameKind::Hello, FrameKind::Data];\n\
        pub fn from_byte(b: u8) -> Option<FrameKind> { Self::ALL.get(b as usize).copied() }\n\
        }\n";
    const USER: &str = "fn go() { let _ = (FrameKind::Hello, FrameKind::Data); }\n";

    #[test]
    fn passes_on_coherent_registry() {
        let tree =
            fixture_tree(&[("rust/src/net/frame.rs", GOOD_FRAME), ("rust/src/net/wire.rs", USER)]);
        assert!(check(&tree).is_empty(), "{:?}", check(&tree));
    }

    #[test]
    fn fires_on_duplicate_discriminant() {
        let bad = GOOD_FRAME.replace("Data = 1", "Data = 0");
        let tree =
            fixture_tree(&[("rust/src/net/frame.rs", bad.as_str()), ("rust/src/net/wire.rs", USER)]);
        let f = check(&tree);
        assert!(f.iter().any(|x| x.rule == "R2" && x.text.contains("discriminant 0, expected 1")));
    }

    #[test]
    fn fires_on_all_registry_out_of_order_or_short() {
        let bad = GOOD_FRAME.replace(
            "[FrameKind::Hello, FrameKind::Data]",
            "[FrameKind::Data, FrameKind::Hello]",
        );
        let tree =
            fixture_tree(&[("rust/src/net/frame.rs", bad.as_str()), ("rust/src/net/wire.rs", USER)]);
        assert!(check(&tree).iter().any(|x| x.text.contains("declaration order")));
    }

    #[test]
    fn fires_on_unreferenced_kind() {
        let user = "fn go() { let _ = FrameKind::Hello; }\n";
        let tree =
            fixture_tree(&[("rust/src/net/frame.rs", GOOD_FRAME), ("rust/src/net/wire.rs", user)]);
        let f = check(&tree);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].text.contains("FrameKind::Data is never referenced"));
    }

    #[test]
    fn baselined_fixture_is_suppressed() {
        let user = "fn go() { let _ = FrameKind::Hello; }\n";
        let tree =
            fixture_tree(&[("rust/src/net/frame.rs", GOOD_FRAME), ("rust/src/net/wire.rs", user)]);
        let al = AllowList::parse(
            "R2 rust/src/net/frame.rs \"FrameKind::Data is never referenced\" reserved kind\n",
            "lint.allow",
        )
        .unwrap();
        let (remaining, baselined, stale) = al.apply(check(&tree));
        assert!(remaining.is_empty());
        assert_eq!(baselined.len(), 1);
        assert!(stale.is_empty());
    }
}
