//! R4 — panic-free wire decode.
//!
//! A malformed or truncated frame from a peer must surface as a typed
//! `FrameError`, never as a panic that takes the worker down. The rule
//! scans every non-test decode-path function in `rust/src/net/` —
//! functions named `decode*`/`parse*`/`read*`/`from_byte`, plus every
//! method of a `WireReader` impl — for `.unwrap()`, `.expect(..)` and
//! the aborting macros.

use crate::findings::Finding;
use crate::scan::{self, Tree};

const MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];
const NAME_PREFIXES: [&str; 4] = ["decode", "parse", "read", "from_byte"];

pub fn check(tree: &Tree) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &tree.files {
        if !f.rel.starts_with("rust/src/net/") {
            continue;
        }
        let b = f.masked.as_bytes();
        let reader_impls: Vec<(usize, usize)> = f
            .impls
            .iter()
            .filter(|im| im.header.contains("WireReader"))
            .map(|im| (im.body_start, im.body_end))
            .collect();
        for span in &f.fns {
            if f.in_test(span.sig_start) {
                continue;
            }
            let in_reader =
                reader_impls.iter().any(|&(lo, hi)| span.sig_start >= lo && span.sig_start < hi);
            let named = NAME_PREFIXES.iter().any(|p| span.name.starts_with(p));
            if !in_reader && !named {
                continue;
            }
            for (off, w) in scan::idents(&f.masked, span.body_start, span.body_end) {
                let panicky = match w {
                    "unwrap" | "expect" => off > 0 && b[off - 1] == b'.',
                    m if MACROS.contains(&m) => b.get(off + w.len()) == Some(&b'!'),
                    _ => false,
                };
                if panicky {
                    out.push(Finding::new(
                        "R4",
                        &f.rel,
                        f.line_of(off),
                        f.line_text(f.line_of(off)).to_string(),
                        "decode paths must be panic-free: return a typed FrameError \
                         (Truncated/BadKind/...) and let the caller decide",
                    ));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allow::AllowList;
    use crate::scan::fixture_tree;

    #[test]
    fn fires_on_unwrap_in_named_decode_fn_and_in_wirereader_impl() {
        let src = "fn decode_header(b: &[u8]) -> u32 { \
                   u32::from_le_bytes(b[..4].try_into().unwrap()) }\n\
                   impl<'a> WireReader<'a> {\n\
                   fn skip(&mut self) { self.take(4).expect(\"short\"); }\n\
                   }\n";
        let tree = fixture_tree(&[("rust/src/net/wire.rs", src)]);
        let f = check(&tree);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "R4"));
    }

    #[test]
    fn fires_on_abort_macros_but_not_on_unwrap_or_variants() {
        let src = "fn read_frame(b: &[u8]) -> u8 { \
                   if b.is_empty() { unreachable!(\"no\") } \
                   b.first().copied().unwrap_or(0) }";
        let tree = fixture_tree(&[("rust/src/net/frame.rs", src)]);
        let f = check(&tree);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].text.contains("unreachable!"));
    }

    #[test]
    fn passes_outside_decode_paths_and_in_tests() {
        let src = "fn encode(v: u16) -> u8 { u8::try_from(v).expect(\"fits\") }\n\
                   #[cfg(test)]\nmod tests {\n\
                   fn read_case() { decode(b\"x\").unwrap(); }\n}\n";
        let tree = fixture_tree(&[("rust/src/net/wire.rs", src)]);
        assert!(check(&tree).is_empty(), "{:?}", check(&tree));
    }

    #[test]
    fn baselined_fixture_is_suppressed() {
        let src = "fn parse_peer(s: &str) -> u16 { s.parse().unwrap() }";
        let tree = fixture_tree(&[("rust/src/net/control.rs", src)]);
        let al = AllowList::parse(
            "R4 rust/src/net/control.rs \"s.parse().unwrap()\" operator-supplied, not wire input\n",
            "lint.allow",
        )
        .unwrap();
        let (remaining, baselined, stale) = al.apply(check(&tree));
        assert!(remaining.is_empty());
        assert_eq!(baselined.len(), 1);
        assert!(stale.is_empty());
    }
}
