//! R6 — no timing sleeps in tests.
//!
//! A bare `thread::sleep(fixed duration)` in a test encodes a guess
//! about scheduler timing and is exactly how chaos-tier tests go
//! flaky. Tests must *poll* for the condition they wait on
//! (`support::poll_until`). A sleep that is lexically inside a
//! `while`/`for`/`loop` body is pacing such a poll and passes; a bare
//! sleep standing in for a condition is flagged. Scope: all of
//! `rust/tests/` plus `#[cfg(test)]` code in `rust/src/`.

use crate::findings::Finding;
use crate::scan::{self, Tree};

pub fn check(tree: &Tree) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &tree.files {
        let whole_file = f.rel.starts_with("rust/tests/");
        if !whole_file && !f.rel.starts_with("rust/src/") {
            continue;
        }
        let mut from = 0usize;
        while let Some(at) = scan::find_word_from(&f.masked, "thread::sleep", from) {
            from = at + 1;
            if !whole_file && !f.in_test(at) {
                continue; // production code is R3's jurisdiction
            }
            let anchor = f.enclosing_fn(at).map(|s| s.body_start).unwrap_or(0);
            if f.inside_loop(anchor, at) {
                continue; // pacing a polling loop
            }
            out.push(Finding::new(
                "R6",
                &f.rel,
                f.line_of(at),
                f.line_text(f.line_of(at)).to_string(),
                "poll for the condition instead of sleeping a fixed duration: \
                 support::poll_until(what, deadline, cond) (rust/tests/support/mod.rs)",
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allow::AllowList;
    use crate::scan::fixture_tree;

    #[test]
    fn fires_on_bare_sleep_in_tests_tree() {
        let src = "#[test]\nfn t() {\n    start();\n    \
                   std::thread::sleep(Duration::from_millis(50));\n    assert!(done());\n}\n";
        let tree = fixture_tree(&[("rust/tests/dist_net.rs", src)]);
        let f = check(&tree);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "R6");
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn fires_in_cfg_test_regions_of_src() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n#[test]\nfn t() { \
                   std::thread::sleep(D); }\n}\n";
        let tree = fixture_tree(&[("rust/src/launch/mod.rs", src)]);
        assert_eq!(check(&tree).len(), 1);
    }

    #[test]
    fn passes_when_sleep_paces_a_polling_loop() {
        let src = "#[test]\nfn t() {\n    while !done() {\n        \
                   std::thread::sleep(Duration::from_millis(5));\n    }\n\
                   for _ in 0..3 { std::thread::sleep(TICK); }\n}\n";
        let tree = fixture_tree(&[("rust/tests/serve.rs", src)]);
        assert!(check(&tree).is_empty(), "{:?}", check(&tree));
    }

    #[test]
    fn production_sleeps_are_not_double_flagged() {
        let src = "fn prod() { std::thread::sleep(D); }";
        let tree = fixture_tree(&[("rust/src/net/param.rs", src)]);
        assert!(check(&tree).is_empty());
    }

    #[test]
    fn baselined_fixture_is_suppressed() {
        let src = "#[test]\nfn t() { std::thread::sleep(Duration::from_millis(150)); }\n";
        let tree = fixture_tree(&[("rust/tests/dist_net.rs", src)]);
        let al = AllowList::parse(
            "R6 rust/tests/dist_net.rs \"from_millis(150)\" scripted restart delay, not a wait\n",
            "lint.allow",
        )
        .unwrap();
        let (remaining, baselined, stale) = al.apply(check(&tree));
        assert!(remaining.is_empty());
        assert_eq!(baselined.len(), 1);
        assert!(stale.is_empty());
    }
}
