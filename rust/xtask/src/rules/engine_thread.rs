//! R5 — engine-per-thread.
//!
//! PJRT artifacts are `Rc`-based and must stay on the thread that
//! loaded them; `serve/service.rs` crosses threads with a `Send`
//! *backend factory* and builds the `Engine` on the worker thread.
//! Two things defeat that discipline and are flagged: `unsafe impl
//! Send/Sync` anywhere (which would let `Rc` state cross threads
//! behind the compiler's back), and a `let` binding of engine/`Rc`
//! state that is then captured by a `thread::spawn(..)`/`.spawn(..)`
//! closure in the same function.

use crate::findings::Finding;
use crate::scan::{self, SourceFile, Tree};

const RC_MARKERS: [&str; 5] = ["Engine::load(", "Rc::new(", ".artifact(", "Rc<", ": Engine"];

pub fn check(tree: &Tree) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &tree.files {
        if !f.rel.starts_with("rust/src/") {
            continue;
        }
        check_unsafe_send(f, &mut out);
        check_spawn_captures(f, &mut out);
    }
    out
}

/// `unsafe impl Send/Sync` is never acceptable in this codebase, tests
/// included.
fn check_unsafe_send(f: &SourceFile, out: &mut Vec<Finding>) {
    let ids = scan::idents(&f.masked, 0, f.masked.len());
    for w in ids.windows(2) {
        if w[0].1 != "unsafe" || w[1].1 != "impl" {
            continue;
        }
        let open = f.masked[w[1].0..].find('{').map(|p| w[1].0 + p).unwrap_or(f.masked.len());
        let header = &f.masked[w[0].0..open];
        if scan::has_word(header, "Send") || scan::has_word(header, "Sync") {
            out.push(Finding::new(
                "R5",
                &f.rel,
                f.line_of(w[0].0),
                f.line_text(f.line_of(w[0].0)).to_string(),
                "never assert Send/Sync for engine state: keep Rc<Artifact>/Engine \
                 on one thread and cross threads with a Send factory instead \
                 (see serve/service.rs)",
            ));
        }
    }
}

/// A `let` whose initializer or type mentions engine/`Rc` state, later
/// named inside a `spawn(..)` argument, is a cross-thread capture.
fn check_spawn_captures(f: &SourceFile, out: &mut Vec<Finding>) {
    let b = f.masked.as_bytes();
    let mut from = 0usize;
    while let Some(at) = scan::find_word_from(&f.masked, "spawn", from) {
        from = at + 1;
        if f.in_test(at) {
            continue;
        }
        // only call sites: `thread::spawn(..)` / `builder.spawn(..)`
        let is_call = at >= 1 && (b[at - 1] == b'.' || (at >= 2 && &f.masked[at - 2..at] == "::"));
        if !is_call {
            continue;
        }
        let mut k = at + "spawn".len();
        while k < b.len() && b[k].is_ascii_whitespace() {
            k += 1;
        }
        if k >= b.len() || b[k] != b'(' {
            continue;
        }
        let close = match scan::match_delim(&f.masked, k, b'(', b')') {
            Some(c) => c,
            None => continue,
        };
        let enclosing = match f.enclosing_fn(at) {
            Some(s) => s,
            None => continue,
        };
        let arg = &f.masked[k..close + 1];
        for (name, stmt) in let_bindings(f, enclosing.body_start, at) {
            // a closure initializer (`let make_backend = move || Engine::load(..)`)
            // defers construction to the spawned thread — that IS the
            // sanctioned factory pattern, not a capture of live state
            let init_is_closure = stmt
                .splitn(2, '=')
                .nth(1)
                .map(|s| {
                    let t = s.trim_start();
                    t.starts_with('|') || t.starts_with("move")
                })
                .unwrap_or(false);
            let suspicious = !init_is_closure && RC_MARKERS.iter().any(|m| stmt.contains(m));
            if suspicious && scan::has_word(arg, &name) {
                out.push(Finding::new(
                    "R5",
                    &f.rel,
                    f.line_of(at),
                    format!("`{name}` (engine/Rc state) is captured by a spawn closure"),
                    "build the engine on the worker thread via a Send factory closure; \
                     Rc<Artifact>/Engine must not cross thread::spawn",
                ));
            }
        }
    }
}

/// `(binding name, full let-statement text)` for every `let` in the
/// span.
fn let_bindings(f: &SourceFile, lo: usize, hi: usize) -> Vec<(String, String)> {
    let ids = scan::idents(&f.masked, lo, hi);
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < ids.len() {
        if ids[i].1 == "let" {
            let mut ni = i + 1;
            if ni < ids.len() && ids[ni].1 == "mut" {
                ni += 1;
            }
            if ni < ids.len() {
                let (off, name) = ids[ni];
                let end = f.masked[off..hi.min(f.masked.len())]
                    .find(';')
                    .map(|p| off + p)
                    .unwrap_or(hi.min(f.masked.len()));
                out.push((name.to_string(), f.masked[ids[i].0..end].to_string()));
            }
            i = ni + 1;
        } else {
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allow::AllowList;
    use crate::scan::fixture_tree;

    #[test]
    fn fires_on_engine_captured_by_spawn() {
        let src = "fn serve() {\n\
                   let engine = Engine::load(&art);\n\
                   std::thread::spawn(move || engine.run());\n}\n";
        let tree = fixture_tree(&[("rust/src/serve/service.rs", src)]);
        let f = check(&tree);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].text.contains("`engine`"));
    }

    #[test]
    fn fires_on_unsafe_impl_send() {
        let src = "struct E(Rc<u8>);\nunsafe impl Send for E {}\n";
        let tree = fixture_tree(&[("rust/src/engine/mod.rs", src)]);
        let f = check(&tree);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].text.contains("unsafe impl Send"));
    }

    #[test]
    fn passes_on_send_factory_pattern() {
        let src = "fn serve(art: Artifact) {\n\
                   let make_backend = move || Engine::load(&art);\n\
                   std::thread::spawn(move || { let engine = make_backend(); engine.run() });\n}\n";
        let tree = fixture_tree(&[("rust/src/serve/service.rs", src)]);
        assert!(check(&tree).is_empty(), "{:?}", check(&tree));
    }

    #[test]
    fn baselined_fixture_is_suppressed() {
        let src = "fn f() { let shared = Rc::new(3); std::thread::spawn(move || shared); }";
        let tree = fixture_tree(&[("rust/src/systems/mod.rs", src)]);
        let al = AllowList::parse(
            "R5 rust/src/systems/mod.rs \"`shared`\" audited: value is moved, not aliased\n",
            "lint.allow",
        )
        .unwrap();
        let (remaining, baselined, stale) = al.apply(check(&tree));
        assert!(remaining.is_empty());
        assert_eq!(baselined.len(), 1);
        assert!(stale.is_empty());
    }
}
