//! The `mava serve` acceptance suites (DESIGN.md §12).
//!
//! Two tiers, both self-contained in this test process:
//!
//! * **hermetic** — [`ServeCore`] driven directly with a [`MockClock`]
//!   and [`MockBackend`]: every coalescing, deadline, pad-masking and
//!   hot-reload decision is asserted without artifacts, sockets or
//!   sleeps (deadline expiry is a `set_us` call);
//! * **loopback TCP** — a real [`ServeService`] on 127.0.0.1 with an
//!   ephemeral port, still backed by the mock policy: frame-level
//!   fault injection (torn payloads, client disconnects), typed slot
//!   exhaustion over the wire, and the halt-probe regression for
//!   shutdown under idle connections.
//!
//! The one artifact-dependent test (the real [`EngineBackend`]) skips
//! when `artifacts/` is not lowered, like the integration suite.

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use mava::net::frame::{frame_bytes, FrameKind};
use mava::params::{ParamStore, ParameterServer};
use mava::runtime::{BucketLadder, Engine, Manifest};
use mava::serve::{
    EngineBackend, MockBackend, MockCall, MockClock, PolicyBackend,
    ServeClient, ServeCore, ServeError, ServeService, SystemClock,
};
use mava::systems::SystemKind;

const RPC: Duration = Duration::from_secs(10);

fn mock_core(
    buckets: &[usize],
    deadline_us: u64,
    max_sessions: usize,
) -> (Arc<MockClock>, ServeCore<MockBackend>) {
    let clock = Arc::new(MockClock::new(0));
    let backend = MockBackend::new(1, 1, 2, buckets);
    let core =
        ServeCore::new(backend, clock.clone(), max_sessions, deadline_us);
    (clock, core)
}

/// Satellite 1a: a full largest bucket flushes immediately — zero
/// padding, zero added latency, no waiting for the deadline.
#[test]
fn full_bucket_flushes_immediately() {
    let (_clock, mut core) = mock_core(&[1, 2, 4], 1_000, 8);
    let sessions: Vec<u64> =
        (0..4).map(|_| core.open_session().unwrap()).collect();
    for &s in &sessions {
        core.submit(s, vec![s as f32]).unwrap();
    }
    // the clock never moved: this flush is size-triggered
    let out = core.step().unwrap();
    assert_eq!(out.len(), 4);
    for (r, &s) in out.iter().zip(&sessions) {
        assert_eq!(r.session, s, "arrival order preserved");
        assert_eq!(r.actions, vec![s as i32], "action traces to its row");
    }
    assert_eq!(
        core.backend().calls,
        vec![MockCall { bucket: 4, active: 4, version: 0 }]
    );
    assert_eq!(core.pending(), 0);
    assert_eq!(core.next_deadline_us(), None);
}

/// Satellite 1b: a partial batch waits until exactly the deadline,
/// then flushes into the smallest covering bucket with the padding
/// rows masked (the mock backend asserts pad observation rows are
/// zero and never writes their actions or carry).
#[test]
fn partial_batch_flushes_exactly_at_deadline_with_padding() {
    let (clock, mut core) = mock_core(&[1, 2, 4], 1_000, 8);
    let sessions: Vec<u64> =
        (0..3).map(|_| core.open_session().unwrap()).collect();
    for &s in &sessions {
        core.submit(s, vec![s as f32]).unwrap();
    }
    assert_eq!(core.next_deadline_us(), Some(1_000));
    clock.set_us(999);
    assert!(core.step().unwrap().is_empty(), "one tick early: no flush");
    clock.set_us(1_000);
    let out = core.step().unwrap();
    assert_eq!(out.len(), 3);
    for (r, &s) in out.iter().zip(&sessions) {
        assert_eq!((r.session, r.actions.clone()), (s, vec![s as i32]));
    }
    assert_eq!(
        core.backend().calls,
        vec![MockCall { bucket: 4, active: 3, version: 0 }],
        "3 rows round up to bucket 4, one masked pad row"
    );
}

/// Satellite 1c: requests arriving while a batch flushes land in the
/// next batch — nothing is lost and nothing is answered twice.
#[test]
fn requests_during_flush_land_in_next_batch() {
    let (clock, mut core) = mock_core(&[1, 2], 1_000, 8);
    let a = core.open_session().unwrap();
    let b = core.open_session().unwrap();
    let c = core.open_session().unwrap();
    core.submit(a, vec![a as f32]).unwrap();
    core.submit(b, vec![b as f32]).unwrap();
    // c arrives after the (a, b) bucket is already full: the same
    // step() flushes (a, b) and must leave c queued, untouched
    core.submit(c, vec![c as f32]).unwrap();
    let first = core.step().unwrap();
    assert_eq!(
        first.iter().map(|r| r.session).collect::<Vec<_>>(),
        vec![a, b]
    );
    assert_eq!(core.pending(), 1, "late request stays queued");
    assert!(core.step().unwrap().is_empty(), "not answered early");
    clock.set_us(1_000);
    let second = core.step().unwrap();
    assert_eq!(second.len(), 1);
    assert_eq!(second[0].session, c);
    assert_eq!(second[0].actions, vec![c as i32]);
    assert_eq!(
        core.backend().calls.len(),
        2,
        "exactly two batches, no re-answering"
    );
}

/// Hot-reload is version-gated and lands only between batches: every
/// response is stamped with the exact version that computed it, the
/// version sequence is monotone, and the installed blob is never torn
/// even under a concurrent publisher.
#[test]
fn hot_reload_is_version_monotone_and_untorn() {
    const DIM: usize = 64;
    let store = Arc::new(ParameterServer::new(vec![0.0f32; DIM]));
    let clock = Arc::new(MockClock::new(0));
    let backend = MockBackend::new(1, 1, 0, &[1, 2]);
    let mut core = ServeCore::new(backend, clock.clone(), 4, 100)
        .with_store(store.clone());
    let s = core.open_session().unwrap();

    // deterministic part: initial blob (version 1), then one publish
    core.submit(s, vec![1.0]).unwrap();
    clock.advance_us(100);
    let out = core.step().unwrap();
    assert_eq!(out[0].version, 1, "initial store blob is version 1");
    assert_eq!(core.backend().params, vec![0.0; DIM]);
    store.push(&[5.0; DIM]).unwrap();
    core.submit(s, vec![1.0]).unwrap();
    clock.advance_us(100);
    let out = core.step().unwrap();
    assert_eq!(out[0].version, 2, "publish picked up before the batch");
    assert_eq!(core.backend().params, vec![5.0; DIM]);

    // racing part: a publisher hammers the store while batches flush;
    // each publish is a constant vector so a torn install is visible
    let publisher = {
        let store = store.clone();
        thread::spawn(move || {
            for i in 0..200u64 {
                store.push(&[i as f32; DIM]).unwrap();
            }
        })
    };
    let mut last_version = 2;
    for _ in 0..100 {
        core.submit(s, vec![1.0]).unwrap();
        clock.advance_us(100);
        let out = core.step().unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].version >= last_version, "version went backwards");
        last_version = out[0].version;
        let p = &core.backend().params;
        assert!(
            p.windows(2).all(|w| w[0] == w[1]),
            "torn reload at version {last_version}"
        );
    }
    publisher.join().unwrap();
    // MockBackend::set_params additionally asserts strict version
    // monotonicity on every install (a stale re-install would panic)
}

/// Closing a session drops its queued requests (their responses are
/// never emitted), and late submits for it are typed errors.
#[test]
fn close_drops_pending_and_late_submits_are_typed() {
    let (clock, mut core) = mock_core(&[1, 2, 4], 1_000, 8);
    let a = core.open_session().unwrap();
    let b = core.open_session().unwrap();
    core.submit(a, vec![a as f32]).unwrap();
    core.submit(b, vec![b as f32]).unwrap();
    assert_eq!(core.close_session(a), Ok(1), "one queued request dropped");
    assert_eq!(
        core.submit(a, vec![0.0]),
        Err(ServeError::UnknownSession(a))
    );
    clock.set_us(1_000);
    let out = core.step().unwrap();
    assert_eq!(out.len(), 1, "closed session must not be answered");
    assert_eq!(out[0].session, b);
}

/// A backend failure is a typed error that consumes the batch; the
/// core keeps serving afterwards.
#[test]
fn backend_failure_is_typed_and_recoverable() {
    let (clock, mut core) = mock_core(&[1, 2], 1_000, 8);
    let s = core.open_session().unwrap();
    core.backend_mut().fail_next = true;
    core.submit(s, vec![1.0]).unwrap();
    clock.set_us(1_000);
    assert!(matches!(core.step(), Err(ServeError::Backend(_))));
    core.submit(s, vec![2.0]).unwrap();
    clock.set_us(2_000);
    assert_eq!(core.step().unwrap().len(), 1, "core serves on after a fault");
    // malformed observations are rejected at submit time
    assert!(matches!(
        core.submit(s, vec![0.0, 0.0]),
        Err(ServeError::BadRequest(_))
    ));
}

// ---------------------------------------------------------------------------
// loopback TCP tier
// ---------------------------------------------------------------------------

/// A serve service over a mock policy: obs width 2, one action per
/// request, buckets {1, 2}.
fn mock_service(max_sessions: usize, deadline_us: u64) -> ServeService {
    ServeService::bind(
        "127.0.0.1",
        || Ok(MockBackend::new(2, 1, 1, &[1, 2])),
        Arc::new(SystemClock::new()),
        None,
        max_sessions,
        deadline_us,
    )
    .unwrap()
}

#[test]
fn serve_over_tcp_end_to_end() {
    let mut svc = mock_service(4, 1_000);
    let mut c = ServeClient::connect(svc.addr()).unwrap();
    let s = c.open_session(RPC).unwrap();
    let (version, actions) = c.act(s, &[7.0, 0.5], RPC).unwrap();
    assert_eq!((version, actions), (0, vec![7]));
    let (_, actions) = c.act(s, &[3.0, 0.5], RPC).unwrap();
    assert_eq!(actions, vec![3]);
    c.close_session(s, RPC).unwrap();
    // the session is gone: acting in it is a typed error frame
    let err = c.act(s, &[1.0, 0.0], RPC).unwrap_err().to_string();
    assert!(err.contains("not yours"), "got: {err}");
    svc.shutdown();
}

/// Satellite 3a: a torn (CRC-corrupt) ActRequest frame gets a typed
/// error response and the connection survives — the stream is still
/// frame-aligned, so the same socket serves real traffic afterwards.
#[test]
fn torn_frame_gets_typed_error_and_connection_survives() {
    let mut svc = mock_service(4, 1_000);
    let mut c = ServeClient::connect(svc.addr()).unwrap();
    let s = c.open_session(RPC).unwrap();

    let mut pay = Vec::new();
    mava::net::wire::encode_act_request(s, &[7.0, 0.5], &mut pay);
    let mut frame = frame_bytes(FrameKind::ActRequest, &pay);
    frame[12] ^= 0xFF; // flip a payload byte under an intact CRC
    c.send_raw(&frame).unwrap();
    let kind = c.recv(RPC).unwrap();
    assert_eq!(kind, FrameKind::Error);
    let msg =
        mava::net::wire::decode_error(c.last_payload()).unwrap();
    assert!(msg.contains("crc"), "typed corruption error, got: {msg}");

    // same connection, same session: still fully functional
    let (_, actions) = c.act(s, &[9.0, 0.5], RPC).unwrap();
    assert_eq!(actions, vec![9]);
    svc.shutdown();
}

/// Satellite 2 (wire view): slot exhaustion surfaces as a typed error
/// frame, never a panic or a dropped connection.
#[test]
fn slot_exhaustion_is_typed_over_tcp() {
    let mut svc = mock_service(1, 1_000);
    let mut c = ServeClient::connect(svc.addr()).unwrap();
    let s = c.open_session(RPC).unwrap();
    let err = c.open_session(RPC).unwrap_err().to_string();
    assert!(err.contains("sessions in use"), "got: {err}");
    // the first session still works after the rejected open
    let (_, actions) = c.act(s, &[4.0, 0.5], RPC).unwrap();
    assert_eq!(actions, vec![4]);
    svc.shutdown();
}

/// Satellite 3b: a client disconnecting mid-batch loses only its own
/// row — the surviving client's request in the same coalescing window
/// completes normally.
#[test]
fn disconnect_mid_batch_drops_only_that_row() {
    // long deadline so both requests share one coalescing window
    let mut svc = mock_service(4, 300_000);
    let mut alive = ServeClient::connect(svc.addr()).unwrap();
    let mut doomed = ServeClient::connect(svc.addr()).unwrap();
    let sa = alive.open_session(RPC).unwrap();
    let sd = doomed.open_session(RPC).unwrap();
    assert_ne!(sa, sd);
    alive.send_act(sa, &[6.0, 0.5]).unwrap();
    doomed.send_act(sd, &[8.0, 0.5]).unwrap();
    drop(doomed); // EOF tears the connection down, closing sd
    match alive.recv(RPC).unwrap() {
        FrameKind::ActResponse => {
            let (session, _, actions) =
                mava::net::wire::decode_act_response(alive.last_payload())
                    .unwrap();
            assert_eq!((session, actions), (sa, vec![6]));
        }
        other => panic!("expected the surviving response, got {other:?}"),
    }
    svc.shutdown();
}

/// Satellite 4 regression: halt probes still fire under the serve
/// listener — shutdown with an idle open connection (a reader parked
/// in its poll loop) completes promptly instead of hanging on a
/// blocking read.
#[test]
fn shutdown_is_prompt_with_idle_connections() {
    let mut svc = mock_service(2, 1_000);
    let mut c = ServeClient::connect(svc.addr()).unwrap();
    let _s = c.open_session(RPC).unwrap();
    let t0 = Instant::now();
    svc.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown hung {:?} with an idle connection",
        t0.elapsed()
    );
}

/// The real-engine backend end to end through the core (artifact-
/// gated, like the integration suite).
#[test]
fn engine_backend_serves_lowered_artifacts() {
    if Manifest::load("artifacts").is_err() {
        eprintln!("artifacts missing; skipping engine serve test");
        return;
    }
    let mut engine = Engine::load("artifacts").unwrap();
    let ladder =
        BucketLadder::from_manifest(&engine.manifest, "smac3m_madqn_policy")
            .unwrap();
    let params = engine.read_init("smac3m_madqn_train", "params0").unwrap();
    let backend = EngineBackend::new(
        &mut engine,
        SystemKind::Madqn,
        &ladder,
        params,
        7,
    )
    .unwrap();
    let ow = backend.obs_width();
    let aw = backend.act_width();
    let clock = Arc::new(MockClock::new(0));
    let mut core = ServeCore::new(backend, clock.clone(), 4, 1_000);
    let s = core.open_session().unwrap();
    core.submit(s, vec![0.3; ow]).unwrap();
    clock.set_us(1_000);
    let out = core.step().unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].session, s);
    assert_eq!(out[0].actions.len(), aw, "one discrete action per agent");
}
