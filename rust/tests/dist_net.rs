//! Hermetic loopback + fault-injection tests for the multi-process
//! wire layer (DESIGN.md §10). Everything runs on 127.0.0.1 with
//! ephemeral ports inside this test process — no artifacts, no child
//! processes, plain `cargo test -q`.
//!
//! Covered here (the ISSUE's distributed acceptance list):
//! * publish/fetch through the parameter protocol is never torn and
//!   versions are monotone per client, under concurrent writers;
//! * a 2-executor + trainer + 2-replay-shard loopback system makes
//!   progress end to end (inserts → samples → publishes → syncs);
//! * killing an executor's control connection trips the driver's stop
//!   signal, the dead node is named, and siblings wind down cleanly;
//! * the trainer's remote sampler degrades to surviving shards when a
//!   replay service dies, and ends (returns `None`) when all are gone.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use mava::launch::{
    outcomes_to_result, LocalLauncher, NodeKind, Program, StopSignal,
};
use mava::net::control::{ControlClient, ControlServer};
use mava::net::param::{ParamService, RemoteParamClient};
use mava::net::replay::{
    RemoteReplaySampler, RemoteShardClient, ReplayService,
};
use mava::params::{ParamStore, ParameterServer};
use mava::replay::{Item, ItemSink, ItemSource, Table, Transition};

fn tr(v: f32) -> Item {
    Item::Transition(Transition { obs: vec![v], ..Default::default() })
}

fn val(item: &Item) -> f32 {
    item.as_transition().obs[0]
}

const RPC: Duration = Duration::from_secs(10);

/// Parameter protocol under concurrent remote writers and readers:
/// a fetched blob is never torn (every element comes from the same
/// publish) and the version each reader observes is strictly
/// monotone.
#[test]
fn remote_params_never_torn_and_monotone() {
    const DIM: usize = 256;
    let server = Arc::new(ParameterServer::new(vec![0.0f32; DIM]));
    let mut svc = ParamService::bind(server, "127.0.0.1").unwrap();
    let addr = svc.addr().to_string();
    let done = Arc::new(AtomicBool::new(false));

    let writers: Vec<_> = (0..2)
        .map(|w| {
            let addr = addr.clone();
            thread::spawn(move || {
                let client =
                    RemoteParamClient::connect(&addr, RPC).unwrap();
                for i in 0..40u64 {
                    // each publish is a constant vector: any mix of
                    // two publishes in one fetch is detectable
                    let v = (w * 1000 + i) as f32;
                    client.push(&[v; DIM]).unwrap();
                }
            })
        })
        .collect();
    let readers: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let done = done.clone();
            thread::spawn(move || -> u64 {
                let client =
                    RemoteParamClient::connect(&addr, RPC).unwrap();
                let mut buf = Vec::new();
                let mut known = 0u64;
                let mut fetches = 0u64;
                loop {
                    match client.sync(known, &mut buf).unwrap() {
                        Some(v) => {
                            assert!(v > known, "version went backwards");
                            known = v;
                            fetches += 1;
                            assert_eq!(buf.len(), DIM);
                            assert!(
                                buf.windows(2).all(|w| w[0] == w[1]),
                                "torn read at version {v}: {:?} != {:?}",
                                buf[0],
                                buf.iter().find(|&&x| x != buf[0])
                            );
                        }
                        None if done.load(Ordering::Acquire) => {
                            return fetches;
                        }
                        None => {}
                    }
                }
            })
        })
        .collect();

    for w in writers {
        w.join().unwrap();
    }
    done.store(true, Ordering::Release);
    for r in readers {
        let fetches = r.join().unwrap();
        assert!(fetches >= 1, "reader never saw a publish");
    }
    svc.shutdown();
}

/// End-to-end loopback of the full replay + parameter data path:
/// two executors stream inserts to their own remote shard and sync
/// params; the trainer samples both shards round-robin and publishes
/// after every batch. The run makes progress and the executors
/// observe the trainer's publishes.
#[test]
fn loopback_two_executors_trainer_replay_make_progress() {
    const DIM: usize = 16;
    const TRAIN_STEPS: u64 = 30;
    let pserver = Arc::new(ParameterServer::new(vec![0.0f32; DIM]));
    let mut psvc = ParamService::bind(pserver, "127.0.0.1").unwrap();
    let paddr = psvc.addr().to_string();
    let tables: Vec<Arc<Table>> = (0..2)
        .map(|k| Arc::new(Table::uniform(512, 4, k as u64)))
        .collect();
    let mut rsvcs: Vec<ReplayService> = tables
        .iter()
        .map(|t| ReplayService::bind(t.clone(), "127.0.0.1").unwrap())
        .collect();
    let raddrs: Vec<String> =
        rsvcs.iter().map(|s| s.addr().to_string()).collect();
    let stop = StopSignal::new();

    let executors: Vec<_> = (0..2usize)
        .map(|k| {
            let stop = stop.clone();
            let paddr = paddr.clone();
            let raddr = raddrs[k].clone();
            thread::spawn(move || -> anyhow::Result<(u64, u64)> {
                let shard = RemoteShardClient::connect(&raddr)?;
                let params = RemoteParamClient::connect(&paddr, RPC)?;
                let mut buf = Vec::new();
                let mut known = 0u64;
                let mut inserted = 0u64;
                while !stop.is_stopped() {
                    let (accepted, recycled) =
                        shard.insert_item_reuse(tr(k as f32), 1.0);
                    shard.check()?;
                    assert!(recycled.is_some(), "item recycled");
                    if accepted {
                        inserted += 1;
                    }
                    if let Some(v) = params.sync(known, &mut buf)? {
                        known = v;
                    }
                }
                // one deterministic final sync: the trainer has
                // published by now, so every executor must see it
                if let Some(v) = params.sync(known, &mut buf)? {
                    known = v;
                }
                Ok((inserted, known))
            })
        })
        .collect();

    let trainer = {
        let raddrs = raddrs.clone();
        let paddr = paddr.clone();
        thread::spawn(move || -> anyhow::Result<(u64, u64)> {
            let source = RemoteReplaySampler::connect(&raddrs, RPC)?;
            let params = RemoteParamClient::connect(&paddr, RPC)?;
            let mut version = 0u64;
            let mut steps = 0u64;
            while steps < TRAIN_STEPS {
                let Some(batch) = source.sample_batch(8) else {
                    break;
                };
                assert_eq!(batch.len(), 8);
                for item in &batch {
                    let v = val(item);
                    assert!(v == 0.0 || v == 1.0, "unknown item {v}");
                }
                steps += 1;
                version = params.push(&[steps as f32; DIM])?;
            }
            Ok((steps, version))
        })
    };

    let (steps, version) = trainer.join().unwrap().unwrap();
    stop.stop();
    assert_eq!(steps, TRAIN_STEPS, "trainer starved");
    assert!(version > 1, "publishes advanced the server version");
    for e in executors {
        let (inserted, known) = e.join().unwrap().unwrap();
        assert!(inserted > 0, "executor inserted experience");
        assert!(
            known > 1,
            "executor never saw a trainer publish (v={known})"
        );
    }
    // teardown in the documented order: close tables, then services
    for (t, s) in tables.iter().zip(rsvcs.iter_mut()) {
        t.close();
        s.shutdown();
    }
    psvc.shutdown();
}

/// Fault injection at the control layer: an executor that drops its
/// control connection mid-run (a dead process, over the wire) trips
/// the driver's stop signal, is marked lost *by name*, and the
/// surviving nodes wind down cleanly through the broadcast `Stop` —
/// the supervisor's collapsed error names exactly the dead node.
#[test]
fn fault_injection_dead_executor_is_named_and_siblings_wind_down() {
    let driver_stop = StopSignal::new();
    let control =
        ControlServer::bind("127.0.0.1", driver_stop.clone()).unwrap();
    let addr = control.addr().to_string();

    // the program's own stop signal is separate: sibling wind-down
    // must flow through the control channel (the wire path), not
    // through shared memory
    let launcher_stop = StopSignal::new();
    let mut program = Program::new();
    for (name, kind) in [
        ("trainer", NodeKind::Trainer),
        ("executor_1", NodeKind::Executor),
    ] {
        let addr = addr.clone();
        program.add_node(name, kind, move || {
            let local = StopSignal::new();
            let ctl = ControlClient::connect(&addr, name, name, "")?;
            let _watch = ctl.watch_stop(local.clone())?;
            let deadline = Instant::now() + Duration::from_secs(30);
            while !local.is_stopped() {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "sibling never received Stop"
                );
                thread::sleep(Duration::from_millis(5));
            }
            Ok(())
        });
    }
    {
        let addr = addr.clone();
        program.add_node("executor_0", NodeKind::Executor, move || {
            // register, run briefly, then die: the dropped connection
            // is the only signal the driver gets
            let ctl =
                ControlClient::connect(&addr, "executor_0", "executor_0", "")?;
            thread::sleep(Duration::from_millis(50));
            drop(ctl);
            anyhow::bail!("simulated crash")
        });
    }
    let handle = LocalLauncher::launch(program, launcher_stop.clone());

    // the driver's supervise loop: wait for the wire to report death
    let deadline = Instant::now() + Duration::from_secs(10);
    while !driver_stop.is_stopped() && Instant::now() < deadline {
        thread::sleep(Duration::from_millis(5));
    }
    assert!(
        driver_stop.is_stopped(),
        "executor death never tripped the driver stop signal"
    );
    assert!(control.lost("executor_0"));
    assert_eq!(control.lost_nodes(), vec!["executor_0".to_string()]);
    assert!(!control.lost("trainer"));
    assert!(!control.lost("executor_1"));

    // wind down the survivors over the wire
    control.stop_all();
    let outcomes = handle.join_deadline(Duration::from_secs(10));
    assert_eq!(outcomes.len(), 3);
    for o in &outcomes {
        match o.name.as_str() {
            "executor_0" => assert!(o.result.is_err()),
            _ => assert!(
                o.result.is_ok(),
                "sibling {} failed: {:?}",
                o.name,
                o.result.as_ref().err()
            ),
        }
    }
    let err = outcomes_to_result(&outcomes).unwrap_err().to_string();
    assert!(
        err.contains("executor_0") && err.contains("simulated crash"),
        "collapsed error must name the dead node: {err}"
    );
    assert!(!err.contains("executor_1"), "survivors not blamed: {err}");
}

/// Replay fault injection: when a shard service dies the trainer-side
/// sampler drops it and keeps sampling the survivors; when the last
/// shard goes, sampling ends with `None` (clean trainer shutdown, not
/// an error).
#[test]
fn remote_sampler_degrades_then_ends() {
    let tables: Vec<Arc<Table>> = (0..2)
        .map(|k| Arc::new(Table::uniform(64, 2, 10 + k as u64)))
        .collect();
    let mut rsvcs: Vec<ReplayService> = tables
        .iter()
        .map(|t| ReplayService::bind(t.clone(), "127.0.0.1").unwrap())
        .collect();
    let raddrs: Vec<String> =
        rsvcs.iter().map(|s| s.addr().to_string()).collect();
    for (k, t) in tables.iter().enumerate() {
        for _ in 0..8 {
            t.insert(tr(k as f32), 1.0);
        }
    }
    let sampler = RemoteReplaySampler::connect(&raddrs, RPC).unwrap();
    assert_eq!(sampler.live_shards(), 2);
    assert!(sampler.sample_batch(4).is_some());

    // kill shard 0 (close first — the documented teardown order)
    tables[0].close();
    rsvcs[0].shutdown();
    for _ in 0..6 {
        let batch = sampler.sample_batch(4).expect("survivor still serves");
        for item in &batch {
            assert_eq!(val(item), 1.0, "sampled from the dead shard");
        }
    }
    assert_eq!(sampler.live_shards(), 1);

    // kill the last shard: the source has ended
    tables[1].close();
    rsvcs[1].shutdown();
    assert!(sampler.sample_batch(4).is_none());
    assert_eq!(sampler.live_shards(), 0);
}
