//! Hermetic loopback + fault-injection tests for the multi-process
//! wire layer (DESIGN.md §10) and the supervised runtime (§13).
//! Everything runs on 127.0.0.1 with ephemeral ports — no artifacts,
//! plain `cargo test -q`. The chaos tier at the bottom spawns real
//! killable child processes, but they are scripted incarnations of
//! *this very test binary* (re-exec'd filtered to `chaos_child_node`),
//! so the suite stays self-contained.
//!
//! Covered here (the ISSUE's distributed acceptance list):
//! * publish/fetch through the parameter protocol is never torn and
//!   versions are monotone per client, under concurrent writers;
//! * a 2-executor + trainer + 2-replay-shard loopback system makes
//!   progress end to end (inserts → samples → publishes → syncs);
//! * killing an executor's control connection trips the driver's stop
//!   signal, the dead node is named, and siblings wind down cleanly;
//! * the trainer's remote sampler degrades to surviving shards when a
//!   replay service dies, and ends (returns `None`) when all are gone;
//! * chaos: a SIGKILLed executor is respawned by the supervisor and
//!   the run completes; a SIGKILLed trainer resumes from its
//!   checkpoint with monotone published versions; a crash-looping
//!   node spends its restart budget and the run completes degraded
//!   on the survivors. No flaky sleeps — every wait is a polled
//!   condition with a deadline, and completion is gated on files and
//!   observed registry state, never on timing.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use mava::launch::{
    outcomes_to_result, LocalLauncher, NodeKind, Program, StopSignal,
};
use mava::net::control::{ControlClient, ControlServer};
use mava::net::param::{ParamService, RemoteParamClient};
use mava::net::replay::{
    RemoteReplaySampler, RemoteShardClient, ReplayService,
};
use mava::params::{ParamStore, ParameterServer};
use mava::replay::{Item, ItemSink, ItemSource, Table, Transition};

mod support;
use support::poll_until;

fn tr(v: f32) -> Item {
    Item::Transition(Transition { obs: vec![v], ..Default::default() })
}

fn val(item: &Item) -> f32 {
    item.as_transition().obs[0]
}

const RPC: Duration = Duration::from_secs(10);

/// Parameter protocol under concurrent remote writers and readers:
/// a fetched blob is never torn (every element comes from the same
/// publish) and the version each reader observes is strictly
/// monotone.
#[test]
fn remote_params_never_torn_and_monotone() {
    const DIM: usize = 256;
    let server = Arc::new(ParameterServer::new(vec![0.0f32; DIM]));
    let mut svc = ParamService::bind(server, "127.0.0.1").unwrap();
    let addr = svc.addr().to_string();
    let done = Arc::new(AtomicBool::new(false));

    let writers: Vec<_> = (0..2)
        .map(|w| {
            let addr = addr.clone();
            thread::spawn(move || {
                let client =
                    RemoteParamClient::connect(&addr, RPC).unwrap();
                for i in 0..40u64 {
                    // each publish is a constant vector: any mix of
                    // two publishes in one fetch is detectable
                    let v = (w * 1000 + i) as f32;
                    client.push(&[v; DIM]).unwrap();
                }
            })
        })
        .collect();
    let readers: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let done = done.clone();
            thread::spawn(move || -> u64 {
                let client =
                    RemoteParamClient::connect(&addr, RPC).unwrap();
                let mut buf = Vec::new();
                let mut known = 0u64;
                let mut fetches = 0u64;
                loop {
                    match client.sync(known, &mut buf).unwrap() {
                        Some(v) => {
                            assert!(v > known, "version went backwards");
                            known = v;
                            fetches += 1;
                            assert_eq!(buf.len(), DIM);
                            assert!(
                                buf.windows(2).all(|w| w[0] == w[1]),
                                "torn read at version {v}: {:?} != {:?}",
                                buf[0],
                                buf.iter().find(|&&x| x != buf[0])
                            );
                        }
                        None if done.load(Ordering::Acquire) => {
                            return fetches;
                        }
                        None => {}
                    }
                }
            })
        })
        .collect();

    for w in writers {
        w.join().unwrap();
    }
    done.store(true, Ordering::Release);
    for r in readers {
        let fetches = r.join().unwrap();
        assert!(fetches >= 1, "reader never saw a publish");
    }
    svc.shutdown();
}

/// End-to-end loopback of the full replay + parameter data path:
/// two executors stream inserts to their own remote shard and sync
/// params; the trainer samples both shards round-robin and publishes
/// after every batch. The run makes progress and the executors
/// observe the trainer's publishes.
#[test]
fn loopback_two_executors_trainer_replay_make_progress() {
    const DIM: usize = 16;
    const TRAIN_STEPS: u64 = 30;
    let pserver = Arc::new(ParameterServer::new(vec![0.0f32; DIM]));
    let mut psvc = ParamService::bind(pserver, "127.0.0.1").unwrap();
    let paddr = psvc.addr().to_string();
    let tables: Vec<Arc<Table>> = (0..2)
        .map(|k| Arc::new(Table::uniform(512, 4, k as u64)))
        .collect();
    let mut rsvcs: Vec<ReplayService> = tables
        .iter()
        .map(|t| ReplayService::bind(t.clone(), "127.0.0.1").unwrap())
        .collect();
    let raddrs: Vec<String> =
        rsvcs.iter().map(|s| s.addr().to_string()).collect();
    let stop = StopSignal::new();

    let executors: Vec<_> = (0..2usize)
        .map(|k| {
            let stop = stop.clone();
            let paddr = paddr.clone();
            let raddr = raddrs[k].clone();
            thread::spawn(move || -> anyhow::Result<(u64, u64)> {
                let shard = RemoteShardClient::connect(&raddr)?;
                let params = RemoteParamClient::connect(&paddr, RPC)?;
                let mut buf = Vec::new();
                let mut known = 0u64;
                let mut inserted = 0u64;
                while !stop.is_stopped() {
                    let (accepted, recycled) =
                        shard.insert_item_reuse(tr(k as f32), 1.0);
                    shard.check()?;
                    assert!(recycled.is_some(), "item recycled");
                    if accepted {
                        inserted += 1;
                    }
                    if let Some(v) = params.sync(known, &mut buf)? {
                        known = v;
                    }
                }
                // one deterministic final sync: the trainer has
                // published by now, so every executor must see it
                if let Some(v) = params.sync(known, &mut buf)? {
                    known = v;
                }
                Ok((inserted, known))
            })
        })
        .collect();

    let trainer = {
        let raddrs = raddrs.clone();
        let paddr = paddr.clone();
        thread::spawn(move || -> anyhow::Result<(u64, u64)> {
            let source = RemoteReplaySampler::connect(&raddrs, RPC)?;
            let params = RemoteParamClient::connect(&paddr, RPC)?;
            let mut version = 0u64;
            let mut steps = 0u64;
            while steps < TRAIN_STEPS {
                let Some(batch) = source.sample_batch(8) else {
                    break;
                };
                assert_eq!(batch.len(), 8);
                for item in &batch {
                    let v = val(item);
                    assert!(v == 0.0 || v == 1.0, "unknown item {v}");
                }
                steps += 1;
                version = params.push(&[steps as f32; DIM])?;
            }
            Ok((steps, version))
        })
    };

    let (steps, version) = trainer.join().unwrap().unwrap();
    stop.stop();
    assert_eq!(steps, TRAIN_STEPS, "trainer starved");
    assert!(version > 1, "publishes advanced the server version");
    for e in executors {
        let (inserted, known) = e.join().unwrap().unwrap();
        assert!(inserted > 0, "executor inserted experience");
        assert!(
            known > 1,
            "executor never saw a trainer publish (v={known})"
        );
    }
    // teardown in the documented order: close tables, then services
    for (t, s) in tables.iter().zip(rsvcs.iter_mut()) {
        t.close();
        s.shutdown();
    }
    psvc.shutdown();
}

/// Fault injection at the control layer: an executor that drops its
/// control connection mid-run (a dead process, over the wire) trips
/// the driver's stop signal, is marked lost *by name*, and the
/// surviving nodes wind down cleanly through the broadcast `Stop` —
/// the supervisor's collapsed error names exactly the dead node.
#[test]
fn fault_injection_dead_executor_is_named_and_siblings_wind_down() {
    let driver_stop = StopSignal::new();
    let control =
        ControlServer::bind("127.0.0.1", driver_stop.clone()).unwrap();
    let addr = control.addr().to_string();

    // the program's own stop signal is separate: sibling wind-down
    // must flow through the control channel (the wire path), not
    // through shared memory
    let launcher_stop = StopSignal::new();
    let mut program = Program::new();
    for (name, kind) in [
        ("trainer", NodeKind::Trainer),
        ("executor_1", NodeKind::Executor),
    ] {
        let addr = addr.clone();
        program.add_node(name, kind, move || {
            let local = StopSignal::new();
            let ctl = ControlClient::connect(&addr, name, name, "")?;
            let _watch = ctl.watch_stop(local.clone())?;
            let deadline = Instant::now() + Duration::from_secs(30);
            while !local.is_stopped() {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "sibling never received Stop"
                );
                thread::sleep(Duration::from_millis(5));
            }
            Ok(())
        });
    }
    let crash_gate = Arc::new(AtomicBool::new(false));
    {
        let addr = addr.clone();
        let gate = crash_gate.clone();
        program.add_node("executor_0", NodeKind::Executor, move || {
            // register, hold until the driver has seen every node,
            // then die: the dropped connection is the only signal the
            // driver gets, and gating the crash on full registration
            // keeps the scenario order-deterministic
            let ctl =
                ControlClient::connect(&addr, "executor_0", "executor_0", "")?;
            while !gate.load(Ordering::Acquire) {
                thread::sleep(Duration::from_millis(5));
            }
            drop(ctl);
            anyhow::bail!("simulated crash")
        });
    }
    let handle = LocalLauncher::launch(program, launcher_stop.clone());
    for name in ["trainer", "executor_1", "executor_0"] {
        control.wait_for(name, Duration::from_secs(30)).unwrap();
    }
    crash_gate.store(true, Ordering::Release);

    // the driver's supervise loop: wait for the wire to report death
    poll_until(
        "executor death trips the driver stop signal",
        Duration::from_secs(10),
        || driver_stop.is_stopped(),
    );
    assert!(control.lost("executor_0"));
    assert_eq!(control.lost_nodes(), vec!["executor_0".to_string()]);
    assert!(!control.lost("trainer"));
    assert!(!control.lost("executor_1"));

    // wind down the survivors over the wire
    control.stop_all();
    let outcomes = handle.join_deadline(Duration::from_secs(10));
    assert_eq!(outcomes.len(), 3);
    for o in &outcomes {
        match o.name.as_str() {
            "executor_0" => assert!(o.result.is_err()),
            _ => assert!(
                o.result.is_ok(),
                "sibling {} failed: {:?}",
                o.name,
                o.result.as_ref().err()
            ),
        }
    }
    let err = outcomes_to_result(&outcomes).unwrap_err().to_string();
    assert!(
        err.contains("executor_0") && err.contains("simulated crash"),
        "collapsed error must name the dead node: {err}"
    );
    assert!(!err.contains("executor_1"), "survivors not blamed: {err}");
}

/// Replay fault injection: when a shard service dies the trainer-side
/// sampler drops it and keeps sampling the survivors; when the last
/// shard goes, sampling ends with `None` (clean trainer shutdown, not
/// an error).
#[test]
fn remote_sampler_degrades_then_ends() {
    let tables: Vec<Arc<Table>> = (0..2)
        .map(|k| Arc::new(Table::uniform(64, 2, 10 + k as u64)))
        .collect();
    let mut rsvcs: Vec<ReplayService> = tables
        .iter()
        .map(|t| ReplayService::bind(t.clone(), "127.0.0.1").unwrap())
        .collect();
    let raddrs: Vec<String> =
        rsvcs.iter().map(|s| s.addr().to_string()).collect();
    for (k, t) in tables.iter().enumerate() {
        for _ in 0..8 {
            t.insert(tr(k as f32), 1.0);
        }
    }
    let sampler = RemoteReplaySampler::connect(&raddrs, RPC).unwrap();
    assert_eq!(sampler.live_shards(), 2);
    assert!(sampler.sample_batch(4).is_some());

    // kill shard 0 (close first — the documented teardown order)
    tables[0].close();
    rsvcs[0].shutdown();
    for _ in 0..6 {
        let batch = sampler.sample_batch(4).expect("survivor still serves");
        for item in &batch {
            assert_eq!(val(item), 1.0, "sampled from the dead shard");
        }
    }
    assert_eq!(sampler.live_shards(), 1);

    // kill the last shard: the source has ended
    tables[1].close();
    rsvcs[1].shutdown();
    assert!(sampler.sample_batch(4).is_none());
    assert_eq!(sampler.live_shards(), 0);
}

// ------------------------------------------------------------------
// Chaos tier (DESIGN.md §13): the supervisor against real processes.
// ------------------------------------------------------------------

#[cfg(unix)]
use std::path::PathBuf;
#[cfg(unix)]
use std::process::{Child, Command};

#[cfg(unix)]
use mava::launch::supervise::{
    supervise, SupervisedSpec, Supervision, SupervisorConfig,
};
#[cfg(unix)]
use mava::net::retry::RetryPolicy;
#[cfg(unix)]
use mava::systems::{read_trainer_checkpoint, write_trainer_checkpoint};

/// Scripted node body for the chaos drivers below. Under a normal
/// test run (no `MAVA_CHAOS_ROLE` in the environment) it is a no-op;
/// the drivers spawn this very test binary filtered to exactly this
/// test, which gives the supervisor real killable processes whose
/// behaviour each scenario scripts through `MAVA_CHAOS_*` variables.
/// Every role registers on the control channel and heartbeats, then
/// exits the *process* directly so its status is the node's status.
#[test]
#[cfg(unix)]
fn chaos_child_node() {
    let Ok(role) = std::env::var("MAVA_CHAOS_ROLE") else {
        return;
    };
    let env = |k: &str| {
        std::env::var(k).unwrap_or_else(|_| panic!("chaos child: {k} unset"))
    };
    let local = StopSignal::new();
    let ctl = ControlClient::connect(
        &env("MAVA_CHAOS_CONTROL"),
        &env("MAVA_CHAOS_NAME"),
        &role,
        "",
    )
    .unwrap();
    let _watch = ctl.watch_stop(local.clone()).unwrap();
    let _beat = ctl
        .start_heartbeat(Duration::from_millis(50), local.clone())
        .unwrap();
    match role.as_str() {
        // stream experience until the broadcast Stop: a clean exit
        "executor" => {
            let shard =
                RemoteShardClient::connect(&env("MAVA_CHAOS_REPLAY"))
                    .unwrap();
            let mut v = 0.0f32;
            while !local.is_stopped() {
                let (_, recycled) = shard.insert_item_reuse(tr(v), 1.0);
                assert!(recycled.is_some());
                shard.check().unwrap();
                v += 1.0;
                thread::sleep(Duration::from_millis(2));
            }
            std::process::exit(0);
        }
        // sample + publish until the driver's done-file appears, then
        // exit cleanly: the supervisor treats that as a completed run.
        // File-gated (not step-counted) so the driver decides when the
        // scenario's fault has been fully observed — no timing races.
        "trainer" => {
            let done = PathBuf::from(env("MAVA_CHAOS_DONE_FILE"));
            let source = RemoteReplaySampler::connect(
                &[env("MAVA_CHAOS_REPLAY")],
                RPC,
            )
            .unwrap();
            let params =
                RemoteParamClient::connect(&env("MAVA_CHAOS_PARAM"), RPC)
                    .unwrap();
            let mut s = 0u64;
            while !done.exists() {
                let batch =
                    source.sample_batch(4).expect("replay ended early");
                assert_eq!(batch.len(), 4);
                s += 1;
                params.push(&[s as f32; 8]).unwrap();
                thread::sleep(Duration::from_millis(5));
            }
            std::process::exit(0);
        }
        // checkpointing trainer: resumes from MAVA_CHAOS_DIR's
        // checkpoint, publishes step `s` as the constant vector [s; 8],
        // checkpoints every MAVA_CHAOS_CKPT_EVERY steps, and dies hard
        // at MAVA_CHAOS_CRASH_AT (0 = run the schedule to completion)
        "ckpt_trainer" => {
            let total: u64 = env("MAVA_CHAOS_STEPS").parse().unwrap();
            let every: u64 =
                env("MAVA_CHAOS_CKPT_EVERY").parse().unwrap();
            let crash_at: u64 =
                env("MAVA_CHAOS_CRASH_AT").parse().unwrap();
            let ckpt =
                PathBuf::from(env("MAVA_CHAOS_DIR")).join("trainer.ckpt");
            let params =
                RemoteParamClient::connect(&env("MAVA_CHAOS_PARAM"), RPC)
                    .unwrap();
            let mut steps = 0u64;
            let mut w = vec![0.0f32; 8];
            if ckpt.exists() {
                let (s, p, _target, _opt) =
                    read_trainer_checkpoint(&ckpt).unwrap();
                assert_eq!(p[0], s as f32, "checkpoint tensors torn");
                steps = s;
                w = p;
            }
            while steps < total {
                steps += 1;
                w.fill(steps as f32);
                params.push(&w).unwrap();
                if steps % every == 0 {
                    write_trainer_checkpoint(&ckpt, steps, &w, &w, &w)
                        .unwrap();
                }
                if crash_at != 0 && steps == crash_at {
                    std::process::exit(9);
                }
                thread::sleep(Duration::from_millis(2));
            }
            write_trainer_checkpoint(&ckpt, steps, &w, &w, &w).unwrap();
            std::process::exit(0);
        }
        other => panic!("unknown chaos role {other}"),
    }
}

#[cfg(unix)]
fn chaos_child(role: &str, name: &str, env: &[(&str, String)]) -> Child {
    let mut cmd = Command::new(std::env::current_exe().unwrap());
    cmd.args(["chaos_child_node", "--exact", "--nocapture"])
        .env("MAVA_CHAOS_ROLE", role)
        .env("MAVA_CHAOS_NAME", name);
    for (k, v) in env {
        cmd.env(k, v);
    }
    cmd.spawn().expect("spawn chaos child")
}

/// SIGKILL — the child gets no chance to clean up, flush, or say
/// goodbye on the control channel. The harshest failure mode.
#[cfg(unix)]
fn sigkill(pid: u32) {
    let _ = Command::new("kill").args(["-9", &pid.to_string()]).status();
}

/// True once `pid` no longer exists. The supervisor reaps its children
/// (`try_wait`), and it processes death and policy in the same poll
/// iteration — so "gone" implies the supervisor has already applied
/// restart/degrade for that incarnation.
#[cfg(unix)]
fn process_gone(pid: u32) -> bool {
    !Command::new("kill")
        .args(["-0", &pid.to_string()])
        .status()
        .map(|s| s.success())
        .unwrap_or(false)
}

#[cfg(unix)]
fn chaos_dir(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let d = std::env::temp_dir()
        .join(format!("mava_chaos_{tag}_{}_{nanos}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[cfg(unix)]
fn chaos_cfg() -> SupervisorConfig {
    SupervisorConfig {
        restart: RetryPolicy::new(10, 80, 2),
        startup: Duration::from_secs(60),
        // death is detected by process exit in these scenarios; the
        // staleness window stays out of the way so a loaded CI box
        // cannot trigger spurious wedge kills
        heartbeat_stale: Duration::from_secs(600),
        wind_down: Duration::from_secs(20),
    }
}

/// Backstop against a hung scenario: trips the stop signal so
/// `supervise` winds down and the test fails on its assertions instead
/// of hanging the suite.
#[cfg(unix)]
fn watchdog(stop: &StopSignal, secs: u64) {
    let stop = stop.clone();
    thread::spawn(move || {
        // early-exit poll: the thread winds down with the scenario
        // instead of outliving the test by the full budget
        let end = Instant::now() + Duration::from_secs(secs);
        while !stop.is_stopped() && Instant::now() < end {
            thread::sleep(Duration::from_millis(25));
        }
        stop.stop();
    });
}

/// Generous deadline for chaos waits: each includes at least one
/// child-process spawn (a re-exec of this test harness) on a possibly
/// loaded CI box. Polls exit the moment the condition holds.
#[cfg(unix)]
const CHAOS_WAIT: Duration = Duration::from_secs(60);

/// Chaos scenario 1: SIGKILL an executor mid-run. The supervisor must
/// detect the death, respawn the node (a second `Hello` arrives under
/// the same name), the restarted incarnation must resume feeding
/// replay, and the run completes with every outcome `Ok`.
#[test]
#[cfg(unix)]
fn chaos_killed_executor_is_restarted_and_run_completes() {
    let dir = chaos_dir("exec");
    let done = dir.join("DONE");
    let table = Arc::new(Table::uniform(256, 4, 42));
    let mut rsvc =
        ReplayService::bind(table.clone(), "127.0.0.1").unwrap();
    let pserver = Arc::new(ParameterServer::new(vec![0.0f32; 8]));
    let mut psvc = ParamService::bind(pserver, "127.0.0.1").unwrap();
    let stop = StopSignal::new();
    let mut control =
        ControlServer::bind_supervised("127.0.0.1", stop.clone())
            .unwrap();
    watchdog(&stop, 120);

    let common = vec![
        ("MAVA_CHAOS_CONTROL", control.addr().to_string()),
        ("MAVA_CHAOS_PARAM", psvc.addr().to_string()),
        ("MAVA_CHAOS_REPLAY", rsvc.addr().to_string()),
        ("MAVA_CHAOS_DONE_FILE", done.display().to_string()),
    ];
    let exec0 = chaos_child("executor", "executor_0", &common);
    let exec_pid = exec0.id();
    let specs = vec![
        SupervisedSpec {
            name: "executor_0".into(),
            kind: NodeKind::Executor,
            supervision: Supervision::RestartThenDegrade,
            child: exec0,
            spawn: {
                let common = common.clone();
                Box::new(move |_| {
                    Ok(chaos_child("executor", "executor_0", &common))
                })
            },
        },
        SupervisedSpec {
            name: "trainer".into(),
            kind: NodeKind::Trainer,
            supervision: Supervision::RestartThenFailStop,
            child: chaos_child("trainer", "trainer", &common),
            spawn: Box::new(|_| {
                anyhow::bail!("the trainer must not need a restart here")
            }),
        },
    ];

    let report = thread::scope(|s| {
        let killer = s.spawn(|| {
            poll_until("first executor feeds replay", CHAOS_WAIT, || {
                control.hello_count("executor_0") >= 1
                    && control.hello_count("trainer") >= 1
                    && table.stats().inserts >= 4
            });
            assert!(
                control.seen_within("executor_0", Duration::from_secs(30)),
                "heartbeats must be flowing before the kill"
            );
            let at_kill = table.stats().inserts;
            sigkill(exec_pid);
            poll_until("supervisor respawns the executor", CHAOS_WAIT, || {
                control.hello_count("executor_0") >= 2
            });
            // the restarted incarnation resumes the data path (>= +2:
            // at most one in-flight insert could be the dead one's)
            poll_until("restarted executor inserts", CHAOS_WAIT, || {
                table.stats().inserts >= at_kill + 2
            });
            std::fs::write(&done, b"done").unwrap();
        });
        let report = supervise(&control, &stop, specs, &chaos_cfg());
        killer.join().unwrap();
        report
    });

    assert!(report.restarts >= 1, "the killed executor was respawned");
    assert!(report.degraded.is_empty(), "nothing spent its budget");
    for o in &report.outcomes {
        assert!(
            o.result.is_ok(),
            "{} failed: {:?}",
            o.name,
            o.result.as_ref().err()
        );
    }
    table.close();
    rsvc.shutdown();
    psvc.shutdown();
    control.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Chaos scenario 2: SIGKILL-equivalent trainer death (it exits hard
/// mid-schedule). The supervisor respawns it, the second incarnation
/// resumes from the latest `MAVATRN1` checkpoint, the published
/// version stream stays strictly monotone (the param server survives),
/// the step value regresses by at most one checkpoint interval, and
/// the schedule completes.
#[test]
#[cfg(unix)]
fn chaos_killed_trainer_resumes_from_checkpoint() {
    const TOTAL: u64 = 24;
    const CKPT_EVERY: u64 = 4;
    const CRASH_AT: u64 = 10;
    let dir = chaos_dir("ckpt");
    let pserver = Arc::new(ParameterServer::new(vec![0.0f32; 8]));
    let mut psvc = ParamService::bind(pserver, "127.0.0.1").unwrap();
    let paddr = psvc.addr().to_string();
    let stop = StopSignal::new();
    let mut control =
        ControlServer::bind_supervised("127.0.0.1", stop.clone())
            .unwrap();
    watchdog(&stop, 120);

    let env = vec![
        ("MAVA_CHAOS_CONTROL", control.addr().to_string()),
        ("MAVA_CHAOS_PARAM", paddr.clone()),
        ("MAVA_CHAOS_DIR", dir.display().to_string()),
        ("MAVA_CHAOS_STEPS", TOTAL.to_string()),
        ("MAVA_CHAOS_CKPT_EVERY", CKPT_EVERY.to_string()),
        ("MAVA_CHAOS_CRASH_AT", CRASH_AT.to_string()),
    ];
    let resume_env: Vec<(&str, String)> = env
        .iter()
        .map(|(k, v)| {
            if *k == "MAVA_CHAOS_CRASH_AT" {
                (*k, "0".to_string())
            } else {
                (*k, v.clone())
            }
        })
        .collect();
    let specs = vec![SupervisedSpec {
        name: "trainer".into(),
        kind: NodeKind::Trainer,
        supervision: Supervision::RestartThenFailStop,
        child: chaos_child("ckpt_trainer", "trainer", &env),
        spawn: Box::new(move |_| {
            Ok(chaos_child("ckpt_trainer", "trainer", &resume_env))
        }),
    }];

    let done = AtomicBool::new(false);
    let report = thread::scope(|s| {
        // a live reader across the whole run: versions must never go
        // backwards even though the trainer died and was replaced
        let reader = s.spawn(|| {
            let client = RemoteParamClient::connect(&paddr, RPC).unwrap();
            let mut buf = Vec::new();
            let mut known = 0u64;
            let mut prev = 0.0f32;
            let mut max = 0.0f32;
            loop {
                match client.sync(known, &mut buf).unwrap() {
                    Some(v) => {
                        assert!(v > known, "version went backwards");
                        known = v;
                        let val = buf[0];
                        assert!(
                            buf.iter().all(|&x| x == val),
                            "torn publish at version {v}"
                        );
                        if val < prev {
                            // the resume replays steps since the last
                            // checkpoint — never more than one interval
                            assert!(
                                prev - val <= CKPT_EVERY as f32,
                                "resume lost more than one checkpoint \
                                 interval: {prev} -> {val}"
                            );
                        }
                        prev = val;
                        max = max.max(val);
                    }
                    None if done.load(Ordering::Acquire) => break,
                    None => {}
                }
            }
            max
        });
        let report = supervise(&control, &stop, specs, &chaos_cfg());
        done.store(true, Ordering::Release);
        let max = reader.join().unwrap();
        assert_eq!(
            max, TOTAL as f32,
            "the resumed trainer finished the schedule"
        );
        report
    });

    assert_eq!(report.restarts, 1, "exactly one respawn");
    assert!(report.degraded.is_empty());
    assert!(
        report.outcomes[0].result.is_ok(),
        "trainer outcome: {:?}",
        report.outcomes[0].result.as_ref().err()
    );
    assert!(
        control.hello_count("trainer") >= 2,
        "both incarnations registered"
    );
    let ckpt = dir.join("trainer.ckpt");
    let (steps, p, t, o) = read_trainer_checkpoint(&ckpt).unwrap();
    assert_eq!(steps, TOTAL, "final checkpoint is the completed state");
    assert_eq!(p[0], TOTAL as f32);
    assert_eq!((t.len(), o.len()), (p.len(), p.len()));
    assert!(
        !dir.join("trainer.ckpt.tmp").exists(),
        "atomic rename leaves no stage file behind"
    );
    psvc.shutdown();
    control.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Chaos scenario 3: a crash-looping executor spends its restart
/// budget (`max_restarts` respawns, each dying) and is *degraded* —
/// removed from the run — while the surviving executor keeps feeding
/// replay and the trainer completes. The run ends `Ok`, the degraded
/// node is named in the report, and nothing else restarted.
#[test]
#[cfg(unix)]
fn chaos_crashloop_spends_budget_and_run_degrades_to_survivors() {
    let dir = chaos_dir("degrade");
    let done = dir.join("DONE");
    let table = Arc::new(Table::uniform(256, 4, 77));
    let mut rsvc =
        ReplayService::bind(table.clone(), "127.0.0.1").unwrap();
    let pserver = Arc::new(ParameterServer::new(vec![0.0f32; 8]));
    let mut psvc = ParamService::bind(pserver, "127.0.0.1").unwrap();
    let stop = StopSignal::new();
    let mut control =
        ControlServer::bind_supervised("127.0.0.1", stop.clone())
            .unwrap();
    watchdog(&stop, 120);

    let common = vec![
        ("MAVA_CHAOS_CONTROL", control.addr().to_string()),
        ("MAVA_CHAOS_PARAM", psvc.addr().to_string()),
        ("MAVA_CHAOS_REPLAY", rsvc.addr().to_string()),
        ("MAVA_CHAOS_DONE_FILE", done.display().to_string()),
    ];
    fn crash() -> Child {
        Command::new("sh").args(["-c", "exit 4"]).spawn().unwrap()
    }
    // every respawned crash-loop incarnation's pid, so the driver can
    // observe (via process death, which implies the supervisor already
    // applied its policy) that the budget really was spent
    let respawned = Arc::new(std::sync::Mutex::new(Vec::<u32>::new()));
    let specs = vec![
        SupervisedSpec {
            name: "executor_0".into(),
            kind: NodeKind::Executor,
            supervision: Supervision::RestartThenDegrade,
            child: crash(),
            spawn: {
                let respawned = respawned.clone();
                Box::new(move |_| {
                    let c = crash();
                    respawned.lock().unwrap().push(c.id());
                    Ok(c)
                })
            },
        },
        SupervisedSpec {
            name: "executor_1".into(),
            kind: NodeKind::Executor,
            supervision: Supervision::RestartThenDegrade,
            child: chaos_child("executor", "executor_1", &common),
            spawn: Box::new(|_| {
                anyhow::bail!("the healthy executor must not restart")
            }),
        },
        SupervisedSpec {
            name: "trainer".into(),
            kind: NodeKind::Trainer,
            supervision: Supervision::RestartThenFailStop,
            child: chaos_child("trainer", "trainer", &common),
            spawn: Box::new(|_| {
                anyhow::bail!("the trainer must not restart")
            }),
        },
    ];

    let report = thread::scope(|s| {
        let observer = s.spawn(|| {
            // both budgeted respawns happen, then the last incarnation
            // dies and is reaped — at which point the supervisor has
            // already marked the node degraded — and only then may the
            // trainer finish
            poll_until("budget consumed", CHAOS_WAIT, || {
                respawned.lock().unwrap().len() == 2
            });
            let last = *respawned.lock().unwrap().last().unwrap();
            poll_until("last incarnation reaped", CHAOS_WAIT, || {
                process_gone(last)
            });
            std::fs::write(&done, b"done").unwrap();
        });
        let report = supervise(&control, &stop, specs, &chaos_cfg());
        observer.join().unwrap();
        report
    });

    assert_eq!(
        report.degraded,
        vec!["executor_0".to_string()],
        "the crash-looper, and only it, was degraded"
    );
    assert_eq!(report.restarts, 2, "exactly the budget was spent");
    for o in &report.outcomes {
        assert!(
            o.result.is_ok(),
            "{} failed: {:?}",
            o.name,
            o.result.as_ref().err()
        );
    }
    table.close();
    rsvc.shutdown();
    psvc.shutdown();
    control.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
