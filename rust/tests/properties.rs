//! Property-style randomized invariant tests (no proptest offline; we
//! drive invariants with seeded xoshiro randomness — failures print the
//! seed, so every case is reproducible).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use mava::core::{Actions, StepType, TimeStep};
use mava::replay::{
    Item, RateLimiter, Selector, SequenceAdder, SumTree, Table,
    TransitionAdder,
};
use mava::rng::Rng;

mod support;
use support::poll_until;

fn ts(obs: f32, rew: f32, last: bool, n: usize) -> TimeStep {
    TimeStep {
        step_type: if last { StepType::Last } else { StepType::Mid },
        observations: vec![vec![obs; 3]; n],
        rewards: vec![rew; n],
        discount: if last { 0.0 } else { 1.0 },
        state: vec![obs; 2],
        legal_actions: None,
    }
}

/// SumTree::sample must agree with a linear weighted scan distribution,
/// and total() must track arbitrary set() sequences exactly.
#[test]
fn prop_sumtree_matches_linear_scan() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let cap = 1 + rng.below(60);
        let mut tree = SumTree::new(cap);
        let mut weights = vec![0.0f64; cap];
        for _ in 0..200 {
            let slot = rng.below(cap);
            let w = (rng.f64() * 10.0).max(0.0);
            tree.set(slot, w);
            weights[slot] = w;
        }
        let total: f64 = weights.iter().sum();
        assert!(
            (tree.total() - total).abs() < 1e-9 * total.max(1.0),
            "seed {seed}: total {} vs {}",
            tree.total(),
            total
        );
        if total > 0.0 {
            // sampled slot must always carry positive weight
            for _ in 0..200 {
                let s = tree.sample(&mut rng);
                assert!(weights[s] > 0.0, "seed {seed}: zero-weight slot");
            }
        }
    }
}

/// Table invariant under random insert/sample interleavings:
/// size <= capacity, inserts - evictions == size, samples only return
/// live items.
#[test]
fn prop_table_size_and_eviction_invariants() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(100 + seed);
        let cap = 4 + rng.below(32);
        let table = Table::new(
            cap,
            if rng.chance(0.5) {
                Selector::Uniform
            } else {
                Selector::Prioritized
            },
            RateLimiter::min_size(1),
            seed,
        );
        let mut next_val = 0f32;
        let mut oldest_alive = 0f32;
        for _ in 0..300 {
            if rng.chance(0.7) {
                let tr = mava::replay::Transition {
                    obs: vec![next_val],
                    ..Default::default()
                };
                table.insert(Item::Transition(tr), rng.f64() * 5.0 + 0.1);
                next_val += 1.0;
                if next_val as usize > cap {
                    oldest_alive = next_val - cap as f32;
                }
            } else if table.stats().size > 0 {
                for item in table.sample(4).unwrap() {
                    let v = item.as_transition().obs[0];
                    assert!(
                        v >= oldest_alive && v < next_val,
                        "seed {seed}: sampled evicted item {v} \
                         (alive range [{oldest_alive}, {next_val}))"
                    );
                }
            }
            let st = table.stats();
            assert!(st.size <= cap);
            assert_eq!(st.inserts - st.evictions, st.size as u64);
        }
    }
}

/// The n-step adder must reproduce the naive n-step return computed
/// from the raw episode, for random episode lengths / n / gamma.
#[test]
fn prop_nstep_adder_matches_naive_returns() {
    for seed in 0..15u64 {
        let mut rng = Rng::new(200 + seed);
        let n_step = 1 + rng.below(4);
        let gamma = 0.5 + 0.5 * rng.f32();
        let len = 1 + rng.below(10);
        let rewards: Vec<f32> =
            (0..len).map(|_| rng.range_f32(-1.0, 1.0)).collect();

        let table = Arc::new(Table::uniform(1024, 1, 0));
        let mut adder = TransitionAdder::new(table.clone(), n_step, gamma);
        adder.observe_first(&ts(0.0, 0.0, false, 2));
        for (t, &r) in rewards.iter().enumerate() {
            adder.observe(
                &Actions::Discrete(vec![t as i32; 2]),
                &ts((t + 1) as f32, r, t + 1 == len, 2),
            );
        }
        let stats = table.stats();
        assert_eq!(stats.inserts as usize, len, "one item per step");

        // collect all items, keyed by their start obs
        let items = table.sample(512).unwrap();
        for item in items {
            let tr = item.as_transition();
            let t0 = tr.obs[0] as usize;
            let horizon = (len - t0).min(n_step);
            let mut want = 0.0f32;
            for k in 0..horizon {
                want += gamma.powi(k as i32) * rewards[t0 + k];
            }
            assert!(
                (tr.rewards[0] - want).abs() < 1e-4,
                "seed {seed}: t0={t0} n={n_step} got {} want {want}",
                tr.rewards[0]
            );
            // discount: gamma^(h-1) * prod(step discounts)
            let terminal = t0 + horizon == len;
            let want_disc = if terminal {
                0.0
            } else {
                gamma.powi(horizon as i32 - 1)
            };
            assert!(
                (tr.discount - want_disc).abs() < 1e-4,
                "seed {seed}: disc {} want {want_disc}",
                tr.discount
            );
        }
    }
}

/// Sequence adder: windows tile the episode, masks mark exactly the
/// valid prefix, and obs length is always (T+1)*N*O.
#[test]
fn prop_sequence_adder_windows_cover_episode() {
    for seed in 0..15u64 {
        let mut rng = Rng::new(300 + seed);
        let t_len = 2 + rng.below(6);
        let period = 1 + rng.below(t_len);
        let len = 1 + rng.below(12);
        let table = Arc::new(Table::uniform(1024, 1, 0));
        let mut adder = SequenceAdder::new(table.clone(), t_len, period);
        adder.observe_first(&ts(0.0, 0.0, false, 2));
        for t in 0..len {
            adder.observe(
                &Actions::Discrete(vec![1; 2]),
                &ts((t + 1) as f32, 0.5, t + 1 == len, 2),
            );
        }
        let expected_windows = len.div_ceil(period);
        assert_eq!(
            table.stats().inserts as usize,
            expected_windows,
            "seed {seed}: len={len} T={t_len} period={period}"
        );
        let mut total_valid = 0.0;
        for item in table.sample(256).unwrap() {
            let s = item.as_sequence();
            assert_eq!(s.obs.len(), (t_len + 1) * 6);
            assert_eq!(s.mask.len(), t_len);
            // mask is a 1-prefix followed by zeros
            let ones = s.mask.iter().take_while(|&&m| m == 1.0).count();
            assert!(s.mask[ones..].iter().all(|&m| m == 0.0));
            assert!(ones >= 1);
            total_valid += ones as f32;
        }
        let _ = total_valid;
    }
}

/// ε-greedy respects legal-action masks for every ε.
#[test]
fn prop_epsilon_greedy_legality() {
    let mut rng = Rng::new(42);
    for _ in 0..200 {
        let n = 2 + rng.below(8);
        let q: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut legal: Vec<bool> = (0..n).map(|_| rng.chance(0.6)).collect();
        if !legal.iter().any(|&l| l) {
            legal[rng.below(n)] = true;
        }
        let eps = rng.f32();
        let a = mava::exploration::epsilon_greedy(
            &q,
            n,
            Some(&legal),
            eps,
            &mut rng,
        );
        assert!(legal[a as usize]);
    }
}

/// Config parse/set round-trip: every settable key accepts its own
/// formatted value back.
#[test]
fn prop_config_set_roundtrip() {
    use mava::config::TrainConfig;
    let mut c = TrainConfig::default();
    let keys = [
        ("system", "qmix"),
        ("preset", "smac3m"),
        ("arch", "networked"),
        ("num_executors", "3"),
        ("num_envs_per_executor", "4"),
        ("max_env_steps", "123"),
        ("lr", "0.01"),
        ("tau", "0.5"),
        ("n_step", "5"),
        ("eps_start", "0.9"),
        ("eps_end", "0.1"),
        ("eps_decay_steps", "10"),
        ("noise_sigma", "0.7"),
        ("replay_size", "77"),
        ("min_replay", "7"),
        ("samples_per_insert", "3.5"),
        ("seed", "9"),
        ("eval_every_steps", "11"),
        ("eval_episodes", "13"),
    ];
    for (k, v) in keys {
        c.set(k, v).unwrap_or_else(|e| panic!("{k}: {e}"));
    }
    assert_eq!(c.system, "qmix");
    assert_eq!(c.num_executors, 3);
    assert_eq!(c.num_envs_per_executor, 4);
    assert_eq!(c.n_step, 5);
    assert_eq!(c.artifact_prefix(), "smac3m_qmix");
}

/// Sharded replay under concurrent per-shard writers and one
/// round-robin reader: aggregate stats stay consistent, every shard's
/// data reaches the sampler, and the ratio limiter holds in aggregate.
#[test]
fn prop_sharded_table_round_robin_aggregates() {
    use mava::replay::{ItemSource, ShardedTable};
    for &shards in &[1usize, 2, 4] {
        let table = Arc::new(ShardedTable::new(
            shards,
            4096,
            Selector::Uniform,
            RateLimiter::SampleToInsertRatio {
                ratio: 1.0,
                min_size: shards,
                error_buffer: 4.0 * shards as f64,
            },
            7,
        ));
        // per-shard sample counts live in shared atomics so the main
        // thread can poll for "every shard reached the sampler"
        // instead of guessing how long the reader needs
        let seen: Arc<Vec<AtomicU64>> =
            Arc::new((0..shards).map(|_| AtomicU64::new(0)).collect());
        let reader = {
            let t = table.clone();
            let seen = seen.clone();
            std::thread::spawn(move || {
                while let Some(batch) = t.sample_batch(2) {
                    for item in batch {
                        let v = item.as_transition().obs[0] as usize;
                        seen[v / 1000].fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        };
        let writers: Vec<_> = (0..shards)
            .map(|k| {
                let shard = table.shard(k);
                std::thread::spawn(move || {
                    for j in 0..200 {
                        let tr = mava::replay::Transition {
                            obs: vec![(k * 1000 + j) as f32],
                            ..Default::default()
                        };
                        if !shard.insert(Item::Transition(tr), 1.0) {
                            break;
                        }
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        poll_until(
            "every shard's data reaches the sampler",
            std::time::Duration::from_secs(10),
            || seen.iter().all(|n| n.load(Ordering::Relaxed) > 0),
        );
        let st = table.stats();
        table.close();
        reader.join().unwrap();
        assert_eq!(st.inserts, 200 * shards as u64, "shards={shards}");
        assert_eq!(st.size, 200 * shards, "no eviction expected");
        for (k, n) in seen.iter().enumerate() {
            let n = n.load(Ordering::Relaxed);
            assert!(n > 0, "shard {k} never sampled (shards={shards})");
        }
        // aggregate flow control: sample calls stay within the summed
        // error buffer of ratio * inserts
        let calls = st.samples as f64;
        assert!(
            calls <= st.inserts as f64 + 4.0 * shards as f64 + 1.0,
            "oversampled: {calls} calls vs {} inserts",
            st.inserts
        );
    }
}

/// Wire frame codec: every frame kind round-trips through
/// encode/decode for random payloads, and the consumed length is
/// exactly header + payload (no over-read).
#[test]
fn prop_frame_roundtrip_all_kinds() {
    use mava::net::frame::{
        decode_slice, encode_frame, FrameKind, HEADER_LEN,
    };
    for seed in 0..10u64 {
        let mut rng = Rng::new(400 + seed);
        for kind in FrameKind::ALL {
            let len = rng.below(200);
            let payload: Vec<u8> =
                (0..len).map(|_| rng.below(256) as u8).collect();
            let mut out = Vec::new();
            encode_frame(kind, &payload, &mut out);
            // trailing garbage must not be consumed
            out.extend_from_slice(&[0xde, 0xad]);
            let (got_kind, got_payload, consumed) =
                decode_slice(&out).unwrap_or_else(|e| {
                    panic!("seed {seed}: {kind:?} failed to decode: {e}")
                });
            assert_eq!(got_kind, kind, "seed {seed}");
            assert_eq!(got_payload, &payload[..], "seed {seed}");
            assert_eq!(consumed, HEADER_LEN + len, "seed {seed}");
        }
    }
}

/// Every truncation of a valid frame decodes to a typed error — never
/// a panic, never a bogus success.
#[test]
fn prop_frame_truncation_is_typed_error() {
    use mava::net::frame::{decode_slice, encode_frame, FrameKind};
    let mut rng = Rng::new(500);
    for kind in [FrameKind::Hello, FrameKind::SampleBatch, FrameKind::Stop] {
        let len = 1 + rng.below(64);
        let payload: Vec<u8> =
            (0..len).map(|_| rng.below(256) as u8).collect();
        let mut out = Vec::new();
        encode_frame(kind, &payload, &mut out);
        for cut in 0..out.len() {
            let err = decode_slice(&out[..cut]).expect_err("truncated");
            // rendering must not panic either
            let _ = err.to_string();
        }
    }
}

/// Corrupting bytes the codec checks (magic, version, payload under
/// CRC, the CRC itself) always yields a typed error, and arbitrary
/// single-byte corruption anywhere never panics or over-reads.
#[test]
fn prop_frame_corruption_is_typed_error() {
    use mava::net::frame::{
        decode_slice, encode_frame, FrameError, FrameKind, HEADER_LEN,
    };
    for seed in 0..10u64 {
        let mut rng = Rng::new(600 + seed);
        let len = 1 + rng.below(64);
        let payload: Vec<u8> =
            (0..len).map(|_| rng.below(256) as u8).collect();
        let mut clean = Vec::new();
        encode_frame(FrameKind::Params, &payload, &mut clean);

        // checked positions: magic [0,1], version [2], crc [8..12],
        // any payload byte — all must produce a typed error
        let mut checked = vec![0usize, 1, 2, 8, 9, 10, 11];
        checked.push(HEADER_LEN + rng.below(len));
        for &pos in &checked {
            let mut bad = clean.clone();
            bad[pos] ^= 1 << rng.below(8);
            if bad == clean {
                continue;
            }
            let err = decode_slice(&bad)
                .expect_err("corruption must not decode");
            let _ = err.to_string();
        }

        // wrong version specifically is named
        let mut bad = clean.clone();
        bad[2] = 7;
        assert!(matches!(
            decode_slice(&bad),
            Err(FrameError::BadVersion(7))
        ));

        // arbitrary corruption anywhere: no panic, and on a lucky
        // decode the consumed length never exceeds the buffer
        for _ in 0..50 {
            let mut bad = clean.clone();
            bad[rng.below(bad.len())] = rng.below(256) as u8;
            if let Ok((_, _, consumed)) = decode_slice(&bad) {
                assert!(consumed <= bad.len(), "seed {seed}: over-read");
            }
        }
    }
}

/// The reconnect backoff schedule (DESIGN.md §13) under random
/// policies: `delay(attempt)` is deterministic, monotone nondecreasing,
/// bounded by `cap`, and a [`Backoff`] pass hands out exactly
/// `max_attempts` delays matching the policy before giving up —
/// `reset()` refills the budget so transient outages never latch.
#[test]
fn prop_backoff_schedule_deterministic_capped_monotone() {
    use mava::net::retry::{Backoff, RetryPolicy};
    use std::time::Duration;
    for seed in 0..25u64 {
        let mut rng = Rng::new(800 + seed);
        let base_ms = 1 + rng.below(100) as u64;
        let cap_ms = base_ms + rng.below(2_000) as u64;
        let attempts = rng.below(14) as u32;
        let p = RetryPolicy::new(base_ms, cap_ms, attempts);

        let mut prev = Duration::ZERO;
        for a in 0..attempts.max(8) {
            let d = p.delay(a);
            assert_eq!(d, p.delay(a), "seed {seed}: nondeterministic");
            assert!(d >= prev, "seed {seed}: schedule not monotone");
            assert!(
                d <= Duration::from_millis(cap_ms),
                "seed {seed}: delay above cap"
            );
            assert!(
                d >= Duration::from_millis(base_ms).min(p.cap),
                "seed {seed}: delay below base"
            );
            prev = d;
        }
        // enormous attempt indices saturate at the cap, no overflow
        assert_eq!(p.delay(u32::MAX), Duration::from_millis(cap_ms));

        // a Backoff pass replays the policy exactly, then dries up
        let mut b = Backoff::new(p);
        for a in 0..attempts {
            assert_eq!(
                b.next_delay(),
                Some(p.delay(a)),
                "seed {seed}: pass diverges from policy at {a}"
            );
        }
        assert_eq!(b.next_delay(), None, "seed {seed}: budget overrun");
        assert_eq!(b.attempt(), attempts, "seed {seed}");
        assert_eq!(
            p.total_delay(),
            (0..attempts).map(|a| p.delay(a)).sum::<Duration>(),
            "seed {seed}: total_delay is not the schedule sum"
        );

        // success refills: the next outage sees the same fresh schedule
        b.reset();
        assert_eq!(b.attempt(), 0, "seed {seed}");
        if attempts > 0 {
            assert_eq!(b.next_delay(), Some(p.delay(0)), "seed {seed}");
        } else {
            assert_eq!(b.next_delay(), None, "seed {seed}");
        }
    }
}

/// The heartbeat liveness frame (DESIGN.md §13): empty payload, a
/// pinned wire kind byte (old and new binaries must agree on it), an
/// exact header-sized encoding, and the same typed-error guarantees as
/// every other frame under truncation and corruption.
#[test]
fn prop_heartbeat_frame_codec() {
    use mava::net::frame::{
        decode_slice, encode_frame, FrameKind, HEADER_LEN,
    };
    let mut clean = Vec::new();
    encode_frame(FrameKind::Heartbeat, &[], &mut clean);
    assert_eq!(clean.len(), HEADER_LEN, "heartbeat is header-only");
    // header layout: magic[0..2] version[2] kind[3] len[4..8] crc[8..12]
    assert_eq!(clean[3], 20, "heartbeat wire kind byte is pinned");
    assert_eq!(&clean[4..8], &[0, 0, 0, 0], "payload length is zero");

    // round-trip, with trailing bytes left unconsumed
    let mut framed = clean.clone();
    framed.extend_from_slice(&[0xde, 0xad]);
    let (kind, payload, consumed) = decode_slice(&framed).unwrap();
    assert_eq!(kind, FrameKind::Heartbeat);
    assert!(payload.is_empty());
    assert_eq!(consumed, HEADER_LEN);

    // every truncation is a typed error, never a panic
    for cut in 0..clean.len() {
        let err = decode_slice(&clean[..cut]).expect_err("truncated");
        let _ = err.to_string();
    }

    // flipping any checked header bit (magic, version, crc) is a typed
    // error; arbitrary corruption anywhere never panics or over-reads
    let mut rng = Rng::new(900);
    for &pos in &[0usize, 1, 2, 8, 9, 10, 11] {
        let mut bad = clean.clone();
        bad[pos] ^= 1 << rng.below(8);
        if bad == clean {
            continue;
        }
        let err =
            decode_slice(&bad).expect_err("corruption must not decode");
        let _ = err.to_string();
    }
    for _ in 0..200 {
        let mut bad = clean.clone();
        bad[rng.below(bad.len())] = rng.below(256) as u8;
        if let Ok((_, _, consumed)) = decode_slice(&bad) {
            assert!(consumed <= bad.len(), "over-read");
        }
    }
}

/// Replay items survive the wire: random transitions and sequences
/// round-trip bit-exactly through the insert and batch payloads.
#[test]
fn prop_item_wire_roundtrip() {
    use mava::net::wire;
    use mava::replay::{Sequence, Transition};
    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f32(-10.0, 10.0)).collect()
    }
    for seed in 0..15u64 {
        let mut rng = Rng::new(700 + seed);
        let item = if rng.chance(0.5) {
            let (no, ns, na, nr) = (
                1 + rng.below(8),
                rng.below(4),
                rng.below(4),
                1 + rng.below(3),
            );
            let actions_disc: Vec<i32> =
                (0..na).map(|_| rng.below(10) as i32).collect();
            Item::Transition(Transition {
                obs: rand_vec(&mut rng, no),
                state: rand_vec(&mut rng, ns),
                actions_disc,
                actions_cont: rand_vec(&mut rng, na),
                rewards: rand_vec(&mut rng, nr),
                discount: rng.f32(),
                next_obs: rand_vec(&mut rng, no),
                next_state: rand_vec(&mut rng, ns),
            })
        } else {
            let (t, no, nt) =
                (1 + rng.below(8), 4 + rng.below(16), rng.below(8));
            let actions: Vec<i32> =
                (0..nt).map(|_| rng.below(10) as i32).collect();
            Item::Sequence(Sequence {
                t,
                obs: rand_vec(&mut rng, no),
                actions,
                rewards: rand_vec(&mut rng, nt),
                discounts: rand_vec(&mut rng, nt),
                mask: rand_vec(&mut rng, nt),
            })
        };
        let priority = rng.f64() * 5.0;
        let mut pay = Vec::new();
        wire::encode_insert(&item, priority, &mut pay);
        let (back, p) = wire::decode_insert(&pay)
            .unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        assert_eq!(back, item, "seed {seed}");
        assert!((p - priority).abs() < 1e-12, "seed {seed}");

        let batch = vec![item.clone(), item.clone(), item];
        pay.clear();
        wire::encode_batch(&batch, &mut pay);
        let back = wire::decode_batch(&pay)
            .unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        assert_eq!(back, batch, "seed {seed}");

        // truncated payloads are typed errors, never panics
        for cut in 0..pay.len().min(40) {
            if let Err(e) = wire::decode_batch(&pay[..cut]) {
                let _ = format!("{e:#}");
            }
        }
    }
}

/// Environments never emit non-finite observations/rewards under long
/// random play (regression guard for the MPE softplus overflow).
#[test]
fn prop_envs_stay_finite_under_random_play() {
    use mava::env::make_env;
    for (name, episodes) in [
        ("matrix", 30),
        ("switch", 30),
        ("smac_lite", 8),
        ("mpe_spread", 8),
        ("mpe_speaker_listener", 8),
        ("multiwalker", 8),
    ] {
        let mut rng = Rng::new(7);
        let mut env = make_env(name, 99).unwrap();
        let spec = env.spec().clone();
        for _ in 0..episodes {
            let mut step = env.reset();
            let mut steps = 0;
            while !step.is_last() {
                let actions = if spec.discrete() {
                    Actions::Discrete(
                        (0..spec.n_agents)
                            .map(|i| {
                                if let Some(l) = &step.legal_actions {
                                    let ids: Vec<usize> = (0..spec
                                        .n_actions())
                                        .filter(|&k| l[i][k])
                                        .collect();
                                    ids[rng.below(ids.len())] as i32
                                } else {
                                    rng.below(spec.n_actions()) as i32
                                }
                            })
                            .collect(),
                    )
                } else {
                    // adversarial: saturated actions stress the physics
                    Actions::Continuous(
                        (0..spec.n_agents)
                            .map(|_| {
                                (0..spec.n_actions())
                                    .map(|_| {
                                        if rng.chance(0.5) { 1.0 } else { -1.0 }
                                    })
                                    .collect()
                            })
                            .collect(),
                    )
                };
                step = env.step(&actions);
                steps += 1;
                for o in &step.observations {
                    assert!(
                        o.iter().all(|x| x.is_finite()),
                        "{name}: non-finite obs"
                    );
                }
                assert!(
                    step.rewards.iter().all(|r| r.is_finite()),
                    "{name}: non-finite reward"
                );
                assert!(steps <= spec.episode_limit + 1);
            }
        }
    }
}

/// Serve session slots (DESIGN.md §12): under random open/close
/// interleavings a freshly opened slot is always zeroed, every open
/// session's carry row holds exactly what that session wrote (no
/// cross-contamination through slot reuse), and exhaustion / unknown
/// ids are typed errors, never panics.
#[test]
fn prop_serve_session_slots_zeroed_and_isolated() {
    use mava::serve::{ServeError, SessionTable};
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let max = 1 + rng.below(6);
        let w = 1 + rng.below(4);
        let mut t = SessionTable::new(max, w);
        let mut open: Vec<u64> = Vec::new();
        for _ in 0..300 {
            if rng.chance(0.5) {
                match t.open() {
                    Ok(id) => {
                        let slot = t.slot(id).unwrap();
                        assert!(
                            t.carry_row(slot).iter().all(|&x| x == 0.0),
                            "seed {seed}: dirty slot handed out"
                        );
                        // stamp the row with the (unique) session id
                        t.carry_row_mut(slot).fill(id as f32);
                        open.push(id);
                    }
                    Err(e) => {
                        assert_eq!(e, ServeError::SlotsExhausted { max });
                        assert_eq!(open.len(), max);
                    }
                }
            } else if !open.is_empty() {
                let id = open.swap_remove(rng.below(open.len()));
                t.close(id).unwrap();
                assert_eq!(t.slot(id), Err(ServeError::UnknownSession(id)));
            }
            for &id in &open {
                let slot = t.slot(id).unwrap();
                assert!(
                    t.carry_row(slot).iter().all(|&x| x == id as f32),
                    "seed {seed}: carry row of {id} cross-contaminated"
                );
            }
        }
    }
}

/// The full serve core under random open/act/close/step interleavings:
/// every response traces back to the session that asked (a mixed-up
/// carry/obs row would answer with the wrong action), closed sessions
/// are never answered, and submitted - dropped == answered exactly —
/// nothing lost, nothing double-answered.
#[test]
fn prop_serve_core_routes_without_cross_contamination() {
    use mava::serve::{MockBackend, MockClock, ServeCore, ServeError};
    use std::collections::HashMap;
    for seed in 0..15u64 {
        let mut rng = Rng::new(seed);
        let clock = std::sync::Arc::new(MockClock::new(0));
        let max = 1 + rng.below(5);
        let mut core = ServeCore::new(
            MockBackend::new(1, 1, 2, &[1, 2, 4]),
            clock.clone(),
            max,
            500,
        );
        let mut open: Vec<u64> = Vec::new();
        let mut submitted = 0u64;
        let mut dropped = 0u64;
        let mut answered: HashMap<u64, u64> = HashMap::new();
        for _ in 0..400 {
            match rng.below(4) {
                0 => match core.open_session() {
                    Ok(id) => open.push(id),
                    Err(e) => {
                        assert_eq!(
                            e,
                            ServeError::SlotsExhausted { max },
                            "seed {seed}"
                        );
                        assert_eq!(open.len(), max);
                    }
                },
                1 if !open.is_empty() => {
                    let id = open[rng.below(open.len())];
                    core.submit(id, vec![id as f32]).unwrap();
                    submitted += 1;
                }
                2 if !open.is_empty() => {
                    let id = open.swap_remove(rng.below(open.len()));
                    dropped += core.close_session(id).unwrap() as u64;
                    assert_eq!(
                        core.submit(id, vec![0.0]),
                        Err(ServeError::UnknownSession(id)),
                        "seed {seed}: closed session must be typed"
                    );
                }
                _ => {
                    clock.advance_us(200);
                    for r in core.step().unwrap() {
                        assert_eq!(
                            r.actions,
                            vec![r.session as i32],
                            "seed {seed}: response from the wrong row"
                        );
                        assert!(
                            open.contains(&r.session),
                            "seed {seed}: closed session answered"
                        );
                        *answered.entry(r.session).or_default() += 1;
                    }
                }
            }
        }
        clock.advance_us(10_000);
        for r in core.step().unwrap() {
            assert_eq!(r.actions, vec![r.session as i32], "seed {seed}");
            *answered.entry(r.session).or_default() += 1;
        }
        let total: u64 = answered.values().sum();
        assert_eq!(
            total + dropped,
            submitted,
            "seed {seed}: lost or duplicated responses"
        );
    }
}
