//! Steady-state allocation gate for the vectorized hot path
//! (DESIGN.md §6): after warm-up, the env → policy-selection → adder
//! loop must perform ZERO heap allocations per vector step.
//!
//! A counting global allocator wraps the system allocator; counting is
//! gated so warm-up (buffer growth, table fill, pool priming) is free,
//! then a measured window of vector steps — crossing episode auto-reset
//! boundaries — must not touch the heap. The policy artifact itself is
//! stubbed with a deterministic Q buffer: PJRT wrapper internals
//! allocate outside Rust's control, and this gate is about *our* loop
//! (obs fill, ε-greedy with legal masks, n-step/sequence accumulation,
//! table insert with item recycling).
//!
//! Everything here is hermetic — no artifacts/ needed — so the gate
//! runs in every CI configuration.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use mava::env::{make_env, ActionBuf, MultiAgentEnv, VecEnv, VecStepBuf};
use mava::replay::{SequenceAdder, Table, TransitionAdder};
use mava::rng::Rng;
use mava::systems::select_discrete_row;
use mava::StepType;

struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Either adder kind behind one dispatch, mirroring the builder's
/// per-instance adder slots.
enum AnyAdder {
    Tr(TransitionAdder),
    Sq(SequenceAdder),
}

impl AnyAdder {
    fn observe_first_row(&mut self, buf: &VecStepBuf, row: usize) {
        match self {
            AnyAdder::Tr(a) => a.observe_first_row(buf, row),
            AnyAdder::Sq(a) => a.observe_first_row(buf, row),
        }
    }

    fn observe_row(&mut self, abuf: &ActionBuf, row: usize, buf: &VecStepBuf) {
        match self {
            AnyAdder::Tr(a) => a.observe_row(abuf, row, buf),
            AnyAdder::Sq(a) => a.observe_row(abuf, row, buf),
        }
    }
}

fn smac_venv(b: usize) -> VecEnv {
    let envs: Vec<Box<dyn MultiAgentEnv>> = (0..b)
        .map(|i| make_env("smac_lite", 100 + i as u64).unwrap())
        .collect();
    VecEnv::new(envs).unwrap()
}

/// Drive `warmup + measured` vector steps of the full
/// env → ε-greedy → adder loop, counting allocations only over the
/// measured tail. Returns the measured allocation count.
fn drive(venv: &mut VecEnv, adders: &mut [AnyAdder], warmup: usize, measured: usize) -> u64 {
    let b = venv.num_envs();
    let spec = venv.spec().clone();
    let n = spec.n_agents;
    let na = spec.n_actions();
    let mut cur = venv.make_buf();
    let mut next = venv.make_buf();
    let mut abuf = venv.make_action_buf();
    let mut rng = Rng::new(7);
    // deterministic Q stub, refreshed in place each step
    let mut q = vec![0.0f32; b * n * na];

    venv.reset_into(&mut cur);
    for (row, adder) in adders.iter_mut().enumerate() {
        adder.observe_first_row(&cur, row);
    }

    ALLOCS.store(0, Ordering::Relaxed);
    for step in 0..warmup + measured {
        if step == warmup {
            COUNTING.store(true, Ordering::Relaxed);
        }
        for (k, qk) in q.iter_mut().enumerate() {
            *qk = ((k + step) % 11) as f32;
        }
        for row in 0..b {
            select_discrete_row(
                &q[row * n * na..(row + 1) * n * na],
                n,
                na,
                cur.legal_row(row),
                0.2,
                &mut rng,
                abuf.disc_row_mut(row),
            );
        }
        venv.step_into(&abuf, &mut next);
        for (row, adder) in adders.iter_mut().enumerate() {
            if next.step_type(row) == StepType::First {
                adder.observe_first_row(&next, row);
            } else {
                adder.observe_row(&abuf, row, &next);
            }
        }
        std::mem::swap(&mut cur, &mut next);
    }
    COUNTING.store(false, Ordering::Relaxed);
    ALLOCS.load(Ordering::Relaxed)
}

/// One test covers both adder kinds so the measured windows never race
/// another test thread of this binary.
#[test]
fn steady_state_vector_step_is_allocation_free() {
    let b = 4;

    // --- n-step transitions ---
    // small table so warm-up reaches capacity and eviction recycling
    // kicks in (the steady-state regime of a real run)
    let table = Arc::new(Table::uniform(64, 1, 0));
    let mut venv = smac_venv(b);
    let mut adders: Vec<AnyAdder> = (0..b)
        .map(|_| AnyAdder::Tr(TransitionAdder::new(table.clone(), 2, 0.99)))
        .collect();
    // 200 warm-up steps: fills the 64-item table (up to 4 inserts per
    // vector step), primes record/item pools, crosses episode resets
    let allocs = drive(&mut venv, &mut adders, 200, 100);
    assert!(
        table.stats().evictions > 0,
        "warm-up never reached table capacity — the test is not \
         measuring the steady-state regime"
    );
    assert_eq!(
        allocs, 0,
        "transition hot path allocated {allocs} times in 100 steady \
         vector steps"
    );

    // --- sequence windows (recurrent systems) ---
    let table = Arc::new(Table::uniform(64, 1, 0));
    let mut venv = smac_venv(b);
    let mut adders: Vec<AnyAdder> = (0..b)
        .map(|_| AnyAdder::Sq(SequenceAdder::new(table.clone(), 8, 8)))
        .collect();
    // sequences only flush at episode ends: warm long enough to cross
    // several (smac episodes cap at 60 steps) and fill the table
    let allocs = drive(&mut venv, &mut adders, 400, 100);
    assert!(table.stats().evictions > 0, "sequence table never filled");
    assert_eq!(
        allocs, 0,
        "sequence hot path allocated {allocs} times in 100 steady \
         vector steps"
    );
}
