//! Shared helpers for the integration suites. Each test binary pulls
//! this in with `mod support;`, so items unused by one binary are
//! expected — hence the file-wide allow.
#![allow(dead_code)]

use std::time::{Duration, Instant};

/// Poll `cond` every few milliseconds until it holds, panicking with
/// `what` if `deadline` elapses first. The R6 lint (DESIGN.md §14)
/// bans bare `thread::sleep` waits in tests; this is the sanctioned
/// replacement: the wait exits the moment the condition holds instead
/// of encoding a guess about scheduler timing, and a hang fails with
/// a named condition instead of wedging the suite.
pub fn poll_until(what: &str, deadline: Duration, mut cond: impl FnMut() -> bool) {
    let end = Instant::now() + deadline;
    while !cond() {
        assert!(Instant::now() < end, "timed out waiting: {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}
