//! Integration tests: full distributed runs over the real PJRT runtime.
//!
//! These need `make artifacts` to have been run; they use the tiny
//! matrix2 preset so each completes in seconds.

use std::time::Duration;

use mava::config::TrainConfig;
use mava::runtime::{Engine, Manifest};
use mava::systems::{self, SystemKind};

fn artifacts_ready() -> bool {
    Manifest::load("artifacts").is_ok()
}

/// Batched policy variants exist only in freshly lowered artifact dirs;
/// vectorized tests skip (not fail) against stale ones.
fn batched_artifacts_ready(name: &str) -> bool {
    Manifest::load("artifacts")
        .map(|m| m.get(name).is_ok())
        .unwrap_or(false)
}

fn tiny_cfg(system: &str) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.system = system.into();
    c.preset = "matrix2".into();
    c.num_executors = 2;
    c.max_env_steps = 4_000;
    c.min_replay = 64;
    c.eps_decay_steps = 2_000;
    c.eps_end = 0.02;
    c.eval_every_steps = 1_000;
    c.eval_episodes = 16;
    c.lr = 1e-3;
    c.seed = 3;
    c
}

/// MADQN learns the climbing game: independent learners reliably find a
/// safe equilibrium worth >= 25/episode (optimal 55, random ~ -7).
#[test]
fn distributed_madqn_learns_matrix_game() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let result =
        systems::train(&tiny_cfg("madqn"), Some(Duration::from_secs(120)))
            .unwrap();
    assert!(result.env_steps >= 4_000);
    assert!(result.train_steps > 100, "trainer starved");
    assert!(!result.evals.is_empty(), "evaluator produced nothing");
    assert!(
        result.best_return().is_some_and(|b| b >= 20.0),
        "did not learn: best {:?}",
        result.best_return()
    );
}

/// VDN's additive mixing on the same game must also learn, exercising the
/// team-reward + global-state plumbing.
#[test]
fn distributed_vdn_learns_matrix_game() {
    if !artifacts_ready() {
        return;
    }
    let result =
        systems::train(&tiny_cfg("vdn"), Some(Duration::from_secs(120)))
            .unwrap();
    assert!(
        result.best_return().is_some_and(|b| b >= 20.0),
        "vdn did not learn: {:?}",
        result.best_return()
    );
}

/// QMIX's pallas mixing kernel inside the lowered train step.
#[test]
fn distributed_qmix_learns_matrix_game() {
    if !artifacts_ready() {
        return;
    }
    let result =
        systems::train(&tiny_cfg("qmix"), Some(Duration::from_secs(120)))
            .unwrap();
    assert!(
        result.best_return().is_some_and(|b| b >= 20.0),
        "qmix did not learn: {:?}",
        result.best_return()
    );
}

/// The vectorized hot path end-to-end: 2 executors x 4 envs each,
/// batched policy artifact, sharded replay. Must still learn the
/// climbing game — vectorization changes throughput, not semantics.
#[test]
fn vectorized_executors_learn_matrix_game() {
    if !batched_artifacts_ready("matrix2_madqn_policy_b4") {
        eprintln!("skipping: re-run `make artifacts` (batched policies)");
        return;
    }
    let mut c = tiny_cfg("madqn");
    c.num_envs_per_executor = 4;
    let result =
        systems::train(&c, Some(Duration::from_secs(120))).unwrap();
    assert!(result.env_steps >= 4_000);
    assert!(result.train_steps > 100, "trainer starved");
    assert!(result.episodes > 100, "auto-reset stalled");
    assert!(
        result.best_return().is_some_and(|b| b >= 20.0),
        "vectorized run did not learn: {:?}",
        result.best_return()
    );
}

/// Vectorized recurrent path: per-instance hidden rows must reset
/// independently at desynchronised episode boundaries (switch3 episode
/// lengths vary per instance).
#[test]
fn vectorized_recurrent_runs_on_switch() {
    if !batched_artifacts_ready("switch3_madqn_rec_policy_b4") {
        return;
    }
    let mut c = tiny_cfg("madqn_rec");
    c.preset = "switch3".into();
    c.num_envs_per_executor = 4;
    c.max_env_steps = 1_500;
    c.min_replay = 32;
    let result = systems::train(&c, Some(Duration::from_secs(120))).unwrap();
    assert!(result.env_steps >= 1_500, "vectorized recurrent stalled");
    assert!(result.train_steps > 0, "trainer idle");
    for e in &result.evals {
        assert!(e.mean_return.is_finite());
        assert!((-1.0..=1.0).contains(&e.mean_return));
    }
}

/// Recurrent + DIAL systems run end-to-end on switch3 (sequence replay,
/// hidden-state carry, message routing). Short run: asserts plumbing and
/// finite losses rather than final performance.
#[test]
fn dial_and_recurrent_run_on_switch() {
    if !artifacts_ready() {
        return;
    }
    for system in ["madqn_rec", "dial"] {
        let mut c = tiny_cfg(system);
        c.preset = "switch3".into();
        c.max_env_steps = 1_500;
        c.min_replay = 32;
        let result =
            systems::train(&c, Some(Duration::from_secs(120))).unwrap();
        assert!(result.env_steps >= 1_500, "{system} stalled");
        assert!(result.train_steps > 0, "{system} trainer idle");
        assert!(!result.evals.is_empty());
        for e in &result.evals {
            assert!(e.mean_return.is_finite());
            assert!((-1.0..=1.0).contains(&e.mean_return), "{system}");
        }
    }
}

/// Continuous control end-to-end: MAD4PG on spread3 with n-step adder.
#[test]
fn mad4pg_runs_on_spread() {
    if !artifacts_ready() {
        return;
    }
    let mut c = tiny_cfg("mad4pg");
    c.preset = "spread3".into();
    c.max_env_steps = 2_000;
    c.n_step = 5;
    c.min_replay = 256;
    c.noise_sigma = 0.3;
    let result = systems::train(&c, Some(Duration::from_secs(180))).unwrap();
    assert!(result.train_steps > 0);
    let best = result.best_return().expect("no evaluation completed");
    assert!(best.is_finite() && best > -200.0, "diverged: {best}");
}

/// Architecture swap: the same preset runs under dec and cen artifacts
/// with identical parameter counts (Block 4's one-line change).
#[test]
fn architecture_swap_is_config_only() {
    if !artifacts_ready() {
        return;
    }
    let manifest = Manifest::load("artifacts").unwrap();
    let dec = manifest.get("walker3_mad4pg_dec_train").unwrap();
    let cen = manifest.get("walker3_mad4pg_cen_train").unwrap();
    assert_eq!(
        dec.meta_usize("params").unwrap(),
        cen.meta_usize("params").unwrap()
    );
}

/// Evaluator-only path: greedy policy from initial parameters.
#[test]
fn greedy_eval_from_init_params() {
    if !artifacts_ready() {
        return;
    }
    let mut engine = Engine::load("artifacts").unwrap();
    let artifact = engine.artifact("smac3m_madqn_policy").unwrap();
    let params = engine.read_init("smac3m_madqn_train", "params0").unwrap();
    let mut executor =
        systems::Executor::new(SystemKind::Madqn, artifact, params, 0)
            .unwrap();
    let mut env = systems::env_for_preset("smac3m", 0, None).unwrap();
    let summary =
        mava::eval::evaluate(&mut executor, env.as_mut(), 3).unwrap();
    assert!(summary.mean_return.is_finite());
    assert!(summary.mean_return >= 0.0, "smac reward is non-negative");
}

/// Trainer checkpoints round-trip the full training state: a restored
/// trainer continues from the same params/opt/step.
#[test]
fn trainer_checkpoint_roundtrip() {
    if !artifacts_ready() {
        return;
    }
    use mava::replay::{Item, Table, Transition};
    use mava::systems::{Family, Trainer};
    use std::sync::Arc;

    let mut engine = Engine::load("artifacts").unwrap();
    let art = engine.artifact("matrix2_madqn_train").unwrap();
    let p0 = engine.read_init("matrix2_madqn_train", "params0").unwrap();
    let o0 = engine.read_init("matrix2_madqn_train", "opt0").unwrap();
    let mut t1 = Trainer::new(
        Family::DqnFf, art.clone(), p0.clone(), o0.clone(), 1e-3, 0.01, 1,
    )
    .unwrap();
    t1.init_target_from_params().unwrap();

    let table = Arc::new(Table::uniform(256, 1, 0));
    for i in 0..64 {
        table.insert(
            Item::Transition(Transition {
                obs: vec![0.1 * i as f32; 8],
                actions_disc: vec![i % 3, (i + 1) % 3],
                rewards: vec![1.0, 1.0],
                discount: 1.0,
                next_obs: vec![0.1; 8],
                ..Default::default()
            }),
            1.0,
        );
    }
    for _ in 0..5 {
        t1.step(&table).unwrap();
    }
    let dir = std::env::temp_dir().join("mava_trainer_ckpt");
    let path = dir.join("t.ckpt");
    t1.save_checkpoint(&path).unwrap();

    let mut t2 =
        Trainer::new(Family::DqnFf, art, p0, o0, 1e-3, 0.01, 1).unwrap();
    t2.load_checkpoint(&path).unwrap();
    assert_eq!(t2.stats.steps, 5);
    assert_eq!(t2.params(), t1.params());

    // replay table checkpoint round-trips alongside
    let rpath = dir.join("replay.ckpt");
    assert_eq!(table.checkpoint(&rpath).unwrap(), 64);
    let restored = Table::uniform(256, 1, 9);
    assert_eq!(restored.restore(&rpath).unwrap(), 64);
    assert_eq!(restored.stats().size, 64);
}

/// Fills a table with a deterministic set of DqnFf transitions; two
/// tables built with the same seed serve identical sample sequences.
fn filled_madqn_table(seed: u64) -> std::sync::Arc<mava::replay::Table> {
    use mava::replay::{Item, Table, Transition};
    let table = std::sync::Arc::new(Table::uniform(256, 1, seed));
    for i in 0..64 {
        table.insert(
            Item::Transition(Transition {
                obs: vec![0.1 * i as f32; 8],
                actions_disc: vec![i % 3, (i + 1) % 3],
                rewards: vec![1.0, 0.5],
                discount: 1.0,
                next_obs: vec![0.1 * (i + 1) as f32; 8],
                ..Default::default()
            }),
            1.0,
        );
    }
    table
}

/// Device residency changes where the state lives, not the numbers:
/// same seed, same data, N steps — the device-resident and
/// host-resident trainers must publish bitwise-identical parameters.
#[test]
fn device_resident_matches_host_path() {
    if !artifacts_ready() {
        return;
    }
    use mava::systems::{Family, Trainer};
    let mut engine = Engine::load("artifacts").unwrap();
    let art = engine.artifact("matrix2_madqn_train").unwrap();
    let p0 = engine.read_init("matrix2_madqn_train", "params0").unwrap();
    let o0 = engine.read_init("matrix2_madqn_train", "opt0").unwrap();
    let mut dev = Trainer::new(
        Family::DqnFf, art.clone(), p0.clone(), o0.clone(), 1e-3, 0.01, 7,
    )
    .unwrap();
    let mut host = Trainer::new_host_resident(
        Family::DqnFf, art, p0, o0, 1e-3, 0.01, 7,
    )
    .unwrap();
    assert!(dev.device_resident());
    assert!(!host.device_resident());
    dev.init_target_from_params().unwrap();
    host.init_target_from_params().unwrap();
    let ta = filled_madqn_table(5);
    let tb = filled_madqn_table(5);
    for i in 0..10 {
        let la = dev.step(&ta).unwrap().unwrap();
        let lb = host.step(&tb).unwrap().unwrap();
        assert_eq!(
            la.to_bits(),
            lb.to_bits(),
            "loss diverged at step {i}: {la} vs {lb}"
        );
    }
    let pa = dev.params_synced().unwrap().to_vec();
    let pb = host.params_synced().unwrap().to_vec();
    assert_eq!(pa.len(), pb.len());
    for (i, (a, b)) in pa.iter().zip(&pb).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param {i}: {a} vs {b}");
    }
}

/// Checkpoints round-trip through the device-resident trainer: the
/// same `MAVATRN1` blob, restored state re-uploaded, and training
/// continues identically after restore.
#[test]
fn device_trainer_checkpoint_roundtrip() {
    if !artifacts_ready() {
        return;
    }
    use mava::systems::{Family, Trainer};
    let mut engine = Engine::load("artifacts").unwrap();
    let art = engine.artifact("matrix2_madqn_train").unwrap();
    let p0 = engine.read_init("matrix2_madqn_train", "params0").unwrap();
    let o0 = engine.read_init("matrix2_madqn_train", "opt0").unwrap();
    let mut t1 = Trainer::new(
        Family::DqnFf, art.clone(), p0.clone(), o0.clone(), 1e-3, 0.01, 2,
    )
    .unwrap();
    t1.init_target_from_params().unwrap();
    let ta = filled_madqn_table(9);
    for _ in 0..4 {
        t1.step(&ta).unwrap().unwrap();
    }
    let path =
        std::env::temp_dir().join("mava_dev_trainer_ckpt").join("t.ckpt");
    t1.save_checkpoint(&path).unwrap();
    let blob = std::fs::read(&path).unwrap();
    assert_eq!(&blob[..8], b"MAVATRN1", "blob format changed");

    let mut t2 = Trainer::new(Family::DqnFf, art, p0, o0, 1e-3, 0.01, 2)
        .unwrap();
    t2.load_checkpoint(&path).unwrap();
    assert_eq!(t2.stats.steps, 4);
    assert_eq!(t1.params(), t2.params_synced().unwrap());
    // restored device state must continue training identically
    let tb = filled_madqn_table(11);
    let tc = filled_madqn_table(11);
    let l1 = t1.step(&tb).unwrap().unwrap();
    let l2 = t2.step(&tc).unwrap().unwrap();
    assert_eq!(l1.to_bits(), l2.to_bits(), "post-restore step diverged");
}

/// `publish_interval` gates the server push (and its P-float download)
/// to every K steps; a shutdown flush still publishes the final params.
#[test]
fn publish_interval_gates_server_pushes() {
    if !artifacts_ready() {
        return;
    }
    use mava::params::ParameterServer;
    use mava::systems::{Family, Trainer};
    let mut engine = Engine::load("artifacts").unwrap();
    let art = engine.artifact("matrix2_madqn_train").unwrap();
    let p0 = engine.read_init("matrix2_madqn_train", "params0").unwrap();
    let o0 = engine.read_init("matrix2_madqn_train", "opt0").unwrap();
    let mut trainer =
        Trainer::new(Family::DqnFf, art, p0.clone(), o0, 1e-3, 0.01, 4)
            .unwrap();
    trainer.init_target_from_params().unwrap();
    trainer.set_publish_interval(3);
    let server = ParameterServer::new(p0); // version 1
    let table = filled_madqn_table(13);
    for step in 1..=7u64 {
        trainer.step_and_publish(&table, &server).unwrap().unwrap();
        let expect = 1 + step / 3; // pushes at steps 3 and 6
        assert_eq!(
            server.version(),
            expect,
            "wrong version after step {step}"
        );
    }
    // shutdown flush publishes the (unpublished) step-7 params ...
    assert!(trainer.publish(&server).unwrap());
    assert_eq!(server.version(), 4);
    assert_eq!(server.get().1, trainer.params());
    // ... exactly once
    assert!(!trainer.publish(&server).unwrap());
    assert_eq!(server.version(), 4);
}

/// Fingerprint preset wires the wrapped env and the fp artifacts.
#[test]
fn fingerprint_preset_runs() {
    if !artifacts_ready() {
        return;
    }
    let mut c = tiny_cfg("madqn");
    c.preset = "smac3m_fp".into();
    c.max_env_steps = 600;
    c.min_replay = 64;
    let result = systems::train(&c, Some(Duration::from_secs(120))).unwrap();
    assert!(result.env_steps >= 600);
    assert!(result.train_steps > 0);
}

/// Satellite: node errors surface through the launcher's typed
/// channel. An executor whose env factory fails makes the run return
/// `Err` *naming the node* (instead of an eprintln and a trainer
/// blocked on an empty replay table until the deadline), and
/// `run_collect` records the failure in `TrainResult::node_failures`.
#[test]
fn failing_node_fails_the_run_naming_the_node() {
    if !artifacts_ready() {
        return;
    }
    use mava::systems::{SystemBuilder, SystemSpec};
    let cfg = tiny_cfg("madqn");
    let spec = SystemSpec::parse("madqn").unwrap();
    // no evaluator: it shares the env factory, and this test pins that
    // ONLY the nodes that actually failed are named (trainer survives)
    let system = SystemBuilder::new(spec, &cfg)
        .executors(2)
        .evaluator(false)
        .env_factory(|_seed, _fp| anyhow::bail!("research env refused to boot"))
        .build()
        .unwrap();
    let err = system.run(Some(Duration::from_secs(120))).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("executor_0") || msg.contains("executor_1"),
        "error must name the failed node: {msg}"
    );
    assert!(msg.contains("research env refused to boot"), "{msg}");

    let result =
        system.run_collect(Some(Duration::from_secs(120))).unwrap();
    assert!(result.failed_node().is_some());
    assert!(
        result
            .node_failures
            .iter()
            .all(|f| f.node.starts_with("executor_")),
        "only the executors failed: {:?}",
        result.node_failures
    );
}

/// The fluent builder drives the same pipeline as `train()`: a system
/// built with explicit executors learns the matrix game, and the
/// headless (no-evaluator) graph reports `best_return() == None`.
#[test]
fn builder_built_system_learns_and_headless_has_no_evals() {
    if !artifacts_ready() {
        return;
    }
    use mava::systems::{SystemBuilder, SystemSpec};
    let cfg = tiny_cfg("madqn");
    let spec = SystemSpec::parse("madqn").unwrap();
    let result = SystemBuilder::new(spec, &cfg)
        .executors(2)
        .build()
        .unwrap()
        .run(Some(Duration::from_secs(120)))
        .unwrap();
    assert!(result.node_failures.is_empty());
    assert!(
        result.best_return().is_some_and(|b| b >= 20.0),
        "builder-built system did not learn: {:?}",
        result.best_return()
    );

    let mut short = tiny_cfg("madqn");
    short.max_env_steps = 500;
    let headless = SystemBuilder::new(spec, &short)
        .evaluator(false)
        .build()
        .unwrap()
        .run(Some(Duration::from_secs(120)))
        .unwrap();
    assert!(headless.evals.is_empty());
    assert_eq!(headless.best_return(), None);
    assert!(headless.env_steps >= 500);
}

/// Vectorized evaluation agrees with the serial path in shape and
/// sanity: B greedy episodes per batched call, exactly n returns.
#[test]
fn vec_evaluator_runs_batched_greedy_episodes() {
    if !batched_artifacts_ready("smac3m_madqn_policy_b4") {
        return;
    }
    let mut engine = Engine::load("artifacts").unwrap();
    let artifact = engine.artifact("smac3m_madqn_policy_b4").unwrap();
    let params = engine.read_init("smac3m_madqn_train", "params0").unwrap();
    let executor =
        systems::VecExecutor::new(SystemKind::Madqn, artifact, params, 0)
            .unwrap();
    let instances: Vec<_> = (0..4)
        .map(|i| systems::env_for_preset("smac3m", i, None).unwrap())
        .collect();
    let venv = mava::env::VecEnv::new(instances).unwrap();
    let mut evaluator =
        mava::eval::VecEvaluator::new(executor, venv).unwrap();
    let returns = evaluator.evaluate(7).unwrap();
    assert_eq!(returns.len(), 7, "exactly n episodes, surplus discarded");
    assert!(returns.iter().all(|r| r.is_finite() && *r >= 0.0));
    // a second call starts fresh (episodes re-reset, still n returns)
    assert_eq!(evaluator.evaluate(3).unwrap().len(), 3);
}

/// Every width in 1..=64 maps onto a lowered bucket (tentpole
/// acceptance: no "no lowered variant" error anywhere in the range),
/// and representative non-bucket widths actually evaluate end-to-end
/// with padding rows masked out of the episode accounting.
#[test]
fn any_width_up_to_64_picks_a_bucket_and_evaluates() {
    if !batched_artifacts_ready("matrix2_madqn_policy_b64") {
        eprintln!("skipping: re-run `make artifacts` (bucket ladder)");
        return;
    }
    use mava::runtime::BucketLadder;
    let mut engine = Engine::load("artifacts").unwrap();
    let ladder =
        BucketLadder::from_manifest(&engine.manifest, "matrix2_madqn_policy")
            .unwrap();
    for n in 1..=64usize {
        let (bucket, pad) = ladder
            .pick(n)
            .unwrap_or_else(|e| panic!("width {n} has no bucket: {e:#}"));
        assert!(bucket >= n && bucket - n == pad, "n={n} -> b{bucket}+{pad}");
        assert!(
            engine.manifest.get(&ladder.artifact_name(bucket)).is_ok(),
            "picked bucket b{bucket} is not in the manifest"
        );
    }
    // padded widths run for real: 3 -> b4, 5 -> b8, 33 -> b64
    let params = engine.read_init("matrix2_madqn_train", "params0").unwrap();
    for n in [3usize, 5, 33] {
        let (bucket, _) = ladder.pick(n).unwrap();
        let artifact =
            engine.artifact(&ladder.artifact_name(bucket)).unwrap();
        let executor = systems::VecExecutor::new(
            SystemKind::Madqn,
            artifact,
            params.clone(),
            0,
        )
        .unwrap();
        let instances: Vec<_> = (0..n)
            .map(|i| {
                systems::env_for_preset("matrix2", i as u64, None).unwrap()
            })
            .collect();
        let venv = mava::env::VecEnv::new(instances).unwrap();
        // VecEvaluator pads the buffers to the bucket and masks the
        // padding rows out of selection + accounting internally
        let mut evaluator =
            mava::eval::VecEvaluator::new(executor, venv).unwrap();
        let returns = evaluator.evaluate(n).unwrap();
        assert_eq!(returns.len(), n, "width {n} (bucket {bucket})");
        assert!(returns.iter().all(|r| r.is_finite()), "width {n}");
    }
}

/// Tentpole acceptance: a D=2 data-parallel step is equivalent to the
/// fused single-device step on the same full batch. Bitwise equality
/// is not expected (XLA associates the batch reduction differently for
/// B and B/2 shapes); the losses and the final parameters must agree
/// to tight relative tolerance, and two dp trainers fed the same
/// stream must be bitwise deterministic (fixed-order all-reduce).
#[test]
fn dp2_trainer_matches_fused_step_and_is_deterministic() {
    if !batched_artifacts_ready("matrix2_madqn_train_dp2") {
        eprintln!("skipping: re-run `make artifacts` (dp variants)");
        return;
    }
    use mava::systems::{Family, Trainer};
    let mut engine = Engine::load("artifacts").unwrap();
    let fused = engine.artifact("matrix2_madqn_train").unwrap();
    let grad = engine.artifact("matrix2_madqn_train_dp2").unwrap();
    let apply = engine.artifact("matrix2_madqn_train_apply").unwrap();
    let p0 = engine.read_init("matrix2_madqn_train", "params0").unwrap();
    let o0 = engine.read_init("matrix2_madqn_train", "opt0").unwrap();

    let mut make_dp = |seed: u64| {
        let mut t = Trainer::new_data_parallel(
            Family::DqnFf,
            grad.clone(),
            apply.clone(),
            p0.clone(),
            o0.clone(),
            1e-3,
            0.01,
            seed,
        )
        .unwrap();
        t.init_target_from_params().unwrap();
        t
    };
    let mut dp_a = make_dp(7);
    let mut dp_b = make_dp(7);
    let mut single =
        Trainer::new(Family::DqnFf, fused, p0.clone(), o0, 1e-3, 0.01, 7)
            .unwrap();
    single.init_target_from_params().unwrap();
    assert_eq!(dp_a.num_lanes(), 2);
    assert!(dp_a.device_resident());

    let (ta, tb, ts) =
        (filled_madqn_table(5), filled_madqn_table(5), filled_madqn_table(5));
    for i in 0..10 {
        let la = dp_a.step(&ta).unwrap().unwrap();
        let lb = dp_b.step(&tb).unwrap().unwrap();
        let ls = single.step(&ts).unwrap().unwrap();
        assert_eq!(la.to_bits(), lb.to_bits(), "dp nondeterminism, step {i}");
        let denom = ls.abs().max(1e-6);
        assert!(
            ((la - ls) / denom).abs() < 1e-4,
            "dp loss diverged at step {i}: {la} vs fused {ls}"
        );
    }
    let pa = dp_a.params_synced().unwrap().to_vec();
    let pb = dp_b.params_synced().unwrap().to_vec();
    let ps = single.params_synced().unwrap().to_vec();
    assert_eq!(pa, pb, "dp lanes are not bitwise deterministic");
    assert_eq!(pa.len(), ps.len());
    for (i, (a, s)) in pa.iter().zip(&ps).enumerate() {
        let denom = s.abs().max(1e-5);
        assert!(
            ((a - s) / denom).abs() < 1e-3,
            "param {i} diverged: dp {a} vs fused {s}"
        );
    }
}

/// The full pipeline with `num_devices=2`: TrainerNode builds the
/// data-parallel trainer from the `_dp2`/`_apply` artifacts and the
/// system still learns the climbing game.
#[test]
fn num_devices_2_pipeline_learns_matrix_game() {
    if !batched_artifacts_ready("matrix2_madqn_train_dp2") {
        return;
    }
    let mut c = tiny_cfg("madqn");
    c.num_devices = 2;
    let result =
        systems::train(&c, Some(Duration::from_secs(120))).unwrap();
    assert!(result.train_steps > 100, "dp trainer starved");
    assert!(
        result.best_return().is_some_and(|b| b >= 20.0),
        "dp run did not learn: {:?}",
        result.best_return()
    );
}

/// End-to-end experiment harness: one scenario, two seeds, writes a
/// schema-valid BENCH_<scenario>.json with per-seed returns and CIs.
#[test]
fn experiment_harness_writes_schema_valid_report() {
    if !artifacts_ready() {
        return;
    }
    let mut cfg = tiny_cfg("madqn");
    cfg.max_env_steps = 1_500;
    cfg.eval_episodes = 8;
    let opts = mava::experiment::ExperimentOpts {
        seeds: 2,
        scenario: Some("matrix2_madqn".into()),
        out_dir: std::env::temp_dir().join("mava_test_experiment"),
        resamples: 200,
        seed_deadline_s: 120,
        ..Default::default()
    };
    let outcomes = mava::experiment::run(&cfg, &opts).unwrap();
    assert_eq!(outcomes.len(), 1);
    let outcome = &outcomes[0];
    assert!(outcome.skipped.is_none());
    let path = outcome.report_path.as_ref().unwrap();
    mava::bench::report::validate_file(path).unwrap();
    let json = mava::bench::report::parse(
        &std::fs::read_to_string(path).unwrap(),
    )
    .unwrap();
    let seeds = json.get("seeds").unwrap().as_arr().unwrap();
    assert_eq!(seeds.len(), 2);
    for s in seeds {
        assert_eq!(
            s.get("returns").unwrap().as_arr().unwrap().len(),
            8,
            "eval_episodes returns recorded per seed"
        );
    }
    let agg = outcome.aggregates.as_ref().unwrap();
    assert!(agg.mean_ci.lo <= agg.mean && agg.mean <= agg.mean_ci.hi);
    assert!(agg.iqm_ci.lo <= agg.iqm && agg.iqm <= agg.iqm_ci.hi);
    let _ = std::fs::remove_dir_all(&opts.out_dir);
}
