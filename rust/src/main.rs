//! `mava` CLI — the leader entrypoint.
//!
//! ```text
//! mava train       [--config FILE] [--key value ...]  run a distributed system
//! mava eval        [--config FILE] [--key value ...]  greedy evaluation only
//! mava launch      [--config FILE] [--key value ...]  multi-process run: one
//!                                                     OS process per node
//! mava node        --role R --control ADDR [...]      one node of a launch
//!                                                     (spawned by `launch`)
//! mava experiment  [--config FILE] [--key value ...]  multi-seed suite ->
//!                                                     BENCH_<scenario>.json
//! mava serve       [--param ADDR] [--key value ...]   policy inference
//!                                                     service (DESIGN.md §12)
//! mava check-bench [DIR ...]                          validate BENCH_*.json
//! mava list                                           list artifacts
//! mava info                                           runtime/platform info
//! ```

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use mava::config::{RawConfig, TrainConfig};
use mava::experiment::{self, ExperimentOpts};
use mava::launch::dist::{self, NodeOpts, Role};
use mava::net::frame::POLL_INTERVAL;
use mava::net::param::RemoteParamClient;
use mava::params::ParamStore;
use mava::runtime::{BucketLadder, Engine, Manifest};
use mava::serve::{EngineBackend, ServeService, SystemClock};
use mava::systems::{self, SystemBuilder, SystemKind, SystemSpec};

fn usage() -> ! {
    eprintln!(
        "usage: mava <train|eval|launch|node|experiment|serve|check-bench|list|info>\n\
         \x20           [--config FILE] [--key value ...]\n\
         keys: system preset arch num_executors num_envs_per_executor\n\
         \x20     num_devices max_env_steps max_train_steps lr tau n_step\n\
         \x20     eps_start eps_end eps_decay_steps noise_sigma replay_size\n\
         \x20     min_replay samples_per_insert publish_interval seed seeds\n\
         \x20     artifacts_dir log_dir eval_every_steps (alias\n\
         \x20     eval_interval) eval_episodes params_sync_every\n\
         \x20     serve_deadline_us serve_max_sessions bind_host\n\
         \x20     dist_timeout_s heartbeat_interval_ms max_restarts\n\
         \x20     checkpoint_interval\n\
         see `mava experiment --help` for the experiment harness\n\
         see `mava serve --help` for the inference service"
    );
    std::process::exit(2);
}

fn experiment_usage() {
    println!(
        "usage: mava experiment [--config FILE] [--key value ...]\n\
         \n\
         Runs S independent seeds of every suite scenario (matrix,\n\
         switch, smac_lite, MPE spread/speaker-listener, multiwalker),\n\
         evaluates each trained policy greedily, and writes one\n\
         schema-versioned BENCH_<scenario>.json per scenario with\n\
         per-seed returns, stratified bootstrap CIs and the IQM.\n\
         Scenarios whose artifacts are not lowered are skipped.\n\
         See EXPERIMENTS.md for the schema and workflow.\n\
         \n\
         harness flags:\n\
         \x20 --seeds S            seeds per scenario (default 5)\n\
         \x20 --scenario SUBSTR    only scenarios whose tag contains SUBSTR\n\
         \x20 --out-dir DIR        BENCH_*.json destination (default .)\n\
         \x20 --seed-deadline-s N  wall-clock budget per seed (default 600)\n\
         \n\
         plus every train config key, most relevantly:\n\
         \x20 --eval-episodes N    greedy episodes per seed (default 10)\n\
         \x20 --eval-interval K    evaluator period in env steps\n\
         \x20 --max_env_steps N    training budget per seed"
    );
}

fn parse_cfg(args: &[String]) -> Result<TrainConfig> {
    let mut rest = Vec::new();
    let mut cfg = TrainConfig::default();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--config" {
            let path = args.get(i + 1).context("--config requires a path")?;
            let raw = RawConfig::load(path)?;
            cfg = TrainConfig::from_raw(&raw)?;
            i += 2;
        } else {
            rest.push(args[i].clone());
            i += 1;
        }
    }
    cfg.apply_cli(&rest)?;
    Ok(cfg)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    systems::check_artifacts(&cfg)?;
    println!(
        "training {} on {} ({}, {} executors x {} envs, {} env steps)",
        cfg.system,
        cfg.preset,
        cfg.arch,
        cfg.num_executors,
        cfg.num_envs_per_executor,
        cfg.max_env_steps
    );
    let spec = SystemSpec::parse(&cfg.system)?;
    let system = SystemBuilder::new(spec, &cfg).build()?;
    println!("program graph: {}", system.node_names().join(" | "));
    let result = system.run(Some(Duration::from_secs(3600)))?;
    println!(
        "done: {} env steps, {} train steps, {} episodes in {:.1}s",
        result.env_steps, result.train_steps, result.episodes, result.wall_s
    );
    println!("train return (moving avg): {:.3}", result.train_return);
    for e in &result.evals {
        println!(
            "  eval t={:<7.1}s env_steps={:<8} train_steps={:<7} return={:.3}",
            e.wall_s, e.env_steps, e.train_steps, e.mean_return
        );
    }
    Ok(())
}

fn launch_usage() {
    println!(
        "usage: mava launch [--config FILE] [--key value ...]\n\
         \n\
         Multi-process run of the program graph (DESIGN.md §10): one\n\
         OS process per node — parameter server, one replay shard per\n\
         executor, trainer, executors, evaluator — wired over loopback\n\
         TCP (--bind_host to change). The driver discovers service\n\
         addresses through a control channel, supervises every child,\n\
         and reports failures by node name. Crashed or heartbeat-silent\n\
         workers are restarted under a per-node budget (DESIGN.md §13):\n\
         the trainer resumes from its checkpoint, executors and the\n\
         evaluator degrade to the survivors once the budget is spent,\n\
         and a dead stateful service (param server, replay shard) still\n\
         ends the run. Accepts every train config key, most relevantly:\n\
         \x20 --num_executors N    executor processes (and replay shards)\n\
         \x20 --bind_host HOST     service bind host (default 127.0.0.1)\n\
         \x20 --dist_timeout_s S   wind-down grace before a straggler\n\
         \x20                      is killed (default 60)\n\
         \x20 --heartbeat_interval_ms MS\n\
         \x20                      node liveness beacon period; silence\n\
         \x20                      for 4 intervals = wedged (default 250)\n\
         \x20 --max_restarts N     per-node respawn budget (default 2,\n\
         \x20                      0 = never restart)\n\
         \x20 --checkpoint_interval K\n\
         \x20                      trainer checkpoint every K train steps\n\
         \x20                      to {{log_dir}}/trainer.ckpt, resumed on\n\
         \x20                      trainer restart (default 0 = off)"
    );
}

fn node_usage() {
    println!(
        "usage: mava node --role ROLE --control ADDR\n\
         \x20               [--param ADDR] [--replay ADDR ...]\n\
         \x20               [--config FILE] [--key value ...]\n\
         \n\
         Runs ONE node of a distributed program (normally spawned by\n\
         `mava launch`, not by hand).\n\
         \x20 --role ROLE      param | replay:K | trainer | executor:K\n\
         \x20                  | evaluator\n\
         \x20 --control ADDR   the driver's control-server address\n\
         \x20 --param ADDR     parameter service (worker roles)\n\
         \x20 --replay ADDR    replay shard service, repeatable in\n\
         \x20                  shard order (trainer: all; executor K:\n\
         \x20                  entry K)"
    );
}

fn cmd_launch(args: &[String]) -> Result<()> {
    if args.iter().any(|a| a == "-h" || a == "--help" || a == "help") {
        launch_usage();
        return Ok(());
    }
    let cfg = parse_cfg(args)?;
    systems::check_artifacts(&cfg)?;
    println!(
        "launching {} on {} ({} executor processes x {} envs)",
        cfg.system, cfg.preset, cfg.num_executors, cfg.num_envs_per_executor
    );
    dist::launch(&cfg)
}

fn cmd_node(args: &[String]) -> Result<()> {
    if args.iter().any(|a| a == "-h" || a == "--help" || a == "help") {
        node_usage();
        return Ok(());
    }
    let mut role = None;
    let mut control = None;
    let mut param = None;
    let mut replay = Vec::new();
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--role" => {
                role = Some(Role::parse(
                    args.get(i + 1).context("--role requires a value")?,
                )?);
                i += 2;
            }
            "--control" => {
                control = Some(
                    args.get(i + 1)
                        .context("--control requires an address")?
                        .clone(),
                );
                i += 2;
            }
            "--param" => {
                param = Some(
                    args.get(i + 1)
                        .context("--param requires an address")?
                        .clone(),
                );
                i += 2;
            }
            "--replay" => {
                replay.push(
                    args.get(i + 1)
                        .context("--replay requires an address")?
                        .clone(),
                );
                i += 2;
            }
            _ => {
                rest.push(args[i].clone());
                i += 1;
            }
        }
    }
    let opts = NodeOpts {
        role: role.context("mava node requires --role")?,
        control: control.context("mava node requires --control")?,
        param,
        replay,
    };
    let cfg = parse_cfg(&rest)?;
    dist::run_node(&cfg, &opts)
}

fn cmd_eval(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    let kind = SystemKind::parse(&cfg.system)?;
    let prefix = cfg.artifact_prefix();
    let mut engine = Engine::load(&cfg.artifacts_dir)?;
    let artifact = engine.artifact(&format!("{prefix}_policy"))?;
    let params = engine.read_init(&format!("{prefix}_train"), "params0")?;
    let mut executor =
        systems::Executor::new(kind, artifact, params, cfg.seed)?;
    let mut env = systems::env_for_preset(&cfg.preset, cfg.seed, None)?;
    let summary =
        mava::eval::evaluate(&mut executor, env.as_mut(), cfg.eval_episodes)?;
    println!(
        "eval {} on {}: mean {:.3} (min {:.3}, max {:.3}) over {} episodes",
        cfg.system,
        cfg.preset,
        summary.mean_return,
        summary.min_return,
        summary.max_return,
        summary.episodes
    );
    Ok(())
}

fn cmd_experiment(args: &[String]) -> Result<()> {
    let mut opts = ExperimentOpts::default();
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "-h" | "--help" | "help" => {
                experiment_usage();
                return Ok(());
            }
            "--scenario" => {
                opts.scenario = Some(
                    args.get(i + 1)
                        .context("--scenario requires a substring")?
                        .clone(),
                );
                i += 2;
            }
            "--out-dir" | "--out_dir" => {
                opts.out_dir = PathBuf::from(
                    args.get(i + 1).context("--out-dir requires a path")?,
                );
                i += 2;
            }
            "--seed-deadline-s" | "--seed_deadline_s" => {
                opts.seed_deadline_s = args
                    .get(i + 1)
                    .context("--seed-deadline-s requires seconds")?
                    .parse()?;
                i += 2;
            }
            _ => {
                rest.push(args[i].clone());
                i += 1;
            }
        }
    }
    let cfg = parse_cfg(&rest)?;
    opts.seeds = cfg.seeds;
    systems::check_artifacts(&cfg)?;
    println!(
        "experiment: {} seed(s) per scenario, eval_episodes={}, \
         max_env_steps={} -> {}",
        opts.seeds,
        cfg.eval_episodes,
        cfg.max_env_steps,
        opts.out_dir.display()
    );
    let outcomes = experiment::run(&cfg, &opts)?;
    ensure!(
        !outcomes.is_empty(),
        "no scenario matched --scenario {:?}",
        opts.scenario
    );
    let written = outcomes.iter().filter(|o| o.report_path.is_some()).count();
    println!("\nexperiment summary ({written}/{} scenarios ran):", outcomes.len());
    for o in &outcomes {
        match (&o.aggregates, &o.skipped) {
            (Some(agg), _) => println!(
                "  {:<24} mean {:>8.3} [{:>8.3}, {:>8.3}]  IQM {:>8.3} \
                 [{:>8.3}, {:>8.3}]",
                o.scenario,
                agg.mean,
                agg.mean_ci.lo,
                agg.mean_ci.hi,
                agg.iqm,
                agg.iqm_ci.lo,
                agg.iqm_ci.hi
            ),
            (None, Some(reason)) => {
                println!("  {:<24} skipped: {reason}", o.scenario)
            }
            _ => {}
        }
    }
    ensure!(
        written > 0,
        "every scenario was skipped — lower artifacts with `make artifacts`"
    );
    Ok(())
}

fn serve_usage() {
    println!(
        "usage: mava serve [--config FILE] [--param ADDR] [--key value ...]\n\
         \n\
         Policy inference service (DESIGN.md §12). Clients open a\n\
         session (one recurrent-carry row per episode), stream\n\
         observations, and receive one greedy discrete action per\n\
         agent. Concurrent requests coalesce into the largest lowered\n\
         _b{{B}} policy bucket reachable within the batching deadline;\n\
         smaller batches flush at the deadline into the smallest\n\
         covering bucket with the padding rows masked. Binds an\n\
         ephemeral port and prints the address; runs until killed.\n\
         \n\
         \x20 --param ADDR             hot-reload checkpoints from a\n\
         \x20                          running parameter service (`mava\n\
         \x20                          launch` prints its address);\n\
         \x20                          without it the artifact's params0\n\
         \x20                          init is served, frozen\n\
         \x20 --serve_deadline_us N    batching deadline in microseconds\n\
         \x20                          (default 2000)\n\
         \x20 --serve_max_sessions N   concurrent-session cap = carry\n\
         \x20                          rows held on device (default 64)\n\
         \x20 --bind_host HOST         listener host (default 127.0.0.1)\n\
         \x20 --system NAME --preset P policy to serve (must be a\n\
         \x20                          discrete-action system)"
    );
}

fn cmd_serve(args: &[String]) -> Result<()> {
    if args.iter().any(|a| a == "-h" || a == "--help" || a == "help") {
        serve_usage();
        return Ok(());
    }
    let mut param_addr: Option<String> = None;
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--param" {
            param_addr = Some(
                args.get(i + 1)
                    .context("--param requires an address")?
                    .clone(),
            );
            i += 2;
        } else {
            rest.push(args[i].clone());
            i += 1;
        }
    }
    let cfg = parse_cfg(&rest)?;
    let kind = SystemKind::parse(&cfg.system)?;
    let prefix = cfg.artifact_prefix();
    let store: Option<Arc<dyn ParamStore>> = match &param_addr {
        Some(addr) => Some(Arc::new(RemoteParamClient::connect(
            addr,
            Duration::from_secs(5),
        )?)),
        None => None,
    };
    // The factory runs on the serve core thread: PJRT artifacts are
    // single-threaded, so the engine must be loaded where it is used.
    let artifacts_dir = cfg.artifacts_dir.clone();
    let seed = cfg.seed;
    let make_backend = move || -> Result<EngineBackend> {
        let mut engine = Engine::load(&artifacts_dir)?;
        let ladder = BucketLadder::from_manifest(
            &engine.manifest,
            &format!("{prefix}_policy"),
        )?;
        let params = engine.read_init(&format!("{prefix}_train"), "params0")?;
        EngineBackend::new(&mut engine, kind, &ladder, params, seed)
    };
    let svc = ServeService::bind(
        &cfg.bind_host,
        make_backend,
        Arc::new(SystemClock::new()),
        store,
        cfg.serve_max_sessions,
        cfg.serve_deadline_us,
    )?;
    println!(
        "serving {} ({}) on {}  deadline={}us  max_sessions={}{}",
        cfg.system,
        cfg.preset,
        svc.addr(),
        cfg.serve_deadline_us,
        cfg.serve_max_sessions,
        match &param_addr {
            Some(a) => format!("  hot-reload from {a}"),
            None => String::new(),
        }
    );
    loop {
        std::thread::sleep(POLL_INTERVAL);
    }
}

/// Collect every `BENCH_*.json` under `dir`, recursing into
/// subdirectories but skipping hidden ones and build/dependency trees
/// (`target`, `node_modules`, `__pycache__`).
fn collect_bench_files(dir: &std::path::Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("read directory {}", dir.display()))?;
    for entry in entries.flatten() {
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if path.is_dir() {
            if name.starts_with('.')
                || matches!(name, "target" | "node_modules" | "__pycache__")
            {
                continue;
            }
            collect_bench_files(&path, out)?;
        } else if name.starts_with("BENCH_") && name.ends_with(".json") {
            out.push(path);
        }
    }
    Ok(())
}

fn cmd_check_bench(args: &[String]) -> Result<()> {
    if args.iter().any(|a| a == "-h" || a == "--help" || a == "help") {
        println!(
            "usage: mava check-bench [DIR ...]\n\
             Recursively validates every BENCH_*.json under each DIR\n\
             (default: .) against the versioned schema in\n\
             rust/src/bench/report.rs (see EXPERIMENTS.md §2).\n\
             Hidden directories, target/, node_modules/ and\n\
             __pycache__/ are skipped. Exits non-zero on any invalid\n\
             report; an empty tree passes."
        );
        return Ok(());
    }
    let dirs: Vec<String> = if args.is_empty() {
        vec![".".into()]
    } else {
        args.to_vec()
    };
    let mut paths = Vec::new();
    for dir in &dirs {
        collect_bench_files(std::path::Path::new(dir), &mut paths)?;
    }
    paths.sort();
    let mut failures = 0usize;
    for path in &paths {
        match mava::bench::report::validate_file(path) {
            Ok(()) => println!("ok   {}", path.display()),
            Err(e) => {
                eprintln!("FAIL {}: {e:#}", path.display());
                failures += 1;
            }
        }
    }
    ensure!(failures == 0, "{failures} schema-invalid bench report(s)");
    if paths.is_empty() {
        println!("no BENCH_*.json files under {dirs:?} (nothing to check)");
    } else {
        println!("{} bench report(s) schema-valid", paths.len());
    }
    Ok(())
}

fn cmd_list(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let mut names: Vec<_> = manifest.artifacts.keys().collect();
    names.sort();
    println!("{} artifacts in {}:", names.len(), cfg.artifacts_dir);
    for n in names {
        let a = &manifest.artifacts[n];
        println!(
            "  {n:<42} params={:<8} inputs={} outputs={}",
            a.meta.get("params").map(String::as_str).unwrap_or("?"),
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}

fn cmd_info(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    let engine = Engine::load(&cfg.artifacts_dir)?;
    println!("platform: {}", engine.platform());
    println!("artifacts: {}", engine.manifest.artifacts.len());
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    match cmd.as_str() {
        "train" => cmd_train(&args[1..]),
        "eval" => cmd_eval(&args[1..]),
        "launch" => cmd_launch(&args[1..]),
        "node" => cmd_node(&args[1..]),
        "experiment" => cmd_experiment(&args[1..]),
        "serve" => cmd_serve(&args[1..]),
        "check-bench" | "check_bench" => cmd_check_bench(&args[1..]),
        "list" => cmd_list(&args[1..]),
        "info" => cmd_info(&args[1..]),
        "-h" | "--help" | "help" => usage(),
        other => bail!("unknown command {other:?}"),
    }
}
