//! `mava` CLI — the leader entrypoint.
//!
//! ```text
//! mava train  [--config FILE] [--key value ...]   run a distributed system
//! mava eval   [--config FILE] [--key value ...]   greedy evaluation only
//! mava list                                       list artifacts
//! mava info                                       runtime/platform info
//! ```

use std::time::Duration;

use anyhow::{bail, Context, Result};

use mava::config::{RawConfig, TrainConfig};
use mava::runtime::{Engine, Manifest};
use mava::systems::{self, SystemKind};

fn usage() -> ! {
    eprintln!(
        "usage: mava <train|eval|list|info> [--config FILE] [--key value ...]\n\
         keys: system preset arch num_executors num_envs_per_executor\n\
         \x20     max_env_steps lr tau n_step eps_start eps_end\n\
         \x20     eps_decay_steps noise_sigma replay_size min_replay\n\
         \x20     samples_per_insert publish_interval seed artifacts_dir\n\
         \x20     log_dir eval_every_steps eval_episodes params_sync_every"
    );
    std::process::exit(2);
}

fn parse_cfg(args: &[String]) -> Result<TrainConfig> {
    let mut rest = Vec::new();
    let mut cfg = TrainConfig::default();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--config" {
            let path = args.get(i + 1).context("--config requires a path")?;
            let raw = RawConfig::load(path)?;
            cfg = TrainConfig::from_raw(&raw)?;
            i += 2;
        } else {
            rest.push(args[i].clone());
            i += 1;
        }
    }
    cfg.apply_cli(&rest)?;
    Ok(cfg)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    systems::check_artifacts(&cfg)?;
    println!(
        "training {} on {} ({}, {} executors x {} envs, {} env steps)",
        cfg.system,
        cfg.preset,
        cfg.arch,
        cfg.num_executors,
        cfg.num_envs_per_executor,
        cfg.max_env_steps
    );
    let result = systems::train(&cfg, Some(Duration::from_secs(3600)))?;
    println!(
        "done: {} env steps, {} train steps, {} episodes in {:.1}s",
        result.env_steps, result.train_steps, result.episodes, result.wall_s
    );
    println!("train return (moving avg): {:.3}", result.train_return);
    for e in &result.evals {
        println!(
            "  eval t={:<7.1}s env_steps={:<8} train_steps={:<7} return={:.3}",
            e.wall_s, e.env_steps, e.train_steps, e.mean_return
        );
    }
    Ok(())
}

fn cmd_eval(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    let kind = SystemKind::parse(&cfg.system)?;
    let prefix = cfg.artifact_prefix();
    let mut engine = Engine::load(&cfg.artifacts_dir)?;
    let artifact = engine.artifact(&format!("{prefix}_policy"))?;
    let params = engine.read_init(&format!("{prefix}_train"), "params0")?;
    let mut executor =
        systems::Executor::new(kind, artifact, params, cfg.seed)?;
    let mut env = systems::env_for_preset(&cfg.preset, cfg.seed, None)?;
    let summary =
        mava::eval::evaluate(&mut executor, env.as_mut(), cfg.eval_episodes)?;
    println!(
        "eval {} on {}: mean {:.3} (min {:.3}, max {:.3}) over {} episodes",
        cfg.system,
        cfg.preset,
        summary.mean_return,
        summary.min_return,
        summary.max_return,
        summary.episodes
    );
    Ok(())
}

fn cmd_list(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let mut names: Vec<_> = manifest.artifacts.keys().collect();
    names.sort();
    println!("{} artifacts in {}:", names.len(), cfg.artifacts_dir);
    for n in names {
        let a = &manifest.artifacts[n];
        println!(
            "  {n:<42} params={:<8} inputs={} outputs={}",
            a.meta.get("params").map(String::as_str).unwrap_or("?"),
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}

fn cmd_info(args: &[String]) -> Result<()> {
    let cfg = parse_cfg(args)?;
    let engine = Engine::load(&cfg.artifacts_dir)?;
    println!("platform: {}", engine.platform());
    println!("artifacts: {}", engine.manifest.artifacts.len());
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    match cmd.as_str() {
        "train" => cmd_train(&args[1..]),
        "eval" => cmd_eval(&args[1..]),
        "list" => cmd_list(&args[1..]),
        "info" => cmd_info(&args[1..]),
        "-h" | "--help" | "help" => usage(),
        other => bail!("unknown command {other:?}"),
    }
}
