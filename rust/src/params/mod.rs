//! Versioned parameter server (Acme's variable source/client).
//!
//! The trainer pushes new flat parameter vectors; executors poll and copy
//! only when the version advanced — the paper's "actors periodically
//! synchronize their parameters with the latest version of the trainer".

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use anyhow::Result;

/// The parameter-store surface nodes program against: the in-process
/// [`ParameterServer`] and the socket-backed
/// [`crate::net::param::RemoteParamClient`] both implement it. The
/// fallible signatures exist for the remote case — the in-process
/// server never fails.
pub trait ParamStore: Send + Sync {
    /// Publish a new parameter vector; returns the new version.
    fn push(&self, params: &[f32]) -> Result<u64>;

    /// Unconditional fetch of `(version, params)`.
    fn get(&self) -> Result<(u64, Vec<f32>)>;

    /// Copy into `dst` only if the store moved past `known_version`;
    /// returns the new version if updated.
    fn sync(
        &self,
        known_version: u64,
        dst: &mut Vec<f32>,
    ) -> Result<Option<u64>>;
}

impl ParamStore for ParameterServer {
    fn push(&self, params: &[f32]) -> Result<u64> {
        ParameterServer::push(self, params);
        Ok(self.version())
    }

    fn get(&self) -> Result<(u64, Vec<f32>)> {
        Ok(ParameterServer::get(self))
    }

    fn sync(
        &self,
        known_version: u64,
        dst: &mut Vec<f32>,
    ) -> Result<Option<u64>> {
        Ok(ParameterServer::sync(self, known_version, dst))
    }
}

pub struct ParameterServer {
    version: AtomicU64,
    params: RwLock<Vec<f32>>,
}

impl ParameterServer {
    pub fn new(initial: Vec<f32>) -> Self {
        ParameterServer {
            version: AtomicU64::new(1),
            params: RwLock::new(initial),
        }
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Publish a new parameter vector (trainer side).
    pub fn push(&self, params: &[f32]) {
        {
            let mut guard = self.params.write().unwrap();
            if guard.len() == params.len() {
                guard.copy_from_slice(params);
            } else {
                *guard = params.to_vec();
            }
        }
        self.version.fetch_add(1, Ordering::AcqRel);
    }

    /// Unconditional fetch.
    pub fn get(&self) -> (u64, Vec<f32>) {
        let guard = self.params.read().unwrap();
        (self.version(), guard.clone())
    }

    /// Copy into `dst` only if the server moved past `known_version`;
    /// returns the new version if updated (executor-side cheap poll).
    pub fn sync(&self, known_version: u64, dst: &mut Vec<f32>) -> Option<u64> {
        let v = self.version();
        if v == known_version {
            return None;
        }
        let guard = self.params.read().unwrap();
        dst.clear();
        dst.extend_from_slice(&guard);
        Some(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_bumps_version() {
        let s = ParameterServer::new(vec![0.0; 4]);
        assert_eq!(s.version(), 1);
        s.push(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.version(), 2);
        assert_eq!(s.get().1, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn sync_skips_when_current() {
        let s = ParameterServer::new(vec![0.5; 2]);
        let mut local = vec![];
        let v = s.sync(0, &mut local).unwrap();
        assert_eq!(v, 1);
        assert_eq!(local, vec![0.5; 2]);
        assert!(s.sync(v, &mut local).is_none());
        s.push(&[1.5, 1.5]);
        assert_eq!(s.sync(v, &mut local), Some(2));
        assert_eq!(local, vec![1.5; 2]);
    }

    #[test]
    fn concurrent_push_and_sync() {
        let s = Arc::new(ParameterServer::new(vec![0.0; 128]));
        let writer = {
            let s = s.clone();
            std::thread::spawn(move || {
                for i in 1..200u32 {
                    s.push(&[i as f32; 128]);
                }
            })
        };
        let mut local = vec![];
        let mut v = 0;
        for _ in 0..500 {
            if let Some(nv) = s.sync(v, &mut local) {
                v = nv;
                // vector must be internally consistent (no torn writes)
                assert!(local.windows(2).all(|w| w[0] == w[1]));
            }
        }
        writer.join().unwrap();
    }
}
