//! Bucketed batch selection: map ANY requested env/eval/serve width to
//! the nearest lowered `_b{B}` policy variant (DESIGN.md §11).
//!
//! AOT compilation freezes shapes, so the Python catalogue lowers a
//! *ladder* of policy batch widths (`POLICY_BATCHES` in
//! python/compile/model.py) rather than every width. [`BucketLadder`]
//! scans the manifest for the variants that actually exist for one
//! policy and [`BucketLadder::pick`] rounds a requested width `n` up to
//! the smallest lowered bucket `B >= n`. The `B - n` padding rows are
//! *masked* by the callers — [`crate::systems::VecExecutor`] selects
//! actions only for active rows, [`crate::env::VecEnv`] fills only real
//! rows, and [`crate::eval::EpisodeAccountant`] accounts only real
//! rows — so padding can never leak into actions, replay inserts or
//! episode returns.

#![warn(missing_docs)]

use anyhow::{bail, Result};

use crate::runtime::Manifest;

/// The lowered policy-batch ladder for ONE policy artifact, scanned
/// from the manifest (so error messages and selection always reflect
/// what `make artifacts` actually produced, never a stale literal).
#[derive(Clone, Debug)]
pub struct BucketLadder {
    base: String,
    buckets: Vec<usize>, // sorted ascending; 1 = the base `*_policy`
}

impl BucketLadder {
    /// Scan `manifest` for `base_policy` (the plain `*_policy` name =
    /// the B=1 bucket) and every `{base_policy}_b{B}` variant.
    pub fn from_manifest(manifest: &Manifest, base_policy: &str) -> Result<BucketLadder> {
        let mut buckets = Vec::new();
        if manifest.artifacts.contains_key(base_policy) {
            buckets.push(1);
        }
        let prefix = format!("{base_policy}_b");
        for name in manifest.artifacts.keys() {
            if let Some(b) = name
                .strip_prefix(&prefix)
                .and_then(|rest| rest.parse::<usize>().ok())
            {
                if b > 1 {
                    buckets.push(b);
                }
            }
        }
        buckets.sort_unstable();
        buckets.dedup();
        if buckets.is_empty() {
            bail!(
                "no lowered policy variants for {base_policy:?} in the \
                 manifest — re-run `make artifacts`"
            );
        }
        Ok(BucketLadder { base: base_policy.to_string(), buckets })
    }

    /// The lowered bucket widths, ascending (1 = the base policy).
    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Largest lowered bucket.
    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().expect("ladder is never empty")
    }

    /// Round `n` requested rows up to the smallest lowered bucket:
    /// `(bucket, pad_rows)` with `bucket - pad_rows == n`. Errors on
    /// `n == 0` and on `n > max`, listing the actually-lowered ladder.
    pub fn pick(&self, n: usize) -> Result<(usize, usize)> {
        if n == 0 {
            bail!(
                "cannot pick a policy bucket for 0 rows ({} ladder: {})",
                self.base,
                self.describe()
            );
        }
        match self.buckets.iter().find(|&&b| b >= n) {
            Some(&b) => Ok((b, b - n)),
            None => bail!(
                "{n} rows exceed the largest lowered policy batch for {} \
                 (lowered ladder: {}); extend POLICY_BATCHES in \
                 python/compile/model.py and re-run `make artifacts`",
                self.base,
                self.describe()
            ),
        }
    }

    /// Artifact name of a bucket: the base policy for `b <= 1`, the
    /// `_b{B}` variant otherwise (the naming scheme
    /// [`crate::systems::SystemSpec::batched_policy_artifact`] owns).
    pub fn artifact_name(&self, bucket: usize) -> String {
        if bucket <= 1 {
            self.base.clone()
        } else {
            format!("{}_b{bucket}", self.base)
        }
    }

    /// The ladder as a human-readable list for error messages,
    /// e.g. `"1, 2, 4, 8, 16, 32, 64"`.
    pub fn describe(&self) -> String {
        self.buckets
            .iter()
            .map(|b| b.to_string())
            .collect::<Vec<_>>()
            .join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest(names: &[&str]) -> Manifest {
        let text: String = names
            .iter()
            .map(|n| format!("artifact {n}\nfile {n}.hlo.txt\nend\n"))
            .collect();
        Manifest::parse(&text, PathBuf::from("/tmp")).unwrap()
    }

    fn ladder() -> BucketLadder {
        let m = manifest(&[
            "p_policy",
            "p_policy_b2",
            "p_policy_b8",
            "p_policy_b64",
            "p_policy_bogus", // non-numeric suffix ignored
            "q_policy_b4",    // different policy ignored
        ]);
        BucketLadder::from_manifest(&m, "p_policy").unwrap()
    }

    #[test]
    fn scans_only_this_policys_numeric_variants() {
        let l = ladder();
        assert_eq!(l.buckets(), &[1, 2, 8, 64]);
        assert_eq!(l.max_bucket(), 64);
        assert_eq!(l.describe(), "1, 2, 8, 64");
        assert_eq!(l.artifact_name(1), "p_policy");
        assert_eq!(l.artifact_name(8), "p_policy_b8");
    }

    #[test]
    fn pick_rounds_up_with_padding() {
        let l = ladder();
        assert_eq!(l.pick(1).unwrap(), (1, 0));
        assert_eq!(l.pick(2).unwrap(), (2, 0));
        assert_eq!(l.pick(3).unwrap(), (8, 5));
        assert_eq!(l.pick(8).unwrap(), (8, 0));
        assert_eq!(l.pick(9).unwrap(), (64, 55));
    }

    #[test]
    fn pick_edge_cases() {
        let l = ladder();
        // n = 0 is a caller bug, named as such
        let err = l.pick(0).unwrap_err().to_string();
        assert!(err.contains("0 rows"), "{err}");
        // n = max is exact
        assert_eq!(l.pick(64).unwrap(), (64, 0));
        // n > max errors listing the real ladder + the fix
        let err = l.pick(65).unwrap_err().to_string();
        assert!(err.contains("1, 2, 8, 64"), "{err}");
        assert!(err.contains("POLICY_BATCHES"), "{err}");
    }

    #[test]
    fn missing_policy_is_an_error() {
        let m = manifest(&["other_policy"]);
        let err = BucketLadder::from_manifest(&m, "p_policy")
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn base_policy_alone_gives_b1_ladder() {
        let m = manifest(&["p_policy"]);
        let l = BucketLadder::from_manifest(&m, "p_policy").unwrap();
        assert_eq!(l.buckets(), &[1]);
        assert_eq!(l.pick(1).unwrap(), (1, 0));
        assert!(l.pick(2).is_err());
    }
}
