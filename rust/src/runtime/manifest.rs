//! Parser for `artifacts/manifest.txt` — the line-based artifact index
//! emitted by `python/compile/aot.py` (no serde offline, hence no JSON).
//!
//! Format, one block per artifact:
//! ```text
//! artifact <name>
//! file <name>.hlo.txt
//! input <name> <f32|i32> [dim ...]     # no dims = scalar
//! output <name> <f32|i32> [dim ...]
//! meta <key> <value>
//! init <name> <file>.f32bin <len>
//! end
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::core::Dtype;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct InitSpec {
    pub name: String,
    pub file: String,
    pub len: usize,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: HashMap<String, String>,
    pub inits: Vec<InitSpec>,
}

impl ArtifactSpec {
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .with_context(|| format!("{}: missing meta {key}", self.name))?
            .parse()
            .with_context(|| format!("{}: bad meta {key}", self.name))
    }

    pub fn meta_f32(&self, key: &str) -> Result<f32> {
        self.meta
            .get(key)
            .with_context(|| format!("{}: missing meta {key}", self.name))?
            .parse()
            .with_context(|| format!("{}: bad meta {key}", self.name))
    }

    pub fn input(&self, name: &str) -> Option<&TensorSpec> {
        self.inputs.iter().find(|t| t.name == name)
    }
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut m = Manifest { dir, artifacts: HashMap::new() };
        let mut cur: Option<ArtifactSpec> = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let tag = it.next().unwrap();
            let rest: Vec<&str> = it.collect();
            let err = || format!("manifest line {}: {line:?}", lineno + 1);
            match tag {
                "artifact" => {
                    if cur.is_some() {
                        bail!("{}: unterminated artifact block", err());
                    }
                    cur = Some(ArtifactSpec {
                        name: rest.first().with_context(err)?.to_string(),
                        file: String::new(),
                        inputs: vec![],
                        outputs: vec![],
                        meta: HashMap::new(),
                        inits: vec![],
                    });
                }
                "file" => {
                    cur.as_mut().with_context(err)?.file =
                        rest.first().with_context(err)?.to_string();
                }
                "input" | "output" => {
                    let spec = TensorSpec {
                        name: rest.first().with_context(err)?.to_string(),
                        dtype: Dtype::parse(rest.get(1).with_context(err)?)?,
                        dims: rest[2..]
                            .iter()
                            .map(|d| d.parse().with_context(err))
                            .collect::<Result<_>>()?,
                    };
                    let art = cur.as_mut().with_context(err)?;
                    if tag == "input" {
                        art.inputs.push(spec);
                    } else {
                        art.outputs.push(spec);
                    }
                }
                "meta" => {
                    let art = cur.as_mut().with_context(err)?;
                    art.meta.insert(
                        rest.first().with_context(err)?.to_string(),
                        rest[1..].join(" "),
                    );
                }
                "init" => {
                    let art = cur.as_mut().with_context(err)?;
                    art.inits.push(InitSpec {
                        name: rest.first().with_context(err)?.to_string(),
                        file: rest.get(1).with_context(err)?.to_string(),
                        len: rest.get(2).with_context(err)?.parse()?,
                    });
                }
                "end" => {
                    let art = cur.take().with_context(err)?;
                    m.artifacts.insert(art.name.clone(), art);
                }
                other => bail!("{}: unknown tag {other:?}", err()),
            }
        }
        if cur.is_some() {
            bail!("manifest ended mid-artifact");
        }
        Ok(m)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Read an init blob (raw little-endian f32) belonging to `spec`.
    pub fn read_init(&self, spec: &ArtifactSpec, name: &str) -> Result<Vec<f32>> {
        let init = spec
            .inits
            .iter()
            .find(|i| i.name == name)
            .with_context(|| format!("{}: no init {name:?}", spec.name))?;
        let bytes = std::fs::read(self.dir.join(&init.file))?;
        if bytes.len() != init.len * 4 {
            bail!(
                "{}: init {} has {} bytes, expected {}",
                spec.name,
                init.file,
                bytes.len(),
                init.len * 4
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact t_policy
file t_policy.hlo.txt
input params f32 100
input obs f32 1 2 4
input lr f32
output q f32 1 2 3
meta n_agents 2
meta gamma 0.99
init params0 t_params0.f32bin 100
end
artifact t_train
file t_train.hlo.txt
input params f32 100
output params f32 100
end
";

    #[test]
    fn parses_two_blocks() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let p = m.get("t_policy").unwrap();
        assert_eq!(p.file, "t_policy.hlo.txt");
        assert_eq!(p.inputs.len(), 3);
        assert_eq!(p.inputs[1].dims, vec![1, 2, 4]);
        assert_eq!(p.inputs[1].numel(), 8);
        assert!(p.inputs[2].dims.is_empty(), "scalar input");
        assert_eq!(p.meta_usize("n_agents").unwrap(), 2);
        assert!((p.meta_f32("gamma").unwrap() - 0.99).abs() < 1e-6);
        assert_eq!(p.inits[0].len, 100);
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("input x f32 1\n", "/tmp".into()).is_err());
        assert!(
            Manifest::parse("artifact a\nartifact b\n", "/tmp".into()).is_err()
        );
        assert!(Manifest::parse("artifact a\n", "/tmp".into()).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // exercised against the actual AOT output when present
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.artifacts.contains_key("matrix2_madqn_policy"));
            let t = m.get("matrix2_madqn_train").unwrap();
            assert_eq!(t.inits.len(), 2);
            let p0 = m.read_init(t, "params0").unwrap();
            assert_eq!(p0.len(), t.meta_usize("params").unwrap());
        }
    }
}
