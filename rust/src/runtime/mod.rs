//! PJRT runtime: loads AOT artifacts (`artifacts/*.hlo.txt`) and executes
//! them from the rust hot path.
//!
//! One [`Engine`] per node/thread (the engine-per-thread rule,
//! DESIGN.md §2): the `xla` crate's `PjRtClient` is `Rc`-based (not
//! `Send`), so every launch-graph node constructs its own engine on its
//! own thread — which also mirrors a real deployment where each worker
//! process owns a runtime instance. Artifacts are HLO *text* (see
//! python/compile/aot.py for why not serialized protos).

mod bucket;
mod engine;
mod manifest;

pub use bucket::BucketLadder;
pub use engine::{Arg, Artifact, Engine};
pub use manifest::{ArtifactSpec, InitSpec, Manifest, TensorSpec};
