//! The PJRT engine: compile HLO-text artifacts once, execute repeatedly.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::{bail, Context, Result};

use crate::core::{Dtype, HostTensor};
use crate::runtime::manifest::{ArtifactSpec, Manifest};

/// A compiled artifact: PJRT executable + its manifest spec.
pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// One-shot latch for the [`Artifact::call_device`] tuple-output
    /// fallback warning: a degraded runtime logs once PER ARTIFACT (so
    /// every affected hot path is named), never once per step.
    untuple_warned: AtomicBool,
}

/// Argument to [`Artifact::call_mixed`] / [`Artifact::call_device`]:
/// host tensor (uploaded per call) or an already-resident device buffer
/// (e.g. cached executor parameters, the trainer's state buffers).
pub enum Arg<'a> {
    Host(&'a HostTensor),
    Dev(&'a xla::PjRtBuffer),
}

impl Artifact {
    /// Upload a host tensor once and keep it on device — used by
    /// executors to cache the (rarely changing) parameter vector so the
    /// acting hot path skips a ~P*4-byte upload per environment step,
    /// and by the trainer to seed its device-resident
    /// `(params, target, opt)` state (DESIGN.md §8).
    pub fn upload(&self, t: &HostTensor, dims: &[usize]) -> Result<xla::PjRtBuffer> {
        let client = self.exe.client();
        let buf = match t.dtype {
            Dtype::F32 => {
                client.buffer_from_host_buffer(t.as_f32(), dims, None)
            }
            Dtype::I32 => {
                client.buffer_from_host_buffer(t.as_i32(), dims, None)
            }
        };
        buf.map_err(|e| anyhow::anyhow!("upload: {e:?}"))
    }

    /// Run the executable over mixed args; returns the raw per-device
    /// output buffers (device 0) without fetching anything to the host.
    fn execute_mixed(&self, inputs: &[Arg]) -> Result<Vec<xla::PjRtBuffer>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, expected {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        // two passes: upload host args first (owned), then collect refs
        for (arg, spec) in inputs.iter().zip(&self.spec.inputs) {
            if let Arg::Host(t) = arg {
                owned.push(self.upload(t, &spec.dims)?);
            }
        }
        let mut owned_it = owned.iter();
        for arg in inputs {
            match arg {
                Arg::Host(_) => refs.push(owned_it.next().unwrap()),
                Arg::Dev(b) => refs.push(b),
            }
        }
        let mut bufs = self
            .exe
            .execute_b(&refs)
            .map_err(|e| anyhow::anyhow!("{}: execute_b: {e:?}", self.spec.name))?;
        if bufs.is_empty() {
            bail!("{}: execute_b returned no device results", self.spec.name);
        }
        Ok(bufs.swap_remove(0))
    }

    /// Execute with a mix of device-resident and host arguments,
    /// fetching every output to the host.
    pub fn call_mixed(&self, inputs: &[Arg]) -> Result<Vec<HostTensor>> {
        let outs = self.execute_mixed(inputs)?;
        // untupled layout: one buffer per declared output
        if outs.len() == self.spec.outputs.len() && outs.len() != 1 {
            return outs
                .iter()
                .enumerate()
                .map(|(i, b)| self.to_host(b, i))
                .collect();
        }
        // single root-tuple buffer
        let result = outs[0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{}: fetch: {e:?}", self.spec.name))?;
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!("{}: output arity mismatch", self.spec.name);
        }
        parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| from_literal(&lit, spec.dtype, spec.dims.clone()))
            .collect()
    }

    /// Execute with device outputs: returns one `PjRtBuffer` per
    /// declared output, in spec order, WITHOUT a host round-trip — so a
    /// caller can feed step `k`'s outputs straight back as `Arg::Dev`
    /// inputs of step `k+1` (the trainer's device-resident state loop,
    /// DESIGN.md §8). Fetch individual outputs with
    /// [`Artifact::to_host`] when a host view is actually needed
    /// (publish ticks, checkpoints, the loss scalar).
    ///
    /// PJRT untuples the root tuple into per-output buffers. If the
    /// runtime instead hands back a single tuple buffer, this degrades
    /// to a host untuple + re-upload (correct, but it pays the
    /// round-trip this path exists to avoid) and warns once.
    ///
    /// Caveat: for an artifact declaring exactly ONE output the two
    /// layouts are indistinguishable here (one buffer either way), so
    /// a degraded runtime's 1-tuple buffer would be returned as-is.
    /// Callers feeding buffers back (the trainer) require >= 4 outputs,
    /// so this ambiguity is unreachable on the state loop.
    pub fn call_device(&self, inputs: &[Arg]) -> Result<Vec<xla::PjRtBuffer>> {
        let outs = self.execute_mixed(inputs)?;
        if outs.len() == self.spec.outputs.len() {
            return Ok(outs);
        }
        if outs.len() != 1 {
            bail!(
                "{}: got {} output buffers, expected {} (or 1 tuple)",
                self.spec.name,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        if !self.untuple_warned.swap(true, Ordering::Relaxed) {
            eprintln!(
                "[runtime] WARNING: {}: PJRT returned a tuple buffer \
                 instead of per-output buffers; device-resident callers \
                 fall back to a host round-trip per step",
                self.spec.name
            );
        }
        let result = outs[0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{}: fetch: {e:?}", self.spec.name))?;
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!("{}: output arity mismatch", self.spec.name);
        }
        parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| {
                let t = from_literal(&lit, spec.dtype, spec.dims.clone())?;
                self.upload(&t, &spec.dims)
            })
            .collect()
    }

    /// Fetch one [`Artifact::call_device`] output buffer to the host,
    /// typed/shaped by declared output `out_index`.
    pub fn to_host(
        &self,
        buf: &xla::PjRtBuffer,
        out_index: usize,
    ) -> Result<HostTensor> {
        let spec = self.spec.outputs.get(out_index).with_context(|| {
            format!(
                "{}: no output {out_index} (have {})",
                self.spec.name,
                self.spec.outputs.len()
            )
        })?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{}: fetch: {e:?}", self.spec.name))?;
        from_literal(&lit, spec.dtype, spec.dims.clone())
    }
    /// Execute with type/shape-checked host tensors.
    pub fn call(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, expected {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            if t.dtype != spec.dtype || t.len() != spec.numel() {
                bail!(
                    "{}: input {} mismatch (got {:?} x{}, want {:?} {:?})",
                    self.spec.name,
                    spec.name,
                    t.dtype,
                    t.len(),
                    spec.dtype,
                    spec.dims
                );
            }
            literals.push(to_literal(t, &spec.dims)?);
        }
        let bufs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("{}: execute failed", self.spec.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("{}: result fetch", self.spec.name))?;
        // lowered with return_tuple=True -> always a tuple
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, expected {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| from_literal(&lit, spec.dtype, spec.dims.clone()))
            .collect()
    }
}

fn to_literal(t: &HostTensor, dims: &[usize]) -> Result<xla::Literal> {
    // single-copy path: bytes straight into a shaped literal (the naive
    // vec1 + reshape round-trip costs a second copy — see §Perf)
    let (ty, bytes): (xla::ElementType, &[u8]) = match t.dtype {
        Dtype::F32 => {
            if dims.is_empty() {
                return Ok(xla::Literal::scalar(t.as_f32()[0]));
            }
            let d = t.as_f32();
            (xla::ElementType::F32, unsafe {
                std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len() * 4)
            })
        }
        Dtype::I32 => {
            if dims.is_empty() {
                return Ok(xla::Literal::scalar(t.as_i32()[0]));
            }
            let d = t.as_i32();
            (xla::ElementType::S32, unsafe {
                std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len() * 4)
            })
        }
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(ty, dims, bytes)?)
}

fn from_literal(
    lit: &xla::Literal,
    dtype: Dtype,
    dims: Vec<usize>,
) -> Result<HostTensor> {
    Ok(match dtype {
        Dtype::F32 => HostTensor::f32(dims, lit.to_vec::<f32>()?),
        Dtype::I32 => HostTensor::i32(dims, lit.to_vec::<i32>()?),
    })
}

/// A per-thread PJRT CPU client plus its compiled artifact cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, std::rc::Rc<Artifact>>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn load(artifacts_dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Engine { client, manifest, cache: HashMap::new() })
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn artifact(&mut self, name: &str) -> Result<std::rc::Rc<Artifact>> {
        if let Some(a) = self.cache.get(name) {
            return Ok(a.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("{name}: parse HLO: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("{name}: compile: {e:?}"))?;
        let art = std::rc::Rc::new(Artifact {
            spec,
            exe,
            untuple_warned: AtomicBool::new(false),
        });
        self.cache.insert(name.to_string(), art.clone());
        Ok(art)
    }

    /// Read an init vector declared by artifact `name`.
    pub fn read_init(&self, name: &str, init: &str) -> Result<Vec<f32>> {
        let spec = self.manifest.get(name)?;
        self.manifest.read_init(spec, init)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of PJRT devices this client enumerates. The CPU client
    /// reports 1; the trainer's data-parallel lanes (DESIGN.md §11) use
    /// this to report whether `num_devices` lanes map onto physical
    /// devices or time-share one (logical lanes — the xla crate pins
    /// execution to device 0, so lanes are a placement-ready structure,
    /// not yet a physical spread).
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// End-to-end: load the tiny matrix2 artifacts, run policy + train.
    /// Requires `make artifacts` to have run (skipped otherwise).
    #[test]
    fn matrix2_policy_and_train_roundtrip() {
        let Ok(mut engine) = Engine::load("artifacts") else {
            eprintln!("artifacts/ missing; skipping");
            return;
        };
        let policy = engine.artifact("matrix2_madqn_policy").unwrap();
        let p = engine.read_init("matrix2_madqn_train", "params0").unwrap();
        let n_params = p.len();
        let params = HostTensor::f32(vec![n_params], p);
        let obs = HostTensor::f32(vec![1, 2, 4], vec![0.1; 8]);
        let out = policy.call(&[&params, &obs]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dims, vec![1, 2, 3]);
        assert!(out[0].as_f32().iter().all(|x| x.is_finite()));

        // one train step reduces nothing yet but must run and mutate params
        let train = engine.artifact("matrix2_madqn_train").unwrap();
        let opt = HostTensor::f32(
            vec![1 + 2 * n_params],
            engine.read_init("matrix2_madqn_train", "opt0").unwrap(),
        );
        let b = 16;
        let batch_obs = HostTensor::f32(vec![b, 2, 4], vec![0.2; b * 8]);
        let act = HostTensor::i32(vec![b, 2], vec![1; b * 2]);
        let rew = HostTensor::f32(vec![b, 2], vec![1.0; b * 2]);
        let disc = HostTensor::f32(vec![b], vec![1.0; b]);
        let next_obs = HostTensor::f32(vec![b, 2, 4], vec![0.3; b * 8]);
        let lr = HostTensor::scalar_f32(1e-3);
        let tau = HostTensor::scalar_f32(0.01);
        let target = params.clone();
        let out = train
            .call(&[
                &params, &target, &opt, &batch_obs, &act, &rew, &disc,
                &next_obs, &lr, &tau,
            ])
            .unwrap();
        assert_eq!(out.len(), 4);
        let new_params = out[0].as_f32();
        assert_ne!(new_params, params.as_f32(), "params must move");
        let loss = out[3].as_f32()[0];
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Ok(mut engine) = Engine::load("artifacts") else {
            return;
        };
        let policy = engine.artifact("matrix2_madqn_policy").unwrap();
        let bad = HostTensor::f32(vec![3], vec![0.0; 3]);
        let obs = HostTensor::f32(vec![1, 2, 4], vec![0.0; 8]);
        assert!(policy.call(&[&bad, &obs]).is_err());
        assert!(policy.call(&[&obs]).is_err());
    }
}
