//! Multi-seed experiment harness: the statistically-robust layer over
//! the System API ([`crate::systems::SystemBuilder`]; EXPERIMENTS.md).
//!
//! The paper's promise is not raw steps/s but *experiment throughput* —
//! enough independent samples per claim to make it sound. This module
//! turns one [`TrainConfig`] into S independent seeds per scenario of
//! the environment suite ([`SUITE`]: matrix, switch, SMAC-lite, MPE
//! spread / speaker-listener, multiwalker), evaluates each trained
//! policy greedily through the vectorized evaluator
//! ([`crate::eval::VecEvaluator`]), aggregates episode returns with
//! per-seed means, stratified bootstrap confidence intervals and the
//! inter-quartile mean ([`crate::eval::stats`]), and serialises every
//! scenario as a schema-versioned `BENCH_<scenario>.json`
//! ([`mod@crate::bench::report`]).
//!
//! Seeds run sequentially on purpose: each built system already
//! saturates the machine with its own executor/trainer program graph,
//! and sequential runs keep per-seed wall-clock (and therefore the
//! steps/s recorded per seed) comparable.
//!
//! Driven by `mava experiment --seeds S [--scenario SUBSTR]
//! [--eval-episodes N] [--eval-interval K]`; scenarios whose artifacts
//! are not lowered are skipped with a note, never failed, so one `make
//! artifacts` preset subset still produces a valid (partial) result
//! set.

#![warn(missing_docs)]

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::bench::report::{self, SeedRecord};
use crate::config::TrainConfig;
use crate::eval::stats::{self, Aggregates};
use crate::runtime::{Engine, Manifest};
use crate::systems::{self, SystemBuilder, SystemSpec};

/// One (environment, system) cell of the experiment grid.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    /// Stable tag used for filtering and the `BENCH_<name>.json` file.
    pub name: &'static str,
    /// Artifact preset (DESIGN.md §4).
    pub preset: &'static str,
    /// System to train (`TrainConfig::system`).
    pub system: &'static str,
}

/// The default experiment suite: every environment of the paper's
/// evaluation set, paired with the system(s) the paper runs on it
/// (README "Systems" table).
pub const SUITE: &[Scenario] = &[
    Scenario { name: "matrix2_madqn", preset: "matrix2", system: "madqn" },
    Scenario { name: "matrix2_vdn", preset: "matrix2", system: "vdn" },
    Scenario {
        name: "switch3_madqn_rec",
        preset: "switch3",
        system: "madqn_rec",
    },
    Scenario { name: "switch3_dial", preset: "switch3", system: "dial" },
    Scenario { name: "smac3m_vdn", preset: "smac3m", system: "vdn" },
    Scenario { name: "smac3m_qmix", preset: "smac3m", system: "qmix" },
    Scenario {
        name: "spread3_maddpg",
        preset: "spread3",
        system: "maddpg",
    },
    Scenario {
        name: "speaker2_maddpg",
        preset: "speaker2",
        system: "maddpg",
    },
    Scenario {
        name: "walker3_mad4pg",
        preset: "walker3",
        system: "mad4pg",
    },
];

/// Harness options beyond the per-run [`TrainConfig`].
#[derive(Clone, Debug)]
pub struct ExperimentOpts {
    /// Independent training seeds per scenario (strata of the
    /// bootstrap).
    pub seeds: usize,
    /// Run only scenarios whose name contains this substring.
    pub scenario: Option<String>,
    /// Directory the `BENCH_<scenario>.json` files are written to.
    pub out_dir: PathBuf,
    /// Confidence level of the bootstrap intervals.
    pub confidence: f64,
    /// Bootstrap replicates per interval.
    pub resamples: usize,
    /// Wall-clock budget per seed run, seconds.
    pub seed_deadline_s: u64,
}

impl Default for ExperimentOpts {
    fn default() -> Self {
        ExperimentOpts {
            seeds: 5,
            scenario: None,
            out_dir: PathBuf::from("."),
            confidence: 0.95,
            resamples: 1_000,
            seed_deadline_s: 600,
        }
    }
}

/// What happened to one scenario of a harness run.
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    /// The scenario's file tag (includes the architecture for
    /// actor-critic systems, e.g. `walker3_mad4pg_dec`).
    pub scenario: String,
    /// Path of the written `BENCH_*.json` (None when skipped).
    pub report_path: Option<PathBuf>,
    /// Aggregates over the per-seed evaluation returns (None when
    /// skipped).
    pub aggregates: Option<Aggregates>,
    /// Why the scenario was skipped, if it was.
    pub skipped: Option<String>,
}

/// Run the experiment grid: S seeds of `base` (with each scenario's
/// preset/system substituted) for every suite entry matching
/// `opts.scenario`, writing one `BENCH_<scenario>.json` per completed
/// scenario and returning every outcome in suite order.
pub fn run(
    base: &TrainConfig,
    opts: &ExperimentOpts,
) -> Result<Vec<ScenarioOutcome>> {
    ensure!(opts.seeds >= 1, "need at least one seed");
    ensure!(
        base.eval_episodes >= 1,
        "need at least one evaluation episode per seed \
         (--eval-episodes)"
    );
    let mut outcomes = Vec::new();
    for sc in SUITE {
        let mut cfg = base.clone();
        cfg.preset = sc.preset.into();
        cfg.system = sc.system.into();
        // the file tag; carries the arch for actor-critic systems
        // (e.g. walker3_mad4pg_dec)
        let tag = cfg.artifact_prefix();
        if let Some(f) = &opts.scenario {
            // match the suite name OR the printed/emitted tag, so a tag
            // copied from a previous run's output always round-trips
            if !sc.name.contains(f.as_str()) && !tag.contains(f.as_str()) {
                continue;
            }
        }
        // skip-not-fail on missing artifacts: partial artifact dirs
        // still yield a valid (partial) result set
        if let Some(reason) = missing_artifacts(&cfg) {
            println!("experiment {tag}: skipped ({reason})");
            outcomes.push(ScenarioOutcome {
                scenario: tag,
                report_path: None,
                aggregates: None,
                skipped: Some(reason),
            });
            continue;
        }
        outcomes.push(run_scenario(&cfg, &tag, opts).with_context(|| {
            format!("experiment scenario {tag}")
        })?);
    }
    Ok(outcomes)
}

/// None when the scenario's train + policy artifacts are lowered,
/// otherwise a human-readable skip reason.
fn missing_artifacts(cfg: &TrainConfig) -> Option<String> {
    let manifest = match Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => m,
        Err(_) => {
            return Some(format!(
                "no artifact manifest in {:?}; run `make artifacts`",
                cfg.artifacts_dir
            ))
        }
    };
    let prefix = cfg.artifact_prefix();
    for name in [format!("{prefix}_train"), format!("{prefix}_policy")] {
        if manifest.get(&name).is_err() {
            return Some(format!("artifact {name:?} not lowered"));
        }
    }
    None
}

fn run_scenario(
    cfg: &TrainConfig,
    tag: &str,
    opts: &ExperimentOpts,
) -> Result<ScenarioOutcome> {
    let spec = SystemSpec::parse(&cfg.system)?;
    let mut records = Vec::with_capacity(opts.seeds);
    for s in 0..opts.seeds {
        let mut seed_cfg = cfg.clone();
        // well-separated seed streams: executors/trainer already derive
        // their own sub-seeds from cfg.seed, so stride generously
        seed_cfg.seed = cfg.seed + 1_000 * s as u64;
        // each seed is one built system; a node failure (trainer,
        // executor or evaluator) aborts the scenario naming the node
        let result = SystemBuilder::new(spec, &seed_cfg)
            .build()?
            .run(Some(Duration::from_secs(opts.seed_deadline_s)))
            .with_context(|| format!("seed {} (index {s})", seed_cfg.seed))?;
        let returns = final_policy_returns(
            &seed_cfg,
            &result.final_params,
            seed_cfg.eval_episodes,
            seed_cfg.seed ^ 0xf17a1,
        )?;
        println!(
            "experiment {tag} seed {} ({}/{}): {} env steps, {} train \
             steps, final eval mean {:.3} over {} episodes",
            seed_cfg.seed,
            s + 1,
            opts.seeds,
            result.env_steps,
            result.train_steps,
            stats::mean(&returns),
            returns.len()
        );
        records.push(SeedRecord {
            seed: seed_cfg.seed,
            returns,
            env_steps: result.env_steps,
            train_steps: result.train_steps,
            wall_s: result.wall_s,
        });
    }
    let per_seed: Vec<Vec<f32>> =
        records.iter().map(|r| r.returns.clone()).collect();
    let agg = stats::aggregate(
        &per_seed,
        opts.confidence,
        opts.resamples,
        cfg.seed ^ 0xb007,
    );
    let json = report::experiment_report(
        tag,
        &cfg.system,
        &cfg.preset,
        cfg.eval_episodes,
        cfg.max_env_steps,
        &records,
        &agg,
    );
    let path = report::write_report(&opts.out_dir, tag, &json)?;
    println!(
        "experiment {tag}: mean {:.3} [{:.3}, {:.3}], IQM {:.3} \
         [{:.3}, {:.3}] -> {}",
        agg.mean,
        agg.mean_ci.lo,
        agg.mean_ci.hi,
        agg.iqm,
        agg.iqm_ci.lo,
        agg.iqm_ci.hi,
        path.display()
    );
    Ok(ScenarioOutcome {
        scenario: tag.to_string(),
        report_path: Some(path),
        aggregates: Some(agg),
        skipped: None,
    })
}

/// Greedy evaluation-episode returns of a parameter vector under
/// `cfg`'s preset/system — the exact vectorized pipeline the in-run
/// evaluator node uses ([`systems::make_vec_evaluator`]), so harness
/// numbers and learning-curve points are directly comparable.
pub fn final_policy_returns(
    cfg: &TrainConfig,
    params: &[f32],
    episodes: usize,
    seed: u64,
) -> Result<Vec<f32>> {
    let mut engine = Engine::load(&cfg.artifacts_dir)?;
    let mut evaluator = systems::make_vec_evaluator(
        &mut engine,
        cfg,
        params.to_vec(),
        episodes,
        seed,
    )?;
    evaluator.evaluate(episodes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systems::SystemKind;

    /// The suite must stay runnable without artifacts: every preset
    /// resolves to an environment and every system parses. (The
    /// artifact-gated end-to-end path is covered in
    /// rust/tests/integration.rs.)
    #[test]
    fn suite_is_well_formed() {
        let mut names = std::collections::HashSet::new();
        for sc in SUITE {
            assert!(names.insert(sc.name), "duplicate scenario {}", sc.name);
            SystemKind::parse(sc.system).unwrap();
            systems::env_for_preset(sc.preset, 0, None).unwrap();
        }
        // all six paper environments are covered
        for preset in
            ["matrix2", "switch3", "smac3m", "spread3", "speaker2", "walker3"]
        {
            assert!(
                SUITE.iter().any(|sc| sc.preset == preset),
                "suite misses {preset}"
            );
        }
    }

    #[test]
    fn scenario_filter_selects_subset() {
        let matching: Vec<_> = SUITE
            .iter()
            .filter(|sc| sc.name.contains("matrix2"))
            .collect();
        assert_eq!(matching.len(), 2);
    }

    #[test]
    fn run_rejects_degenerate_options() {
        let cfg = TrainConfig::default();
        let mut opts = ExperimentOpts { seeds: 0, ..Default::default() };
        assert!(run(&cfg, &opts).is_err());
        opts.seeds = 1;
        let mut cfg = cfg;
        cfg.eval_episodes = 0;
        assert!(run(&cfg, &opts).is_err());
    }
}
