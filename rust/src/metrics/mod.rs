//! Metrics: episode statistics, moving averages, CSV loggers and timers.

#![warn(missing_docs)]

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

/// Exponential/windowed running statistics over a scalar stream.
#[derive(Clone, Debug)]
pub struct MovingStats {
    window: usize,
    buf: Vec<f32>,
    next: usize,
    count: u64,
}

impl MovingStats {
    /// Statistics over a sliding window of the last `window` values.
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        MovingStats { window, buf: Vec::with_capacity(window), next: 0, count: 0 }
    }

    /// Record one value (evicting the oldest once the window is full).
    pub fn push(&mut self, x: f32) {
        if self.buf.len() < self.window {
            self.buf.push(x);
        } else {
            self.buf[self.next] = x;
            self.next = (self.next + 1) % self.window;
        }
        self.count += 1;
    }

    /// Total values ever pushed (not capped by the window).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of the windowed values (0.0 while empty).
    pub fn mean(&self) -> f32 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().sum::<f32>() / self.buf.len() as f32
    }

    /// Smallest windowed value (+∞ while empty).
    pub fn min(&self) -> f32 {
        self.buf.iter().copied().fold(f32::INFINITY, f32::min)
    }

    /// Largest windowed value (-∞ while empty).
    pub fn max(&self) -> f32 {
        self.buf.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }
}

/// Thread-safe CSV logger (one row per call, header written once).
pub struct CsvLogger {
    inner: Mutex<BufWriter<File>>,
}

impl CsvLogger {
    /// Create (truncate) the CSV at `path` and write its header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvLogger { inner: Mutex::new(w) })
    }

    /// Append one row (flushed immediately; errors are ignored).
    pub fn log(&self, row: &[f64]) {
        let mut w = self.inner.lock().unwrap();
        let s: Vec<String> = row.iter().map(|x| format!("{x}")).collect();
        let _ = writeln!(w, "{}", s.join(","));
        let _ = w.flush();
    }
}

/// Wall-clock stopwatch with named laps (perf instrumentation).
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds since [`Timer::start`].
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds since [`Timer::start`].
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Lightweight counter bundle shared across executor/trainer threads.
#[derive(Default)]
pub struct Counters {
    /// Total environment steps across all executors.
    pub env_steps: std::sync::atomic::AtomicU64,
    /// Total completed episodes across all executors.
    pub episodes: std::sync::atomic::AtomicU64,
    /// Total trainer steps.
    pub train_steps: std::sync::atomic::AtomicU64,
}

impl Counters {
    /// Add `n` environment steps.
    pub fn add_env_steps(&self, n: u64) {
        self.env_steps.fetch_add(n, std::sync::atomic::Ordering::Relaxed);
    }
    /// Record one completed episode.
    pub fn add_episode(&self) {
        self.episodes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
    /// Record one trainer step.
    pub fn add_train_step(&self) {
        self.train_steps.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
    /// Current environment-step total.
    pub fn env_steps(&self) -> u64 {
        self.env_steps.load(std::sync::atomic::Ordering::Relaxed)
    }
    /// Current episode total.
    pub fn episodes(&self) -> u64 {
        self.episodes.load(std::sync::atomic::Ordering::Relaxed)
    }
    /// Current trainer-step total.
    pub fn train_steps(&self) -> u64 {
        self.train_steps.load(std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moving_stats_windowed_mean() {
        let mut m = MovingStats::new(3);
        for x in [1.0, 2.0, 3.0, 4.0] {
            m.push(x);
        }
        // window holds 4,2,3 -> mean 3
        assert!((m.mean() - 3.0).abs() < 1e-6);
        assert_eq!(m.count(), 4);
        assert_eq!(m.min(), 2.0);
        assert_eq!(m.max(), 4.0);
    }

    #[test]
    fn csv_logger_writes_rows() {
        let dir = std::env::temp_dir().join("mava_test_logs");
        let path = dir.join("t.csv");
        let log = CsvLogger::create(&path, &["a", "b"]).unwrap();
        log.log(&[1.0, 2.5]);
        log.log(&[3.0, 4.0]);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("a,b\n1,2.5\n"));
    }

    #[test]
    fn counters_accumulate() {
        let c = Counters::default();
        c.add_env_steps(10);
        c.add_env_steps(5);
        c.add_episode();
        c.add_train_step();
        assert_eq!(c.env_steps(), 15);
        assert_eq!(c.episodes(), 1);
        assert_eq!(c.train_steps(), 1);
    }
}
