//! # mava-rs — distributed multi-agent reinforcement learning
//!
//! A Rust + JAX + Pallas reproduction of *Mava: a research framework for
//! distributed multi-agent reinforcement learning* (Pretorius et al.,
//! 2021). The Rust layer (L3) owns everything the paper delegates to
//! Launchpad / Reverb / Acme: the process topology, replay data flow,
//! executor-trainer coordination and environments. Model compute (L2/L1)
//! is AOT-compiled JAX+Pallas loaded as HLO artifacts and executed through
//! the PJRT C API — Python never runs on the acting or training path.
//!
//! ## Layout
//! - [`core`] — multi-agent timesteps, specs, host tensors
//! - [`rng`] — deterministic xoshiro256++ RNG (no external crates)
//! - [`config`] — TOML-subset config system + CLI parsing
//! - [`env`] — environment suite: switch riddle, smac_lite, MPE,
//!   multiwalker; `VecEnv` batched stepping into reusable
//!   struct-of-arrays buffers (DESIGN.md §6)
//! - [`replay`] — Reverb-style tables: selectors, rate limiters, adders;
//!   `ShardedTable` per-executor sharding (DESIGN.md §5)
//! - [`params`] — versioned parameter server
//! - [`launch`] — Launchpad-style program graph + local launcher;
//!   `launch::dist` multi-process launch driver (DESIGN.md §10)
//! - [`net`] — wire layer for multi-process runs: frame codec +
//!   parameter / replay / control TCP protocols (DESIGN.md §10)
//! - [`runtime`] — PJRT engine: loads `artifacts/*.hlo.txt`
//! - [`serve`] — `mava serve`: policy inference service with
//!   deadline-based dynamic batching (DESIGN.md §12)
//! - [`arch`] — system architectures (decentralised / centralised / networked)
//! - [`systems`] — MADQN, DIAL, VDN, QMIX, MADDPG, MAD4PG
//! - [`exploration`] — ε-greedy schedules, Gaussian/OU noise
//! - [`metrics`] — loggers, moving statistics, timers
//! - [`eval`] — serial + vectorized evaluation loops, robust statistics
//!   (bootstrap CIs, IQM), solve detection
//! - [`experiment`] — multi-seed experiment harness over the env suite
//!   (EXPERIMENTS.md)
//! - [`bench`] — shared mini-benchmark harness (criterion is unavailable
//!   offline) + the versioned `BENCH_*.json` report writer

pub mod arch;
pub mod bench;
pub mod config;
pub mod core;
pub mod env;
pub mod eval;
pub mod experiment;
pub mod exploration;
pub mod launch;
pub mod metrics;
pub mod net;
pub mod params;
pub mod replay;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod systems;

pub use crate::core::{Actions, EnvSpec, StepType, TimeStep};
pub use env::MultiAgentEnv;
