//! System architectures (paper §4, Figure 3).
//!
//! The architecture defines the information flow between agents: fully
//! independent (decentralised), via a shared central unit (centralised),
//! or along a topology (networked). In mava-rs the flow is *baked into
//! the lowered artifact* (the critic-input mask / message-routing matrix
//! is a compile-time constant), so picking an architecture means picking
//! the matching artifact variant — this module maps the paper's
//! architecture classes to artifact name tags and exposes the adjacency
//! logic used by networked systems.

use std::fmt;

/// Paper Figure 3: decentralised / centralised / networked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Architecture {
    /// `DecentralisedPolicyActor` / `DecentralisedQValueCritic`
    Decentralised,
    /// `CentralisedQValueCritic`
    Centralised,
    /// `NetworkedQValueCritic` (line topology by default)
    Networked,
}

impl Architecture {
    /// The tag used in artifact names (`*_dec_*`, `*_cen_*`, `*_net_*`).
    pub fn tag(&self) -> &'static str {
        match self {
            Architecture::Decentralised => "dec",
            Architecture::Centralised => "cen",
            Architecture::Networked => "net",
        }
    }

    pub fn parse(s: &str) -> Option<Architecture> {
        match s {
            "decentralised" | "dec" => Some(Architecture::Decentralised),
            "centralised" | "cen" => Some(Architecture::Centralised),
            "networked" | "net" => Some(Architecture::Networked),
            _ => None,
        }
    }

    /// Information-flow mask: may agent `i` observe agent `j`'s
    /// observation/action during centralised training? Mirrors
    /// `python/compile/systems/maddpg.py::arch_mask`.
    pub fn allows(&self, i: usize, j: usize) -> bool {
        match self {
            Architecture::Decentralised => i == j,
            Architecture::Centralised => true,
            Architecture::Networked => (i as isize - j as isize).abs() <= 1,
        }
    }

    /// Neighbourhood of agent `i` in an `n`-agent system.
    pub fn neighbours(&self, i: usize, n: usize) -> Vec<usize> {
        (0..n).filter(|&j| self.allows(i, j)).collect()
    }
}

impl fmt::Display for Architecture {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Architecture::Decentralised => "decentralised",
            Architecture::Centralised => "centralised",
            Architecture::Networked => "networked",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for a in [
            Architecture::Decentralised,
            Architecture::Centralised,
            Architecture::Networked,
        ] {
            assert_eq!(Architecture::parse(&a.to_string()), Some(a));
            assert_eq!(Architecture::parse(a.tag()), Some(a));
        }
        assert_eq!(Architecture::parse("bogus"), None);
    }

    #[test]
    fn masks_match_paper_figure_3() {
        let dec = Architecture::Decentralised;
        assert_eq!(dec.neighbours(1, 3), vec![1]);
        let cen = Architecture::Centralised;
        assert_eq!(cen.neighbours(1, 3), vec![0, 1, 2]);
        let net = Architecture::Networked;
        assert_eq!(net.neighbours(0, 4), vec![0, 1]);
        assert_eq!(net.neighbours(2, 4), vec![1, 2, 3]);
    }
}
