//! Robust aggregates over episode returns (EXPERIMENTS.md §3).
//!
//! A point estimate from a handful of MARL runs is statistically
//! fragile; the experiment harness therefore reports, per scenario:
//!
//! * **per-seed means** — one number per independent training seed;
//! * the **inter-quartile mean** ([`iqm`]) of the pooled episode
//!   returns — the rliable-style robust point estimate (mean of the
//!   middle 50% of sorted samples, cutting `floor(n/4)` from each end);
//! * **stratified bootstrap confidence intervals**
//!   ([`stratified_bootstrap_ci`]) — each bootstrap replicate resamples
//!   *within* each seed (stratum) with replacement, so the interval
//!   reflects both per-seed episode noise and seed-to-seed variation
//!   without letting one seed's episodes stand in for another's.
//!
//! All randomness comes from the crate's deterministic
//! [`crate::rng::Rng`]; the same `(data, seed, resamples)` triple always
//! produces the same interval, which keeps `BENCH_*.json` artifacts
//! reproducible bit-for-bit.

use crate::rng::Rng;

/// Arithmetic mean of `xs` (0.0 for an empty slice).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Inter-quartile mean: the mean of the middle 50% of sorted samples
/// (`floor(n/4)` samples cut from each end; the whole sample when
/// `n < 4`). 0.0 for an empty slice.
pub fn iqm(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
    v.sort_by(f64::total_cmp);
    let cut = v.len() / 4;
    let mid = &v[cut..v.len() - cut];
    mid.iter().sum::<f64>() / mid.len() as f64
}

/// Linear-interpolated quantile `q ∈ [0, 1]` of an already-sorted
/// slice (0.0 for an empty slice).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let i = pos.floor() as usize;
    let frac = pos - i as f64;
    if i + 1 < sorted.len() {
        sorted[i] * (1.0 - frac) + sorted[i + 1] * frac
    } else {
        sorted[i]
    }
}

/// A bootstrap confidence interval for one statistic.
#[derive(Clone, Copy, Debug, Default)]
pub struct BootstrapCi {
    /// Lower interval bound.
    pub lo: f64,
    /// Upper interval bound.
    pub hi: f64,
    /// Confidence level the interval was computed at (e.g. 0.95).
    pub confidence: f64,
    /// Number of bootstrap replicates drawn.
    pub resamples: usize,
}

/// Stratified percentile-bootstrap confidence interval for `stat` over
/// `strata` (one stratum per seed).
///
/// Each of `resamples` replicates resamples every stratum with
/// replacement at its own size, pools the resamples, and evaluates
/// `stat`; the interval is the `[α/2, 1-α/2]` percentile range of the
/// replicate distribution (α = 1 - `confidence`), widened if necessary
/// to include the point estimate `stat(pooled data)` — so the reported
/// interval always brackets the reported point estimate. With a fixed
/// `seed`, raising `confidence` only widens the interval (the same
/// replicate set is re-quantiled), so intervals are monotone in the
/// confidence level.
pub fn stratified_bootstrap_ci(
    strata: &[Vec<f32>],
    stat: impl Fn(&[f32]) -> f64,
    confidence: f64,
    resamples: usize,
    seed: u64,
) -> BootstrapCi {
    let total: usize = strata.iter().map(|s| s.len()).sum();
    if total == 0 || resamples == 0 {
        return BootstrapCi { lo: 0.0, hi: 0.0, confidence, resamples };
    }
    let pooled: Vec<f32> = strata.iter().flatten().copied().collect();
    let point = stat(&pooled);
    let mut rng = Rng::new(seed);
    let mut sample = Vec::with_capacity(total);
    let mut reps = Vec::with_capacity(resamples);
    for _ in 0..resamples {
        sample.clear();
        for s in strata {
            for _ in 0..s.len() {
                sample.push(s[rng.below(s.len())]);
            }
        }
        reps.push(stat(&sample));
    }
    reps.sort_by(f64::total_cmp);
    let alpha = (1.0 - confidence.clamp(0.0, 1.0)).max(0.0);
    BootstrapCi {
        lo: percentile(&reps, alpha / 2.0).min(point),
        hi: percentile(&reps, 1.0 - alpha / 2.0).max(point),
        confidence,
        resamples,
    }
}

/// The full aggregate block the experiment harness serialises per
/// scenario (see EXPERIMENTS.md for the JSON mapping).
#[derive(Clone, Debug, Default)]
pub struct Aggregates {
    /// Mean episode return of each seed, in seed order.
    pub per_seed_means: Vec<f64>,
    /// Mean over all pooled episode returns.
    pub mean: f64,
    /// Inter-quartile mean over all pooled episode returns.
    pub iqm: f64,
    /// Stratified bootstrap CI for the pooled mean.
    pub mean_ci: BootstrapCi,
    /// Stratified bootstrap CI for the pooled IQM.
    pub iqm_ci: BootstrapCi,
}

/// Compute every aggregate over per-seed episode returns.
///
/// `per_seed[s]` holds seed `s`'s evaluation episode returns; the two
/// intervals share the replicate RNG seed, so repeated calls are
/// bit-identical.
pub fn aggregate(
    per_seed: &[Vec<f32>],
    confidence: f64,
    resamples: usize,
    seed: u64,
) -> Aggregates {
    let pooled: Vec<f32> = per_seed.iter().flatten().copied().collect();
    Aggregates {
        per_seed_means: per_seed.iter().map(|s| mean(s)).collect(),
        mean: mean(&pooled),
        iqm: iqm(&pooled),
        mean_ci: stratified_bootstrap_ci(
            per_seed,
            mean,
            confidence,
            resamples,
            seed,
        ),
        iqm_ci: stratified_bootstrap_ci(
            per_seed,
            iqm,
            confidence,
            resamples,
            seed ^ 0x19_b007,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_iqm_fixtures() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(iqm(&[]), 0.0);
        let xs = [2.0f32, 4.0, 6.0];
        assert!((mean(&xs) - 4.0).abs() < 1e-12);
        // n < 4: IQM degenerates to the mean
        assert!((iqm(&xs) - 4.0).abs() < 1e-12);
        // n = 8: cut 2 from each end -> mean(3,4,5,6) = 4.5
        let xs: Vec<f32> = (1..=8).map(|x| x as f32).collect();
        assert!((iqm(&xs) - 4.5).abs() < 1e-12);
        // IQM shrugs off outliers the mean cannot: cut 1 from each end
        let xs = [0.0f32, 1.0, 2.0, 3.0, 1000.0];
        assert!((iqm(&xs) - 2.0).abs() < 1e-12, "iqm {}", iqm(&xs));
        assert!(mean(&xs) > 200.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert!((percentile(&v, 0.5) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn bootstrap_ci_on_constant_data_collapses() {
        let strata = vec![vec![3.0f32; 10], vec![3.0f32; 10]];
        let ci =
            stratified_bootstrap_ci(&strata, |xs| mean(xs), 0.95, 200, 1);
        assert!((ci.lo - 3.0).abs() < 1e-9);
        assert!((ci.hi - 3.0).abs() < 1e-9);
    }

    #[test]
    fn bootstrap_ci_deterministic_and_empty_safe() {
        let strata = vec![vec![1.0f32, 2.0, 3.0], vec![4.0, 5.0, 6.0]];
        let a = stratified_bootstrap_ci(&strata, |xs| mean(xs), 0.9, 300, 7);
        let b = stratified_bootstrap_ci(&strata, |xs| mean(xs), 0.9, 300, 7);
        assert_eq!(a.lo, b.lo);
        assert_eq!(a.hi, b.hi);
        let empty =
            stratified_bootstrap_ci(&[], |xs| mean(xs), 0.9, 300, 7);
        assert_eq!((empty.lo, empty.hi), (0.0, 0.0));
    }

    /// Property: the CI always contains the sample statistic, for both
    /// mean and IQM, over randomized strata shapes and data.
    #[test]
    fn prop_ci_contains_sample_statistic() {
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            let n_strata = 2 + rng.below(4);
            let strata: Vec<Vec<f32>> = (0..n_strata)
                .map(|_| {
                    let n = 3 + rng.below(28);
                    (0..n)
                        .map(|_| rng.range_f32(-10.0, 10.0))
                        .collect()
                })
                .collect();
            let pooled: Vec<f32> =
                strata.iter().flatten().copied().collect();
            for (name, stat) in [
                ("mean", mean as fn(&[f32]) -> f64),
                ("iqm", iqm as fn(&[f32]) -> f64),
            ] {
                let point = stat(&pooled);
                let ci = stratified_bootstrap_ci(
                    &strata, stat, 0.95, 400, seed,
                );
                assert!(
                    ci.lo <= point && point <= ci.hi,
                    "seed {seed} {name}: {point} outside [{}, {}]",
                    ci.lo,
                    ci.hi
                );
            }
        }
    }

    /// Property: with the RNG seed fixed, a higher confidence level
    /// never narrows the interval (lo non-increasing, hi
    /// non-decreasing).
    #[test]
    fn prop_ci_monotone_in_confidence() {
        for seed in 0..10u64 {
            let mut rng = Rng::new(seed ^ 0xc0ff);
            let strata: Vec<Vec<f32>> = (0..3)
                .map(|_| {
                    (0..12).map(|_| rng.range_f32(0.0, 5.0)).collect()
                })
                .collect();
            let mut prev: Option<BootstrapCi> = None;
            for conf in [0.5, 0.8, 0.9, 0.95, 0.99] {
                let ci = stratified_bootstrap_ci(
                    &strata,
                    |xs| mean(xs),
                    conf,
                    300,
                    seed,
                );
                if let Some(p) = prev {
                    assert!(
                        ci.lo <= p.lo + 1e-12 && ci.hi >= p.hi - 1e-12,
                        "seed {seed}: CI narrowed going {} -> {}",
                        p.confidence,
                        conf
                    );
                }
                prev = Some(ci);
            }
        }
    }

    #[test]
    fn aggregate_per_seed_means() {
        let per_seed =
            vec![vec![1.0f32, 3.0], vec![5.0, 7.0], vec![9.0, 11.0]];
        let a = aggregate(&per_seed, 0.95, 200, 3);
        assert_eq!(a.per_seed_means, vec![2.0, 6.0, 10.0]);
        assert!((a.mean - 6.0).abs() < 1e-12);
        assert!(a.mean_ci.lo <= a.mean && a.mean <= a.mean_ci.hi);
        assert!(a.iqm_ci.lo <= a.iqm && a.iqm <= a.iqm_ci.hi);
    }
}
