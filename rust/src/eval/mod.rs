//! Evaluation utilities: greedy rollouts, summaries and solve detection.

use anyhow::Result;

use crate::env::MultiAgentEnv;
use crate::systems::{eval_episode, EvalPoint, Executor};

/// Summary of a batch of evaluation episodes.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalSummary {
    pub episodes: usize,
    pub mean_return: f32,
    pub min_return: f32,
    pub max_return: f32,
}

/// Run `n` greedy episodes and summarise.
pub fn evaluate(
    executor: &mut Executor,
    env: &mut dyn MultiAgentEnv,
    n: usize,
) -> Result<EvalSummary> {
    let mut returns = Vec::with_capacity(n);
    for _ in 0..n {
        returns.push(eval_episode(executor, env)?);
    }
    Ok(EvalSummary {
        episodes: n,
        mean_return: returns.iter().sum::<f32>() / n.max(1) as f32,
        min_return: returns.iter().copied().fold(f32::INFINITY, f32::min),
        max_return: returns.iter().copied().fold(f32::NEG_INFINITY, f32::max),
    })
}

/// Whether a learning curve crossed and held a threshold: the last
/// `hold` points all at or above `threshold`.
pub fn solved(evals: &[EvalPoint], threshold: f32, hold: usize) -> bool {
    if evals.len() < hold || hold == 0 {
        return false;
    }
    evals[evals.len() - hold..]
        .iter()
        .all(|e| e.mean_return >= threshold)
}

/// Area under the (env_steps, return) learning curve — a scale-free
/// score for comparing systems on the same budget (trapezoidal).
pub fn auc(evals: &[EvalPoint]) -> f64 {
    if evals.len() < 2 {
        return 0.0;
    }
    let mut area = 0.0;
    for w in evals.windows(2) {
        let dx = (w[1].env_steps - w[0].env_steps) as f64;
        area += dx * 0.5 * (w[0].mean_return as f64 + w[1].mean_return as f64);
    }
    area
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(env_steps: u64, r: f32) -> EvalPoint {
        EvalPoint { wall_s: 0.0, env_steps, train_steps: 0, mean_return: r }
    }

    #[test]
    fn solved_requires_hold() {
        let evals = vec![pt(0, 0.0), pt(1, 1.0), pt(2, 0.9), pt(3, 1.0)];
        assert!(solved(&evals, 0.9, 2));
        assert!(!solved(&evals, 0.95, 2));
        assert!(!solved(&evals, 0.9, 10), "not enough points");
    }

    #[test]
    fn auc_trapezoid() {
        let evals = vec![pt(0, 0.0), pt(10, 1.0), pt(20, 1.0)];
        assert!((auc(&evals) - (5.0 + 10.0)).abs() < 1e-9);
    }
}
