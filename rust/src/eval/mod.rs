//! Evaluation: greedy rollouts (serial and vectorized), robust
//! statistics, summaries and solve detection.
//!
//! - [`evaluate`] / [`eval_episode`](crate::systems::eval_episode) —
//!   the serial `[1, N, O]` path (episodic, latency-insensitive);
//! - [`VecEvaluator`] — B greedy episodes per batched policy call on
//!   top of [`crate::env::VecEnv`] (DESIGN.md §6 applied to
//!   evaluation);
//! - [`stats`] — per-seed means, stratified bootstrap confidence
//!   intervals and the inter-quartile mean the experiment harness
//!   serialises (EXPERIMENTS.md).

#![warn(missing_docs)]

pub mod stats;
mod vec_eval;

pub use vec_eval::{EpisodeAccountant, VecEvaluator};

use anyhow::Result;

use crate::env::MultiAgentEnv;
use crate::systems::{eval_episode, EvalPoint, Executor};

/// Summary of a batch of evaluation episodes.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalSummary {
    /// Number of episodes summarised.
    pub episodes: usize,
    /// Mean episode return (0.0 when `episodes == 0`).
    pub mean_return: f32,
    /// Smallest episode return (0.0 when `episodes == 0`).
    pub min_return: f32,
    /// Largest episode return (0.0 when `episodes == 0`).
    pub max_return: f32,
}

impl EvalSummary {
    /// Summarise a slice of episode returns. An empty slice yields the
    /// all-zero summary — never ±∞ sentinels, which used to leak out of
    /// the degenerate `n = 0` evaluation and poison downstream
    /// aggregation.
    pub fn from_returns(returns: &[f32]) -> EvalSummary {
        if returns.is_empty() {
            return EvalSummary::default();
        }
        EvalSummary {
            episodes: returns.len(),
            mean_return: returns.iter().sum::<f32>() / returns.len() as f32,
            min_return: returns.iter().copied().fold(f32::INFINITY, f32::min),
            max_return: returns
                .iter()
                .copied()
                .fold(f32::NEG_INFINITY, f32::max),
        }
    }
}

/// Run `n` greedy episodes and summarise (`n = 0` yields the all-zero
/// summary).
pub fn evaluate(
    executor: &mut Executor,
    env: &mut dyn MultiAgentEnv,
    n: usize,
) -> Result<EvalSummary> {
    let mut returns = Vec::with_capacity(n);
    for _ in 0..n {
        returns.push(eval_episode(executor, env)?);
    }
    Ok(EvalSummary::from_returns(&returns))
}

/// Whether a learning curve crossed and held a threshold: the last
/// `hold` points all at or above `threshold`.
pub fn solved(evals: &[EvalPoint], threshold: f32, hold: usize) -> bool {
    if evals.len() < hold || hold == 0 {
        return false;
    }
    evals[evals.len() - hold..]
        .iter()
        .all(|e| e.mean_return >= threshold)
}

/// Area under the (env_steps, return) learning curve — a scale-free
/// score for comparing systems on the same budget (trapezoidal).
pub fn auc(evals: &[EvalPoint]) -> f64 {
    if evals.len() < 2 {
        return 0.0;
    }
    let mut area = 0.0;
    for w in evals.windows(2) {
        let dx = (w[1].env_steps - w[0].env_steps) as f64;
        area += dx * 0.5 * (w[0].mean_return as f64 + w[1].mean_return as f64);
    }
    area
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(env_steps: u64, r: f32) -> EvalPoint {
        EvalPoint { wall_s: 0.0, env_steps, train_steps: 0, mean_return: r }
    }

    #[test]
    fn solved_requires_hold() {
        let evals = vec![pt(0, 0.0), pt(1, 1.0), pt(2, 0.9), pt(3, 1.0)];
        assert!(solved(&evals, 0.9, 2));
        assert!(!solved(&evals, 0.95, 2));
        assert!(!solved(&evals, 0.9, 10), "not enough points");
    }

    #[test]
    fn auc_trapezoid() {
        let evals = vec![pt(0, 0.0), pt(10, 1.0), pt(20, 1.0)];
        assert!((auc(&evals) - (5.0 + 10.0)).abs() < 1e-9);
    }

    #[test]
    fn summary_from_returns() {
        let s = EvalSummary::from_returns(&[1.0, 3.0, -2.0]);
        assert_eq!(s.episodes, 3);
        assert!((s.mean_return - 2.0 / 3.0).abs() < 1e-6);
        assert_eq!(s.min_return, -2.0);
        assert_eq!(s.max_return, 3.0);
    }

    /// The degenerate n = 0 case: zeros, not min=+INF / max=-INF.
    #[test]
    fn summary_of_zero_episodes_is_all_zero() {
        let s = EvalSummary::from_returns(&[]);
        assert_eq!(s.episodes, 0);
        assert_eq!(s.mean_return, 0.0);
        assert_eq!(s.min_return, 0.0);
        assert_eq!(s.max_return, 0.0);
        assert!(s.min_return.is_finite() && s.max_return.is_finite());
    }
}
