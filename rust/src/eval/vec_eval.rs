//! Vectorized greedy evaluation: B episodes advance per batched
//! policy-artifact call.
//!
//! The serial evaluator stepped ONE episode at a time through the
//! `[1, N, O]` policy artifact — fine for a latency-insensitive node,
//! but it made large evaluation budgets (the statistically meaningful
//! ones, see EXPERIMENTS.md) pay the full per-call dispatch cost per
//! episode step. [`VecEvaluator`] reuses the vectorized acting path
//! (DESIGN.md §6): a [`VecEnv`] steps B differently-seeded instances
//! with per-row auto-reset, a [`VecExecutor`] acts greedily for all of
//! them in one `[B, N, O]` artifact call, and an [`EpisodeAccountant`]
//! tracks per-row running returns across the desynchronised episode
//! boundaries.
//!
//! The accountant is deliberately independent of the executor so its
//! row-reset bookkeeping is testable without compiled artifacts; the
//! evaluator is the thin artifact-bound shell around it.

use anyhow::{ensure, Result};

use crate::core::StepType;
use crate::env::{ActionBuf, VecEnv, VecStep, VecStepBuf};
use crate::systems::VecExecutor;

/// Per-row episode-return bookkeeping over a stream of [`VecStep`]s.
///
/// Feed every post-`reset` vector step to [`EpisodeAccountant::observe`].
/// For each row it accumulates the mean-over-agents reward on `Mid` and
/// `Last` steps, records the finished return when a row's episode ends,
/// and — when a row comes back as `First` after an auto-reset — zeroes
/// that row's running return and reports the row index so the caller
/// can zero the matching recurrent-state row
/// ([`VecExecutor::reset_instance`]).
#[derive(Clone, Debug)]
pub struct EpisodeAccountant {
    running: Vec<f32>,
    completed: Vec<f32>,
    reset_scratch: Vec<usize>,
}

impl EpisodeAccountant {
    /// Track `batch` environment rows, all starting at return 0.
    pub fn new(batch: usize) -> EpisodeAccountant {
        EpisodeAccountant {
            running: vec![0.0; batch],
            completed: Vec::new(),
            reset_scratch: Vec::new(),
        }
    }

    /// Fold one vector step into the per-row accounts; returns the rows
    /// that auto-reset on this step (their recurrent state must be
    /// zeroed before the next policy call).
    pub fn observe(&mut self, vs: &VecStep) -> Vec<usize> {
        debug_assert_eq!(vs.steps.len(), self.running.len());
        let mut reset_rows = Vec::new();
        for (i, ts) in vs.steps.iter().enumerate() {
            if ts.step_type == StepType::First {
                self.running[i] = 0.0;
                reset_rows.push(i);
                continue;
            }
            self.running[i] += ts.rewards.iter().sum::<f32>()
                / ts.rewards.len().max(1) as f32;
            if ts.is_last() {
                self.completed.push(self.running[i]);
            }
        }
        reset_rows
    }

    /// [`EpisodeAccountant::observe`] over a struct-of-arrays
    /// [`VecStepBuf`]; the returned reset-row slice is backed by a
    /// reused scratch buffer (valid until the next call).
    ///
    /// The buffer may be *wider* than the accountant (bucket padding,
    /// DESIGN.md §11): only the first `batch` rows — the real
    /// environments — are folded in; padding rows can never contribute
    /// a reward or a completed return.
    pub fn observe_buf(&mut self, buf: &VecStepBuf) -> &[usize] {
        debug_assert!(
            buf.num_envs() >= self.running.len(),
            "step buf narrower than accountant"
        );
        self.reset_scratch.clear();
        for i in 0..self.running.len() {
            if buf.step_type(i) == StepType::First {
                self.running[i] = 0.0;
                self.reset_scratch.push(i);
                continue;
            }
            self.running[i] += buf.mean_reward(i);
            if buf.is_last(i) {
                self.completed.push(self.running[i]);
            }
        }
        &self.reset_scratch
    }

    /// Episode returns completed so far, in completion order.
    pub fn completed(&self) -> &[f32] {
        &self.completed
    }

    /// Consume the accountant, yielding the completed episode returns.
    pub fn into_completed(self) -> Vec<f32> {
        self.completed
    }
}

/// Batched greedy evaluator: one policy-artifact call advances B
/// evaluation episodes.
///
/// Construction pairs a [`VecExecutor`] (lowered at batch B) with a
/// [`VecEnv`] of B instances; [`VecEvaluator::evaluate`] then runs
/// greedy (ε = 0, σ = 0) episodes until `n` returns have completed.
/// Rows auto-reset independently, so episodes of different lengths
/// never stall the batch.
pub struct VecEvaluator {
    executor: VecExecutor,
    venv: VecEnv,
    // SoA double buffer + action batch, reused across evaluate calls
    cur: VecStepBuf,
    next: VecStepBuf,
    abuf: ActionBuf,
}

impl VecEvaluator {
    /// Pair an executor with an environment batch of at most its width.
    ///
    /// The executor's artifact bucket may exceed the number of real
    /// environments (bucketed lowering, DESIGN.md §11): the SoA buffers
    /// are sized at the bucket, the [`VecEnv`] fills only the first
    /// `venv.num_envs()` rows, the executor selects actions only for
    /// those rows, and the accountant never sees the padding.
    pub fn new(
        mut executor: VecExecutor,
        venv: VecEnv,
    ) -> Result<VecEvaluator> {
        ensure!(
            executor.num_envs() >= venv.num_envs(),
            "policy artifact bucket {} < VecEnv batch {} — pick the \
             bucket with BucketLadder::pick",
            executor.num_envs(),
            venv.num_envs()
        );
        let bucket = executor.num_envs();
        executor.set_active_rows(venv.num_envs())?;
        let cur = venv.make_buf_padded(bucket);
        let next = venv.make_buf_padded(bucket);
        let abuf = venv.make_action_buf_padded(bucket);
        Ok(VecEvaluator { executor, venv, cur, next, abuf })
    }

    /// Number of episodes advanced per policy call.
    pub fn num_envs(&self) -> usize {
        self.venv.num_envs()
    }

    /// Parameter-server version the evaluator last synced to.
    pub fn params_version(&self) -> u64 {
        self.executor.params_version
    }

    /// Snapshot fresh parameters (from the parameter server) before the
    /// next [`VecEvaluator::evaluate`] call.
    pub fn set_params(&mut self, version: u64, params: &[f32]) {
        self.executor.set_params(version, params);
    }

    /// Run greedy episodes until `n` returns complete; returns exactly
    /// the first `n` in completion order. See
    /// [`VecEvaluator::evaluate_until`] for cancellation.
    pub fn evaluate(&mut self, n: usize) -> Result<Vec<f32>> {
        self.evaluate_until(n, || false)
    }

    /// [`VecEvaluator::evaluate`] with a cancellation probe checked once
    /// per vector step: when `cancelled` returns true the call stops
    /// early and yields however many episodes completed (possibly fewer
    /// than `n`).
    ///
    /// With B > 1 the final wave may finish more than `n` episodes;
    /// the surplus (in completion order) is discarded so summaries are
    /// comparable across batch widths.
    pub fn evaluate_until(
        &mut self,
        n: usize,
        mut cancelled: impl FnMut() -> bool,
    ) -> Result<Vec<f32>> {
        if n == 0 {
            return Ok(Vec::new());
        }
        self.venv.reset_into(&mut self.cur);
        self.executor.reset_state();
        let mut acct = EpisodeAccountant::new(self.venv.num_envs());
        while acct.completed().len() < n && !cancelled() {
            // greedy batched policy call through the SoA hot path:
            // device-resident carry, one obs upload + one action
            // download per vector step (DESIGN.md §6)
            self.executor.select_actions_into(
                &self.cur,
                0.0,
                0.0,
                &mut self.abuf,
            )?;
            self.venv.step_into(&self.abuf, &mut self.next);
            for &row in acct.observe_buf(&self.next) {
                self.executor.reset_instance(row);
            }
            std::mem::swap(&mut self.cur, &mut self.next);
        }
        let mut returns = acct.into_completed();
        returns.truncate(n);
        Ok(returns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ActionSpec, Actions, EnvSpec, TimeStep};
    use crate::env::MultiAgentEnv;

    /// Deterministic env: episode of `limit` steps, reward `gain` per
    /// agent per step, so an episode's mean-over-agents return is
    /// exactly `limit * gain`. The spec's episode_limit is a fixed cap
    /// (instances may end earlier, like smac_lite), so differently-
    /// paced instances still batch into one VecEnv.
    struct RewardEnv {
        spec: EnvSpec,
        gain: f32,
        limit: usize,
        t: usize,
    }

    impl RewardEnv {
        fn new(gain: f32, limit: usize) -> Self {
            RewardEnv {
                spec: EnvSpec {
                    name: "reward".into(),
                    n_agents: 2,
                    obs_dim: 1,
                    action: ActionSpec::Discrete { n: 2 },
                    state_dim: 0,
                    episode_limit: 16,
                },
                gain,
                limit,
                t: 0,
            }
        }
    }

    impl MultiAgentEnv for RewardEnv {
        fn spec(&self) -> &EnvSpec {
            &self.spec
        }

        fn reset(&mut self) -> TimeStep {
            self.t = 0;
            TimeStep {
                step_type: StepType::First,
                observations: vec![vec![0.0]; 2],
                rewards: vec![0.0; 2],
                discount: 1.0,
                state: vec![],
                legal_actions: None,
            }
        }

        fn step(&mut self, _a: &Actions) -> TimeStep {
            self.t += 1;
            let last = self.t >= self.limit;
            TimeStep {
                step_type: if last { StepType::Last } else { StepType::Mid },
                observations: vec![vec![self.t as f32]; 2],
                rewards: vec![self.gain; 2],
                discount: 1.0,
                state: vec![],
                legal_actions: None,
            }
        }
    }

    fn venv(specs: &[(f32, usize)]) -> VecEnv {
        VecEnv::new(
            specs
                .iter()
                .map(|&(gain, limit)| {
                    Box::new(RewardEnv::new(gain, limit))
                        as Box<dyn MultiAgentEnv>
                })
                .collect(),
        )
        .unwrap()
    }

    fn acts(b: usize) -> Vec<Actions> {
        vec![Actions::Discrete(vec![0, 0]); b]
    }

    /// Desynchronised rows: the accountant must credit each return to
    /// its own row, record completions at each row's own boundary, and
    /// report exactly the auto-reset rows.
    #[test]
    fn accountant_tracks_desynchronised_rows() {
        // row 0: 2-step episodes of reward 1; row 1: 3-step of reward 10
        let mut venv = venv(&[(1.0, 2), (10.0, 3)]);
        let mut acct = EpisodeAccountant::new(2);
        let mut vs = venv.reset();
        let mut resets = Vec::new();
        for _ in 0..6 {
            vs = venv.step(&acts(2));
            resets.push(acct.observe(&vs));
        }
        // row 0 completes at vector steps 2 and 5 (reset consumed step 3);
        // row 1 completes at vector step 3 (reset consumed step 4)
        assert_eq!(acct.completed(), &[2.0, 30.0, 2.0]);
        // auto-resets surface exactly once per boundary, one step later
        assert_eq!(resets[0], Vec::<usize>::new());
        assert_eq!(resets[2], vec![0usize]);
        assert_eq!(resets[3], vec![1usize]);
        assert_eq!(resets[4], Vec::<usize>::new());
        assert_eq!(resets[5], vec![0usize]); // row 0's second boundary
    }

    /// A fresh First row must not inherit the previous episode's
    /// partial return.
    #[test]
    fn accountant_zeroes_running_return_on_reset() {
        let mut venv = venv(&[(5.0, 2)]);
        let mut acct = EpisodeAccountant::new(1);
        venv.reset();
        for _ in 0..3 {
            acct.observe(&venv.step(&acts(1)));
        }
        // steps: Mid(+5), Last(+5 -> complete 10), First(reset)
        assert_eq!(acct.completed(), &[10.0]);
        // next full episode must again be exactly 10
        for _ in 0..2 {
            acct.observe(&venv.step(&acts(1)));
        }
        assert_eq!(acct.completed(), &[10.0, 10.0]);
    }

    /// Rewards carried by a `Last` step count; rewards on a `First`
    /// (auto-reset) step are ignored by construction.
    #[test]
    fn accountant_counts_terminal_reward_once() {
        let mut venv = venv(&[(2.0, 1)]); // every step is Last
        let mut acct = EpisodeAccountant::new(1);
        venv.reset();
        acct.observe(&venv.step(&acts(1))); // Last: +2, complete
        acct.observe(&venv.step(&acts(1))); // First: ignored
        acct.observe(&venv.step(&acts(1))); // Last: +2, complete
        assert_eq!(acct.completed(), &[2.0, 2.0]);
    }

    /// The SoA accountant path must mirror the legacy VecStep path
    /// row for row (RewardEnv is bridged, exercising the non-SoA
    /// scatter too).
    #[test]
    fn accountant_buf_matches_legacy() {
        let specs = [(1.0, 2), (10.0, 3)];
        let mut legacy_env = venv(&specs);
        let mut soa_env = venv(&specs);
        let mut legacy = EpisodeAccountant::new(2);
        let mut soa = EpisodeAccountant::new(2);
        let mut buf = soa_env.make_buf();
        let abuf = soa_env.make_action_buf();
        legacy_env.reset();
        soa_env.reset_into(&mut buf);
        for _ in 0..7 {
            let vs = legacy_env.step(&acts(2));
            soa_env.step_into(&abuf, &mut buf);
            let want = legacy.observe(&vs);
            let got = soa.observe_buf(&buf);
            assert_eq!(want, got);
        }
        assert_eq!(legacy.completed(), soa.completed());
    }

    /// Bucket padding (DESIGN.md §11): with a step buffer wider than
    /// the accountant, padding rows must contribute no rewards, no
    /// completed returns and no reset rows — the accounts must be
    /// bitwise identical to an unpadded run of the same environments.
    #[test]
    fn accountant_ignores_padding_rows() {
        let specs = [(1.0, 2), (10.0, 3)];
        let mut plain_env = venv(&specs);
        let mut padded_env = venv(&specs);
        let mut plain = EpisodeAccountant::new(2);
        let mut padded = EpisodeAccountant::new(2);
        let mut buf = plain_env.make_buf();
        let mut wide = padded_env.make_buf_padded(8); // 6 padding rows
        let abuf = plain_env.make_action_buf();
        let abuf_wide = padded_env.make_action_buf_padded(8);
        plain_env.reset_into(&mut buf);
        padded_env.reset_into(&mut wide);
        // poison the padding rows' rewards: if the accountant ever
        // read them, the running returns would diverge
        for i in 2..8 {
            for r in wide.rewards_row_mut(i) {
                *r = 1.0e6;
            }
        }
        for _ in 0..7 {
            plain_env.step_into(&abuf, &mut buf);
            padded_env.step_into(&abuf_wide, &mut wide);
            for i in 2..8 {
                for r in wide.rewards_row_mut(i) {
                    *r = 1.0e6;
                }
            }
            let want = plain.observe_buf(&buf).to_vec();
            let got = padded.observe_buf(&wide).to_vec();
            assert_eq!(want, got, "reset rows diverged");
        }
        assert_eq!(plain.completed(), padded.completed());
    }

    #[test]
    fn accountant_works_with_real_env() {
        use crate::env::make_env;
        let mut venv = VecEnv::new(
            (0..4).map(|i| make_env("matrix", i).unwrap()).collect(),
        )
        .unwrap();
        let mut acct = EpisodeAccountant::new(4);
        venv.reset();
        // matrix episodes are 5 steps; 11 vector steps crosses one
        // boundary per row (reset at step 6)
        for _ in 0..11 {
            acct.observe(&venv.step(&acts(4)));
        }
        assert_eq!(acct.completed().len(), 8);
        assert!(acct.completed().iter().all(|r| r.is_finite()));
    }
}
