//! The executor: Mava's multi-agent actor collection (paper Block 1).
//!
//! Runs the policy artifact for all agents in one fused call (the pallas
//! `agent_net` path), applies exploration in rust, carries recurrent
//! state / DIAL inboxes between steps, and forwards transitions to an
//! adder. Parameters are refreshed from the parameter server between
//! episodes.
//!
//! Two actors share this module: [`Executor`] acts for a single
//! environment (`[1, N, O]` policy artifacts — evaluation and B=1
//! training), and [`VecExecutor`] acts for a whole [`crate::env::VecEnv`]
//! batch with one `[B, N, O]` artifact call per vector step
//! (DESIGN.md §6).

use std::rc::Rc;

use anyhow::Result;

use crate::core::{Actions, HostTensor, TimeStep};
use crate::env::VecStep;
use crate::exploration::{epsilon_greedy, gaussian_noise};
use crate::rng::Rng;
use crate::runtime::{Arg, Artifact};
use crate::systems::SystemKind;

/// Recurrent carry between environment steps (`B = 1` for [`Executor`],
/// `B = num_envs_per_executor` for [`VecExecutor`]).
#[derive(Clone, Debug)]
pub enum ActorState {
    /// Feedforward systems: nothing carried.
    None,
    /// GRU hidden state `[B, N, H]`.
    Hidden(HostTensor),
    /// DIAL: hidden state `[B, N, H]` + routed message inbox `[B, N, M]`.
    HiddenInbox(HostTensor, HostTensor),
}

/// Multi-agent actor for a single environment: a thin B=1 wrapper over
/// [`VecExecutor`] (evaluation and `num_envs_per_executor = 1` acting).
///
/// Derefs to its inner [`VecExecutor`], so parameter state
/// (`params_version`, [`VecExecutor::set_params`]) and recurrent-state
/// control ([`VecExecutor::reset_state`]) are shared with the batched
/// path — one implementation of the artifact dispatch and exploration
/// logic serves both.
pub struct Executor(VecExecutor);

impl Executor {
    /// Build an actor over a `[1, N, O]` policy artifact, starting from
    /// `initial_params` (the artifact's `params0` init blob).
    pub fn new(
        kind: SystemKind,
        artifact: Rc<Artifact>,
        initial_params: Vec<f32>,
        seed: u64,
    ) -> Result<Executor> {
        let inner = VecExecutor::new(kind, artifact, initial_params, seed)?;
        anyhow::ensure!(
            inner.num_envs() == 1,
            "Executor needs a [1, N, O] policy artifact (got batch {}); \
             use VecExecutor for batched acting",
            inner.num_envs()
        );
        Ok(Executor(inner))
    }

    /// Select actions for every agent. `eps`/`sigma` control exploration
    /// (pass 0.0 for greedy evaluation).
    pub fn select_actions(
        &mut self,
        ts: &TimeStep,
        eps: f32,
        sigma: f32,
    ) -> Result<Actions> {
        let mut joint = self.0.select_actions_steps(&[ts], eps, sigma)?;
        Ok(joint.pop().unwrap())
    }
}

impl std::ops::Deref for Executor {
    type Target = VecExecutor;

    fn deref(&self) -> &VecExecutor {
        &self.0
    }
}

impl std::ops::DerefMut for Executor {
    fn deref_mut(&mut self) -> &mut VecExecutor {
        &mut self.0
    }
}

/// Vectorized multi-agent actor: one `[B, N, O]` policy artifact acting
/// for all agents of a whole [`crate::env::VecEnv`] batch per call.
///
/// This is the executor half of the vectorized hot path (DESIGN.md §6):
/// instead of `B` separate PJRT dispatches per vector step, the stacked
/// observations go through a single batched artifact call and the
/// per-instance recurrent carries live as rows of one `[B, N, H]`
/// tensor. [`VecExecutor::reset_instance`] zeroes exactly one row when
/// that instance's episode auto-resets, so desynchronised episode
/// boundaries never force a full-batch reset.
pub struct VecExecutor {
    kind: SystemKind,
    artifact: Rc<Artifact>,
    /// Current flat parameter vector (host copy).
    pub params: HostTensor,
    /// Parameter-server version `params` was last synced to.
    pub params_version: u64,
    /// device-resident copy of `params`, rebuilt lazily after set_params
    params_buf: Option<xla::PjRtBuffer>,
    state: ActorState, // tensors carry [B, N, H] / [B, N, M]
    rng: Rng,
    batch: usize,
    n_agents: usize,
    obs_dim: usize,
    n_actions: usize,
    hidden: usize,
    msg_dim: usize,
}

impl VecExecutor {
    /// Build a vectorized actor over a batched policy artifact
    /// (`*_policy_b{B}`; the environment batch is read from the
    /// artifact's `obs` input shape).
    pub fn new(
        kind: SystemKind,
        artifact: Rc<Artifact>,
        initial_params: Vec<f32>,
        seed: u64,
    ) -> Result<VecExecutor> {
        let spec = &artifact.spec;
        let n_agents = spec.meta_usize("n_agents")?;
        let obs_dim = spec.meta_usize("obs_dim")?;
        let n_actions = spec.meta_usize("act_dim")?;
        let hidden = spec.meta_usize("hidden")?;
        let msg_dim = spec.meta_usize("msg_dim")?;
        let p = spec.meta_usize("params")?;
        let batch = spec
            .input("obs")
            .map(|t| *t.dims.first().unwrap_or(&1))
            .unwrap_or(1);
        anyhow::ensure!(batch >= 1, "{}: bad env batch", spec.name);
        anyhow::ensure!(
            initial_params.len() == p,
            "params len {} != artifact {}",
            initial_params.len(),
            p
        );
        let mut ex = VecExecutor {
            kind,
            artifact,
            params: HostTensor::f32(vec![p], initial_params),
            params_version: 0,
            params_buf: None,
            state: ActorState::None,
            rng: Rng::new(seed),
            batch,
            n_agents,
            obs_dim,
            n_actions,
            hidden,
            msg_dim,
        };
        ex.reset_state();
        Ok(ex)
    }

    /// Number of environment instances the artifact was lowered for.
    pub fn num_envs(&self) -> usize {
        self.batch
    }

    /// Number of agents per environment instance.
    pub fn n_agents(&self) -> usize {
        self.n_agents
    }

    /// Zero the recurrent carry of every instance.
    pub fn reset_state(&mut self) {
        self.state = match self.kind {
            SystemKind::MadqnRec => ActorState::Hidden(HostTensor::zeros_f32(
                vec![self.batch, self.n_agents, self.hidden],
            )),
            SystemKind::Dial => ActorState::HiddenInbox(
                HostTensor::zeros_f32(vec![
                    self.batch,
                    self.n_agents,
                    self.hidden,
                ]),
                HostTensor::zeros_f32(vec![
                    self.batch,
                    self.n_agents,
                    self.msg_dim,
                ]),
            ),
            _ => ActorState::None,
        };
    }

    /// Zero only instance `b`'s recurrent carry (call when that
    /// instance's episode auto-resets).
    pub fn reset_instance(&mut self, b: usize) {
        debug_assert!(b < self.batch);
        match &mut self.state {
            ActorState::None => {}
            ActorState::Hidden(h) => {
                let row = self.n_agents * self.hidden;
                h.as_f32_mut()[b * row..(b + 1) * row].fill(0.0);
            }
            ActorState::HiddenInbox(h, inbox) => {
                let row = self.n_agents * self.hidden;
                h.as_f32_mut()[b * row..(b + 1) * row].fill(0.0);
                let row = self.n_agents * self.msg_dim;
                inbox.as_f32_mut()[b * row..(b + 1) * row].fill(0.0);
            }
        }
    }

    /// Update parameters from the server copy.
    pub fn set_params(&mut self, version: u64, params: &[f32]) {
        self.params.as_f32_mut().copy_from_slice(params);
        self.params_version = version;
        self.params_buf = None; // stale device copy
    }

    /// Select a joint action for every environment instance with ONE
    /// batched policy artifact call. `eps`/`sigma` control exploration
    /// exactly as in [`Executor::select_actions`].
    pub fn select_actions_vec(
        &mut self,
        vs: &VecStep,
        eps: f32,
        sigma: f32,
    ) -> Result<Vec<Actions>> {
        let steps: Vec<&TimeStep> = vs.steps.iter().collect();
        self.select_actions_steps(&steps, eps, sigma)
    }

    /// [`Self::select_actions_vec`] over borrowed per-instance
    /// timesteps — the obs tensor is packed straight from the borrows
    /// (no `TimeStep` clone on the hot path).
    pub fn select_actions_steps(
        &mut self,
        steps: &[&TimeStep],
        eps: f32,
        sigma: f32,
    ) -> Result<Vec<Actions>> {
        anyhow::ensure!(
            steps.len() == self.batch,
            "vec step batch {} != artifact batch {}",
            steps.len(),
            self.batch
        );
        let mut data =
            Vec::with_capacity(self.batch * self.n_agents * self.obs_dim);
        for ts in steps {
            anyhow::ensure!(
                ts.observations.len() == self.n_agents
                    && ts.observations.iter().all(|o| o.len() == self.obs_dim),
                "obs shape mismatch (want {}x{})",
                self.n_agents,
                self.obs_dim
            );
            for o in &ts.observations {
                data.extend_from_slice(o);
            }
        }
        let obs = HostTensor::f32(
            vec![self.batch, self.n_agents, self.obs_dim],
            data,
        );
        if self.params_buf.is_none() {
            let dims = [self.params.len()];
            self.params_buf = Some(self.artifact.upload(&self.params, &dims)?);
        }
        let pbuf = self.params_buf.as_ref().unwrap();
        let outputs = match &self.state {
            ActorState::None => self
                .artifact
                .call_mixed(&[Arg::Dev(pbuf), Arg::Host(&obs)])?,
            ActorState::Hidden(h) => self.artifact.call_mixed(&[
                Arg::Dev(pbuf),
                Arg::Host(&obs),
                Arg::Host(h),
            ])?,
            ActorState::HiddenInbox(h, inbox) => self.artifact.call_mixed(&[
                Arg::Dev(pbuf),
                Arg::Host(&obs),
                Arg::Host(h),
                Arg::Host(inbox),
            ])?,
        };
        match &mut self.state {
            ActorState::None => {}
            ActorState::Hidden(h) => *h = outputs[1].clone(),
            ActorState::HiddenInbox(h, inbox) => {
                *h = outputs[1].clone();
                *inbox = outputs[2].clone();
            }
        }

        let per_env = self.n_agents * self.n_actions;
        let out = outputs[0].as_f32(); // [B, N, A]
        let mut joint = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            let block = &out[b * per_env..(b + 1) * per_env];
            let legal_b = steps[b].legal_actions.as_ref();
            if self.kind.discrete() {
                let a = (0..self.n_agents)
                    .map(|i| {
                        let qi =
                            &block[i * self.n_actions..(i + 1) * self.n_actions];
                        let legal = legal_b.map(|l| l[i].as_slice());
                        epsilon_greedy(
                            qi,
                            self.n_actions,
                            legal,
                            eps,
                            &mut self.rng,
                        )
                    })
                    .collect();
                joint.push(Actions::Discrete(a));
            } else {
                let a = (0..self.n_agents)
                    .map(|i| {
                        let mut ai = block
                            [i * self.n_actions..(i + 1) * self.n_actions]
                            .to_vec();
                        if sigma > 0.0 {
                            gaussian_noise(&mut ai, sigma, &mut self.rng);
                        }
                        ai
                    })
                    .collect();
                joint.push(Actions::Continuous(a));
            }
        }
        Ok(joint)
    }
}
