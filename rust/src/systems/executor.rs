//! The executor: Mava's multi-agent actor collection (paper Block 1).
//!
//! Runs the policy artifact for all agents in one fused call (the pallas
//! `agent_net` path), applies exploration in rust, carries recurrent
//! state / DIAL inboxes between steps, and forwards transitions to an
//! adder. Parameters are refreshed from the parameter server between
//! episodes.

use std::rc::Rc;

use anyhow::Result;

use crate::core::{Actions, HostTensor, TimeStep};
use crate::exploration::{epsilon_greedy, gaussian_noise};
use crate::rng::Rng;
use crate::runtime::{Arg, Artifact};
use crate::systems::SystemKind;

/// Recurrent carry between environment steps.
#[derive(Clone, Debug)]
pub enum ActorState {
    None,
    /// GRU hidden state [1, N, H]
    Hidden(HostTensor),
    /// DIAL: hidden state + routed message inbox [1, N, M]
    HiddenInbox(HostTensor, HostTensor),
}

/// Multi-agent actor: one policy artifact acting for all agents.
pub struct Executor {
    kind: SystemKind,
    artifact: Rc<Artifact>,
    pub params: HostTensor,
    pub params_version: u64,
    /// device-resident copy of `params`, rebuilt lazily after set_params
    params_buf: Option<xla::PjRtBuffer>,
    state: ActorState,
    rng: Rng,
    n_agents: usize,
    obs_dim: usize,
    n_actions: usize, // discrete count or continuous dim
    hidden: usize,
    msg_dim: usize,
}

impl Executor {
    pub fn new(
        kind: SystemKind,
        artifact: Rc<Artifact>,
        initial_params: Vec<f32>,
        seed: u64,
    ) -> Result<Executor> {
        let spec = &artifact.spec;
        let n_agents = spec.meta_usize("n_agents")?;
        let obs_dim = spec.meta_usize("obs_dim")?;
        let n_actions = spec.meta_usize("act_dim")?;
        let hidden = spec.meta_usize("hidden")?;
        let msg_dim = spec.meta_usize("msg_dim")?;
        let p = spec.meta_usize("params")?;
        anyhow::ensure!(
            initial_params.len() == p,
            "params len {} != artifact {}",
            initial_params.len(),
            p
        );
        let mut ex = Executor {
            kind,
            artifact,
            params: HostTensor::f32(vec![p], initial_params),
            params_version: 0,
            params_buf: None,
            state: ActorState::None,
            rng: Rng::new(seed),
            n_agents,
            obs_dim,
            n_actions,
            hidden,
            msg_dim,
        };
        ex.reset_state();
        Ok(ex)
    }

    pub fn n_agents(&self) -> usize {
        self.n_agents
    }

    /// Zero recurrent state; call at every episode start.
    pub fn reset_state(&mut self) {
        self.state = match self.kind {
            SystemKind::MadqnRec => ActorState::Hidden(HostTensor::zeros_f32(
                vec![1, self.n_agents, self.hidden],
            )),
            SystemKind::Dial => ActorState::HiddenInbox(
                HostTensor::zeros_f32(vec![1, self.n_agents, self.hidden]),
                HostTensor::zeros_f32(vec![1, self.n_agents, self.msg_dim]),
            ),
            _ => ActorState::None,
        };
    }

    /// Update parameters from the server copy.
    pub fn set_params(&mut self, version: u64, params: &[f32]) {
        self.params.as_f32_mut().copy_from_slice(params);
        self.params_version = version;
        self.params_buf = None; // stale device copy
    }

    fn obs_tensor(&self, ts: &TimeStep) -> HostTensor {
        let mut data = Vec::with_capacity(self.n_agents * self.obs_dim);
        for o in &ts.observations {
            debug_assert_eq!(o.len(), self.obs_dim);
            data.extend_from_slice(o);
        }
        HostTensor::f32(vec![1, self.n_agents, self.obs_dim], data)
    }

    /// Select actions for every agent. `eps`/`sigma` control exploration
    /// (pass 0.0 for greedy evaluation).
    pub fn select_actions(
        &mut self,
        ts: &TimeStep,
        eps: f32,
        sigma: f32,
    ) -> Result<Actions> {
        let obs = self.obs_tensor(ts);
        // the parameter vector dominates upload bytes on the acting path;
        // keep it device-resident and invalidate only on set_params.
        if self.params_buf.is_none() {
            let dims = [self.params.len()];
            self.params_buf = Some(self.artifact.upload(&self.params, &dims)?);
        }
        let pbuf = self.params_buf.as_ref().unwrap();
        let outputs = match &self.state {
            ActorState::None => self
                .artifact
                .call_mixed(&[Arg::Dev(pbuf), Arg::Host(&obs)])?,
            ActorState::Hidden(h) => self.artifact.call_mixed(&[
                Arg::Dev(pbuf),
                Arg::Host(&obs),
                Arg::Host(h),
            ])?,
            ActorState::HiddenInbox(h, inbox) => self.artifact.call_mixed(&[
                Arg::Dev(pbuf),
                Arg::Host(&obs),
                Arg::Host(h),
                Arg::Host(inbox),
            ])?,
        };
        // update carries
        match &mut self.state {
            ActorState::None => {}
            ActorState::Hidden(h) => *h = outputs[1].clone(),
            ActorState::HiddenInbox(h, inbox) => {
                *h = outputs[1].clone();
                *inbox = outputs[2].clone();
            }
        }

        if self.kind.discrete() {
            let q = outputs[0].as_f32(); // [1, N, A]
            let a = (0..self.n_agents)
                .map(|i| {
                    let qi = &q[i * self.n_actions..(i + 1) * self.n_actions];
                    let legal = ts
                        .legal_actions
                        .as_ref()
                        .map(|l| l[i].as_slice());
                    epsilon_greedy(qi, self.n_actions, legal, eps, &mut self.rng)
                })
                .collect();
            Ok(Actions::Discrete(a))
        } else {
            let act = outputs[0].as_f32(); // [1, N, A]
            let a = (0..self.n_agents)
                .map(|i| {
                    let mut ai = act
                        [i * self.n_actions..(i + 1) * self.n_actions]
                        .to_vec();
                    if sigma > 0.0 {
                        gaussian_noise(&mut ai, sigma, &mut self.rng);
                    }
                    ai
                })
                .collect();
            Ok(Actions::Continuous(a))
        }
    }
}
