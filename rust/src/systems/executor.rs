//! The executor: Mava's multi-agent actor collection (paper Block 1).
//!
//! Runs the policy artifact for all agents in one fused call (the pallas
//! `agent_net` path), applies exploration in rust, carries recurrent
//! state / DIAL inboxes between steps, and forwards transitions to an
//! adder. Parameters are refreshed from the parameter server between
//! episodes.
//!
//! Two actors share this module: [`Executor`] acts for a single
//! environment (`[1, N, O]` policy artifacts — evaluation and B=1
//! training), and [`VecExecutor`] acts for a whole [`crate::env::VecEnv`]
//! batch with one `[B, N, O]` artifact call per vector step
//! (DESIGN.md §6).
//!
//! On the vectorized hot path ([`VecExecutor::select_actions_into`])
//! the recurrent carry is **device-resident**: each call feeds the
//! previous call's hidden/inbox output buffers straight back as
//! device arguments ([`crate::runtime::Artifact::call_device`]) and
//! downloads only the `[B, N, A]` action head — per steady-state step
//! the only transfers are one obs upload and one action download, the
//! same trick the trainer plays with its `(params, target, opt)` state
//! (DESIGN.md §8). Per-row auto-resets re-zero just that row: the
//! carry is pulled to a host mirror once per reset event (not per
//! step), masked, and re-fed as a host argument on the next call.

use std::rc::Rc;

use anyhow::Result;

use crate::core::{Actions, HostTensor, TimeStep};
use crate::env::{ActionBuf, VecStep, VecStepBuf};
use crate::exploration::{epsilon_greedy, epsilon_greedy_masked, gaussian_noise};
use crate::rng::Rng;
use crate::runtime::{Arg, Artifact};
use crate::systems::{Family, SystemKind};

/// Recurrent carry between environment steps (`B = 1` for [`Executor`],
/// `B = num_envs_per_executor` for [`VecExecutor`]).
#[derive(Clone, Debug)]
pub enum ActorState {
    /// Feedforward systems: nothing carried.
    None,
    /// GRU hidden state `[B, N, H]`.
    Hidden(HostTensor),
    /// DIAL: hidden state `[B, N, H]` + routed message inbox `[B, N, M]`.
    HiddenInbox(HostTensor, HostTensor),
}

/// The device-resident half of the recurrent carry: output buffers of
/// the previous policy call, fed back as `Arg::Dev` inputs of the next
/// one. When present, the device buffers are authoritative and the
/// host-side [`ActorState`] is a stale mirror.
struct DevCarry {
    hidden: xla::PjRtBuffer,
    inbox: Option<xla::PjRtBuffer>,
}

/// Pick per-agent discrete actions for one row of a `[B, N, A]`
/// Q-value batch, honouring an optional f32 legal mask row `[N*A]`
/// (1.0 legal). Shared by the executor hot path and the hermetic
/// legal-masking tests; allocation-free.
pub fn select_discrete_row(
    q_row: &[f32],
    n_agents: usize,
    n_actions: usize,
    legal_row: Option<&[f32]>,
    eps: f32,
    rng: &mut Rng,
    out: &mut [i32],
) {
    debug_assert_eq!(q_row.len(), n_agents * n_actions);
    debug_assert_eq!(out.len(), n_agents);
    for i in 0..n_agents {
        let qi = &q_row[i * n_actions..(i + 1) * n_actions];
        let legal =
            legal_row.map(|l| &l[i * n_actions..(i + 1) * n_actions]);
        out[i] = epsilon_greedy_masked(qi, n_actions, legal, eps, rng);
    }
}

/// Multi-agent actor for a single environment: a thin B=1 wrapper over
/// [`VecExecutor`] (evaluation and `num_envs_per_executor = 1` acting).
///
/// Derefs to its inner [`VecExecutor`], so parameter state
/// (`params_version`, [`VecExecutor::set_params`]) and recurrent-state
/// control ([`VecExecutor::reset_state`]) are shared with the batched
/// path — one implementation of the artifact dispatch and exploration
/// logic serves both.
pub struct Executor(VecExecutor);

impl Executor {
    /// Build an actor over a `[1, N, O]` policy artifact, starting from
    /// `initial_params` (the artifact's `params0` init blob).
    pub fn new(
        kind: SystemKind,
        artifact: Rc<Artifact>,
        initial_params: Vec<f32>,
        seed: u64,
    ) -> Result<Executor> {
        let inner = VecExecutor::new(kind, artifact, initial_params, seed)?;
        anyhow::ensure!(
            inner.num_envs() == 1,
            "Executor needs a [1, N, O] policy artifact (got batch {}); \
             use VecExecutor for batched acting",
            inner.num_envs()
        );
        Ok(Executor(inner))
    }

    /// Select actions for every agent. `eps`/`sigma` control exploration
    /// (pass 0.0 for greedy evaluation).
    pub fn select_actions(
        &mut self,
        ts: &TimeStep,
        eps: f32,
        sigma: f32,
    ) -> Result<Actions> {
        let mut joint = self.0.select_actions_steps(&[ts], eps, sigma)?;
        Ok(joint.pop().unwrap())
    }
}

impl std::ops::Deref for Executor {
    type Target = VecExecutor;

    fn deref(&self) -> &VecExecutor {
        &self.0
    }
}

impl std::ops::DerefMut for Executor {
    fn deref_mut(&mut self) -> &mut VecExecutor {
        &mut self.0
    }
}

/// Vectorized multi-agent actor: one `[B, N, O]` policy artifact acting
/// for all agents of a whole [`crate::env::VecEnv`] batch per call.
///
/// This is the executor half of the vectorized hot path (DESIGN.md §6):
/// instead of `B` separate PJRT dispatches per vector step, the stacked
/// observations go through a single batched artifact call and the
/// per-instance recurrent carries live as rows of one `[B, N, H]`
/// tensor — device-resident on the SoA path
/// ([`VecExecutor::select_actions_into`]). [`VecExecutor::reset_instance`]
/// zeroes exactly one row when that instance's episode auto-resets, so
/// desynchronised episode boundaries never force a full-batch reset.
pub struct VecExecutor {
    kind: SystemKind,
    artifact: Rc<Artifact>,
    /// Current flat parameter vector (host copy).
    pub params: HostTensor,
    /// Parameter-server version `params` was last synced to.
    pub params_version: u64,
    /// device-resident copy of `params`, rebuilt lazily after set_params
    params_buf: Option<xla::PjRtBuffer>,
    /// host mirror of the recurrent carry ([B, N, H] / [B, N, M]);
    /// stale while `dev_state` is Some
    state: ActorState,
    /// device-resident carry (SoA path); authoritative when Some
    dev_state: Option<DevCarry>,
    /// rows whose carry must be zeroed before the next device call
    pending_resets: Vec<usize>,
    rng: Rng,
    batch: usize,
    /// rows 0..active are real environments; rows active..batch are
    /// bucket padding (never selected for, so the RNG stream matches
    /// an unpadded run of the same `active` width)
    active: usize,
    n_agents: usize,
    obs_dim: usize,
    n_actions: usize,
    hidden: usize,
    msg_dim: usize,
}

impl VecExecutor {
    /// Build a vectorized actor over a batched policy artifact
    /// (`*_policy_b{B}`; the environment batch is read from the
    /// artifact's `obs` input shape).
    pub fn new(
        kind: SystemKind,
        artifact: Rc<Artifact>,
        initial_params: Vec<f32>,
        seed: u64,
    ) -> Result<VecExecutor> {
        let spec = &artifact.spec;
        let n_agents = spec.meta_usize("n_agents")?;
        let obs_dim = spec.meta_usize("obs_dim")?;
        let n_actions = spec.meta_usize("act_dim")?;
        let hidden = spec.meta_usize("hidden")?;
        let msg_dim = spec.meta_usize("msg_dim")?;
        let p = spec.meta_usize("params")?;
        let batch = spec
            .input("obs")
            .map(|t| *t.dims.first().unwrap_or(&1))
            .unwrap_or(1);
        anyhow::ensure!(batch >= 1, "{}: bad env batch", spec.name);
        anyhow::ensure!(
            initial_params.len() == p,
            "params len {} != artifact {}",
            initial_params.len(),
            p
        );
        let mut ex = VecExecutor {
            kind,
            artifact,
            params: HostTensor::f32(vec![p], initial_params),
            params_version: 0,
            params_buf: None,
            state: ActorState::None,
            dev_state: None,
            pending_resets: Vec::new(),
            rng: Rng::new(seed),
            batch,
            active: batch,
            n_agents,
            obs_dim,
            n_actions,
            hidden,
            msg_dim,
        };
        ex.reset_state();
        Ok(ex)
    }

    /// Number of environment instances the artifact was lowered for
    /// (the bucket width, including any padding rows).
    pub fn num_envs(&self) -> usize {
        self.batch
    }

    /// Number of real (non-padding) rows actions are selected for.
    pub fn active_rows(&self) -> usize {
        self.active
    }

    /// Restrict action selection to the first `n` rows of the bucket
    /// (DESIGN.md §11): when a `num_envs` request is rounded up to the
    /// nearest lowered `_b{B}` bucket, the `B - n` trailing rows are
    /// padding. The policy artifact still computes Q-values for them
    /// (shapes are frozen), but no action is selected, no RNG draw is
    /// consumed and nothing is written to their action-buffer rows —
    /// the stream of random numbers matches an unpadded run exactly.
    pub fn set_active_rows(&mut self, n: usize) -> Result<()> {
        anyhow::ensure!(
            n >= 1 && n <= self.batch,
            "active rows {} out of range 1..={} (artifact bucket {})",
            n,
            self.batch,
            self.batch
        );
        self.active = n;
        Ok(())
    }

    /// Number of agents per environment instance.
    pub fn n_agents(&self) -> usize {
        self.n_agents
    }

    /// Per-agent observation width the artifact was lowered for.
    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    /// Per-agent action-space size (discrete actions / head width).
    pub fn n_actions(&self) -> usize {
        self.n_actions
    }

    /// Per-row width of the flat recurrent carry in f32s: hidden state
    /// plus (for DIAL) the message inbox, concatenated per row. 0 for
    /// feedforward families — such systems have no carry to export.
    pub fn carry_width(&self) -> usize {
        match self.kind.family() {
            Family::DqnRec => self.n_agents * self.hidden,
            Family::Dial => self.n_agents * (self.hidden + self.msg_dim),
            _ => 0,
        }
    }

    /// Copy the recurrent carry of every row into `out` (shape
    /// `[batch, carry_width]`, each row laid out `[hidden | inbox]`).
    /// Drains any device-resident carry first, so the copy reflects
    /// the state *after* the most recent policy call and pending
    /// per-row resets. The serve path uses this to scatter a batch's
    /// carry rows back to their per-session slots.
    pub fn export_carry(&mut self, out: &mut [f32]) -> Result<()> {
        let cw = self.carry_width();
        anyhow::ensure!(
            out.len() == self.batch * cw,
            "carry export buffer {} != batch {} x width {cw}",
            out.len(),
            self.batch
        );
        self.apply_pending_resets()?;
        self.drain_device_state()?;
        let hw = self.n_agents * self.hidden;
        match &self.state {
            ActorState::None => {}
            ActorState::Hidden(h) => out.copy_from_slice(h.as_f32()),
            ActorState::HiddenInbox(h, inbox) => {
                let iw = self.n_agents * self.msg_dim;
                let (hs, is) = (h.as_f32(), inbox.as_f32());
                for b in 0..self.batch {
                    let row = &mut out[b * cw..(b + 1) * cw];
                    row[..hw].copy_from_slice(&hs[b * hw..(b + 1) * hw]);
                    row[hw..].copy_from_slice(&is[b * iw..(b + 1) * iw]);
                }
            }
        }
        Ok(())
    }

    /// Overwrite the recurrent carry of every row from `rows` (the
    /// inverse layout of [`VecExecutor::export_carry`]). Any
    /// device-resident carry and pending resets are discarded — the
    /// imported rows are authoritative and feed the next policy call
    /// as the host mirror. The serve path uses this to gather a
    /// batch's per-session carry rows before inference.
    pub fn import_carry(&mut self, rows: &[f32]) -> Result<()> {
        let cw = self.carry_width();
        anyhow::ensure!(
            rows.len() == self.batch * cw,
            "carry import buffer {} != batch {} x width {cw}",
            rows.len(),
            self.batch
        );
        self.dev_state = None;
        self.pending_resets.clear();
        let hw = self.n_agents * self.hidden;
        let iw = self.n_agents * self.msg_dim;
        let batch = self.batch;
        match &mut self.state {
            ActorState::None => {}
            ActorState::Hidden(h) => h.as_f32_mut().copy_from_slice(rows),
            ActorState::HiddenInbox(h, inbox) => {
                let (hs, is) = (h.as_f32_mut(), inbox.as_f32_mut());
                for b in 0..batch {
                    let row = &rows[b * cw..(b + 1) * cw];
                    hs[b * hw..(b + 1) * hw].copy_from_slice(&row[..hw]);
                    is[b * iw..(b + 1) * iw].copy_from_slice(&row[hw..]);
                }
            }
        }
        Ok(())
    }

    /// Zero the recurrent carry of every instance (drops any
    /// device-resident carry; the zeroed host mirror feeds the next
    /// call). The carry shape is dictated by the system's data-plumbing
    /// [`Family`] (via its [`crate::systems::SystemSpec`]), not by
    /// per-kind special cases.
    pub fn reset_state(&mut self) {
        self.dev_state = None;
        self.pending_resets.clear();
        self.state = match self.kind.family() {
            Family::DqnRec => ActorState::Hidden(HostTensor::zeros_f32(
                vec![self.batch, self.n_agents, self.hidden],
            )),
            Family::Dial => ActorState::HiddenInbox(
                HostTensor::zeros_f32(vec![
                    self.batch,
                    self.n_agents,
                    self.hidden,
                ]),
                HostTensor::zeros_f32(vec![
                    self.batch,
                    self.n_agents,
                    self.msg_dim,
                ]),
            ),
            _ => ActorState::None,
        };
    }

    /// Zero only instance `b`'s recurrent carry (call when that
    /// instance's episode auto-resets). With a device-resident carry
    /// the zeroing is deferred and batched: the rows are masked in one
    /// host round-trip right before the next policy call.
    pub fn reset_instance(&mut self, b: usize) {
        debug_assert!(b < self.batch);
        if matches!(self.state, ActorState::None) {
            return;
        }
        if self.dev_state.is_some() {
            if !self.pending_resets.contains(&b) {
                self.pending_resets.push(b);
            }
            return;
        }
        self.zero_host_rows(&[b]);
    }

    fn zero_host_rows(&mut self, rows: &[usize]) {
        match &mut self.state {
            ActorState::None => {}
            ActorState::Hidden(h) => {
                let w = self.n_agents * self.hidden;
                for &b in rows {
                    h.as_f32_mut()[b * w..(b + 1) * w].fill(0.0);
                }
            }
            ActorState::HiddenInbox(h, inbox) => {
                let w = self.n_agents * self.hidden;
                for &b in rows {
                    h.as_f32_mut()[b * w..(b + 1) * w].fill(0.0);
                }
                let w = self.n_agents * self.msg_dim;
                for &b in rows {
                    inbox.as_f32_mut()[b * w..(b + 1) * w].fill(0.0);
                }
            }
        }
    }

    /// Pull a device-resident carry back into the host mirror (one
    /// fetch per tensor) and drop the device buffers. No-op when the
    /// carry already lives on the host.
    fn drain_device_state(&mut self) -> Result<()> {
        let Some(carry) = self.dev_state.take() else {
            return Ok(());
        };
        match &mut self.state {
            ActorState::None => {}
            ActorState::Hidden(h) => {
                *h = self.artifact.to_host(&carry.hidden, 1)?;
            }
            ActorState::HiddenInbox(h, inbox) => {
                *h = self.artifact.to_host(&carry.hidden, 1)?;
                let ib = carry.inbox.as_ref().expect("DIAL carry has inbox");
                *inbox = self.artifact.to_host(ib, 2)?;
            }
        }
        Ok(())
    }

    /// Apply deferred per-row resets. Drains the device carry (if any)
    /// first, so one reset event costs one host round-trip however many
    /// rows it covers — the next call re-uploads the masked mirror.
    fn apply_pending_resets(&mut self) -> Result<()> {
        if self.pending_resets.is_empty() {
            return Ok(());
        }
        self.drain_device_state()?;
        let rows = std::mem::take(&mut self.pending_resets);
        self.zero_host_rows(&rows);
        self.pending_resets = rows;
        self.pending_resets.clear();
        Ok(())
    }

    /// Update parameters from the server copy.
    pub fn set_params(&mut self, version: u64, params: &[f32]) {
        self.params.as_f32_mut().copy_from_slice(params);
        self.params_version = version;
        self.params_buf = None; // stale device copy
    }

    fn ensure_params_buf(&mut self) -> Result<()> {
        if self.params_buf.is_none() {
            let dims = [self.params.len()];
            self.params_buf =
                Some(self.artifact.upload(&self.params, &dims)?);
        }
        Ok(())
    }

    /// Select a joint action for every row of the SoA batch with ONE
    /// batched policy-artifact call, writing the result into `out`.
    ///
    /// This is the steady-state hot path: parameters and the recurrent
    /// carry stay on device (`Arg::Dev`), only the `[B, N, O]`
    /// observations are uploaded and only the `[B, N, A]` action head
    /// is downloaded. `eps`/`sigma` control exploration exactly as in
    /// [`Executor::select_actions`].
    pub fn select_actions_into(
        &mut self,
        buf: &VecStepBuf,
        eps: f32,
        sigma: f32,
        out: &mut ActionBuf,
    ) -> Result<()> {
        anyhow::ensure!(
            buf.num_envs() == self.batch
                && buf.n_agents() == self.n_agents
                && buf.obs_dim() == self.obs_dim,
            "vec step buf [{}x{}x{}] != artifact [{}x{}x{}]",
            buf.num_envs(),
            buf.n_agents(),
            buf.obs_dim(),
            self.batch,
            self.n_agents,
            self.obs_dim
        );
        anyhow::ensure!(
            out.num_envs() == self.batch,
            "action buf batch {} != artifact batch {}",
            out.num_envs(),
            self.batch
        );
        self.apply_pending_resets()?;
        self.ensure_params_buf()?;
        let artifact = self.artifact.clone();
        let pbuf = self.params_buf.as_ref().unwrap();
        let q = if matches!(self.state, ActorState::None) {
            // feedforward: one declared output, no carry to keep on
            // device — the host-output path is exact here
            let mut outs = artifact
                .call_mixed(&[Arg::Dev(pbuf), Arg::Host(&buf.obs)])?;
            outs.swap_remove(0)
        } else {
            // take the device carry out so the post-call reassignment
            // does not alias the argument borrows
            let dev = self.dev_state.take();
            let mut args: Vec<Arg> = Vec::with_capacity(4);
            args.push(Arg::Dev(pbuf));
            args.push(Arg::Host(&buf.obs));
            match (&self.state, &dev) {
                (_, Some(carry)) => {
                    args.push(Arg::Dev(&carry.hidden));
                    if let Some(ib) = &carry.inbox {
                        args.push(Arg::Dev(ib));
                    }
                }
                (ActorState::Hidden(h), None) => {
                    args.push(Arg::Host(h));
                }
                (ActorState::HiddenInbox(h, inbox), None) => {
                    args.push(Arg::Host(h));
                    args.push(Arg::Host(inbox));
                }
                (ActorState::None, None) => unreachable!(),
            }
            let outs = artifact.call_device(&args)?;
            drop(args);
            let q = artifact.to_host(&outs[0], 0)?;
            let mut it = outs.into_iter();
            let _q_dev = it.next();
            let hidden = it.next().expect("recurrent policy outputs");
            let inbox = it.next();
            self.dev_state = Some(DevCarry { hidden, inbox });
            q
        };

        let per_env = self.n_agents * self.n_actions;
        let qs = q.as_f32(); // [B, N, A]
        // padding rows (active..batch) are skipped entirely: no action
        // selection, no RNG consumption, no action-buffer writes
        for b in 0..self.active {
            let q_row = &qs[b * per_env..(b + 1) * per_env];
            if self.kind.discrete() {
                select_discrete_row(
                    q_row,
                    self.n_agents,
                    self.n_actions,
                    buf.legal_row(b),
                    eps,
                    &mut self.rng,
                    out.disc_row_mut(b),
                );
            } else {
                let row = out.cont_row_mut(b);
                row.copy_from_slice(q_row);
                if sigma > 0.0 {
                    for i in 0..self.n_agents {
                        gaussian_noise(
                            &mut row[i * self.n_actions
                                ..(i + 1) * self.n_actions],
                            sigma,
                            &mut self.rng,
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Select a joint action for every environment instance with ONE
    /// batched policy artifact call (legacy array-of-structs path).
    pub fn select_actions_vec(
        &mut self,
        vs: &VecStep,
        eps: f32,
        sigma: f32,
    ) -> Result<Vec<Actions>> {
        let steps: Vec<&TimeStep> = vs.steps.iter().collect();
        self.select_actions_steps(&steps, eps, sigma)
    }

    /// [`Self::select_actions_vec`] over borrowed per-instance
    /// timesteps — the obs tensor is packed straight from the borrows
    /// (no `TimeStep` clone on the hot path). Carries recurrent state
    /// on the host; if a device-resident carry is live (mixed use with
    /// [`VecExecutor::select_actions_into`]) it is drained first.
    pub fn select_actions_steps(
        &mut self,
        steps: &[&TimeStep],
        eps: f32,
        sigma: f32,
    ) -> Result<Vec<Actions>> {
        anyhow::ensure!(
            steps.len() == self.batch,
            "vec step batch {} != artifact batch {}",
            steps.len(),
            self.batch
        );
        self.apply_pending_resets()?;
        self.drain_device_state()?;
        let mut data =
            Vec::with_capacity(self.batch * self.n_agents * self.obs_dim);
        for ts in steps {
            anyhow::ensure!(
                ts.observations.len() == self.n_agents
                    && ts.observations.iter().all(|o| o.len() == self.obs_dim),
                "obs shape mismatch (want {}x{})",
                self.n_agents,
                self.obs_dim
            );
            for o in &ts.observations {
                data.extend_from_slice(o);
            }
        }
        let obs = HostTensor::f32(
            vec![self.batch, self.n_agents, self.obs_dim],
            data,
        );
        self.ensure_params_buf()?;
        let pbuf = self.params_buf.as_ref().unwrap();
        let mut outputs = match &self.state {
            ActorState::None => self
                .artifact
                .call_mixed(&[Arg::Dev(pbuf), Arg::Host(&obs)])?,
            ActorState::Hidden(h) => self.artifact.call_mixed(&[
                Arg::Dev(pbuf),
                Arg::Host(&obs),
                Arg::Host(h),
            ])?,
            ActorState::HiddenInbox(h, inbox) => self.artifact.call_mixed(&[
                Arg::Dev(pbuf),
                Arg::Host(&obs),
                Arg::Host(h),
                Arg::Host(inbox),
            ])?,
        };
        // move the fresh carry out of the outputs instead of cloning it
        // (outputs[0] stays in place: indices removed back to front)
        match &mut self.state {
            ActorState::None => {}
            ActorState::Hidden(h) => *h = outputs.swap_remove(1),
            ActorState::HiddenInbox(h, inbox) => {
                *inbox = outputs.swap_remove(2);
                *h = outputs.swap_remove(1);
            }
        }

        let per_env = self.n_agents * self.n_actions;
        let out = outputs[0].as_f32(); // [B, N, A]
        let mut joint = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            let block = &out[b * per_env..(b + 1) * per_env];
            let legal_b = steps[b].legal_actions.as_ref();
            if self.kind.discrete() {
                let a = (0..self.n_agents)
                    .map(|i| {
                        let qi =
                            &block[i * self.n_actions..(i + 1) * self.n_actions];
                        let legal = legal_b.map(|l| l[i].as_slice());
                        epsilon_greedy(
                            qi,
                            self.n_actions,
                            legal,
                            eps,
                            &mut self.rng,
                        )
                    })
                    .collect();
                joint.push(Actions::Discrete(a));
            } else {
                let a = (0..self.n_agents)
                    .map(|i| {
                        let mut ai = block
                            [i * self.n_actions..(i + 1) * self.n_actions]
                            .to_vec();
                        if sigma > 0.0 {
                            gaussian_noise(&mut ai, sigma, &mut self.rng);
                        }
                        ai
                    })
                    .collect();
                joint.push(Actions::Continuous(a));
            }
        }
        Ok(joint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{ActionSpec, Actions as CoreActions, EnvSpec, StepType};
    use crate::env::{MultiAgentEnv, VecEnv};

    /// smac_lite-shaped fixture: discrete actions with a legal mask
    /// that only ever allows action `t % n + (row legal offset)`, plus
    /// short episodes so a B>1 batch crosses auto-reset boundaries.
    struct MaskedEnv {
        spec: EnvSpec,
        t: usize,
        id: usize,
        limit: usize,
    }

    impl MaskedEnv {
        fn new(id: usize) -> Self {
            MaskedEnv {
                spec: EnvSpec {
                    name: "masked".into(),
                    n_agents: 3,
                    obs_dim: 2,
                    action: ActionSpec::Discrete { n: 5 },
                    state_dim: 0,
                    episode_limit: 4,
                },
                t: 0,
                id,
                limit: 4,
            }
        }

        fn meta(&self) -> crate::core::StepMeta {
            crate::core::StepMeta {
                step_type: if self.t == 0 {
                    StepType::First
                } else if self.t >= self.limit {
                    StepType::Last
                } else {
                    StepType::Mid
                },
                discount: 1.0,
            }
        }
    }

    impl MultiAgentEnv for MaskedEnv {
        fn spec(&self) -> &EnvSpec {
            &self.spec
        }

        fn reset(&mut self) -> crate::core::TimeStep {
            let m = self.reset_soa();
            self.materialize(m)
        }

        fn step(&mut self, _a: &CoreActions) -> crate::core::TimeStep {
            let m = self.step_soa(&crate::core::ActionsRef::Discrete(&[
                0, 0, 0,
            ]));
            self.materialize(m)
        }

        fn writes_soa(&self) -> bool {
            true
        }

        fn has_legal(&self) -> bool {
            true
        }

        fn reset_soa(&mut self) -> crate::core::StepMeta {
            self.t = 0;
            self.meta()
        }

        fn step_soa(
            &mut self,
            _a: &crate::core::ActionsRef,
        ) -> crate::core::StepMeta {
            self.t += 1;
            self.meta()
        }

        fn write_obs(&mut self, out: &mut [f32]) {
            out.fill(self.t as f32);
        }

        fn write_rewards(&mut self, out: &mut [f32]) {
            out.fill(if self.t == 0 { 0.0 } else { 1.0 });
        }

        fn write_legal(&mut self, out: &mut [f32]) {
            out.fill(0.0);
            // agent i's single legal action rotates with t, offset by
            // the instance id so rows differ
            for i in 0..3 {
                out[i * 5 + (self.t + self.id + i) % 5] = 1.0;
            }
        }
    }

    /// Satellite: ε-greedy through the vectorized SoA path must never
    /// pick an illegal action for any row of a B>1 batch — including
    /// the row right after an auto-reset — at any ε.
    #[test]
    fn vectorized_masking_never_selects_illegal() {
        let envs: Vec<Box<dyn MultiAgentEnv>> =
            (0..4).map(|i| -> Box<dyn MultiAgentEnv> {
                Box::new(MaskedEnv::new(i))
            }).collect();
        let mut venv = VecEnv::new(envs).unwrap();
        let mut buf = venv.make_buf();
        let mut abuf = venv.make_action_buf();
        venv.reset_into(&mut buf);
        let mut rng = Rng::new(3);
        // Q prefers an often-illegal action everywhere: the mask must
        // override the argmax on greedy steps and bound random steps
        let q: Vec<f32> = (0..4 * 3 * 5)
            .map(|k| if k % 5 == 0 { 10.0 } else { (k % 7) as f32 })
            .collect();
        let mut saw_reset_row = false;
        for step in 0..40 {
            let eps = [0.0, 0.3, 1.0][step % 3];
            for b in 0..4 {
                select_discrete_row(
                    &q[b * 15..(b + 1) * 15],
                    3,
                    5,
                    buf.legal_row(b),
                    eps,
                    &mut rng,
                    abuf.disc_row_mut(b),
                );
                let legal = buf.legal_row(b).unwrap();
                for (i, &a) in abuf.row(b).as_discrete().iter().enumerate()
                {
                    assert_eq!(
                        legal[i * 5 + a as usize],
                        1.0,
                        "illegal action {a} for agent {i} row {b} \
                         (step {step}, eps {eps})"
                    );
                }
                saw_reset_row |= buf.step_type(b) == StepType::First
                    && step > 0;
            }
            venv.step_into(&abuf, &mut buf);
        }
        assert!(saw_reset_row, "test never crossed an auto-reset");
    }
}
