//! System nodes: the executor / trainer / evaluator programs that used
//! to live as inline closures in `train()`.
//!
//! Each node is a plain struct with an explicit [`SystemHandles`]
//! context (the shared services of paper Block 2's program graph:
//! sharded replay table, parameter server, counters, stop signal, eval
//! sink) and a fallible `run(&mut self) -> Result<()>`. Errors are
//! *propagated* through the launcher's typed outcome channel
//! ([`crate::launch::NodeOutcome`]) instead of `eprintln!`-and-die: a
//! failing node trips the program's [`StopSignal`] and
//! `SystemBuilder`-built runs surface it as a `train()` error naming
//! the node.
//!
//! Research forks override what a node is made of, not how it runs:
//! the [`EnvFactory`] and [`AdderFactory`] hooks (set on
//! [`crate::systems::SystemBuilder`]) swap the environment or the
//! experience packaging per node without touching the loop bodies.

#![warn(missing_docs)]

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::TrainConfig;
use crate::core::StepType;
use crate::env::wrappers::Fingerprint;
use crate::env::{ActionBuf, MultiAgentEnv, VecEnv, VecStepBuf};
use crate::exploration::EpsilonSchedule;
use crate::launch::StopSignal;
use crate::metrics::{Counters, MovingStats};
use crate::params::ParamStore;
use crate::replay::{
    ItemSink, ItemSource, SequenceAdder, TransitionAdder,
};
use crate::runtime::Engine;
use crate::systems::builder::make_vec_evaluator_with;
use crate::systems::{SystemSpec, Trainer, VecExecutor};

/// Per-instance adder slot for the vectorized executor loop: each
/// environment instance accumulates its own episode independently.
/// Built by [`SystemSpec::make_adder`] or a custom [`AdderFactory`].
pub enum Adder {
    /// N-step transition adder (feedforward systems).
    Tr(TransitionAdder),
    /// Fixed-length sequence adder (recurrent systems).
    Sq(SequenceAdder),
}

impl Adder {
    /// Start a new episode from the reset step in `next`'s row `row`.
    pub fn observe_first_row(&mut self, next: &VecStepBuf, row: usize) {
        match self {
            Adder::Tr(a) => a.observe_first_row(next, row),
            Adder::Sq(a) => a.observe_first_row(next, row),
        }
    }

    /// Record one (action, resulting step) pair for row `row`.
    pub fn observe_row(
        &mut self,
        actions: &ActionBuf,
        row: usize,
        next: &VecStepBuf,
    ) {
        match self {
            Adder::Tr(a) => a.observe_row(actions, row, next),
            Adder::Sq(a) => a.observe_row(actions, row, next),
        }
    }
}

/// One evaluator measurement (a point on the paper's learning curves).
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    /// Wall-clock seconds since the run started.
    pub wall_s: f64,
    /// Total environment steps across all executors at measurement time.
    pub env_steps: u64,
    /// Total trainer steps at measurement time.
    pub train_steps: u64,
    /// Mean greedy episode return over `eval_episodes`.
    pub mean_return: f32,
}

/// Builds one environment instance for a node: `(seed, fingerprint)`
/// → env. The default factory is [`crate::systems::env_for_preset`]
/// over `cfg.preset`; override it on the builder to run a custom
/// environment through an existing system's artifacts.
pub type EnvFactory = Arc<
    dyn Fn(u64, Option<Fingerprint>) -> Result<Box<dyn MultiAgentEnv>>
        + Send
        + Sync,
>;

/// Builds one per-instance [`Adder`] feeding a replay shard. The
/// default factory is [`SystemSpec::make_adder`]; override it on the
/// builder to change how experience is packaged (e.g. prioritised
/// insertion or a different sequence period) without forking the
/// executor loop.
pub type AdderFactory =
    Arc<dyn Fn(Arc<dyn ItemSink>) -> Adder + Send + Sync>;

/// Shared services every node of a built system runs against — the
/// edges of the paper's program graph (Block 2 inset), made explicit
/// instead of being closure captures. Replay handles are *not* here:
/// each node owns its own end of the replay data path (the trainer a
/// sample source, each executor its shard sink), which is what lets
/// the same node structs run in-process or against remote services
/// (DESIGN.md §10).
#[derive(Clone)]
pub struct SystemHandles {
    /// Versioned parameter store the trainer publishes to — the
    /// in-process [`crate::params::ParameterServer`] or a remote
    /// client speaking the param wire protocol.
    pub server: Arc<dyn ParamStore>,
    /// Global env/train step + episode counters.
    pub counters: Arc<Counters>,
    /// Cooperative shutdown flag shared by every node.
    pub stop: StopSignal,
    /// Eval sink: the evaluator appends learning-curve points here.
    pub evals: Arc<Mutex<Vec<EvalPoint>>>,
    /// Moving window over training episode returns.
    pub train_returns: Arc<Mutex<MovingStats>>,
    /// Shared exploration fingerprint (the `_fp` presets read it).
    pub fingerprint: Fingerprint,
    /// Program start time (evaluator timestamps are relative to it).
    pub started: Instant,
}

/// The trainer checkpoint location for `cfg`:
/// `{log_dir}/trainer.ckpt` when checkpointing is on
/// (`checkpoint_interval > 0`), else `None`. Shared by every
/// [`TrainerNode`] construction site so a restarted trainer looks for
/// its checkpoint exactly where the previous incarnation wrote it.
pub fn trainer_checkpoint_path(cfg: &TrainConfig) -> Option<PathBuf> {
    (cfg.checkpoint_interval > 0)
        .then(|| PathBuf::from(&cfg.log_dir).join("trainer.ckpt"))
}

/// The trainer node: device-resident + prefetched train loop
/// (DESIGN.md §8). Samples the sharded table round-robin, runs the
/// fused train-step artifact and publishes parameters every
/// `publish_interval` steps, with a final flush at shutdown.
///
/// With a [`TrainerNode::checkpoint`] path set, the node additionally
/// saves a `MAVATRN1` checkpoint every `checkpoint_interval` train
/// steps (and at clean shutdown), and *resumes* from an existing
/// checkpoint at startup — the recovery half of the supervisor's
/// trainer restart policy (DESIGN.md §13).
pub struct TrainerNode {
    /// System being trained.
    pub spec: &'static SystemSpec,
    /// Run configuration.
    pub cfg: TrainConfig,
    /// Shared program services.
    pub handles: SystemHandles,
    /// Train-step artifact name (from [`SystemSpec::train_artifact`]).
    pub train_name: String,
    /// Initial parameters (the artifact's `params0` init blob).
    pub params0: Vec<f32>,
    /// Initial optimiser state (the artifact's `opt0` init blob).
    pub opt0: Vec<f32>,
    /// Where sample batches come from: the in-process
    /// [`crate::replay::ShardedTable`] or a remote replay sampler.
    pub source: Arc<dyn ItemSource + Send + Sync>,
    /// Checkpoint file (`{log_dir}/trainer.ckpt` when
    /// `checkpoint_interval > 0`, else `None` = no checkpointing).
    pub checkpoint: Option<PathBuf>,
}

impl TrainerNode {
    /// Run the train loop until stop / `max_train_steps` / table close.
    pub fn run(&mut self) -> Result<()> {
        let h = &self.handles;
        let mut engine = Engine::load(&self.cfg.artifacts_dir)?;
        let mut trainer = if self.cfg.num_devices > 1 {
            // data-parallel lanes (DESIGN.md §11): sharded gradients
            // all-reduced across `num_devices` lock-step replicas.
            // The builder fail-fasts on missing dp artifacts; this
            // context covers direct TrainerNode construction.
            let d = self.cfg.num_devices;
            let grad = engine
                .artifact(&format!("{}_dp{d}", self.train_name))
                .with_context(|| {
                    format!(
                        "num_devices={d} needs a lowered \
                         {}_dp{d} artifact (DP_SHARDS in \
                         python/compile/model.py; mean-loss systems \
                         only) — re-run `make artifacts`",
                        self.train_name
                    )
                })?;
            let apply =
                engine.artifact(&format!("{}_apply", self.train_name))?;
            if engine.device_count() < d {
                eprintln!(
                    "[trainer] note: {} PJRT device(s) visible, \
                     running {d} data-parallel lanes on them",
                    engine.device_count()
                );
            }
            Trainer::new_data_parallel(
                self.spec.family,
                grad,
                apply,
                self.params0.clone(),
                self.opt0.clone(),
                self.cfg.lr,
                self.cfg.tau,
                self.cfg.seed ^ 0x77aa,
            )?
        } else {
            let artifact = engine.artifact(&self.train_name)?;
            Trainer::new(
                self.spec.family,
                artifact,
                self.params0.clone(),
                self.opt0.clone(),
                self.cfg.lr,
                self.cfg.tau,
                self.cfg.seed ^ 0x77aa,
            )?
        };
        trainer.set_publish_interval(self.cfg.publish_interval);
        let resumed = match &self.checkpoint {
            Some(path) if path.exists() => {
                trainer.load_checkpoint(path).with_context(|| {
                    format!("resume from checkpoint {}", path.display())
                })?;
                eprintln!(
                    "[trainer] resumed from {} at step {}",
                    path.display(),
                    trainer.stats.steps
                );
                true
            }
            _ => false,
        };
        if !resumed {
            // fresh start only: on resume the restored target network
            // must NOT be clobbered with a copy of the online params
            trainer.init_target_from_params()?;
        }
        h.server.push(trainer.params())?;
        // sample+assemble runs on a prefetch thread; only plain
        // HostTensors cross the channel (no PJRT handle leaves this
        // thread — the §2 engine-per-thread rule holds)
        let prefetch = trainer.spawn_prefetcher(self.source.clone(), 2);
        while !h.stop.is_stopped() {
            // Ok(None) once the table closed (shutdown);
            // Err if assembly failed on the prefetch thread
            let Some(batch) = prefetch.next_batch()? else {
                break;
            };
            trainer.step_batch(&batch)?;
            prefetch.recycle(batch);
            h.counters.add_train_step();
            trainer.maybe_publish(h.server.as_ref())?;
            if let Some(path) = &self.checkpoint {
                if self.cfg.checkpoint_interval > 0
                    && trainer.stats.steps % self.cfg.checkpoint_interval
                        == 0
                {
                    trainer.save_checkpoint(path)?;
                }
            }
            if self.cfg.max_train_steps > 0
                && trainer.stats.steps >= self.cfg.max_train_steps
            {
                break;
            }
        }
        // a final checkpoint so a post-run restart resumes at the end
        // state instead of replaying the last cadence window
        if let Some(path) = &self.checkpoint {
            trainer.save_checkpoint(path)?;
        }
        // the publish cadence may be mid-window at shutdown: flush the
        // final parameters unconditionally; a remote store may already
        // be gone during a stop-requested teardown, which is not a
        // trainer failure
        if let Err(e) = trainer.publish(h.server.as_ref()) {
            if !h.stop.is_stopped() {
                return Err(e);
            }
        }
        Ok(())
    }
}

/// One executor node of the vectorized hot path (DESIGN.md §6): steps
/// `num_envs_per_executor` environment instances through a [`VecEnv`]
/// with one batched policy-artifact call per vector step, feeding its
/// own replay shard so executors never contend on a replay lock.
pub struct ExecutorNode {
    /// Executor index (names the node and strides its seeds).
    pub worker: usize,
    /// System being run.
    pub spec: &'static SystemSpec,
    /// Run configuration.
    pub cfg: TrainConfig,
    /// Shared program services.
    pub handles: SystemHandles,
    /// This executor's own replay shard sink — a local
    /// [`crate::replay::Table`] or a remote shard client.
    pub shard: Arc<dyn ItemSink>,
    /// Policy artifact name lowered for this executor's env batch.
    pub policy_name: String,
    /// Initial parameters (the artifact's `params0` init blob).
    pub params0: Vec<f32>,
    /// Environment factory (default: the preset's env).
    pub env_factory: EnvFactory,
    /// Per-instance adder factory (default: the spec's adder).
    pub adder_factory: AdderFactory,
}

impl ExecutorNode {
    /// Run the acting loop until stop / `max_env_steps`.
    pub fn run(&mut self) -> Result<()> {
        let h = &self.handles;
        let num_envs = self.cfg.num_envs_per_executor.max(1);
        let mut engine = Engine::load(&self.cfg.artifacts_dir)?;
        let artifact =
            engine.artifact(&self.policy_name).with_context(|| {
                format!(
                    "policy artifact {:?} unavailable — it was picked \
                     from the manifest's bucket ladder; regenerate with \
                     `make artifacts`",
                    self.policy_name
                )
            })?;
        let mut executor = VecExecutor::new(
            self.spec.kind,
            artifact,
            self.params0.clone(),
            self.cfg.seed + 1000 + self.worker as u64,
        )?;
        // the artifact is the BUCKET num_envs rounded up to
        // (DESIGN.md §11): real envs fill rows 0..num_envs, the
        // executor masks the padding rows out of action selection
        executor.set_active_rows(num_envs)?;
        let bucket = executor.num_envs();
        let mut instances = Vec::with_capacity(num_envs);
        for i in 0..num_envs {
            instances.push((self.env_factory)(
                self.cfg.seed + (self.worker * num_envs + i) as u64,
                Some(h.fingerprint.clone()),
            )?);
        }
        let mut venv = VecEnv::new(instances)?;
        let schedule = EpsilonSchedule::new(
            self.cfg.eps_start,
            self.cfg.eps_end,
            self.cfg.eps_decay_steps,
        );
        // one adder per instance: episodes accumulate independently
        // across the batch
        let mut adders: Vec<Adder> = (0..num_envs)
            .map(|_| (self.adder_factory)(self.shard.clone()))
            .collect();
        let mut ep_returns = vec![0.0f32; num_envs];
        // SoA double buffer: `cur` feeds the policy call, the envs
        // write the next vector step into `next`, then the buffers
        // swap — allocated once here, refilled in place forever after
        // (DESIGN.md §6). Sized at the bucket; rows num_envs..bucket
        // stay pad-safe defaults and are never read.
        let mut cur = venv.make_buf_padded(bucket);
        let mut next = venv.make_buf_padded(bucket);
        let mut abuf = venv.make_action_buf_padded(bucket);
        let mut params_scratch = Vec::new();
        venv.reset_into(&mut cur);
        for (i, adder) in adders.iter_mut().enumerate() {
            adder.observe_first_row(&cur, i);
        }
        while !h.stop.is_stopped()
            && h.counters.env_steps() < self.cfg.max_env_steps
        {
            // a permanently lost sink (remote shard disconnect) fails
            // the node instead of silently dropping experience
            self.shard.check()?;
            let eps = schedule.value(h.counters.env_steps());
            h.fingerprint.set(
                eps,
                (h.counters.env_steps() as f32
                    / self.cfg.max_env_steps as f32)
                    .min(1.0),
            );
            // ONE batched policy call for all B instances; params +
            // recurrent carry stay device-resident
            executor.select_actions_into(
                &cur,
                eps,
                self.cfg.noise_sigma,
                &mut abuf,
            )?;
            venv.step_into(&abuf, &mut next);
            let mut episode_ended = false;
            for (i, adder) in adders.iter_mut().enumerate() {
                if next.step_type(i) == StepType::First {
                    // this slot auto-reset: new episode
                    adder.observe_first_row(&next, i);
                    executor.reset_instance(i);
                    ep_returns[i] = 0.0;
                    continue;
                }
                adder.observe_row(&abuf, i, &next);
                h.counters.add_env_steps(1);
                ep_returns[i] += next.mean_reward(i);
                if next.is_last(i) {
                    h.counters.add_episode();
                    h.train_returns.lock().unwrap().push(ep_returns[i]);
                    episode_ended = true;
                }
            }
            if episode_ended {
                // cheap version check at episode boundaries
                if let Some(v) = h
                    .server
                    .sync(executor.params_version, &mut params_scratch)?
                {
                    executor.set_params(v, &params_scratch);
                }
            }
            std::mem::swap(&mut cur, &mut next);
        }
        Ok(())
    }
}

/// The evaluator node (vectorized, `eval/vec_eval.rs`). Snapshots
/// published params every `eval_every_steps` env steps and runs greedy
/// episodes through the largest lowered policy batch that fits the
/// episode budget — one artifact call advances B episodes, and the
/// node never takes a lock the executors or trainer hold, so
/// evaluation cannot stall acting or training.
pub struct EvaluatorNode {
    /// Run configuration.
    pub cfg: TrainConfig,
    /// Shared program services.
    pub handles: SystemHandles,
    /// Initial parameters (the artifact's `params0` init blob).
    pub params0: Vec<f32>,
    /// Environment factory (default: the preset's env).
    pub env_factory: EnvFactory,
}

impl EvaluatorNode {
    /// Run the measurement loop until stop.
    pub fn run(&mut self) -> Result<()> {
        let h = &self.handles;
        let mut engine = Engine::load(&self.cfg.artifacts_dir)?;
        let mut evaluator = make_vec_evaluator_with(
            &mut engine,
            &self.cfg,
            self.params0.clone(),
            self.cfg.eval_episodes,
            self.cfg.seed ^ 0xe7a1,
            &self.env_factory,
        )?;
        let mut next_eval_at = 0u64;
        while !h.stop.is_stopped() {
            let steps = h.counters.env_steps();
            if steps < next_eval_at {
                std::thread::sleep(crate::net::frame::POLL_INTERVAL);
                continue;
            }
            next_eval_at = steps + self.cfg.eval_every_steps;
            let mut buf = Vec::new();
            if let Some(v) =
                h.server.sync(evaluator.params_version(), &mut buf)?
            {
                evaluator.set_params(v, &buf);
            }
            let returns = evaluator
                .evaluate_until(self.cfg.eval_episodes, || {
                    h.stop.is_stopped()
                })?;
            if returns.is_empty() {
                continue; // stopped mid-wave or eval_episodes == 0
            }
            let point = EvalPoint {
                wall_s: h.started.elapsed().as_secs_f64(),
                env_steps: h.counters.env_steps(),
                train_steps: h.counters.train_steps(),
                mean_return: crate::eval::stats::mean(&returns) as f32,
            };
            h.evals.lock().unwrap().push(point);
        }
        Ok(())
    }
}
