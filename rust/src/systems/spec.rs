//! Data-driven system specifications: the single source of truth for
//! everything a [`SystemKind`] implies.
//!
//! The paper's core claim is that a MARL *system* is a reusable
//! composition (§4, Figure 2). Before this module, the knowledge of
//! what each system *is* — which artifact names it loads, what batch
//! layout its trainer consumes, which adder packages its transitions,
//! how it explores, whether it carries recurrent state — was scattered
//! across `match kind` arms in the builder, executor, trainer and
//! config. [`SystemSpec`] centralises all of it in one declarative
//! table ([`SPECS`]), so adding a system is declaring a spec plus its
//! lowered artifacts, not a builder rewrite.
//!
//! The spec also owns the *preset* → environment mapping
//! ([`env_for_preset`]) and the artifact naming scheme
//! (`{preset}_{system}[_{arch}]_{policy,train}` with `_b{B}` batched
//! policy variants — DESIGN.md §4).

#![warn(missing_docs)]

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::arch::Architecture;
use crate::env::wrappers::{Fingerprint, FingerprintWrapper};
use crate::env::{make_env, MultiAgentEnv};
use crate::replay::{ItemSink, SequenceAdder, TransitionAdder};
use crate::systems::nodes::Adder;
use crate::systems::{Family, SystemKind};

/// How executor experience is packaged for the replay table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdderKind {
    /// N-step transitions (feedforward systems).
    Transition,
    /// Fixed-length sequences (recurrent systems).
    Sequence,
}

/// How the executor explores around the policy output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExplorationMode {
    /// Discrete actions: ε-greedy over per-agent Q rows.
    EpsilonGreedy,
    /// Continuous actions: additive Gaussian noise on the action head.
    GaussianNoise,
}

/// Declarative description of one baseline system: everything the
/// builder, nodes and artifact lookup need beyond the hyperparameters
/// in [`crate::config::TrainConfig`].
#[derive(Debug)]
pub struct SystemSpec {
    /// The enum tag (kept for exhaustive matches in the runtime layers).
    pub kind: SystemKind,
    /// Config-string name (`TrainConfig::system`), e.g. `"vdn"`.
    pub name: &'static str,
    /// Batch layout the train artifact consumes.
    pub family: Family,
    /// How executor experience is packaged for replay.
    pub adder: AdderKind,
    /// How the executor explores.
    pub exploration: ExplorationMode,
    /// Whether the executor carries recurrent state across steps.
    pub recurrent: bool,
    /// Whether the action space is discrete.
    pub discrete: bool,
    /// Whether the artifact prefix carries the architecture tag
    /// (actor-critic systems are lowered per architecture,
    /// e.g. `walker3_mad4pg_dec`).
    pub arch_in_prefix: bool,
}

/// The system table: one [`SystemSpec`] per implemented baseline
/// (paper §4 "System implementations"). [`SystemSpec::parse`] and
/// [`SystemSpec::of`] resolve into this table.
pub const SPECS: &[SystemSpec] = &[
    SystemSpec {
        kind: SystemKind::Madqn,
        name: "madqn",
        family: Family::DqnFf,
        adder: AdderKind::Transition,
        exploration: ExplorationMode::EpsilonGreedy,
        recurrent: false,
        discrete: true,
        arch_in_prefix: false,
    },
    SystemSpec {
        kind: SystemKind::MadqnRec,
        name: "madqn_rec",
        family: Family::DqnRec,
        adder: AdderKind::Sequence,
        exploration: ExplorationMode::EpsilonGreedy,
        recurrent: true,
        discrete: true,
        arch_in_prefix: false,
    },
    SystemSpec {
        kind: SystemKind::Dial,
        name: "dial",
        family: Family::Dial,
        adder: AdderKind::Sequence,
        exploration: ExplorationMode::EpsilonGreedy,
        recurrent: true,
        discrete: true,
        arch_in_prefix: false,
    },
    SystemSpec {
        kind: SystemKind::Vdn,
        name: "vdn",
        family: Family::ValueDecomp,
        adder: AdderKind::Transition,
        exploration: ExplorationMode::EpsilonGreedy,
        recurrent: false,
        discrete: true,
        arch_in_prefix: false,
    },
    SystemSpec {
        kind: SystemKind::Qmix,
        name: "qmix",
        family: Family::ValueDecomp,
        adder: AdderKind::Transition,
        exploration: ExplorationMode::EpsilonGreedy,
        recurrent: false,
        discrete: true,
        arch_in_prefix: false,
    },
    SystemSpec {
        kind: SystemKind::Maddpg,
        name: "maddpg",
        family: Family::Ddpg,
        adder: AdderKind::Transition,
        exploration: ExplorationMode::GaussianNoise,
        recurrent: false,
        discrete: false,
        arch_in_prefix: true,
    },
    SystemSpec {
        kind: SystemKind::Mad4pg,
        name: "mad4pg",
        family: Family::Ddpg,
        adder: AdderKind::Transition,
        exploration: ExplorationMode::GaussianNoise,
        recurrent: false,
        discrete: false,
        arch_in_prefix: true,
    },
];

impl SystemSpec {
    /// The spec of a [`SystemKind`].
    pub fn of(kind: SystemKind) -> &'static SystemSpec {
        SPECS.iter()
            .find(|s| s.kind == kind)
            .expect("every SystemKind has a spec")
    }

    /// Resolve a config `system` string (e.g. `"vdn"`) into the table.
    pub fn parse(name: &str) -> Result<&'static SystemSpec> {
        match SPECS.iter().find(|s| s.name == name) {
            Some(s) => Ok(s),
            None => bail!("unknown system {name:?}"),
        }
    }

    /// Does the trainer consume sequences rather than transitions?
    pub fn sequences(&self) -> bool {
        self.adder == AdderKind::Sequence
    }

    /// Artifact name tag for this system on `preset` under `arch`,
    /// e.g. `smac3m_vdn` or `walker3_mad4pg_dec` (DESIGN.md §4).
    pub fn artifact_prefix(&self, preset: &str, arch: Architecture) -> String {
        if self.arch_in_prefix {
            format!("{preset}_{}_{}", self.name, arch.tag())
        } else {
            format!("{preset}_{}", self.name)
        }
    }

    /// Name of the `[1, N, O]` policy artifact under `prefix`.
    pub fn policy_artifact(&self, prefix: &str) -> String {
        format!("{prefix}_policy")
    }

    /// Name of the policy artifact lowered for an environment batch of
    /// `b` (the `_b{B}` variants the vectorized executor acts through;
    /// `b <= 1` is the base `[1, N, O]` artifact).
    pub fn batched_policy_artifact(&self, prefix: &str, b: usize) -> String {
        if b <= 1 {
            self.policy_artifact(prefix)
        } else {
            format!("{prefix}_policy_b{b}")
        }
    }

    /// Name of the fused train-step artifact under `prefix`.
    pub fn train_artifact(&self, prefix: &str) -> String {
        format!("{prefix}_train")
    }

    /// Build the default adder for one environment instance feeding
    /// `shard`, from the train artifact's metadata (`seq_len`) and the
    /// run's hyperparameters (`n_step`, `gamma`). This is the factory
    /// the [`crate::systems::SystemBuilder`] uses unless a per-node
    /// override replaces it.
    pub fn make_adder(
        &self,
        shard: Arc<dyn ItemSink>,
        n_step: usize,
        gamma: f32,
        seq_len: usize,
    ) -> Adder {
        match self.adder {
            AdderKind::Sequence => Adder::Sq(SequenceAdder::new(
                shard,
                seq_len.max(1),
                seq_len.max(1),
            )),
            AdderKind::Transition => {
                Adder::Tr(TransitionAdder::new(shard, n_step, gamma))
            }
        }
    }
}

/// Environment for an artifact preset (DESIGN.md §4).
///
/// The `_fp` suffix is orthogonal to the base preset: `smac3m_fp`,
/// `matrix2_fp`, … all wrap the base environment with the fingerprint
/// stabilisation module ([`FingerprintWrapper`]); a genuinely unknown
/// base is rejected with the same error as an unknown plain preset.
pub fn env_for_preset(
    preset: &str,
    seed: u64,
    fingerprint: Option<Fingerprint>,
) -> Result<Box<dyn MultiAgentEnv>> {
    let base_preset = preset.strip_suffix("_fp").unwrap_or(preset);
    let base = match base_preset {
        "matrix2" => "matrix",
        "switch3" => "switch",
        "smac3m" => "smac_lite",
        "spread3" => "mpe_spread",
        "speaker2" => "mpe_speaker_listener",
        "walker3" => "multiwalker",
        _ => bail!("unknown preset {preset:?}"),
    };
    let env = make_env(base, seed)?;
    if preset.ends_with("_fp") {
        let fp = fingerprint.unwrap_or_default();
        // Box<dyn MultiAgentEnv> implements the trait (all SoA hooks
        // forwarded), so the wrapper composes over it directly and the
        // _fp preset stays on the allocation-free path
        Ok(Box::new(FingerprintWrapper::new(env, fp)))
    } else {
        Ok(env)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_KINDS: [SystemKind; 7] = [
        SystemKind::Madqn,
        SystemKind::MadqnRec,
        SystemKind::Dial,
        SystemKind::Vdn,
        SystemKind::Qmix,
        SystemKind::Maddpg,
        SystemKind::Mad4pg,
    ];

    /// Every kind resolves to exactly one spec and parse round-trips
    /// through the spec's name.
    #[test]
    fn table_is_total_and_round_trips() {
        assert_eq!(SPECS.len(), ALL_KINDS.len());
        for kind in ALL_KINDS {
            let spec = SystemSpec::of(kind);
            assert_eq!(spec.kind, kind);
            let reparsed = SystemSpec::parse(spec.name).unwrap();
            assert_eq!(reparsed.kind, kind, "{} round-trip", spec.name);
        }
        assert!(SystemSpec::parse("bogus").is_err());
    }

    /// Spec fields must be mutually coherent for every system:
    /// recurrent systems train on sequences, continuous systems explore
    /// with noise, discrete with ε-greedy, and the family matches the
    /// legacy SystemKind accessors (which now delegate here).
    #[test]
    fn specs_are_internally_coherent() {
        for spec in SPECS {
            assert_eq!(
                spec.recurrent,
                spec.adder == AdderKind::Sequence,
                "{}: recurrence and sequence replay must agree",
                spec.name
            );
            assert_eq!(
                spec.discrete,
                spec.exploration == ExplorationMode::EpsilonGreedy,
                "{}: action space and exploration mode must agree",
                spec.name
            );
            assert_eq!(
                spec.arch_in_prefix,
                spec.family == Family::Ddpg,
                "{}: only actor-critic systems are lowered per arch",
                spec.name
            );
            assert_eq!(spec.family, spec.kind.family(), "{}", spec.name);
            assert_eq!(spec.discrete, spec.kind.discrete(), "{}", spec.name);
            assert_eq!(spec.recurrent, spec.kind.recurrent(), "{}", spec.name);
            assert_eq!(spec.sequences(), spec.kind.sequences(), "{}", spec.name);
        }
    }

    /// Artifact naming: prefix carries the arch tag exactly for the
    /// actor-critic systems, batched variants are `_b{B}` suffixed, and
    /// `b <= 1` degrades to the base policy name.
    #[test]
    fn artifact_names_are_coherent() {
        for spec in SPECS {
            let prefix = spec.artifact_prefix("smac3m", Architecture::Decentralised);
            if spec.arch_in_prefix {
                assert_eq!(prefix, format!("smac3m_{}_dec", spec.name));
            } else {
                assert_eq!(prefix, format!("smac3m_{}", spec.name));
            }
            assert_eq!(
                spec.policy_artifact(&prefix),
                format!("{prefix}_policy")
            );
            assert_eq!(
                spec.train_artifact(&prefix),
                format!("{prefix}_train")
            );
            assert_eq!(
                spec.batched_policy_artifact(&prefix, 16),
                format!("{prefix}_policy_b16")
            );
            for b in [0, 1] {
                assert_eq!(
                    spec.batched_policy_artifact(&prefix, b),
                    spec.policy_artifact(&prefix)
                );
            }
        }
    }

    /// The `_fp` suffix wraps ANY known base preset and unknown bases
    /// are rejected with the unknown-preset error, fp or not.
    #[test]
    fn fp_suffix_is_orthogonal_to_base_preset() {
        for base in
            ["matrix2", "switch3", "smac3m", "spread3", "speaker2", "walker3"]
        {
            let plain = env_for_preset(base, 0, None).unwrap();
            let fp = env_for_preset(&format!("{base}_fp"), 0, None).unwrap();
            // the wrapper widens each observation by the 2 fingerprint
            // features; everything else matches the base env
            assert_eq!(
                fp.spec().obs_dim,
                plain.spec().obs_dim + 2,
                "{base}_fp must wrap the {base} base env"
            );
            assert_eq!(fp.spec().n_agents, plain.spec().n_agents);
        }
        for bogus in ["bogus", "bogus_fp", "_fp"] {
            let err = env_for_preset(bogus, 0, None).unwrap_err();
            assert!(
                err.to_string().contains("unknown preset"),
                "{bogus}: {err}"
            );
        }
    }
}
