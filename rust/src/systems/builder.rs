//! System construction: the fluent [`SystemBuilder`] that wires
//! executors, trainer, replay, parameter server and evaluator into a
//! Launchpad-style program and runs it (paper Block 2).
//!
//! Three layers (DESIGN.md §9):
//! 1. [`SystemSpec`](crate::systems::SystemSpec) — *what* a system is
//!    (artifact names, batch family, adder kind, exploration mode);
//! 2. [`crate::systems::nodes`] — *how* each node runs (executor /
//!    trainer / evaluator loops over an explicit
//!    [`SystemHandles`] context, each a fallible `run()`);
//! 3. [`SystemBuilder`] → [`System`] — *wiring*: which nodes exist,
//!    how replay is sharded, and the per-node override points
//!    (custom env factory, custom adder) for research forks.
//!
//! [`train`] is a thin wrapper over the builder; node errors are
//! propagated through the launcher's typed outcome channel and turn
//! into a `train()` error naming the failed node.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::config::TrainConfig;
use crate::core::StepType;
use crate::env::wrappers::Fingerprint;
use crate::env::{MultiAgentEnv, VecEnv};
use crate::eval::VecEvaluator;
use crate::launch::{
    node_failure_error, LocalLauncher, NodeKind, Program, StopSignal,
};
use crate::metrics::{Counters, MovingStats};
use crate::params::ParameterServer;
use crate::replay::{ItemSink, RateLimiter, Selector, ShardedTable};
use crate::runtime::{BucketLadder, Engine, Manifest};
use crate::systems::nodes::{
    Adder, AdderFactory, EnvFactory, EvalPoint, EvaluatorNode, ExecutorNode,
    SystemHandles, TrainerNode,
};
use crate::systems::spec::env_for_preset;
use crate::systems::{Executor, SystemSpec, VecExecutor};

/// One node failure recorded by a system run: which node died and the
/// rendered error chain.
#[derive(Clone, Debug)]
pub struct NodeFailure {
    /// Name of the failed node (e.g. `executor_0`).
    pub node: String,
    /// The propagated error, rendered with its context chain.
    pub error: String,
}

/// Outcome of a full distributed training run.
#[derive(Clone, Debug, Default)]
pub struct TrainResult {
    /// Evaluator measurements in chronological order.
    pub evals: Vec<EvalPoint>,
    /// Total environment steps executed.
    pub env_steps: u64,
    /// Total trainer steps executed.
    pub train_steps: u64,
    /// Total completed episodes across all executors.
    pub episodes: u64,
    /// Total wall-clock seconds.
    pub wall_s: f64,
    /// moving-average training return at shutdown
    pub train_return: f32,
    /// Final published parameters (the trainer flushes at shutdown), so
    /// callers — the experiment harness in particular — can evaluate the
    /// trained policy without re-running the program graph.
    pub final_params: Vec<f32>,
    /// Nodes that returned an error (or panicked) during the run, in
    /// launch order. Empty on a clean run; [`System::run`] (and
    /// therefore [`train`]) converts a non-empty list into an `Err`
    /// naming the node.
    pub node_failures: Vec<NodeFailure>,
}

impl TrainResult {
    /// Best evaluator measurement of the run, or `None` when no
    /// evaluation ever completed (evaluator disabled, or the run was
    /// shorter than one eval interval).
    pub fn best_return(&self) -> Option<f32> {
        self.evals.iter().map(|e| e.mean_return).reduce(f32::max)
    }

    /// First wall-clock time at which the evaluator reached `threshold`.
    pub fn time_to(&self, threshold: f32) -> Option<f64> {
        self.evals
            .iter()
            .find(|e| e.mean_return >= threshold)
            .map(|e| e.wall_s)
    }

    /// Name of the first failed node, if any node failed.
    pub fn failed_node(&self) -> Option<&str> {
        self.node_failures.first().map(|f| f.node.as_str())
    }
}

/// Number of evaluation episodes to advance per batched policy call:
/// `cap` clamped to the largest lowered bucket of `policy_name`'s
/// ladder ([`BucketLadder`], DESIGN.md §11). The executor then runs at
/// the bucket `pick` rounds that width up to, with the surplus rows
/// masked as padding — so any `cap` in `1..=max_bucket` vectorizes
/// fully instead of dropping to the largest batch that divides it.
///
/// The evaluator node and the experiment harness use this to vectorize
/// evaluation opportunistically — a stale artifact directory without
/// batched variants degrades to the serial path instead of failing.
pub fn eval_policy_batch(
    manifest: &Manifest,
    policy_name: &str,
    cap: usize,
) -> usize {
    match BucketLadder::from_manifest(manifest, policy_name) {
        Ok(ladder) => cap.max(1).min(ladder.max_bucket()),
        Err(_) => 1,
    }
}

/// Build the vectorized greedy evaluator shared by the evaluator node
/// and the experiment harness: resolves `cfg.system` into its
/// [`SystemSpec`], clamps `cap` to the lowered policy ladder
/// ([`eval_policy_batch`]), builds that many fingerprinted instances
/// of `cfg.preset` (env `i` seeded `seed + 1 + i`) and pairs them with
/// a [`VecExecutor`] at the bucket that width rounds up to.
pub fn make_vec_evaluator(
    engine: &mut Engine,
    cfg: &TrainConfig,
    params: Vec<f32>,
    cap: usize,
    seed: u64,
) -> Result<VecEvaluator> {
    let preset = cfg.preset.clone();
    let factory: EnvFactory =
        Arc::new(move |s, fp| env_for_preset(&preset, s, fp));
    make_vec_evaluator_with(engine, cfg, params, cap, seed, &factory)
}

/// [`make_vec_evaluator`] with an explicit [`EnvFactory`] — the hook
/// the evaluator node uses so a builder-level custom environment also
/// drives evaluation.
pub fn make_vec_evaluator_with(
    engine: &mut Engine,
    cfg: &TrainConfig,
    params: Vec<f32>,
    cap: usize,
    seed: u64,
    env_factory: &EnvFactory,
) -> Result<VecEvaluator> {
    let spec = SystemSpec::parse(&cfg.system)?;
    let prefix = spec.artifact_prefix(&cfg.preset, cfg.arch);
    let policy_name = spec.policy_artifact(&prefix);
    let batch = eval_policy_batch(&engine.manifest, &policy_name, cap.max(1));
    // round the real width up to its bucket; VecEvaluator masks the
    // padding rows out of selection and accounting (DESIGN.md §11)
    let artifact_name =
        match BucketLadder::from_manifest(&engine.manifest, &policy_name) {
            Ok(ladder) => ladder.artifact_name(ladder.pick(batch)?.0),
            Err(_) => policy_name.clone(), // serial fallback, B = 1
        };
    let artifact = engine.artifact(&artifact_name)?;
    let executor = VecExecutor::new(spec.kind, artifact, params, seed)?;
    let mut instances = Vec::with_capacity(batch);
    for i in 0..batch {
        instances.push(env_factory(
            seed.wrapping_add(1 + i as u64),
            Some(Fingerprint::new(0.0, 1.0)),
        )?);
    }
    VecEvaluator::new(executor, VecEnv::new(instances)?)
}

/// Run one greedy evaluation episode; returns the mean-over-agents
/// episode return.
pub fn eval_episode(
    executor: &mut Executor,
    env: &mut dyn MultiAgentEnv,
) -> Result<f32> {
    let mut ts = env.reset();
    executor.reset_state();
    let mut ret = 0.0;
    while ts.step_type != StepType::Last {
        let actions = executor.select_actions(&ts, 0.0, 0.0)?;
        ts = env.step(&actions);
        ret += ts.rewards.iter().sum::<f32>() / ts.rewards.len() as f32;
    }
    Ok(ret)
}

/// Fluent constructor for a [`System`]: start from a
/// [`SystemSpec`] + [`TrainConfig`], optionally override the node
/// graph (executor count, evaluator presence) and the per-node
/// factories, then [`SystemBuilder::build`].
///
/// ```no_run
/// # use mava::config::TrainConfig;
/// # use mava::systems::{SystemBuilder, SystemSpec};
/// # fn main() -> anyhow::Result<()> {
/// let cfg = TrainConfig::default();
/// let spec = SystemSpec::parse("vdn")?;
/// let result = SystemBuilder::new(spec, &cfg)
///     .executors(4)
///     .build()?
///     .run(None)?;
/// # Ok(()) }
/// ```
pub struct SystemBuilder {
    spec: &'static SystemSpec,
    cfg: TrainConfig,
    evaluator: bool,
    env_factory: Option<EnvFactory>,
    adder_factory: Option<AdderFactory>,
}

impl SystemBuilder {
    /// Start building `spec`'s system under `cfg`. The spec is
    /// authoritative: `cfg.system` is normalised to `spec.name`, so a
    /// stale config string cannot select different artifacts than the
    /// spec the caller chose.
    pub fn new(spec: &'static SystemSpec, cfg: &TrainConfig) -> SystemBuilder {
        let mut cfg = cfg.clone();
        cfg.system = spec.name.to_string();
        cfg.num_executors = cfg.num_executors.max(1);
        SystemBuilder {
            spec,
            cfg,
            evaluator: true,
            env_factory: None,
            adder_factory: None,
        }
    }

    /// Set the number of executor nodes (default: `cfg.num_executors`).
    pub fn executors(mut self, n: usize) -> SystemBuilder {
        self.cfg.num_executors = n;
        self
    }

    /// Set the environment instances each executor steps per batched
    /// policy call (default: `cfg.num_envs_per_executor`). Must match
    /// a lowered `_b{B}` policy variant.
    pub fn envs_per_executor(mut self, b: usize) -> SystemBuilder {
        self.cfg.num_envs_per_executor = b;
        self
    }

    /// Include (default) or drop the evaluator node. Headless runs
    /// produce no [`EvalPoint`]s — `best_return()` is then `None`.
    pub fn evaluator(mut self, on: bool) -> SystemBuilder {
        self.evaluator = on;
        self
    }

    /// Override how environment instances are built (research fork
    /// hook): `(seed, fingerprint)` → env. Applies to executor *and*
    /// evaluator nodes. The env must match the preset's lowered
    /// artifact contract (obs/action dims — DESIGN.md §4).
    pub fn env_factory(
        mut self,
        f: impl Fn(u64, Option<Fingerprint>) -> Result<Box<dyn MultiAgentEnv>>
            + Send
            + Sync
            + 'static,
    ) -> SystemBuilder {
        self.env_factory = Some(Arc::new(f));
        self
    }

    /// Override how per-instance adders are built (research fork
    /// hook): replay shard → [`Adder`]. Default:
    /// [`SystemSpec::make_adder`] with the run's `n_step`/`gamma` and
    /// the artifact's `seq_len`.
    pub fn adder_factory(
        mut self,
        f: impl Fn(Arc<dyn ItemSink>) -> Adder + Send + Sync + 'static,
    ) -> SystemBuilder {
        self.adder_factory = Some(Arc::new(f));
        self
    }

    /// Validate the configuration and produce a runnable [`System`].
    ///
    /// Hermetic: the artifact directory is only touched by
    /// [`System::run`], so a built system's graph shape can be
    /// inspected (and tested) without lowered artifacts.
    pub fn build(self) -> Result<System> {
        ensure!(
            self.cfg.num_executors >= 1,
            "a system needs at least one executor node"
        );
        self.cfg.validate()?;
        let env_factory = match self.env_factory {
            Some(f) => f,
            None => {
                // fail at build, not on a node thread, for a bogus
                // preset: constructing one throwaway env validates it
                env_for_preset(&self.cfg.preset, self.cfg.seed, None)?;
                let preset = self.cfg.preset.clone();
                Arc::new(move |s, fp| env_for_preset(&preset, s, fp))
                    as EnvFactory
            }
        };
        Ok(System {
            spec: self.spec,
            cfg: self.cfg,
            evaluator: self.evaluator,
            env_factory,
            adder_factory: self.adder_factory,
        })
    }
}

/// A built (but not yet launched) system: the node graph is fixed and
/// inspectable; [`System::run`] loads artifacts, launches every node
/// on its own thread and supervises the run.
pub struct System {
    spec: &'static SystemSpec,
    cfg: TrainConfig,
    evaluator: bool,
    env_factory: EnvFactory,
    adder_factory: Option<AdderFactory>,
}

impl System {
    /// The system's spec.
    pub fn spec(&self) -> &'static SystemSpec {
        self.spec
    }

    /// The (normalised) configuration the system runs under.
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Number of replay shards — one per executor, so the insert hot
    /// path never crosses executor threads (DESIGN.md §5).
    pub fn num_replay_shards(&self) -> usize {
        self.cfg.num_executors
    }

    /// The node graph, in launch order: `(name, kind)` per node.
    pub fn nodes(&self) -> Vec<(String, NodeKind)> {
        let mut plan =
            vec![("trainer".to_string(), NodeKind::Trainer)];
        for worker in 0..self.cfg.num_executors {
            plan.push((format!("executor_{worker}"), NodeKind::Executor));
        }
        if self.evaluator {
            plan.push(("evaluator".to_string(), NodeKind::Evaluator));
        }
        plan
    }

    /// Names of every node, in launch order.
    pub fn node_names(&self) -> Vec<String> {
        self.nodes().into_iter().map(|(n, _)| n).collect()
    }

    /// Number of nodes of the given kind.
    pub fn node_count(&self, kind: NodeKind) -> usize {
        self.nodes().iter().filter(|(_, k)| *k == kind).count()
    }

    /// Launch and supervise the system. `deadline` bounds wall-clock
    /// time (benches); `None` = until `max_env_steps`.
    ///
    /// Returns `Err` — naming the node — if any node failed or
    /// panicked; use [`System::run_collect`] to get the partial
    /// [`TrainResult`] with the failures recorded instead.
    pub fn run(&self, deadline: Option<Duration>) -> Result<TrainResult> {
        let result = self.run_collect(deadline)?;
        if result.node_failures.is_empty() {
            return Ok(result);
        }
        let pairs: Vec<(&str, &str)> = result
            .node_failures
            .iter()
            .map(|f| (f.node.as_str(), f.error.as_str()))
            .collect();
        Err(node_failure_error(&pairs))
    }

    /// Like [`System::run`], but node failures are *recorded* in
    /// [`TrainResult::node_failures`] instead of becoming an `Err`
    /// (the launcher's error channel, exposed raw). `Err` is reserved
    /// for setup problems: missing artifacts, un-lowered batches.
    pub fn run_collect(
        &self,
        deadline: Option<Duration>,
    ) -> Result<TrainResult> {
        let cfg = &self.cfg;
        let spec = self.spec;
        let prefix = spec.artifact_prefix(&cfg.preset, cfg.arch);
        let policy_name = spec.policy_artifact(&prefix);
        let train_name = spec.train_artifact(&prefix);
        // executors act through a batched policy artifact when
        // vectorized: the requested env count rounds UP to the nearest
        // lowered bucket, padding rows masked (DESIGN.md §11); the
        // evaluator picks its own batch from the same ladder
        let num_envs = cfg.num_envs_per_executor.max(1);

        // --- initial parameters from the AOT init blobs ---
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        // fail fast on an un-bucketable env batch: executor threads
        // could only surface this after launch, leaving the trainer
        // blocked on an empty replay table until the deadline. pick()
        // errors name the ladder the manifest actually holds.
        let ladder = BucketLadder::from_manifest(&manifest, &policy_name)?;
        let (exec_bucket, _pad) =
            ladder.pick(num_envs).with_context(|| {
                format!(
                    "num_envs_per_executor={num_envs} has no lowered \
                     policy bucket"
                )
            })?;
        let exec_policy_name = ladder.artifact_name(exec_bucket);
        // data-parallel training needs the sharded grad + apply pair
        // lowered for exactly this device count — fail fast with the
        // fix, not after launch
        if cfg.num_devices > 1 {
            let dp_name = format!("{train_name}_dp{}", cfg.num_devices);
            let apply_name = format!("{train_name}_apply");
            if manifest.get(&dp_name).is_err()
                || manifest.get(&apply_name).is_err()
            {
                bail!(
                    "num_devices={} needs data-parallel artifacts \
                     {dp_name:?} and {apply_name:?}; they are lowered \
                     for DP_SHARDS in python/compile/model.py for \
                     systems whose loss is an unweighted batch mean \
                     (recurrent/masked-mean systems are dp-ineligible) \
                     — re-run `make artifacts` or set num_devices=1",
                    cfg.num_devices
                );
            }
        }
        let train_art = manifest.get(&train_name)?.clone();
        let params0 = manifest.read_init(&train_art, "params0")?;
        let opt0 = manifest.read_init(&train_art, "opt0")?;
        let seq_len = train_art.meta_usize("seq_len")?;
        let gamma = train_art.meta_f32("gamma")?;
        let batch = train_art.meta_usize("batch")?;

        // --- shared services (the handles every node runs against) ---
        // one replay shard per executor: the insert hot path never
        // crosses executor threads, the trainer round-robins the shards
        let table = Arc::new(ShardedTable::new(
            self.num_replay_shards(),
            cfg.replay_size,
            Selector::Uniform,
            RateLimiter::sample_to_insert(
                cfg.samples_per_insert / batch as f64,
                cfg.min_replay,
            ),
            cfg.seed ^ 0x7ab1e,
        ));
        let handles = SystemHandles {
            server: Arc::new(ParameterServer::new(params0.clone())),
            counters: Arc::new(Counters::default()),
            stop: StopSignal::new(),
            evals: Arc::new(Mutex::new(Vec::new())),
            train_returns: Arc::new(Mutex::new(MovingStats::new(64))),
            fingerprint: Fingerprint::new(cfg.eps_start, 0.0),
            started: Instant::now(),
        };
        let adder_factory = self.adder_factory.clone().unwrap_or_else(|| {
            let n_step = cfg.n_step;
            Arc::new(move |shard: Arc<dyn ItemSink>| {
                spec.make_adder(shard, n_step, gamma, seq_len)
            }) as AdderFactory
        });

        // --- assemble the program graph (same order as `nodes()`) ---
        let mut program = Program::new();
        {
            let mut node = TrainerNode {
                spec,
                cfg: cfg.clone(),
                handles: handles.clone(),
                train_name,
                params0: params0.clone(),
                opt0,
                source: table.clone(),
                checkpoint: crate::systems::nodes::trainer_checkpoint_path(
                    &cfg,
                ),
            };
            program.add_node("trainer", NodeKind::Trainer, move || {
                node.run()
            });
        }
        for worker in 0..cfg.num_executors {
            let mut node = ExecutorNode {
                worker,
                spec,
                cfg: cfg.clone(),
                handles: handles.clone(),
                shard: table.shard(worker),
                policy_name: exec_policy_name.clone(),
                params0: params0.clone(),
                env_factory: self.env_factory.clone(),
                adder_factory: adder_factory.clone(),
            };
            program.add_node(
                format!("executor_{worker}"),
                NodeKind::Executor,
                move || node.run(),
            );
        }
        if self.evaluator {
            let mut node = EvaluatorNode {
                cfg: cfg.clone(),
                handles: handles.clone(),
                params0,
                env_factory: self.env_factory.clone(),
            };
            program.add_node("evaluator", NodeKind::Evaluator, move || {
                node.run()
            });
        }

        // --- launch and supervise ---
        let stop = handles.stop.clone();
        let handle = LocalLauncher::launch(program, stop.clone());
        loop {
            std::thread::sleep(crate::net::frame::POLL_INTERVAL);
            if handles.counters.env_steps() >= cfg.max_env_steps {
                break;
            }
            if let Some(d) = deadline {
                if handles.started.elapsed() >= d {
                    break;
                }
            }
            // also set by any node that errored: stop supervising a
            // program whose trainer (or executor) is already dead
            if stop.is_stopped() {
                break;
            }
        }
        stop.stop();
        table.close();
        // deadline-aware join: a node wedged in a blocking call (e.g. a
        // socket read in a remote-backed run) is reported by name
        // instead of hanging the supervisor forever
        let outcomes = handle
            .join_deadline(Duration::from_secs(cfg.dist_timeout_s.max(1)));

        let node_failures: Vec<NodeFailure> = outcomes
            .iter()
            .filter_map(|o| {
                o.result.as_ref().err().map(|e| NodeFailure {
                    node: o.name.clone(),
                    error: format!("{e:#}"),
                })
            })
            .collect();
        let evals = std::mem::take(&mut *handles.evals.lock().unwrap());
        // the trainer flushed its final publish before joining, so this
        // is the trained policy (params0 if the trainer never stepped)
        let (_, final_params) = handles.server.get()?;
        Ok(TrainResult {
            evals,
            env_steps: handles.counters.env_steps(),
            train_steps: handles.counters.train_steps(),
            episodes: handles.counters.episodes(),
            wall_s: handles.started.elapsed().as_secs_f64(),
            train_return: handles.train_returns.lock().unwrap().mean(),
            final_params,
            node_failures,
        })
    }
}

/// Build and run the full distributed system described by `cfg` — a
/// thin wrapper over [`SystemBuilder`]. `deadline` bounds wall-clock
/// time (benches); `None` = until `max_env_steps`. Returns `Err`
/// naming the node if any node of the program failed.
pub fn train(
    cfg: &TrainConfig,
    deadline: Option<Duration>,
) -> Result<TrainResult> {
    let spec = SystemSpec::parse(&cfg.system)?;
    SystemBuilder::new(spec, cfg).build()?.run(deadline)
}

/// Convenience wrapper used by tests and examples: errors if the
/// artifacts directory is missing.
pub fn check_artifacts(cfg: &TrainConfig) -> Result<()> {
    Manifest::load(&cfg.artifacts_dir)
        .context("artifacts missing — run `make artifacts`")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Satellite: the graph shape of a built system is inspectable
    /// without an artifacts directory — node count per kind, launch
    /// order, and the shard wiring (one replay shard per executor).
    #[test]
    fn builder_graph_shape_is_hermetic() {
        let cfg = TrainConfig::default();
        let spec = SystemSpec::parse("vdn").unwrap();
        let system =
            SystemBuilder::new(spec, &cfg).executors(3).build().unwrap();
        assert_eq!(
            system.node_names(),
            ["trainer", "executor_0", "executor_1", "executor_2", "evaluator"]
        );
        assert_eq!(system.node_count(NodeKind::Trainer), 1);
        assert_eq!(system.node_count(NodeKind::Executor), 3);
        assert_eq!(system.node_count(NodeKind::Evaluator), 1);
        assert_eq!(system.num_replay_shards(), 3);

        let headless = SystemBuilder::new(spec, &cfg)
            .executors(1)
            .evaluator(false)
            .build()
            .unwrap();
        assert_eq!(headless.node_names(), ["trainer", "executor_0"]);
        assert_eq!(headless.node_count(NodeKind::Evaluator), 0);
    }

    /// The spec passed to the builder is authoritative over the config
    /// string; degenerate graphs are rejected at build.
    #[test]
    fn builder_normalises_system_and_validates() {
        let mut cfg = TrainConfig::default();
        cfg.system = "madqn".into();
        let spec = SystemSpec::parse("qmix").unwrap();
        let system = SystemBuilder::new(spec, &cfg).build().unwrap();
        assert_eq!(system.config().system, "qmix");
        assert_eq!(system.spec().kind, crate::systems::SystemKind::Qmix);

        assert!(
            SystemBuilder::new(spec, &cfg).executors(0).build().is_err(),
            "zero executors is a dead graph"
        );
        cfg.preset = "not_a_preset".into();
        let err = SystemBuilder::new(spec, &cfg).build().unwrap_err();
        assert!(
            err.to_string().contains("unknown preset"),
            "bad preset must fail at build, not on a node thread: {err}"
        );
    }

    /// A custom env factory skips the preset validation (the fork owns
    /// its environment) and is kept for both executors and evaluator.
    #[test]
    fn builder_accepts_custom_env_factory_with_any_preset() {
        let mut cfg = TrainConfig::default();
        cfg.preset = "my_research_env".into();
        let spec = SystemSpec::parse("madqn").unwrap();
        let system = SystemBuilder::new(spec, &cfg)
            .env_factory(|seed, _fp| {
                crate::systems::env_for_preset("matrix2", seed, None)
            })
            .build()
            .unwrap();
        assert_eq!(system.node_count(NodeKind::Executor), 1);
    }

    /// `best_return` distinguishes "never evaluated" from any real
    /// measurement (the n=0 mirror of the PR-3 ±INF fix).
    #[test]
    fn best_return_is_none_without_evals() {
        let mut r = TrainResult::default();
        assert_eq!(r.best_return(), None);
        assert_eq!(r.failed_node(), None);
        r.evals.push(EvalPoint {
            wall_s: 1.0,
            env_steps: 10,
            train_steps: 1,
            mean_return: -3.5,
        });
        assert_eq!(r.best_return(), Some(-3.5));
        r.evals.push(EvalPoint {
            wall_s: 2.0,
            env_steps: 20,
            train_steps: 2,
            mean_return: 1.25,
        });
        assert_eq!(r.best_return(), Some(1.25));
        assert_eq!(r.time_to(1.0), Some(2.0));
        assert_eq!(r.time_to(9.0), None);
    }
}
