//! System builder: wires executors, trainer, replay, parameter server and
//! evaluator into a Launchpad-style program and runs it (paper Block 2).
//!
//! Executor nodes run the vectorized hot path (DESIGN.md §6): each node
//! steps `num_envs_per_executor` environment instances through a
//! [`crate::env::VecEnv`], acts with one batched policy-artifact call
//! per vector step, and feeds its own [`crate::replay::ShardedTable`]
//! shard so executors never contend on a replay lock.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::core::StepType;
use crate::env::wrappers::{Fingerprint, FingerprintWrapper};
use crate::env::{make_env, ActionBuf, MultiAgentEnv, VecEnv, VecStepBuf};
use crate::eval::VecEvaluator;
use crate::exploration::EpsilonSchedule;
use crate::launch::{LocalLauncher, NodeKind, Program, StopSignal};
use crate::metrics::{Counters, MovingStats};
use crate::params::ParameterServer;
use crate::replay::{
    RateLimiter, Selector, SequenceAdder, ShardedTable, TransitionAdder,
};
use crate::runtime::{Engine, Manifest};
use crate::systems::{Executor, SystemKind, Trainer, VecExecutor};

/// Per-instance adder slot for the vectorized executor loop: each
/// environment instance accumulates its own episode independently.
enum Adder {
    Tr(TransitionAdder),
    Sq(SequenceAdder),
}

impl Adder {
    fn observe_first_row(&mut self, next: &VecStepBuf, row: usize) {
        match self {
            Adder::Tr(a) => a.observe_first_row(next, row),
            Adder::Sq(a) => a.observe_first_row(next, row),
        }
    }

    fn observe_row(
        &mut self,
        actions: &ActionBuf,
        row: usize,
        next: &VecStepBuf,
    ) {
        match self {
            Adder::Tr(a) => a.observe_row(actions, row, next),
            Adder::Sq(a) => a.observe_row(actions, row, next),
        }
    }
}

/// One evaluator measurement (a point on the paper's learning curves).
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    /// Wall-clock seconds since the run started.
    pub wall_s: f64,
    /// Total environment steps across all executors at measurement time.
    pub env_steps: u64,
    /// Total trainer steps at measurement time.
    pub train_steps: u64,
    /// Mean greedy episode return over `eval_episodes`.
    pub mean_return: f32,
}

/// Outcome of a full distributed training run.
#[derive(Clone, Debug, Default)]
pub struct TrainResult {
    /// Evaluator measurements in chronological order.
    pub evals: Vec<EvalPoint>,
    /// Total environment steps executed.
    pub env_steps: u64,
    /// Total trainer steps executed.
    pub train_steps: u64,
    /// Total completed episodes across all executors.
    pub episodes: u64,
    /// Total wall-clock seconds.
    pub wall_s: f64,
    /// moving-average training return at shutdown
    pub train_return: f32,
    /// Final published parameters (the trainer flushes at shutdown), so
    /// callers — the experiment harness in particular — can evaluate the
    /// trained policy without re-running the program graph.
    pub final_params: Vec<f32>,
}

impl TrainResult {
    /// Best evaluator measurement of the run.
    pub fn best_return(&self) -> f32 {
        self.evals
            .iter()
            .map(|e| e.mean_return)
            .fold(f32::NEG_INFINITY, f32::max)
    }

    /// First wall-clock time at which the evaluator reached `threshold`.
    pub fn time_to(&self, threshold: f32) -> Option<f64> {
        self.evals
            .iter()
            .find(|e| e.mean_return >= threshold)
            .map(|e| e.wall_s)
    }
}

/// Environment for an artifact preset (DESIGN.md §4). The `_fp` presets
/// wrap the base env with the fingerprint stabilisation module.
pub fn env_for_preset(
    preset: &str,
    seed: u64,
    fingerprint: Option<Fingerprint>,
) -> Result<Box<dyn MultiAgentEnv>> {
    let base = match preset {
        "matrix2" => "matrix",
        "switch3" => "switch",
        "smac3m" | "smac3m_fp" => "smac_lite",
        "spread3" => "mpe_spread",
        "speaker2" => "mpe_speaker_listener",
        "walker3" => "multiwalker",
        other => bail!("unknown preset {other:?}"),
    };
    let env = make_env(base, seed)?;
    if preset.ends_with("_fp") {
        let fp = fingerprint.unwrap_or_default();
        // Box<dyn MultiAgentEnv> implements the trait (all SoA hooks
        // forwarded), so the wrapper composes over it directly and the
        // _fp preset stays on the allocation-free path
        Ok(Box::new(FingerprintWrapper::new(env, fp)))
    } else {
        Ok(env)
    }
}

/// Largest lowered batch for `policy_name` that is still at most
/// `cap`: scans the manifest for `{policy_name}_b{B}` variants and
/// falls back to 1 (the base `[1, N, O]` artifact) when none fit.
///
/// The evaluator node and the experiment harness use this to vectorize
/// evaluation opportunistically — a stale artifact directory without
/// batched variants degrades to the serial path instead of failing.
pub fn eval_policy_batch(
    manifest: &Manifest,
    policy_name: &str,
    cap: usize,
) -> usize {
    let prefix = format!("{policy_name}_b");
    manifest
        .artifacts
        .keys()
        .filter_map(|n| n.strip_prefix(&prefix).and_then(|b| b.parse().ok()))
        .filter(|&b: &usize| b >= 1 && b <= cap.max(1))
        .max()
        .unwrap_or(1)
}

/// Build the vectorized greedy evaluator shared by the evaluator node
/// and the experiment harness: parses `cfg.system`, picks the largest
/// lowered policy batch that fits `cap` ([`eval_policy_batch`]),
/// builds that many fingerprinted instances of `cfg.preset` (env `i`
/// seeded `seed + 1 + i`) and pairs them with a
/// [`VecExecutor`] holding `params`.
pub fn make_vec_evaluator(
    engine: &mut Engine,
    cfg: &TrainConfig,
    params: Vec<f32>,
    cap: usize,
    seed: u64,
) -> Result<VecEvaluator> {
    let kind = SystemKind::parse(&cfg.system)?;
    let policy_name = format!("{}_policy", cfg.artifact_prefix());
    let batch = eval_policy_batch(&engine.manifest, &policy_name, cap.max(1));
    let artifact_name = if batch == 1 {
        policy_name
    } else {
        format!("{policy_name}_b{batch}")
    };
    let artifact = engine.artifact(&artifact_name)?;
    let executor = VecExecutor::new(kind, artifact, params, seed)?;
    let mut instances = Vec::with_capacity(batch);
    for i in 0..batch {
        instances.push(env_for_preset(
            &cfg.preset,
            seed.wrapping_add(1 + i as u64),
            Some(Fingerprint::new(0.0, 1.0)),
        )?);
    }
    VecEvaluator::new(executor, VecEnv::new(instances)?)
}

/// Run one greedy evaluation episode; returns the mean-over-agents
/// episode return.
pub fn eval_episode(
    executor: &mut Executor,
    env: &mut dyn MultiAgentEnv,
) -> Result<f32> {
    let mut ts = env.reset();
    executor.reset_state();
    let mut ret = 0.0;
    while ts.step_type != StepType::Last {
        let actions = executor.select_actions(&ts, 0.0, 0.0)?;
        ts = env.step(&actions);
        ret += ts.rewards.iter().sum::<f32>() / ts.rewards.len() as f32;
    }
    Ok(ret)
}

/// Build and run the full distributed system described by `cfg`.
/// `deadline` bounds wall-clock time (benches); `None` = until
/// `max_env_steps`.
pub fn train(cfg: &TrainConfig, deadline: Option<Duration>) -> Result<TrainResult> {
    let kind = SystemKind::parse(&cfg.system)?;
    let prefix = cfg.artifact_prefix();
    let policy_name = format!("{prefix}_policy");
    let train_name = format!("{prefix}_train");
    // executors act through a batched policy artifact when vectorized;
    // the evaluator picks its own batch (largest lowered batch that
    // fits eval_episodes, see the evaluator node below)
    let num_envs = cfg.num_envs_per_executor.max(1);
    let exec_policy_name = if num_envs == 1 {
        policy_name.clone()
    } else {
        format!("{prefix}_policy_b{num_envs}")
    };

    // --- initial parameters from the AOT init blobs ---
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    // fail fast on an un-lowered env batch: executor threads could only
    // surface this after launch, leaving the trainer blocked on an
    // empty replay table until the deadline
    if manifest.get(&exec_policy_name).is_err() {
        let mut batches: Vec<usize> = manifest
            .artifacts
            .keys()
            .filter_map(|n| {
                n.strip_prefix(&format!("{policy_name}_b"))
                    .and_then(|b| b.parse().ok())
            })
            .collect();
        batches.push(1);
        batches.sort_unstable();
        bail!(
            "no policy artifact {exec_policy_name:?} for \
             num_envs_per_executor={num_envs}; lowered batches for \
             {policy_name:?}: {batches:?} (extend POLICY_BATCHES in \
             python/compile/model.py and re-run `make artifacts`)"
        );
    }
    let train_spec = manifest.get(&train_name)?.clone();
    let params0 = manifest.read_init(&train_spec, "params0")?;
    let opt0 = manifest.read_init(&train_spec, "opt0")?;
    let seq_len = train_spec.meta_usize("seq_len")?;
    let gamma = train_spec.meta_f32("gamma")?;
    let batch = train_spec.meta_usize("batch")?;

    // --- shared services (the "nodes" executors/trainer talk to) ---
    // one replay shard per executor: the insert hot path never crosses
    // executor threads, the trainer round-robins the shards
    let table = Arc::new(ShardedTable::new(
        cfg.num_executors.max(1),
        cfg.replay_size,
        Selector::Uniform,
        RateLimiter::sample_to_insert(
            cfg.samples_per_insert / batch as f64,
            cfg.min_replay,
        ),
        cfg.seed ^ 0x7ab1e,
    ));
    let server = Arc::new(ParameterServer::new(params0.clone()));
    let counters = Arc::new(Counters::default());
    let stop = StopSignal::new();
    let evals = Arc::new(Mutex::new(Vec::<EvalPoint>::new()));
    let train_returns = Arc::new(Mutex::new(MovingStats::new(64)));
    let fingerprint = Fingerprint::new(cfg.eps_start, 0.0);
    let started = Instant::now();

    let mut program = Program::new();

    // --- trainer node (device-resident + prefetched, DESIGN.md §8) ---
    {
        let cfg = cfg.clone();
        let table = table.clone();
        let server = server.clone();
        let counters = counters.clone();
        let stop = stop.clone();
        let train_name = train_name.clone();
        let params0 = params0.clone();
        program.add_node("trainer", NodeKind::Trainer, move || {
            let run = || -> Result<()> {
                let mut engine = Engine::load(&cfg.artifacts_dir)?;
                let artifact = engine.artifact(&train_name)?;
                let mut trainer = Trainer::new(
                    kind.family(),
                    artifact,
                    params0,
                    opt0,
                    cfg.lr,
                    cfg.tau,
                    cfg.seed ^ 0x77aa,
                )?;
                trainer.set_publish_interval(cfg.publish_interval);
                trainer.init_target_from_params()?;
                server.push(trainer.params());
                // sample+assemble runs on a prefetch thread; only plain
                // HostTensors cross the channel (no PJRT handle leaves
                // this thread — the §2 engine-per-thread rule holds)
                let prefetch = trainer.spawn_prefetcher(table.clone(), 2);
                while !stop.is_stopped() {
                    // Ok(None) once the table closed (shutdown);
                    // Err if assembly failed on the prefetch thread
                    let Some(batch) = prefetch.next_batch()? else {
                        break;
                    };
                    trainer.step_batch(&batch)?;
                    prefetch.recycle(batch);
                    counters.add_train_step();
                    trainer.maybe_publish(&server)?;
                    if cfg.max_train_steps > 0
                        && trainer.stats.steps >= cfg.max_train_steps
                    {
                        break;
                    }
                }
                // the publish cadence may be mid-window at shutdown:
                // flush the final parameters unconditionally
                trainer.publish(&server)?;
                Ok(())
            };
            if let Err(e) = run() {
                eprintln!("[trainer] error: {e:#}");
            }
        });
    }

    // --- executor nodes (vectorized hot path, DESIGN.md §6) ---
    for worker in 0..cfg.num_executors {
        let cfg = cfg.clone();
        let shard = table.shard(worker);
        let server = server.clone();
        let counters = counters.clone();
        let stop = stop.clone();
        let exec_policy_name = exec_policy_name.clone();
        let params0 = params0.clone();
        let train_returns = train_returns.clone();
        let fingerprint = fingerprint.clone();
        program.add_node(
            format!("executor_{worker}"),
            NodeKind::Executor,
            move || {
                let run = || -> Result<()> {
                    let mut engine = Engine::load(&cfg.artifacts_dir)?;
                    let artifact = engine
                        .artifact(&exec_policy_name)
                        .with_context(|| {
                            format!(
                                "policy artifact {exec_policy_name:?} \
                                 unavailable — num_envs_per_executor \
                                 must match a lowered policy batch; \
                                 regenerate with `make artifacts`"
                            )
                        })?;
                    let mut executor = VecExecutor::new(
                        kind,
                        artifact,
                        params0,
                        cfg.seed + 1000 + worker as u64,
                    )?;
                    let mut instances = Vec::with_capacity(num_envs);
                    for i in 0..num_envs {
                        instances.push(env_for_preset(
                            &cfg.preset,
                            cfg.seed + (worker * num_envs + i) as u64,
                            Some(fingerprint.clone()),
                        )?);
                    }
                    let mut venv = VecEnv::new(instances)?;
                    let schedule = EpsilonSchedule::new(
                        cfg.eps_start,
                        cfg.eps_end,
                        cfg.eps_decay_steps,
                    );
                    // one adder per instance: episodes accumulate
                    // independently across the batch
                    let use_seq = kind.sequences();
                    let mut adders: Vec<Adder> = (0..num_envs)
                        .map(|_| {
                            if use_seq {
                                Adder::Sq(SequenceAdder::new(
                                    shard.clone(),
                                    seq_len.max(1),
                                    seq_len.max(1),
                                ))
                            } else {
                                Adder::Tr(TransitionAdder::new(
                                    shard.clone(),
                                    cfg.n_step,
                                    gamma,
                                ))
                            }
                        })
                        .collect();
                    let mut ep_returns = vec![0.0f32; num_envs];
                    // SoA double buffer: `cur` feeds the policy call,
                    // the envs write the next vector step into `next`,
                    // then the buffers swap — allocated once here,
                    // refilled in place forever after (DESIGN.md §6)
                    let mut cur = venv.make_buf();
                    let mut next = venv.make_buf();
                    let mut abuf = venv.make_action_buf();
                    let mut params_scratch = Vec::new();
                    venv.reset_into(&mut cur);
                    for (i, adder) in adders.iter_mut().enumerate() {
                        adder.observe_first_row(&cur, i);
                    }
                    while !stop.is_stopped()
                        && counters.env_steps() < cfg.max_env_steps
                    {
                        let eps = schedule.value(counters.env_steps());
                        fingerprint.set(
                            eps,
                            (counters.env_steps() as f32
                                / cfg.max_env_steps as f32)
                                .min(1.0),
                        );
                        // ONE batched policy call for all B instances;
                        // params + recurrent carry stay device-resident
                        executor.select_actions_into(
                            &cur,
                            eps,
                            cfg.noise_sigma,
                            &mut abuf,
                        )?;
                        venv.step_into(&abuf, &mut next);
                        let mut episode_ended = false;
                        for (i, adder) in adders.iter_mut().enumerate() {
                            if next.step_type(i) == StepType::First {
                                // this slot auto-reset: new episode
                                adder.observe_first_row(&next, i);
                                executor.reset_instance(i);
                                ep_returns[i] = 0.0;
                                continue;
                            }
                            adder.observe_row(&abuf, i, &next);
                            counters.add_env_steps(1);
                            ep_returns[i] += next.mean_reward(i);
                            if next.is_last(i) {
                                counters.add_episode();
                                train_returns
                                    .lock()
                                    .unwrap()
                                    .push(ep_returns[i]);
                                episode_ended = true;
                            }
                        }
                        if episode_ended {
                            // cheap version check at episode boundaries
                            if let Some(v) = server.sync(
                                executor.params_version,
                                &mut params_scratch,
                            ) {
                                executor.set_params(v, &params_scratch);
                            }
                        }
                        std::mem::swap(&mut cur, &mut next);
                    }
                    Ok(())
                };
                if let Err(e) = run() {
                    eprintln!("[executor_{worker}] error: {e:#}");
                }
            },
        );
    }

    // --- evaluator node (vectorized, eval/vec_eval.rs) ---
    // Snapshots published params every `eval_every_steps` env steps and
    // runs greedy episodes through the largest lowered policy batch that
    // fits the episode budget — one artifact call advances B episodes,
    // and the node never takes a lock the executors or trainer hold, so
    // evaluation cannot stall acting or training.
    {
        let cfg = cfg.clone();
        let server = server.clone();
        let counters = counters.clone();
        let stop = stop.clone();
        let params0 = params0.clone();
        let evals = evals.clone();
        program.add_node("evaluator", NodeKind::Evaluator, move || {
            let run = || -> Result<()> {
                let mut engine = Engine::load(&cfg.artifacts_dir)?;
                let mut evaluator = make_vec_evaluator(
                    &mut engine,
                    &cfg,
                    params0,
                    cfg.eval_episodes,
                    cfg.seed ^ 0xe7a1,
                )?;
                let mut next_eval_at = 0u64;
                while !stop.is_stopped() {
                    let steps = counters.env_steps();
                    if steps < next_eval_at {
                        std::thread::sleep(Duration::from_millis(10));
                        continue;
                    }
                    next_eval_at = steps + cfg.eval_every_steps;
                    let mut buf = Vec::new();
                    if let Some(v) =
                        server.sync(evaluator.params_version(), &mut buf)
                    {
                        evaluator.set_params(v, &buf);
                    }
                    let returns = evaluator.evaluate_until(
                        cfg.eval_episodes,
                        || stop.is_stopped(),
                    )?;
                    if returns.is_empty() {
                        continue; // stopped mid-wave or eval_episodes == 0
                    }
                    let point = EvalPoint {
                        wall_s: started.elapsed().as_secs_f64(),
                        env_steps: counters.env_steps(),
                        train_steps: counters.train_steps(),
                        mean_return: crate::eval::stats::mean(&returns)
                            as f32,
                    };
                    evals.lock().unwrap().push(point);
                }
                Ok(())
            };
            if let Err(e) = run() {
                eprintln!("[evaluator] error: {e:#}");
            }
        });
    }

    // --- launch and supervise ---
    let handle = LocalLauncher::launch(program, stop.clone());
    loop {
        std::thread::sleep(Duration::from_millis(20));
        if counters.env_steps() >= cfg.max_env_steps {
            break;
        }
        if let Some(d) = deadline {
            if started.elapsed() >= d {
                break;
            }
        }
        if stop.is_stopped() {
            break;
        }
    }
    stop.stop();
    table.close();
    handle.join();

    let evals = Arc::try_unwrap(evals)
        .map_err(|_| anyhow::anyhow!("eval history still shared"))?
        .into_inner()
        .unwrap();
    // the trainer flushed its final publish before joining, so this is
    // the trained policy (params0 if the trainer never stepped)
    let (_, final_params) = server.get();
    let result = TrainResult {
        evals,
        env_steps: counters.env_steps(),
        train_steps: counters.train_steps(),
        episodes: counters.episodes(),
        wall_s: started.elapsed().as_secs_f64(),
        train_return: train_returns.lock().unwrap().mean(),
        final_params,
    };
    Ok(result)
}

/// Convenience wrapper used by tests and examples: errors if the
/// artifacts directory is missing.
pub fn check_artifacts(cfg: &TrainConfig) -> Result<()> {
    Manifest::load(&cfg.artifacts_dir)
        .context("artifacts missing — run `make artifacts`")?;
    Ok(())
}
