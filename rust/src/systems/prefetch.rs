//! Batch prefetching: sample + assemble on a dedicated thread so batch
//! `k+1` is built while the train artifact executes step `k`
//! (DESIGN.md §8).
//!
//! Only plain [`HostTensor`]s cross the channel — the prefetch thread
//! owns no PJRT engine, so the engine-per-thread rule (DESIGN.md §2)
//! is preserved: uploads still happen on the trainer's thread, inside
//! the artifact call. A recycle channel hands consumed batches back to
//! the prefetcher, so the steady state allocates nothing.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::core::HostTensor;
use crate::replay::ItemSource;
use crate::systems::{BatchArena, BatchAssembler};

/// Handle to a trainer-side prefetch thread.
///
/// The thread runs `sample → assemble → send` until its replay source
/// closes (`sample_batch` returns `None`), assembly fails (the error
/// is forwarded through the channel, not swallowed), or the consumer
/// drops this handle. Bounded depth keeps at most `depth` assembled
/// batches in flight, so prefetched data is never more than `depth`
/// batches staler than the replay table.
pub struct BatchPrefetcher {
    full: mpsc::Receiver<Result<Vec<HostTensor>>>,
    empty: mpsc::Sender<Vec<HostTensor>>,
    handle: Option<JoinHandle<()>>,
}

impl BatchPrefetcher {
    /// Spawn the prefetch thread over `source`. `assembler` moves onto
    /// the thread (seed it like the trainer's inline assembler — or use
    /// [`crate::systems::Trainer::spawn_prefetcher`], which clones it —
    /// for path-independent DIAL noise); `depth >= 1` bounds the
    /// in-flight batch count.
    pub fn spawn<S>(
        source: Arc<S>,
        mut assembler: BatchAssembler,
        depth: usize,
    ) -> BatchPrefetcher
    where
        S: ItemSource + Send + Sync + ?Sized + 'static,
    {
        let (full_tx, full_rx) = mpsc::sync_channel(depth.max(1));
        let (empty_tx, empty_rx) = mpsc::channel::<Vec<HostTensor>>();
        let handle = std::thread::Builder::new()
            .name("trainer-prefetch".into())
            .spawn(move || {
                let batch = assembler.batch_size();
                loop {
                    // blocks on replay flow control; unblocked by close()
                    let Some(items) = source.sample_batch(batch) else {
                        break;
                    };
                    // reuse a recycled batch's allocations when available
                    let mut arena = BatchArena::from_tensors(
                        empty_rx.try_recv().unwrap_or_default(),
                    );
                    match assembler.assemble_into(&items, &mut arena) {
                        Ok(()) => {
                            // consumer gone -> stop prefetching
                            if full_tx.send(Ok(arena.into_tensors())).is_err()
                            {
                                break;
                            }
                        }
                        Err(e) => {
                            // surface the failure to the consumer — a
                            // swallowed error would look like a clean
                            // shutdown
                            let _ = full_tx.send(Err(
                                e.context("prefetch batch assembly")
                            ));
                            break;
                        }
                    }
                }
            })
            .expect("spawn trainer-prefetch thread");
        BatchPrefetcher { full: full_rx, empty: empty_tx, handle: Some(handle) }
    }

    /// Next assembled batch, blocking until one is ready. `Ok(None)`
    /// once the prefetch thread has exited cleanly (source closed) and
    /// the channel drained; `Err` if assembly failed on the thread.
    pub fn next_batch(&self) -> Result<Option<Vec<HostTensor>>> {
        match self.full.recv() {
            Ok(Ok(batch)) => Ok(Some(batch)),
            Ok(Err(e)) => Err(e),
            Err(_) => Ok(None), // thread exited after a clean close
        }
    }

    /// Hand a consumed batch back for allocation reuse.
    pub fn recycle(&self, batch: Vec<HostTensor>) {
        let _ = self.empty.send(batch);
    }
}

impl Drop for BatchPrefetcher {
    fn drop(&mut self) {
        // Dropping `full` makes the thread's next send fail, so it
        // exits after at most one more sample. Join only when already
        // finished: a thread still blocked inside `sample_batch` on an
        // open table is only unblocked by the table's `close()`, which
        // the program supervisor owns — joining here could deadlock.
        if let Some(h) = self.handle.take() {
            if h.is_finished() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{Item, Table, Transition};
    use crate::runtime::ArtifactSpec;
    use crate::systems::Family;
    use std::collections::HashMap;

    fn ff_spec() -> ArtifactSpec {
        ArtifactSpec {
            name: "test_train".into(),
            file: String::new(),
            inputs: vec![],
            outputs: vec![],
            meta: [
                ("batch", 2usize),
                ("n_agents", 2),
                ("obs_dim", 3),
                ("act_dim", 4),
                ("state_dim", 0),
                ("seq_len", 0),
                ("msg_dim", 0),
            ]
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect::<HashMap<_, _>>(),
            inits: vec![],
        }
    }

    fn filled_table(n: usize) -> Arc<Table> {
        let table = Arc::new(Table::uniform(64, 1, 0));
        for i in 0..n {
            table.insert(
                Item::Transition(Transition {
                    obs: vec![i as f32; 6],
                    actions_disc: vec![0, 1],
                    rewards: vec![1.0, 1.0],
                    discount: 1.0,
                    next_obs: vec![0.5; 6],
                    ..Default::default()
                }),
                1.0,
            );
        }
        table
    }

    #[test]
    fn prefetches_batches_until_close() {
        let table = filled_table(8);
        let asm = BatchAssembler::new(Family::DqnFf, &ff_spec(), 0).unwrap();
        let pf = BatchPrefetcher::spawn(table.clone(), asm, 2);
        for _ in 0..5 {
            let batch =
                pf.next_batch().unwrap().expect("prefetcher starved");
            assert_eq!(batch.len(), 5);
            assert_eq!(batch[0].dims, vec![2, 2, 3]);
            assert_eq!(batch[3].as_f32(), &[1.0, 1.0]);
            pf.recycle(batch);
        }
        table.close();
        // drain whatever was in flight; the stream must then end
        while pf.next_batch().unwrap().is_some() {}
    }

    #[test]
    fn closed_source_ends_stream() {
        let table = filled_table(0);
        table.close();
        let asm = BatchAssembler::new(Family::DqnFf, &ff_spec(), 0).unwrap();
        let pf = BatchPrefetcher::spawn(table, asm, 1);
        assert!(pf.next_batch().unwrap().is_none());
    }

    #[test]
    fn assembly_failure_surfaces_as_error() {
        // items with a wrong obs length: assembly must fail on the
        // thread and the error must reach the consumer (not look like
        // a clean shutdown)
        let table = Arc::new(Table::uniform(8, 1, 0));
        for _ in 0..4 {
            table.insert(
                Item::Transition(Transition {
                    obs: vec![0.0; 2], // != n_agents * obs_dim
                    actions_disc: vec![0, 1],
                    rewards: vec![1.0, 1.0],
                    discount: 1.0,
                    next_obs: vec![0.5; 6],
                    ..Default::default()
                }),
                1.0,
            );
        }
        let asm = BatchAssembler::new(Family::DqnFf, &ff_spec(), 0).unwrap();
        let pf = BatchPrefetcher::spawn(table.clone(), asm, 1);
        assert!(pf.next_batch().is_err());
        // after the failure the stream ends
        assert!(pf.next_batch().unwrap().is_none());
        table.close();
    }
}
