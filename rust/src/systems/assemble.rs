//! Batch assembly for the trainer hot path: sampled replay items →
//! the fixed-shape input tensors the train artifact expects.
//!
//! The seed trainer rebuilt every batch tensor from freshly allocated
//! `Vec`s each step. Here assembly writes into a reusable
//! [`BatchArena`] of preallocated tensors instead (zero steady-state
//! allocation), and the [`BatchAssembler`] owning the per-family
//! layout logic is a standalone object so the same code runs inline in
//! [`crate::systems::Trainer::step`] or on a
//! [`crate::systems::BatchPrefetcher`] thread (DESIGN.md §8).

use anyhow::{ensure, Result};

use crate::core::{Dtype, HostTensor};
use crate::replay::Item;
use crate::rng::Rng;
use crate::runtime::ArtifactSpec;
use crate::systems::Family;

/// Reusable storage for one assembled batch: the train artifact's
/// input tensors (batch portion only — state, lr and tau are the
/// trainer's own). Starts empty; [`BatchAssembler::assemble_into`]
/// (re)allocates it lazily on first use or layout change, then reuses
/// the buffers on every later call.
#[derive(Default)]
pub struct BatchArena {
    tensors: Vec<HostTensor>,
}

impl BatchArena {
    /// Rebuild an arena around tensors handed back by a consumer (the
    /// prefetcher's recycle path); mismatched layouts are detected and
    /// replaced at the next `assemble_into`.
    pub fn from_tensors(tensors: Vec<HostTensor>) -> Self {
        BatchArena { tensors }
    }

    /// The assembled batch, in artifact input order.
    pub fn tensors(&self) -> &[HostTensor] {
        &self.tensors
    }

    /// Take ownership of the assembled batch (to send across a
    /// channel); pair with [`BatchArena::from_tensors`] to recycle.
    pub fn into_tensors(self) -> Vec<HostTensor> {
        self.tensors
    }

    /// Reallocate only when the held tensors don't match `layout`.
    fn ensure_layout(&mut self, layout: &[(Dtype, Vec<usize>)]) {
        let matches = self.tensors.len() == layout.len()
            && self
                .tensors
                .iter()
                .zip(layout)
                .all(|(t, (d, dims))| t.dtype == *d && &t.dims == dims);
        if matches {
            return;
        }
        self.tensors = layout
            .iter()
            .map(|(d, dims)| match d {
                Dtype::F32 => HostTensor::zeros_f32(dims.clone()),
                Dtype::I32 => HostTensor::zeros_i32(dims.clone()),
            })
            .collect();
    }
}

/// Copy one item's row into batch slot `b` of a `[B, ...]` tensor.
fn fill_f32(t: &mut HostTensor, b: usize, row: &[f32]) -> Result<()> {
    let r = t.len() / t.dims[0];
    ensure!(
        row.len() == r,
        "batch item field len {} != expected {r}",
        row.len()
    );
    t.as_f32_mut()[b * r..(b + 1) * r].copy_from_slice(row);
    Ok(())
}

/// [`fill_f32`] for i32 tensors (discrete joint actions).
fn fill_i32(t: &mut HostTensor, b: usize, row: &[i32]) -> Result<()> {
    let r = t.len() / t.dims[0];
    ensure!(
        row.len() == r,
        "batch item field len {} != expected {r}",
        row.len()
    );
    t.as_i32_mut()[b * r..(b + 1) * r].copy_from_slice(row);
    Ok(())
}

/// Turns sampled replay items into the train artifact's batch inputs.
///
/// Owns the per-family batch layout, the preset dims (read once from
/// the artifact spec) and the DIAL channel-noise generator. Cheap to
/// construct; hold one per consumer thread (the trainer's inline path
/// and the prefetch thread each own one — cloned or seeded
/// identically, so the two paths draw the same DIAL noise sequence).
#[derive(Clone)]
pub struct BatchAssembler {
    family: Family,
    batch: usize,
    n_agents: usize,
    seq_len: usize,
    /// per-family tensor layout, computed once (checked per call
    /// against the arena without allocating)
    layout: Vec<(Dtype, Vec<usize>)>,
    rng: Rng, // DIAL channel noise
}

impl BatchAssembler {
    /// Build an assembler for `family` batches, reading the preset
    /// dims from a train artifact's spec.
    pub fn new(
        family: Family,
        spec: &ArtifactSpec,
        seed: u64,
    ) -> Result<BatchAssembler> {
        let batch = spec.meta_usize("batch")?;
        let n_agents = spec.meta_usize("n_agents")?;
        let seq_len = spec.meta_usize("seq_len")?;
        let layout = layout_for(
            family,
            batch,
            n_agents,
            spec.meta_usize("obs_dim")?,
            spec.meta_usize("act_dim")?,
            spec.meta_usize("state_dim")?,
            seq_len,
            spec.meta_usize("msg_dim")?,
        );
        Ok(BatchAssembler {
            family,
            batch,
            n_agents,
            seq_len,
            layout,
            rng: Rng::new(seed),
        })
    }

    /// Batch size the artifact was lowered at (items per assembly).
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Assemble `items` into `arena` (reallocating it only on first
    /// use or layout change). After `Ok(())`, `arena.tensors()` holds
    /// the artifact's batch inputs in order.
    pub fn assemble_into(
        &mut self,
        items: &[Item],
        arena: &mut BatchArena,
    ) -> Result<()> {
        ensure!(items.len() == self.batch, "short batch: {}", items.len());
        arena.ensure_layout(&self.layout);
        let ts = &mut arena.tensors;
        match self.family {
            Family::DqnFf => {
                for (b, it) in items.iter().enumerate() {
                    let t = it.as_transition();
                    fill_f32(&mut ts[0], b, &t.obs)?;
                    fill_i32(&mut ts[1], b, &t.actions_disc)?;
                    fill_f32(&mut ts[2], b, &t.rewards)?;
                    ts[3].as_f32_mut()[b] = t.discount;
                    fill_f32(&mut ts[4], b, &t.next_obs)?;
                }
            }
            Family::ValueDecomp => {
                for (b, it) in items.iter().enumerate() {
                    let t = it.as_transition();
                    fill_f32(&mut ts[0], b, &t.obs)?;
                    fill_f32(&mut ts[1], b, &t.state)?;
                    fill_i32(&mut ts[2], b, &t.actions_disc)?;
                    // team reward: env replicates the shared reward
                    ensure!(!t.rewards.is_empty(), "transition without rewards");
                    ts[3].as_f32_mut()[b] = t.rewards[0];
                    ts[4].as_f32_mut()[b] = t.discount;
                    fill_f32(&mut ts[5], b, &t.next_obs)?;
                    fill_f32(&mut ts[6], b, &t.next_state)?;
                }
            }
            Family::Ddpg => {
                for (b, it) in items.iter().enumerate() {
                    let t = it.as_transition();
                    fill_f32(&mut ts[0], b, &t.obs)?;
                    fill_f32(&mut ts[1], b, &t.actions_cont)?;
                    fill_f32(&mut ts[2], b, &t.rewards)?;
                    ts[3].as_f32_mut()[b] = t.discount;
                    fill_f32(&mut ts[4], b, &t.next_obs)?;
                }
            }
            Family::DqnRec | Family::Dial => {
                let (t_len, n) = (self.seq_len, self.n_agents);
                for (b, it) in items.iter().enumerate() {
                    let sq = it.as_sequence();
                    ensure!(sq.t == t_len, "sequence length mismatch");
                    fill_f32(&mut ts[0], b, &sq.obs)?;
                    fill_i32(&mut ts[1], b, &sq.actions)?;
                    if self.family == Family::Dial {
                        // team reward: one shared scalar per step
                        ensure!(
                            sq.rewards.len() == t_len * n,
                            "sequence rewards len mismatch"
                        );
                        let rew = ts[2].as_f32_mut();
                        for step in 0..t_len {
                            rew[b * t_len + step] = sq.rewards[step * n];
                        }
                    } else {
                        fill_f32(&mut ts[2], b, &sq.rewards)?;
                    }
                    fill_f32(&mut ts[3], b, &sq.discounts)?;
                    fill_f32(&mut ts[4], b, &sq.mask)?;
                }
                if self.family == Family::Dial {
                    for x in ts[5].as_f32_mut() {
                        *x = self.rng.normal_f32();
                    }
                }
            }
        }
        Ok(())
    }
}

/// The per-family batch tensor layout, in artifact input order
/// (`b` batch, `n` agents, `o` obs dim, `a` act dim, `s` state dim,
/// `t` sequence length, `m` message dim).
#[allow(clippy::too_many_arguments)]
fn layout_for(
    family: Family,
    b: usize,
    n: usize,
    o: usize,
    a: usize,
    s: usize,
    t: usize,
    m: usize,
) -> Vec<(Dtype, Vec<usize>)> {
    match family {
        Family::DqnFf => vec![
            (Dtype::F32, vec![b, n, o]),
            (Dtype::I32, vec![b, n]),
            (Dtype::F32, vec![b, n]),
            (Dtype::F32, vec![b]),
            (Dtype::F32, vec![b, n, o]),
        ],
        Family::ValueDecomp => vec![
            (Dtype::F32, vec![b, n, o]),
            (Dtype::F32, vec![b, s]),
            (Dtype::I32, vec![b, n]),
            (Dtype::F32, vec![b]),
            (Dtype::F32, vec![b]),
            (Dtype::F32, vec![b, n, o]),
            (Dtype::F32, vec![b, s]),
        ],
        Family::Ddpg => vec![
            (Dtype::F32, vec![b, n, o]),
            (Dtype::F32, vec![b, n, a]),
            (Dtype::F32, vec![b, n]),
            (Dtype::F32, vec![b]),
            (Dtype::F32, vec![b, n, o]),
        ],
        Family::DqnRec => vec![
            (Dtype::F32, vec![b, t + 1, n, o]),
            (Dtype::I32, vec![b, t, n]),
            (Dtype::F32, vec![b, t, n]),
            (Dtype::F32, vec![b, t]),
            (Dtype::F32, vec![b, t]),
        ],
        Family::Dial => vec![
            (Dtype::F32, vec![b, t + 1, n, o]),
            (Dtype::I32, vec![b, t, n]),
            (Dtype::F32, vec![b, t]),
            (Dtype::F32, vec![b, t]),
            (Dtype::F32, vec![b, t]),
            (Dtype::F32, vec![b, t + 1, n, m]),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{Sequence, Transition};
    use std::collections::HashMap;

    /// A synthetic train-artifact spec carrying only the meta dims the
    /// assembler reads — no PJRT involved.
    fn spec(dims: &[(&str, usize)]) -> ArtifactSpec {
        ArtifactSpec {
            name: "test_train".into(),
            file: String::new(),
            inputs: vec![],
            outputs: vec![],
            meta: dims
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect::<HashMap<_, _>>(),
            inits: vec![],
        }
    }

    fn ff_spec() -> ArtifactSpec {
        spec(&[
            ("batch", 2),
            ("n_agents", 2),
            ("obs_dim", 3),
            ("act_dim", 4),
            ("state_dim", 5),
            ("seq_len", 0),
            ("msg_dim", 0),
        ])
    }

    fn transition(v: f32) -> Item {
        Item::Transition(Transition {
            obs: vec![v; 6],
            state: vec![v + 0.5; 5],
            actions_disc: vec![1, 2],
            rewards: vec![v, -v],
            discount: 0.9,
            next_obs: vec![v + 1.0; 6],
            next_state: vec![v + 1.5; 5],
            ..Default::default()
        })
    }

    #[test]
    fn dqnff_layout_and_values() {
        let mut asm =
            BatchAssembler::new(Family::DqnFf, &ff_spec(), 0).unwrap();
        assert_eq!(asm.batch_size(), 2);
        let mut arena = BatchArena::default();
        let items = vec![transition(1.0), transition(2.0)];
        asm.assemble_into(&items, &mut arena).unwrap();
        let ts = arena.tensors();
        assert_eq!(ts.len(), 5);
        assert_eq!(ts[0].dims, vec![2, 2, 3]);
        assert_eq!(ts[0].as_f32()[..6], [1.0; 6]);
        assert_eq!(ts[0].as_f32()[6..], [2.0; 6]);
        assert_eq!(ts[1].as_i32(), &[1, 2, 1, 2]);
        assert_eq!(ts[2].as_f32(), &[1.0, -1.0, 2.0, -2.0]);
        assert_eq!(ts[3].as_f32(), &[0.9, 0.9]);
        assert_eq!(ts[4].as_f32()[..6], [2.0; 6]);
    }

    #[test]
    fn arena_reuses_allocations() {
        let mut asm =
            BatchAssembler::new(Family::DqnFf, &ff_spec(), 0).unwrap();
        let mut arena = BatchArena::default();
        let items = vec![transition(1.0), transition(2.0)];
        asm.assemble_into(&items, &mut arena).unwrap();
        let ptr0 = arena.tensors()[0].as_f32().as_ptr();
        asm.assemble_into(&items, &mut arena).unwrap();
        assert_eq!(
            ptr0,
            arena.tensors()[0].as_f32().as_ptr(),
            "second assembly reallocated the arena"
        );
    }

    #[test]
    fn value_decomp_team_reward_and_state() {
        let mut asm =
            BatchAssembler::new(Family::ValueDecomp, &ff_spec(), 0).unwrap();
        let mut arena = BatchArena::default();
        let items = vec![transition(1.0), transition(2.0)];
        asm.assemble_into(&items, &mut arena).unwrap();
        let ts = arena.tensors();
        assert_eq!(ts.len(), 7);
        assert_eq!(ts[1].dims, vec![2, 5]);
        assert_eq!(ts[1].as_f32()[..5], [1.5; 5]);
        // team reward = rewards[0]
        assert_eq!(ts[3].as_f32(), &[1.0, 2.0]);
        assert_eq!(ts[6].as_f32()[5..], [3.5; 5]);
    }

    fn seq_spec() -> ArtifactSpec {
        spec(&[
            ("batch", 1),
            ("n_agents", 2),
            ("obs_dim", 3),
            ("act_dim", 4),
            ("state_dim", 0),
            ("seq_len", 2),
            ("msg_dim", 2),
        ])
    }

    fn sequence() -> Item {
        Item::Sequence(Sequence {
            t: 2,
            obs: (0..18).map(|i| i as f32).collect(), // (T+1)*N*O
            actions: vec![0, 1, 2, 3],                // T*N
            rewards: vec![5.0, 6.0, 7.0, 8.0],        // T*N
            discounts: vec![1.0, 0.0],
            mask: vec![1.0, 1.0],
        })
    }

    #[test]
    fn dial_gathers_team_reward_and_draws_noise() {
        let mut asm =
            BatchAssembler::new(Family::Dial, &seq_spec(), 7).unwrap();
        let mut arena = BatchArena::default();
        asm.assemble_into(&[sequence()], &mut arena).unwrap();
        let ts = arena.tensors();
        assert_eq!(ts.len(), 6);
        // team reward: rewards[step * n]
        assert_eq!(ts[2].as_f32(), &[5.0, 7.0]);
        assert_eq!(ts[5].dims, vec![1, 3, 2, 2]);
        let noise0 = ts[5].as_f32().to_vec();
        assert!(noise0.iter().any(|x| *x != 0.0), "noise not drawn");
        asm.assemble_into(&[sequence()], &mut arena).unwrap();
        assert_ne!(
            arena.tensors()[5].as_f32(),
            &noise0[..],
            "noise must advance between batches"
        );
    }

    #[test]
    fn recurrent_keeps_per_agent_rewards() {
        let mut asm =
            BatchAssembler::new(Family::DqnRec, &seq_spec(), 0).unwrap();
        let mut arena = BatchArena::default();
        asm.assemble_into(&[sequence()], &mut arena).unwrap();
        let ts = arena.tensors();
        assert_eq!(ts.len(), 5);
        assert_eq!(ts[2].as_f32(), &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(ts[3].as_f32(), &[1.0, 0.0]);
    }

    #[test]
    fn rejects_short_batch_and_bad_rows() {
        let mut asm =
            BatchAssembler::new(Family::DqnFf, &ff_spec(), 0).unwrap();
        let mut arena = BatchArena::default();
        assert!(asm.assemble_into(&[transition(1.0)], &mut arena).is_err());
        let bad = Item::Transition(Transition {
            obs: vec![0.0; 2], // wrong [N*O]
            actions_disc: vec![0, 0],
            rewards: vec![0.0, 0.0],
            next_obs: vec![0.0; 6],
            ..Default::default()
        });
        assert!(asm
            .assemble_into(&[bad, transition(1.0)], &mut arena)
            .is_err());
    }
}
