//! MARL systems: the Executor-Trainer paradigm (paper §4, Figure 2).
//!
//! A *system* = executor(s) + trainer + dataset (replay table). The
//! executor is the multi-agent actor collection: it runs the policy
//! artifact, explores, and feeds an adder. The trainer is the multi-agent
//! learner collection: it samples the table and runs the fused train-step
//! artifact (loss + Adam + target update in one HLO module), then pushes
//! fresh parameters to the parameter server.
//!
//! Implemented baseline systems (paper §4 "System implementations"):
//! MADQN (feedforward + recurrent), DIAL, VDN, QMIX, MADDPG, MAD4PG.
//!
//! Executors come in two shapes: the single-environment [`Executor`]
//! (evaluation, B=1) and the batched [`VecExecutor`] driving a
//! [`crate::env::VecEnv`] with one policy call per vector step
//! (DESIGN.md §6).
//!
//! The trainer hot path (DESIGN.md §8) is device-resident and
//! pipelined: [`Trainer`] keeps `(params, target, opt)` in PJRT
//! buffers across steps, a [`BatchAssembler`] writes sampled items
//! into a reusable [`BatchArena`], and a [`BatchPrefetcher`] thread
//! assembles batch `k+1` while step `k` executes.
//!
//! System *construction* is layered (DESIGN.md §9): a declarative
//! [`SystemSpec`] (what a system is), the [`mod@nodes`] module's
//! executor/trainer/evaluator node structs (how each runs, over an
//! explicit [`SystemHandles`] context), and the fluent
//! [`SystemBuilder`] that wires them into a launchable [`System`].
//! [`train`] is a thin wrapper over the builder.

#![warn(missing_docs)]

mod assemble;
mod builder;
mod executor;
pub mod nodes;
mod prefetch;
mod spec;
mod trainer;

pub use assemble::{BatchArena, BatchAssembler};
pub use builder::{
    check_artifacts, eval_episode, eval_policy_batch, make_vec_evaluator,
    make_vec_evaluator_with, train, NodeFailure, System, SystemBuilder,
    TrainResult,
};
pub use executor::{
    select_discrete_row, ActorState, Executor, VecExecutor,
};
pub use nodes::{
    trainer_checkpoint_path, Adder, AdderFactory, EnvFactory, EvalPoint,
    EvaluatorNode, ExecutorNode, SystemHandles, TrainerNode,
};
pub use prefetch::BatchPrefetcher;
pub use spec::{
    env_for_preset, AdderKind, ExplorationMode, SystemSpec, SPECS,
};
pub use trainer::{
    read_trainer_checkpoint, write_trainer_checkpoint, Trainer,
    TrainerStats,
};

use anyhow::Result;

/// Which baseline system is running (selects artifacts + data plumbing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// Independent feedforward multi-agent DQN.
    Madqn,
    /// Recurrent (GRU) multi-agent DQN.
    MadqnRec,
    /// Differentiable inter-agent learning (learned communication).
    Dial,
    /// Value-decomposition networks (additive mixing).
    Vdn,
    /// QMIX (monotonic hypernetwork mixing).
    Qmix,
    /// Multi-agent DDPG (continuous control).
    Maddpg,
    /// Distributional multi-agent D4PG.
    Mad4pg,
}

/// Data-plumbing family: what the executor carries between steps and what
/// batch layout the train artifact consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// feedforward Q: transition batch (obs, act, rew[B,N], disc, next)
    DqnFf,
    /// recurrent Q: sequence batch (obs, act, rew[B,T,N], disc, mask)
    DqnRec,
    /// DIAL: sequence batch + team reward + channel noise
    Dial,
    /// VDN/QMIX: transition batch + global state + team reward
    ValueDecomp,
    /// MADDPG/MAD4PG: continuous transition batch
    Ddpg,
}

impl SystemKind {
    /// Parse a config `system` string (e.g. `"vdn"`) through the
    /// [`SystemSpec`] table.
    pub fn parse(s: &str) -> Result<SystemKind> {
        Ok(SystemSpec::parse(s)?.kind)
    }

    /// This kind's declarative spec — the single source of truth for
    /// everything below.
    pub fn spec(&self) -> &'static SystemSpec {
        SystemSpec::of(*self)
    }

    /// The data-plumbing family this system trains with.
    pub fn family(&self) -> Family {
        self.spec().family
    }

    /// Whether the action space is discrete.
    pub fn discrete(&self) -> bool {
        self.spec().discrete
    }

    /// Does the executor carry recurrent state across steps?
    pub fn recurrent(&self) -> bool {
        self.spec().recurrent
    }

    /// Does the trainer consume sequences rather than transitions?
    pub fn sequences(&self) -> bool {
        self.spec().sequences()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_family() {
        assert_eq!(SystemKind::parse("vdn").unwrap(), SystemKind::Vdn);
        assert_eq!(SystemKind::Vdn.family(), Family::ValueDecomp);
        assert_eq!(SystemKind::Mad4pg.family(), Family::Ddpg);
        assert!(!SystemKind::Mad4pg.discrete());
        assert!(SystemKind::Dial.recurrent());
        assert!(!SystemKind::Madqn.sequences());
        assert!(SystemKind::parse("bogus").is_err());
    }
}
