//! The trainer: Mava's multi-agent learner collection.
//!
//! Samples the replay table, assembles the fixed-shape batch the train
//! artifact expects (through a reusable [`BatchArena`], or a
//! [`crate::systems::BatchPrefetcher`] thread), executes one fused
//! train step (loss + clipped Adam + Polyak target update, a single
//! HLO module) and publishes the updated parameters every
//! `publish_interval` steps.
//!
//! In the default *device-resident* mode the training state
//! `(params [P], target [P], opt [1+2P])` lives in PJRT buffers across
//! steps: each step feeds the previous step's output buffers straight
//! back as [`Arg::Dev`] inputs, so the steady state uploads only the
//! batch and downloads only the loss — the ~5P-float state round-trip
//! the seed trainer paid per step is gone (DESIGN.md §8). Host copies
//! are refreshed only on publish ticks and checkpoints.
//!
//! The *data-parallel* mode ([`Trainer::new_data_parallel`],
//! DESIGN.md §11) runs D device lanes in lock-step over the sharded
//! `{train}_dp{D}` gradient artifact: the assembled full batch is
//! split into D leading-dim shards, each lane computes its shard's
//! gradient, the gradients are all-reduced (fixed-order mean) on the
//! host, and every lane applies the SAME reduced gradient through the
//! `{train}_apply` artifact (clip + Adam + Polyak) — so the lane
//! states stay bitwise identical and lane 0 is always the system of
//! record for publishes and checkpoints.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::core::{Dtype, HostTensor};
use crate::params::ParamStore;
use crate::replay::ItemSource;
use crate::runtime::{Arg, Artifact};
use crate::systems::{BatchArena, BatchAssembler, BatchPrefetcher, Family};

/// Progress counters the trainer exposes to supervisors and benches.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainerStats {
    /// Completed train steps.
    pub steps: u64,
    /// Loss of the most recent step.
    pub last_loss: f32,
}

/// Device-resident training state: the three buffers fed back into the
/// train artifact every step without touching the host, plus the
/// constant `lr`/`tau` scalars (uploaded once at construction).
struct DeviceState {
    params: xla::PjRtBuffer,
    target: xla::PjRtBuffer,
    opt: xla::PjRtBuffer,
    lr: xla::PjRtBuffer,
    tau: xla::PjRtBuffer,
}

/// Data-parallel lane state (DESIGN.md §11): D replicas of the
/// training state plus the sharded-gradient / apply artifact pair.
/// The lanes are bitwise identical between steps by construction —
/// every lane applies the same host-reduced gradient — so any lane
/// can serve reads; lane 0 is used by convention.
struct DpLanes {
    /// `{train}_dp{D}`: `(params, target, shard_batch...) ->
    /// (grads [P], loss)` — the shard's UNCLIPPED mean gradient.
    grad: Rc<Artifact>,
    /// `{train}_apply`: `(params, target, opt, grads, lr, tau) ->
    /// (params', target', opt')` — clip + Adam + Polyak, applied
    /// post-all-reduce.
    apply: Rc<Artifact>,
    lanes: Vec<DeviceState>,
    /// Reused per-lane shard tensors (one per batch input; refilled in
    /// place each lane, alive only while that lane's call runs).
    shard_scratch: Vec<HostTensor>,
    /// Reused fixed-order all-reduce accumulator `[P]`.
    grad_acc: Vec<f32>,
    /// Reused per-lane loss accumulator (loss vectors are tiny).
    loss_acc: Vec<f32>,
}

/// The multi-agent learner: samples replay, runs the fused train-step
/// artifact and publishes fresh parameters.
pub struct Trainer {
    artifact: Rc<Artifact>,
    // Host mirrors of the training state. Authoritative on the host
    // path; on the device path they lag the device buffers and are
    // refreshed on publish ticks, checkpoints and explicit syncs.
    params: HostTensor,
    target: HostTensor,
    opt: HostTensor,
    /// `Some` = device-resident mode (the default).
    dev: Option<DeviceState>,
    /// `Some` = data-parallel mode (`dev` is then `None`).
    dp: Option<DpLanes>,
    params_mirror_fresh: bool,
    /// covers the target + opt mirrors (downloaded only by checkpoints)
    aux_mirror_fresh: bool,
    lr: HostTensor,
    tau: HostTensor,
    batch: usize,
    assembler: BatchAssembler,
    arena: BatchArena,
    /// `MAVA_TRACE_LOSS`, read once at construction (not per step).
    trace: bool,
    publish_every: u64,
    last_published_step: u64,
    /// Progress counters (steps, last loss).
    pub stats: TrainerStats,
}

impl Trainer {
    /// Build a device-resident trainer over a train-step artifact,
    /// starting from the artifact's `params0`/`opt0` init blobs.
    /// `family` is the batch layout declared by the system's
    /// [`crate::systems::SystemSpec`] (the
    /// [`crate::systems::TrainerNode`] passes `spec.family`).
    pub fn new(
        family: Family,
        artifact: Rc<Artifact>,
        params0: Vec<f32>,
        opt0: Vec<f32>,
        lr: f32,
        tau: f32,
        seed: u64,
    ) -> Result<Trainer> {
        Self::build(family, artifact, params0, opt0, lr, tau, seed, true)
    }

    /// Build a trainer that keeps its state on the host and re-uploads
    /// it every step (the seed behaviour) — the baseline
    /// `benches/trainer_throughput.rs` measures the device path
    /// against.
    pub fn new_host_resident(
        family: Family,
        artifact: Rc<Artifact>,
        params0: Vec<f32>,
        opt0: Vec<f32>,
        lr: f32,
        tau: f32,
        seed: u64,
    ) -> Result<Trainer> {
        Self::build(family, artifact, params0, opt0, lr, tau, seed, false)
    }

    /// Build a data-parallel trainer over the `{train}_dp{D}` sharded
    /// gradient artifact and its `{train}_apply` companion
    /// (DESIGN.md §11). The lane count D is the gradient artifact's
    /// `dp_shards` meta; batches are still assembled at the FULL batch
    /// size (the gradient artifact carries the same `batch` meta as
    /// the fused train step), split into D leading-dim shards per
    /// step. Only losses that are unweighted batch means are lowered
    /// this way, so mean-of-shard-gradients equals the full-batch
    /// gradient exactly.
    #[allow(clippy::too_many_arguments)]
    pub fn new_data_parallel(
        family: Family,
        grad_artifact: Rc<Artifact>,
        apply_artifact: Rc<Artifact>,
        params0: Vec<f32>,
        opt0: Vec<f32>,
        lr: f32,
        tau: f32,
        seed: u64,
    ) -> Result<Trainer> {
        let gspec = &grad_artifact.spec;
        let aspec = &apply_artifact.spec;
        let p = gspec.meta_usize("params")?;
        anyhow::ensure!(params0.len() == p, "params0 len mismatch");
        anyhow::ensure!(opt0.len() == 1 + 2 * p, "opt0 len mismatch");
        let shards = gspec.meta_usize("dp_shards")?;
        let shard_batch = gspec.meta_usize("shard_batch")?;
        let batch = gspec.meta_usize("batch")?;
        anyhow::ensure!(
            shards >= 2 && shards * shard_batch == batch,
            "{}: dp_shards {} * shard_batch {} != batch {}",
            gspec.name,
            shards,
            shard_batch,
            batch
        );
        anyhow::ensure!(
            gspec.inputs.len() >= 3 && gspec.outputs.len() == 2,
            "{}: dp gradient artifact must take (params, target, \
             shard_batch...) and return (grads, loss)",
            gspec.name
        );
        anyhow::ensure!(
            aspec.inputs.len() == 6 && aspec.outputs.len() == 3,
            "{}: apply artifact must take (params, target, opt, grads, \
             lr, tau) and return (params', target', opt')",
            aspec.name
        );
        let assembler = BatchAssembler::new(family, gspec, seed)?;
        let mut t = Trainer {
            batch,
            artifact: grad_artifact,
            params: HostTensor::f32(vec![p], params0),
            target: HostTensor::f32(vec![p], vec![0.0; p]),
            opt: HostTensor::f32(vec![1 + 2 * p], opt0),
            dev: None,
            dp: None,
            params_mirror_fresh: true,
            aux_mirror_fresh: true,
            lr: HostTensor::scalar_f32(lr),
            tau: HostTensor::scalar_f32(tau),
            assembler,
            arena: BatchArena::default(),
            trace: std::env::var_os("MAVA_TRACE_LOSS").is_some(),
            publish_every: 1,
            last_published_step: 0,
            stats: TrainerStats::default(),
        };
        let lanes = (0..shards)
            .map(|_| t.upload_lane(&apply_artifact))
            .collect::<Result<Vec<_>>>()?;
        t.dp = Some(DpLanes {
            grad: t.artifact.clone(),
            apply: apply_artifact,
            lanes,
            shard_scratch: Vec::new(),
            grad_acc: Vec::new(),
            loss_acc: Vec::new(),
        });
        Ok(t)
    }

    /// Number of data-parallel device lanes (1 on the fused paths).
    pub fn num_lanes(&self) -> usize {
        self.dp.as_ref().map_or(1, |dp| dp.lanes.len())
    }

    #[allow(clippy::too_many_arguments)]
    fn build(
        family: Family,
        artifact: Rc<Artifact>,
        params0: Vec<f32>,
        opt0: Vec<f32>,
        lr: f32,
        tau: f32,
        seed: u64,
        device_resident: bool,
    ) -> Result<Trainer> {
        let spec = &artifact.spec;
        let p = spec.meta_usize("params")?;
        anyhow::ensure!(params0.len() == p, "params0 len mismatch");
        anyhow::ensure!(opt0.len() == 1 + 2 * p, "opt0 len mismatch");
        anyhow::ensure!(
            spec.inputs.len() >= 5 && spec.outputs.len() >= 4,
            "{}: train artifact must take (params, target, opt, batch..., \
             lr, tau) and return (params', target', opt', loss, ...)",
            spec.name
        );
        let assembler = BatchAssembler::new(family, spec, seed)?;
        let mut t = Trainer {
            batch: spec.meta_usize("batch")?,
            artifact,
            params: HostTensor::f32(vec![p], params0),
            target: HostTensor::f32(vec![p], vec![0.0; p]),
            opt: HostTensor::f32(vec![1 + 2 * p], opt0),
            dev: None,
            dp: None,
            params_mirror_fresh: true,
            aux_mirror_fresh: true,
            lr: HostTensor::scalar_f32(lr),
            tau: HostTensor::scalar_f32(tau),
            assembler,
            arena: BatchArena::default(),
            trace: std::env::var_os("MAVA_TRACE_LOSS").is_some(),
            publish_every: 1,
            last_published_step: 0,
            stats: TrainerStats::default(),
        };
        if device_resident {
            t.dev = Some(t.upload_state()?);
        }
        Ok(t)
    }

    /// Upload the host mirrors as fresh device state (construction,
    /// checkpoint restore). `lr`/`tau` are the train artifact's last
    /// two inputs.
    fn upload_state(&self) -> Result<DeviceState> {
        let ins = &self.artifact.spec.inputs;
        let k = ins.len();
        Ok(DeviceState {
            params: self.artifact.upload(&self.params, &ins[0].dims)?,
            target: self.artifact.upload(&self.target, &ins[1].dims)?,
            opt: self.artifact.upload(&self.opt, &ins[2].dims)?,
            lr: self.artifact.upload(&self.lr, &ins[k - 2].dims)?,
            tau: self.artifact.upload(&self.tau, &ins[k - 1].dims)?,
        })
    }

    /// Upload the host mirrors as one fresh data-parallel lane. The
    /// apply artifact's inputs dictate the state shapes:
    /// `(params, target, opt, grads, lr, tau)`.
    fn upload_lane(&self, apply: &Artifact) -> Result<DeviceState> {
        let ins = &apply.spec.inputs;
        Ok(DeviceState {
            params: apply.upload(&self.params, &ins[0].dims)?,
            target: apply.upload(&self.target, &ins[1].dims)?,
            opt: apply.upload(&self.opt, &ins[2].dims)?,
            lr: apply.upload(&self.lr, &ins[4].dims)?,
            tau: apply.upload(&self.tau, &ins[5].dims)?,
        })
    }

    /// Whether the training state lives in device buffers.
    pub fn device_resident(&self) -> bool {
        self.dev.is_some() || self.dp.is_some()
    }

    /// Publish to the parameter server every `every` steps (default 1).
    /// The host download of the parameter vector happens only on those
    /// ticks; values < 1 are clamped to 1.
    pub fn set_publish_interval(&mut self, every: u64) {
        self.publish_every = every.max(1);
    }

    /// Target network starts as a copy of the online parameters.
    pub fn init_target_from_params(&mut self) -> Result<()> {
        self.sync_mirrors_full()?;
        let p = self.params.as_f32().to_vec();
        self.target.as_f32_mut().copy_from_slice(&p);
        if let Some(dp) = &mut self.dp {
            // every lane gets its own fresh upload of the same mirror,
            // preserving the bitwise lock-step invariant
            for lane in &mut dp.lanes {
                lane.target = dp
                    .apply
                    .upload(&self.target, &dp.apply.spec.inputs[1].dims)?;
            }
            return Ok(());
        }
        if self.dev.is_none() {
            return Ok(());
        }
        let buf = self
            .artifact
            .upload(&self.target, &self.artifact.spec.inputs[1].dims)?;
        if let Some(dev) = &mut self.dev {
            dev.target = buf;
        }
        Ok(())
    }

    /// Current online parameters (flat host view). On the device path
    /// this is the copy as of the last publish / checkpoint / sync —
    /// use [`Trainer::params_synced`] to force a download first.
    pub fn params(&self) -> &[f32] {
        self.params.as_f32()
    }

    /// Download the online parameters from the device (if stale) and
    /// return the fresh host view.
    pub fn params_synced(&mut self) -> Result<&[f32]> {
        self.sync_params_mirror()?;
        Ok(self.params.as_f32())
    }

    /// Batch size the train artifact was lowered at.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Spawn a [`BatchPrefetcher`] thread assembling this trainer's
    /// batches from `source`, for the pipelined loop
    /// (`next_batch` → [`Trainer::step_batch`] → `recycle`). The
    /// thread gets a clone of the trainer's internal assembler, so the
    /// prefetched path continues the exact DIAL-noise sequence the
    /// inline [`Trainer::step`] path would have drawn.
    pub fn spawn_prefetcher<S>(
        &self,
        source: std::sync::Arc<S>,
        depth: usize,
    ) -> BatchPrefetcher
    where
        S: ItemSource + Send + Sync + ?Sized + 'static,
    {
        BatchPrefetcher::spawn(source, self.assembler.clone(), depth)
    }

    fn sync_params_mirror(&mut self) -> Result<()> {
        if self.params_mirror_fresh {
            return Ok(());
        }
        if let Some(dp) = &self.dp {
            // lanes are bitwise identical; lane 0 is the system of
            // record (apply outputs: params', target', opt')
            self.params = dp.apply.to_host(&dp.lanes[0].params, 0)?;
            self.params_mirror_fresh = true;
            return Ok(());
        }
        // stale mirrors only exist on the device paths
        let dev = self.dev.as_ref().expect("host path mirrors never stale");
        self.params = self.artifact.to_host(&dev.params, 0)?;
        self.params_mirror_fresh = true;
        Ok(())
    }

    fn sync_mirrors_full(&mut self) -> Result<()> {
        self.sync_params_mirror()?;
        if self.aux_mirror_fresh {
            return Ok(());
        }
        if let Some(dp) = &self.dp {
            self.target = dp.apply.to_host(&dp.lanes[0].target, 1)?;
            self.opt = dp.apply.to_host(&dp.lanes[0].opt, 2)?;
            self.aux_mirror_fresh = true;
            return Ok(());
        }
        let dev = self.dev.as_ref().expect("host path mirrors never stale");
        self.target = self.artifact.to_host(&dev.target, 1)?;
        self.opt = self.artifact.to_host(&dev.opt, 2)?;
        self.aux_mirror_fresh = true;
        Ok(())
    }

    /// Run one training step on a batch sampled from `source` — a single
    /// [`crate::replay::Table`] or a [`crate::replay::ShardedTable`]
    /// (round-robin over executor shards). Returns None when the source
    /// was closed (shutdown).
    pub fn step<S: ItemSource>(&mut self, source: &S) -> Result<Option<f32>> {
        let Some(items) = source.sample_batch(self.batch) else {
            return Ok(None);
        };
        let mut arena = std::mem::take(&mut self.arena);
        let assembled = self.assembler.assemble_into(&items, &mut arena);
        let stepped =
            assembled.and_then(|()| self.step_batch(arena.tensors()));
        self.arena = arena;
        Ok(Some(stepped?))
    }

    /// Run one training step on an already-assembled batch (the
    /// prefetch path: `inputs` comes from a
    /// [`crate::systems::BatchPrefetcher`]).
    pub fn step_batch(&mut self, inputs: &[HostTensor]) -> Result<f32> {
        if self.trace {
            trace_inputs(inputs, self.stats.steps);
        }
        if self.dp.is_some() {
            return self.step_batch_dp(inputs);
        }
        let loss_t: HostTensor;
        if let Some(mut dev) = self.dev.take() {
            let outs = {
                let mut args: Vec<Arg> = Vec::with_capacity(inputs.len() + 5);
                args.push(Arg::Dev(&dev.params));
                args.push(Arg::Dev(&dev.target));
                args.push(Arg::Dev(&dev.opt));
                for t in inputs {
                    args.push(Arg::Host(t));
                }
                args.push(Arg::Dev(&dev.lr));
                args.push(Arg::Dev(&dev.tau));
                self.artifact.call_device(&args)
            };
            let outs = match outs {
                Ok(o) => o,
                Err(e) => {
                    // the (unchanged) state stays resident for the caller
                    self.dev = Some(dev);
                    return Err(e)
                        .context("train artifact execution (device path)");
                }
            };
            let mut it = outs.into_iter();
            dev.params = it.next().unwrap();
            dev.target = it.next().unwrap();
            dev.opt = it.next().unwrap();
            let loss_buf = it.next().unwrap();
            let fetched = self.artifact.to_host(&loss_buf, 3);
            self.dev = Some(dev);
            // the device state advanced even if the loss fetch failed:
            // mark mirrors stale and count the step NOW, so the publish
            // dedup and checkpoint counter stay in sync with the
            // actually-applied updates
            self.params_mirror_fresh = false;
            self.aux_mirror_fresh = false;
            self.stats.steps += 1;
            loss_t = fetched?;
        } else {
            let mut refs: Vec<&HostTensor> =
                Vec::with_capacity(inputs.len() + 5);
            refs.push(&self.params);
            refs.push(&self.target);
            refs.push(&self.opt);
            refs.extend(inputs.iter());
            refs.push(&self.lr);
            refs.push(&self.tau);
            let out = self
                .artifact
                .call(&refs)
                .context("train artifact execution")?;
            // move (not clone) the big state tensors out of the result
            let mut it = out.into_iter();
            self.params = it.next().unwrap();
            self.target = it.next().unwrap();
            self.opt = it.next().unwrap();
            loss_t = it.next().unwrap();
            self.stats.steps += 1;
        }
        let loss = loss_t.as_f32()[0];
        self.stats.last_loss = loss;
        if self.trace {
            eprintln!(
                "[trainer] step {} losses {:?}",
                self.stats.steps,
                loss_t.as_f32()
            );
        }
        if !loss.is_finite() {
            eprintln!(
                "[trainer] WARNING: non-finite loss at step {}: {:?}",
                self.stats.steps,
                loss_t.as_f32()
            );
        }
        Ok(loss)
    }

    /// One data-parallel train step (DESIGN.md §11): split the
    /// full-batch `inputs` into D leading-dim shards, compute each
    /// lane's shard gradient, all-reduce on the host (fixed lane
    /// order, so the reduction is deterministic), then apply the SAME
    /// reduced gradient on every lane — the lane states stay bitwise
    /// identical. The reported loss is the mean of the lane losses.
    ///
    /// On error the lanes may be mid-update and no longer lock-step;
    /// the step is not counted and the trainer must be rebuilt (a
    /// failed node is torn down by the launcher anyway).
    fn step_batch_dp(&mut self, inputs: &[HostTensor]) -> Result<f32> {
        let mut dp = self.dp.take().expect("dp path");
        let stepped = dp_step(&mut dp, inputs);
        self.dp = Some(dp);
        let loss_vec = stepped?;
        self.params_mirror_fresh = false;
        self.aux_mirror_fresh = false;
        self.stats.steps += 1;
        let loss = loss_vec[0];
        self.stats.last_loss = loss;
        if self.trace {
            eprintln!(
                "[trainer] step {} losses {:?} (dp mean over {} lanes)",
                self.stats.steps,
                loss_vec,
                self.num_lanes()
            );
        }
        if !loss.is_finite() {
            eprintln!(
                "[trainer] WARNING: non-finite loss at step {}: {:?}",
                self.stats.steps, loss_vec
            );
        }
        Ok(loss)
    }

    /// Push the current parameters to `server` unless this step's
    /// parameters were already pushed. Downloads the flat param vector
    /// from the device first (the only steady-state host copy of the
    /// training state). Returns whether a push happened.
    pub fn publish(&mut self, server: &dyn ParamStore) -> Result<bool> {
        if self.last_published_step == self.stats.steps {
            return Ok(false);
        }
        self.sync_params_mirror()?;
        server.push(self.params.as_f32())?;
        self.last_published_step = self.stats.steps;
        Ok(true)
    }

    /// [`Trainer::publish`], gated on the publish cadence: pushes only
    /// when the step counter hits a multiple of `publish_interval`.
    pub fn maybe_publish(&mut self, server: &dyn ParamStore) -> Result<bool> {
        if self.stats.steps % self.publish_every != 0 {
            return Ok(false);
        }
        self.publish(server)
    }

    /// Step and (subject to the publish cadence) publish to the
    /// parameter server.
    pub fn step_and_publish<S: ItemSource>(
        &mut self,
        source: &S,
        server: &dyn ParamStore,
    ) -> Result<Option<f32>> {
        let r = self.step(source)?;
        if r.is_some() {
            self.maybe_publish(server)?;
        }
        Ok(r)
    }

    /// Persist the full training state (online + target params, Adam
    /// state, step counter) as a little-endian f32/u64 blob so long runs
    /// survive restarts. On the device path this forces a download of
    /// all three state tensors (the blob format — `MAVATRN1` — is
    /// unchanged from the host-resident trainer). The write is atomic
    /// (temp file + rename), so a trainer killed mid-save leaves the
    /// previous checkpoint intact — see [`write_trainer_checkpoint`].
    pub fn save_checkpoint(
        &mut self,
        path: impl AsRef<std::path::Path>,
    ) -> Result<()> {
        self.sync_mirrors_full()?;
        write_trainer_checkpoint(
            path.as_ref(),
            self.stats.steps,
            self.params.as_f32(),
            self.target.as_f32(),
            self.opt.as_f32(),
        )
    }

    /// Restore state saved by [`Trainer::save_checkpoint`]. Shapes must
    /// match the artifact this trainer was built for. On the device
    /// path the restored state is re-uploaded into fresh buffers.
    pub fn load_checkpoint(
        &mut self,
        path: impl AsRef<std::path::Path>,
    ) -> Result<()> {
        let (steps, params, target, opt) =
            read_trainer_checkpoint(path.as_ref())?;
        self.stats.steps = steps;
        for (t, src) in [
            (&mut self.params, &params),
            (&mut self.target, &target),
            (&mut self.opt, &opt),
        ] {
            anyhow::ensure!(
                src.len() == t.len(),
                "checkpoint tensor len {} != expected {}",
                src.len(),
                t.len()
            );
            t.as_f32_mut().copy_from_slice(src);
        }
        self.params_mirror_fresh = true;
        self.aux_mirror_fresh = true;
        // the restored parameters have not been pushed anywhere yet
        self.last_published_step = u64::MAX;
        if self.dp.is_some() {
            // rebuild every lane from the restored mirrors: all lanes
            // restart bitwise identical
            let apply =
                self.dp.as_ref().expect("dp path").apply.clone();
            let n = self.dp.as_ref().expect("dp path").lanes.len();
            let lanes = (0..n)
                .map(|_| self.upload_lane(&apply))
                .collect::<Result<Vec<_>>>()?;
            self.dp.as_mut().expect("dp path").lanes = lanes;
        } else if self.dev.is_some() {
            self.dev = Some(self.upload_state()?);
        }
        Ok(())
    }
}

/// Write a `MAVATRN1` trainer checkpoint blob: magic, step counter,
/// then the three length-prefixed f32 tensors (online params, target
/// params, optimiser state), all little-endian. The blob is staged to
/// `{path}.tmp` and renamed into place, so readers never observe a
/// torn file and a crash mid-save leaves the previous checkpoint
/// intact (rename is atomic on POSIX filesystems).
///
/// Free function (rather than a [`Trainer`] method) so the recovery
/// machinery — and its fault-injection tests — can produce and consume
/// real checkpoint blobs without building a trainer.
pub fn write_trainer_checkpoint(
    path: &std::path::Path,
    steps: u64,
    params: &[f32],
    target: &[f32],
    opt: &[f32],
) -> Result<()> {
    use std::io::Write;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    {
        let mut w =
            std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(b"MAVATRN1")?;
        w.write_all(&steps.to_le_bytes())?;
        for t in [params, target, opt] {
            w.write_all(&(t.len() as u64).to_le_bytes())?;
            // one bulk write per tensor, not one per element
            w.write_all(f32_bytes(t))?;
        }
        w.flush()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("commit checkpoint {}", path.display()))?;
    Ok(())
}

/// Read a blob written by [`write_trainer_checkpoint`]: returns
/// `(steps, params, target, opt)`. Validates the magic and that the
/// file ends exactly after the last tensor.
pub fn read_trainer_checkpoint(
    path: &std::path::Path,
) -> Result<(u64, Vec<f32>, Vec<f32>, Vec<f32>)> {
    use std::io::Read;
    let mut r = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| {
            format!("open checkpoint {}", path.display())
        })?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == b"MAVATRN1", "not a trainer checkpoint");
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let steps = u64::from_le_bytes(b8);
    let mut tensors = Vec::with_capacity(3);
    for _ in 0..3 {
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        let mut t = vec![0f32; n];
        // one bulk read straight into the tensor, not one per element
        r.read_exact(f32_bytes_mut(&mut t))?;
        tensors.push(t);
    }
    anyhow::ensure!(
        r.read(&mut [0u8; 1])? == 0,
        "trailing bytes after checkpoint tensors"
    );
    let opt = tensors.pop().expect("three tensors");
    let target = tensors.pop().expect("three tensors");
    let params = tensors.pop().expect("three tensors");
    Ok((steps, params, target, opt))
}

/// Run one data-parallel step over `dp`'s lanes. Returns the
/// element-wise mean of the lane loss vectors (multi-loss systems —
/// MADDPG — report `[critic, actor]`).
fn dp_step(dp: &mut DpLanes, inputs: &[HostTensor]) -> Result<Vec<f32>> {
    let shards = dp.lanes.len();
    // --- phase 1: per-lane shard gradients, host all-reduce ---
    // fixed lane order makes the f32 summation deterministic: the
    // reduced gradient is a pure function of (lane states, batch),
    // and every lane receives the identical result
    dp.grad_acc.clear();
    dp.loss_acc.clear();
    for (d, lane) in dp.lanes.iter().enumerate() {
        fill_shards(&mut dp.shard_scratch, inputs, d, shards)?;
        let outs = {
            let mut args: Vec<Arg> =
                Vec::with_capacity(2 + dp.shard_scratch.len());
            args.push(Arg::Dev(&lane.params));
            args.push(Arg::Dev(&lane.target));
            for t in &dp.shard_scratch {
                args.push(Arg::Host(t));
            }
            dp.grad
                .call_device(&args)
                .context("dp gradient artifact execution")?
        };
        // the download is the lane's sync point, so the shard scratch
        // can be refilled for the next lane right after
        let g = dp.grad.to_host(&outs[0], 0)?;
        let l = dp.grad.to_host(&outs[1], 1)?;
        if d == 0 {
            dp.grad_acc.extend_from_slice(g.as_f32());
            dp.loss_acc.extend_from_slice(l.as_f32());
        } else {
            for (a, &x) in dp.grad_acc.iter_mut().zip(g.as_f32()) {
                *a += x;
            }
            for (a, &x) in dp.loss_acc.iter_mut().zip(l.as_f32()) {
                *a += x;
            }
        }
    }
    let inv = 1.0 / shards as f32;
    for a in &mut dp.grad_acc {
        *a *= inv;
    }
    for a in &mut dp.loss_acc {
        *a *= inv;
    }
    let reduced =
        HostTensor::f32(vec![dp.grad_acc.len()], dp.grad_acc.clone());
    // --- phase 2: identical apply (clip + Adam + Polyak) per lane ---
    for lane in &mut dp.lanes {
        let outs = {
            let args = [
                Arg::Dev(&lane.params),
                Arg::Dev(&lane.target),
                Arg::Dev(&lane.opt),
                Arg::Host(&reduced),
                Arg::Dev(&lane.lr),
                Arg::Dev(&lane.tau),
            ];
            dp.apply
                .call_device(&args)
                .context("dp apply artifact execution")?
        };
        let mut it = outs.into_iter();
        lane.params = it.next().unwrap();
        lane.target = it.next().unwrap();
        lane.opt = it.next().unwrap();
    }
    Ok(dp.loss_acc.clone())
}

/// Split `inputs` (leading dim = full batch) into shard `d` of
/// `shards`, refilling the reusable `scratch` tensors in place (they
/// are allocated on the first step and reused forever after).
fn fill_shards(
    scratch: &mut Vec<HostTensor>,
    inputs: &[HostTensor],
    d: usize,
    shards: usize,
) -> Result<()> {
    if scratch.len() != inputs.len() {
        scratch.clear();
        for t in inputs {
            anyhow::ensure!(
                t.dims.first().is_some_and(|b| b % shards == 0),
                "batch tensor dims {:?} do not split into {} shards",
                t.dims,
                shards
            );
            let mut dims = t.dims.clone();
            dims[0] /= shards;
            scratch.push(match t.dtype {
                Dtype::F32 => HostTensor::zeros_f32(dims),
                Dtype::I32 => HostTensor::zeros_i32(dims),
            });
        }
    }
    for (s, t) in scratch.iter_mut().zip(inputs) {
        let n = t.len() / shards;
        match t.dtype {
            Dtype::F32 => s
                .as_f32_mut()
                .copy_from_slice(&t.as_f32()[d * n..(d + 1) * n]),
            Dtype::I32 => s
                .as_i32_mut()
                .copy_from_slice(&t.as_i32()[d * n..(d + 1) * n]),
        }
    }
    Ok(())
}

/// `MAVA_TRACE_LOSS` diagnostics over the assembled batch inputs.
fn trace_inputs(inputs: &[HostTensor], steps: u64) {
    for (i, t) in inputs.iter().enumerate() {
        if t.dtype == crate::core::Dtype::F32 {
            let bad = t.as_f32().iter().filter(|x| !x.is_finite()).count();
            let mx = t.as_f32().iter().fold(0.0f32, |a, &b| a.max(b.abs()));
            if bad > 0 || steps == 0 {
                eprintln!(
                    "[trainer] input {i} dims {:?} nonfinite {bad} \
                     max|x| {mx}",
                    t.dims
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shards must tile the batch exactly: concatenating shard
    /// 0..D of every input reproduces the full tensors bitwise, and
    /// the scratch is reused (no shape churn between calls).
    #[test]
    fn fill_shards_tiles_the_batch_exactly() {
        let obs = HostTensor::f32(
            vec![4, 2, 3],
            (0..24).map(|x| x as f32 * 0.5).collect(),
        );
        let act = HostTensor::i32(vec![4, 2], (0..8).collect());
        let inputs = [obs, act];
        let mut scratch = Vec::new();
        let mut got_f = Vec::new();
        let mut got_i = Vec::new();
        for d in 0..2 {
            fill_shards(&mut scratch, &inputs, d, 2).unwrap();
            assert_eq!(scratch[0].dims, [2, 2, 3]);
            assert_eq!(scratch[1].dims, [2, 2]);
            got_f.extend_from_slice(scratch[0].as_f32());
            got_i.extend_from_slice(scratch[1].as_i32());
        }
        assert_eq!(got_f, inputs[0].as_f32());
        assert_eq!(got_i, inputs[1].as_i32());
    }

    #[test]
    fn fill_shards_rejects_indivisible_batch() {
        let inputs = [HostTensor::f32(vec![3, 2], vec![0.0; 6])];
        let mut scratch = Vec::new();
        let err = fill_shards(&mut scratch, &inputs, 0, 2)
            .unwrap_err()
            .to_string();
        assert!(err.contains("do not split"), "{err}");
    }
}

// Checkpoint I/O moves each tensor as one little-endian byte slice.
// mava targets little-endian hosts throughout (the init blobs and the
// literal upload path in runtime::engine already assume LE); fail the
// build rather than silently write native-endian blobs elsewhere.
#[cfg(not(target_endian = "little"))]
compile_error!("mava checkpoint I/O assumes a little-endian host");

fn f32_bytes(xs: &[f32]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
    }
}

fn f32_bytes_mut(xs: &mut [f32]) -> &mut [u8] {
    unsafe {
        std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut u8, xs.len() * 4)
    }
}
