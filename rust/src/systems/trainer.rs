//! The trainer: Mava's multi-agent learner collection.
//!
//! Samples the replay table, assembles the fixed-shape batch the train
//! artifact expects, executes one fused train step (loss + clipped Adam +
//! Polyak target update, a single HLO module) and publishes the updated
//! parameters.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::core::HostTensor;
use crate::params::ParameterServer;
use crate::replay::{Item, ItemSource};
use crate::rng::Rng;
use crate::runtime::Artifact;
use crate::systems::Family;

/// Progress counters the trainer exposes to supervisors and benches.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainerStats {
    /// Completed train steps.
    pub steps: u64,
    /// Loss of the most recent step.
    pub last_loss: f32,
}

/// The multi-agent learner: samples replay, runs the fused train-step
/// artifact and publishes fresh parameters.
pub struct Trainer {
    family: Family,
    artifact: Rc<Artifact>,
    params: HostTensor,
    target: HostTensor,
    opt: HostTensor,
    lr: HostTensor,
    tau: HostTensor,
    rng: Rng, // DIAL channel noise
    // batch dims from artifact meta
    batch: usize,
    n_agents: usize,
    obs_dim: usize,
    act_dim: usize,
    state_dim: usize,
    seq_len: usize,
    msg_dim: usize,
    /// Progress counters (steps, last loss).
    pub stats: TrainerStats,
}

impl Trainer {
    /// Build a trainer over a train-step artifact, starting from the
    /// artifact's `params0`/`opt0` init blobs.
    pub fn new(
        family: Family,
        artifact: Rc<Artifact>,
        params0: Vec<f32>,
        opt0: Vec<f32>,
        lr: f32,
        tau: f32,
        seed: u64,
    ) -> Result<Trainer> {
        let spec = &artifact.spec;
        let p = spec.meta_usize("params")?;
        anyhow::ensure!(params0.len() == p, "params0 len mismatch");
        anyhow::ensure!(opt0.len() == 1 + 2 * p, "opt0 len mismatch");
        Ok(Trainer {
            family,
            batch: spec.meta_usize("batch")?,
            n_agents: spec.meta_usize("n_agents")?,
            obs_dim: spec.meta_usize("obs_dim")?,
            act_dim: spec.meta_usize("act_dim")?,
            state_dim: spec.meta_usize("state_dim")?,
            seq_len: spec.meta_usize("seq_len")?,
            msg_dim: spec.meta_usize("msg_dim")?,
            artifact,
            params: HostTensor::f32(vec![p], params0),
            target: HostTensor::f32(vec![p], opt_target_init(p)),
            opt: HostTensor::f32(vec![1 + 2 * p], opt0),
            lr: HostTensor::scalar_f32(lr),
            tau: HostTensor::scalar_f32(tau),
            rng: Rng::new(seed),
            stats: TrainerStats::default(),
        })
    }

    /// Target network starts as a copy of the online parameters.
    pub fn init_target_from_params(&mut self) {
        let p = self.params.as_f32().to_vec();
        self.target.as_f32_mut().copy_from_slice(&p);
    }

    /// Current online parameters (flat host view).
    pub fn params(&self) -> &[f32] {
        self.params.as_f32()
    }

    /// Batch size the train artifact was lowered at.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Run one training step on a batch sampled from `source` — a single
    /// [`crate::replay::Table`] or a [`crate::replay::ShardedTable`]
    /// (round-robin over executor shards). Returns None when the source
    /// was closed (shutdown).
    pub fn step<S: ItemSource>(&mut self, source: &S) -> Result<Option<f32>> {
        let Some(items) = source.sample_batch(self.batch) else {
            return Ok(None);
        };
        let inputs = self.assemble(&items)?;
        if std::env::var_os("MAVA_TRACE_LOSS").is_some() {
            for (i, t) in inputs.iter().enumerate() {
                if t.dtype == crate::core::Dtype::F32 {
                    let bad =
                        t.as_f32().iter().filter(|x| !x.is_finite()).count();
                    let mx = t
                        .as_f32()
                        .iter()
                        .fold(0.0f32, |a, &b| a.max(b.abs()));
                    if bad > 0 || self.stats.steps == 0 {
                        eprintln!(
                            "[trainer] input {i} dims {:?} nonfinite {bad} \
                             max|x| {mx}",
                            t.dims
                        );
                    }
                }
            }
        }
        let mut refs: Vec<&HostTensor> =
            vec![&self.params, &self.target, &self.opt];
        refs.extend(inputs.iter());
        refs.push(&self.lr);
        refs.push(&self.tau);
        let out = self
            .artifact
            .call(&refs)
            .context("train artifact execution")?;
        // move (not clone) the big state tensors out of the result
        let mut it = out.into_iter();
        self.params = it.next().unwrap();
        self.target = it.next().unwrap();
        self.opt = it.next().unwrap();
        let out: Vec<HostTensor> = it.collect();
        let loss = out[0].as_f32()[0];
        self.stats.steps += 1;
        self.stats.last_loss = loss;
        if std::env::var_os("MAVA_TRACE_LOSS").is_some() {
            eprintln!(
                "[trainer] step {} losses {:?}",
                self.stats.steps,
                out[0].as_f32()
            );
        }
        if !loss.is_finite() {
            eprintln!(
                "[trainer] WARNING: non-finite loss at step {}: {:?}",
                self.stats.steps,
                out[0].as_f32()
            );
        }
        Ok(Some(loss))
    }

    /// Persist the full training state (online + target params, Adam
    /// state, step counter) as a little-endian f32/u64 blob so long runs
    /// survive restarts.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        use std::io::Write;
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(b"MAVATRN1")?;
        w.write_all(&self.stats.steps.to_le_bytes())?;
        for t in [&self.params, &self.target, &self.opt] {
            w.write_all(&(t.len() as u64).to_le_bytes())?;
            for x in t.as_f32() {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Restore state saved by [`Trainer::save_checkpoint`]. Shapes must
    /// match the artifact this trainer was built for.
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        use std::io::Read;
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"MAVATRN1", "not a trainer checkpoint");
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        self.stats.steps = u64::from_le_bytes(b8);
        for t in [&mut self.params, &mut self.target, &mut self.opt] {
            r.read_exact(&mut b8)?;
            let n = u64::from_le_bytes(b8) as usize;
            anyhow::ensure!(
                n == t.len(),
                "checkpoint tensor len {n} != expected {}",
                t.len()
            );
            let mut bytes = vec![0u8; n * 4];
            r.read_exact(&mut bytes)?;
            for (dst, c) in
                t.as_f32_mut().iter_mut().zip(bytes.chunks_exact(4))
            {
                *dst = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
        }
        Ok(())
    }

    /// Step and publish to the parameter server.
    pub fn step_and_publish<S: ItemSource>(
        &mut self,
        source: &S,
        server: &ParameterServer,
    ) -> Result<Option<f32>> {
        let r = self.step(source)?;
        if r.is_some() {
            server.push(self.params());
        }
        Ok(r)
    }

    /// Assemble the artifact's batch inputs from sampled items.
    fn assemble(&mut self, items: &[Item]) -> Result<Vec<HostTensor>> {
        let (b, n, o, a, s) = (
            self.batch,
            self.n_agents,
            self.obs_dim,
            self.act_dim,
            self.state_dim,
        );
        anyhow::ensure!(items.len() == b, "short batch: {}", items.len());
        match self.family {
            Family::DqnFf => {
                let mut obs = Vec::with_capacity(b * n * o);
                let mut act = Vec::with_capacity(b * n);
                let mut rew = Vec::with_capacity(b * n);
                let mut disc = Vec::with_capacity(b);
                let mut next_obs = Vec::with_capacity(b * n * o);
                for it in items {
                    let t = it.as_transition();
                    obs.extend_from_slice(&t.obs);
                    act.extend_from_slice(&t.actions_disc);
                    rew.extend_from_slice(&t.rewards);
                    disc.push(t.discount);
                    next_obs.extend_from_slice(&t.next_obs);
                }
                Ok(vec![
                    HostTensor::f32(vec![b, n, o], obs),
                    HostTensor::i32(vec![b, n], act),
                    HostTensor::f32(vec![b, n], rew),
                    HostTensor::f32(vec![b], disc),
                    HostTensor::f32(vec![b, n, o], next_obs),
                ])
            }
            Family::ValueDecomp => {
                let mut obs = Vec::with_capacity(b * n * o);
                let mut state = Vec::with_capacity(b * s);
                let mut act = Vec::with_capacity(b * n);
                let mut rew = Vec::with_capacity(b);
                let mut disc = Vec::with_capacity(b);
                let mut next_obs = Vec::with_capacity(b * n * o);
                let mut next_state = Vec::with_capacity(b * s);
                for it in items {
                    let t = it.as_transition();
                    obs.extend_from_slice(&t.obs);
                    state.extend_from_slice(&t.state);
                    act.extend_from_slice(&t.actions_disc);
                    // team reward: env replicates the shared reward
                    rew.push(t.rewards[0]);
                    disc.push(t.discount);
                    next_obs.extend_from_slice(&t.next_obs);
                    next_state.extend_from_slice(&t.next_state);
                }
                Ok(vec![
                    HostTensor::f32(vec![b, n, o], obs),
                    HostTensor::f32(vec![b, s], state),
                    HostTensor::i32(vec![b, n], act),
                    HostTensor::f32(vec![b], rew),
                    HostTensor::f32(vec![b], disc),
                    HostTensor::f32(vec![b, n, o], next_obs),
                    HostTensor::f32(vec![b, s], next_state),
                ])
            }
            Family::Ddpg => {
                let mut obs = Vec::with_capacity(b * n * o);
                let mut act = Vec::with_capacity(b * n * a);
                let mut rew = Vec::with_capacity(b * n);
                let mut disc = Vec::with_capacity(b);
                let mut next_obs = Vec::with_capacity(b * n * o);
                for it in items {
                    let t = it.as_transition();
                    obs.extend_from_slice(&t.obs);
                    act.extend_from_slice(&t.actions_cont);
                    rew.extend_from_slice(&t.rewards);
                    disc.push(t.discount);
                    next_obs.extend_from_slice(&t.next_obs);
                }
                Ok(vec![
                    HostTensor::f32(vec![b, n, o], obs),
                    HostTensor::f32(vec![b, n, a], act),
                    HostTensor::f32(vec![b, n], rew),
                    HostTensor::f32(vec![b], disc),
                    HostTensor::f32(vec![b, n, o], next_obs),
                ])
            }
            Family::DqnRec | Family::Dial => {
                let t_len = self.seq_len;
                let mut obs = Vec::with_capacity(b * (t_len + 1) * n * o);
                let mut act = Vec::with_capacity(b * t_len * n);
                let mut rew_agents = Vec::with_capacity(b * t_len * n);
                let mut rew_team = Vec::with_capacity(b * t_len);
                let mut disc = Vec::with_capacity(b * t_len);
                let mut mask = Vec::with_capacity(b * t_len);
                for it in items {
                    let sq = it.as_sequence();
                    anyhow::ensure!(sq.t == t_len, "sequence length mismatch");
                    obs.extend_from_slice(&sq.obs);
                    act.extend_from_slice(&sq.actions);
                    rew_agents.extend_from_slice(&sq.rewards);
                    for step in 0..t_len {
                        rew_team.push(sq.rewards[step * n]);
                    }
                    disc.extend_from_slice(&sq.discounts);
                    mask.extend_from_slice(&sq.mask);
                }
                let mut out = vec![
                    HostTensor::f32(vec![b, t_len + 1, n, o], obs),
                    HostTensor::i32(vec![b, t_len, n], act),
                ];
                if self.family == Family::Dial {
                    out.push(HostTensor::f32(vec![b, t_len], rew_team));
                } else {
                    out.push(HostTensor::f32(vec![b, t_len, n], rew_agents));
                }
                out.push(HostTensor::f32(vec![b, t_len], disc));
                out.push(HostTensor::f32(vec![b, t_len], mask));
                if self.family == Family::Dial {
                    let m = self.msg_dim;
                    let len = b * (t_len + 1) * n * m;
                    let noise: Vec<f32> =
                        (0..len).map(|_| self.rng.normal_f32()).collect();
                    out.push(HostTensor::f32(
                        vec![b, t_len + 1, n, m],
                        noise,
                    ));
                }
                Ok(out)
            }
        }
    }
}

fn opt_target_init(p: usize) -> Vec<f32> {
    vec![0.0; p]
}
