//! Exploration: ε-greedy schedules and continuous action noise.
//!
//! Lives in Rust (not in the lowered artifacts) so the AOT graphs stay
//! deterministic and the same policy artifact serves both exploring
//! executors and greedy evaluators.

use crate::rng::Rng;

/// Linearly decaying epsilon schedule.
#[derive(Clone, Copy, Debug)]
pub struct EpsilonSchedule {
    pub start: f32,
    pub end: f32,
    pub decay_steps: u64,
}

impl EpsilonSchedule {
    pub fn new(start: f32, end: f32, decay_steps: u64) -> Self {
        EpsilonSchedule { start, end, decay_steps }
    }

    pub fn value(&self, step: u64) -> f32 {
        if step >= self.decay_steps {
            return self.end;
        }
        let frac = step as f32 / self.decay_steps as f32;
        self.start + (self.end - self.start) * frac
    }
}

/// ε-greedy over per-agent Q-values with optional legal-action masks.
///
/// Allocation-free: the legal set is scanned in place rather than
/// collected, so this can run per agent per row on the vectorized hot
/// path without touching the heap. The RNG call sequence (one `chance`,
/// then at most one `below`) is unchanged from the collecting
/// implementation, so seeded rollouts stay bit-identical.
pub fn epsilon_greedy(
    q: &[f32],
    n_actions: usize,
    legal: Option<&[bool]>,
    eps: f32,
    rng: &mut Rng,
) -> i32 {
    eps_greedy_by(q, n_actions, |a| legal.map_or(true, |m| m[a]), eps, rng)
}

/// [`epsilon_greedy`] over an f32 mask row (1.0 legal, 0.0 illegal) —
/// the layout of the SoA batch buffer's legal plane
/// ([`crate::env::VecStepBuf`]).
pub fn epsilon_greedy_masked(
    q: &[f32],
    n_actions: usize,
    legal: Option<&[f32]>,
    eps: f32,
    rng: &mut Rng,
) -> i32 {
    eps_greedy_by(q, n_actions, |a| legal.map_or(true, |m| m[a] > 0.5), eps, rng)
}

fn eps_greedy_by(
    q: &[f32],
    n_actions: usize,
    legal: impl Fn(usize) -> bool,
    eps: f32,
    rng: &mut Rng,
) -> i32 {
    debug_assert_eq!(q.len(), n_actions);
    if rng.chance(eps) {
        let count = (0..n_actions).filter(|&a| legal(a)).count();
        debug_assert!(count > 0, "no legal actions");
        let pick = rng.below(count);
        let mut seen = 0;
        for a in 0..n_actions {
            if legal(a) {
                if seen == pick {
                    return a as i32;
                }
                seen += 1;
            }
        }
        unreachable!("pick within legal count");
    }
    let mut best: Option<usize> = None;
    for a in 0..n_actions {
        if !legal(a) {
            continue;
        }
        best = match best {
            Some(b) if q[b] >= q[a] => Some(b),
            _ => Some(a),
        };
    }
    best.expect("no legal actions") as i32
}

/// Additive Gaussian action noise, clipped to [-1, 1] (DDPG-style).
pub fn gaussian_noise(action: &mut [f32], sigma: f32, rng: &mut Rng) {
    for a in action.iter_mut() {
        *a = (*a + sigma * rng.normal_f32()).clamp(-1.0, 1.0);
    }
}

/// Ornstein-Uhlenbeck process (the original DDPG exploration noise).
#[derive(Clone, Debug)]
pub struct OuNoise {
    theta: f32,
    sigma: f32,
    state: Vec<f32>,
}

impl OuNoise {
    pub fn new(dim: usize, theta: f32, sigma: f32) -> Self {
        OuNoise { theta, sigma, state: vec![0.0; dim] }
    }

    pub fn reset(&mut self) {
        self.state.iter_mut().for_each(|x| *x = 0.0);
    }

    pub fn apply(&mut self, action: &mut [f32], rng: &mut Rng) {
        for (a, s) in action.iter_mut().zip(self.state.iter_mut()) {
            *s += -self.theta * *s + self.sigma * rng.normal_f32();
            *a = (*a + *s).clamp(-1.0, 1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_decays_linearly() {
        let s = EpsilonSchedule::new(1.0, 0.1, 100);
        assert_eq!(s.value(0), 1.0);
        assert!((s.value(50) - 0.55).abs() < 1e-6);
        assert_eq!(s.value(100), 0.1);
        assert_eq!(s.value(10_000), 0.1);
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let q = [0.1, 0.9, 0.3];
        assert_eq!(epsilon_greedy(&q, 3, None, 0.0, &mut rng), 1);
    }

    #[test]
    fn masked_greedy_respects_legality() {
        let mut rng = Rng::new(0);
        let q = [0.1, 0.9, 0.3];
        let legal = [true, false, true];
        assert_eq!(epsilon_greedy(&q, 3, Some(&legal), 0.0, &mut rng), 2);
        // random branch also restricted to legal actions
        for _ in 0..100 {
            let a = epsilon_greedy(&q, 3, Some(&legal), 1.0, &mut rng);
            assert_ne!(a, 1);
        }
    }

    /// The f32-mask variant must agree with the bool-mask path call for
    /// call on a shared RNG stream.
    #[test]
    fn masked_f32_matches_bool() {
        let q = [0.4f32, 0.9, 0.1, 0.7];
        let legal_b = [true, false, true, true];
        let legal_f = [1.0f32, 0.0, 1.0, 1.0];
        let mut ra = Rng::new(5);
        let mut rb = Rng::new(5);
        for i in 0..200 {
            let eps = (i % 10) as f32 / 10.0;
            let a = epsilon_greedy(&q, 4, Some(&legal_b), eps, &mut ra);
            let b =
                epsilon_greedy_masked(&q, 4, Some(&legal_f), eps, &mut rb);
            assert_eq!(a, b);
            assert_ne!(a, 1, "illegal action selected");
        }
    }

    #[test]
    fn full_epsilon_is_roughly_uniform() {
        let mut rng = Rng::new(1);
        let q = [0.0; 4];
        let mut counts = [0; 4];
        for _ in 0..4000 {
            counts[epsilon_greedy(&q, 4, None, 1.0, &mut rng) as usize] += 1;
        }
        for c in counts {
            assert!(c > 800, "counts {counts:?}");
        }
    }

    #[test]
    fn gaussian_noise_clips() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let mut a = [0.9f32, -0.9];
            gaussian_noise(&mut a, 1.0, &mut rng);
            assert!(a.iter().all(|x| (-1.0..=1.0).contains(x)));
        }
    }

    #[test]
    fn ou_noise_is_correlated() {
        let mut rng = Rng::new(3);
        let mut ou = OuNoise::new(1, 0.15, 0.2);
        let mut prev = [0.0f32];
        let mut corr_hits = 0;
        for _ in 0..200 {
            let mut a = [0.0f32];
            ou.apply(&mut a, &mut rng);
            if a[0].signum() == prev[0].signum() {
                corr_hits += 1;
            }
            prev = a;
        }
        assert!(corr_hits > 120, "OU should be temporally correlated");
    }
}
