//! Deterministic retry with capped exponential backoff — the policy
//! every wire client reconnects under (DESIGN.md §13).
//!
//! Three layers, pure to impure:
//!
//! * [`RetryPolicy`] — the schedule itself: `delay(attempt)` is a pure
//!   function (base doubling per attempt, capped), so the property
//!   tests pin it without touching time;
//! * [`Backoff`] — a consumable iterator over one policy's delays,
//!   used by the blocking clients ([`crate::net::param::RemoteParamClient`],
//!   [`crate::net::replay::RemoteShardClient`]) that sleep between
//!   reconnect attempts inside a call;
//! * [`Pacer`] — a clock-paced probe schedule for non-blocking callers
//!   ([`crate::net::replay::RemoteReplaySampler`] re-probing evicted
//!   shards, the supervisor pacing node restarts). Time is read
//!   through the injected [`Clock`] — the same seam the serve batcher
//!   uses — so pacing decisions test hermetically under a
//!   [`crate::serve::MockClock`].

#![warn(missing_docs)]

use std::sync::Arc;
use std::time::Duration;

use crate::serve::{Clock, SystemClock};

/// A deterministic capped-exponential-backoff schedule: attempt `a`
/// waits `min(base * 2^a, cap)`, and a caller gives up after
/// `max_attempts` consecutive failures. No jitter — the schedule is a
/// pure function of the attempt index, which keeps fault-injection
/// tests reproducible.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Delay before the first retry.
    pub base: Duration,
    /// Upper bound every delay saturates at.
    pub cap: Duration,
    /// Consecutive failures tolerated before the caller reports the
    /// stored error instead of retrying (0 = never retry).
    pub max_attempts: u32,
}

impl RetryPolicy {
    /// A policy from millisecond figures.
    pub const fn new(
        base_ms: u64,
        cap_ms: u64,
        max_attempts: u32,
    ) -> RetryPolicy {
        RetryPolicy {
            base: Duration::from_millis(base_ms),
            cap: Duration::from_millis(cap_ms),
            max_attempts,
        }
    }

    /// The default schedule for wire clients: 50ms doubling to a 2s
    /// cap over 6 attempts (~4s of total waiting), well inside the
    /// default `dist_timeout_s`.
    pub const fn net_default() -> RetryPolicy {
        RetryPolicy::new(50, 2_000, 6)
    }

    /// Delay before retry `attempt` (0-based): `min(base * 2^attempt,
    /// cap)`, saturating.
    pub fn delay(&self, attempt: u32) -> Duration {
        let base_ms = self.base.as_millis() as u64;
        let mult = 1u64.checked_shl(attempt.min(63)).unwrap_or(u64::MAX);
        let ms = base_ms.saturating_mul(mult);
        Duration::from_millis(ms).min(self.cap)
    }

    /// Total time spent sleeping if every attempt fails — the bound on
    /// how long a blocking client stalls before surfacing the error.
    pub fn total_delay(&self) -> Duration {
        (0..self.max_attempts).map(|a| self.delay(a)).sum()
    }
}

/// One consumable pass over a [`RetryPolicy`]'s delays. Blocking
/// clients drive it inside a call: `next_delay()` hands out the
/// schedule until the budget is spent, `reset()` (on success) refills
/// it so the *next* outage gets a fresh budget — transient errors
/// never accumulate into a latched failure.
#[derive(Clone, Debug)]
pub struct Backoff {
    policy: RetryPolicy,
    attempt: u32,
}

impl Backoff {
    /// A fresh pass over `policy`.
    pub fn new(policy: RetryPolicy) -> Backoff {
        Backoff { policy, attempt: 0 }
    }

    /// Delay before the next retry, or `None` once `max_attempts`
    /// delays have been handed out (the caller should give up).
    pub fn next_delay(&mut self) -> Option<Duration> {
        if self.attempt >= self.policy.max_attempts {
            return None;
        }
        let d = self.policy.delay(self.attempt);
        self.attempt += 1;
        Some(d)
    }

    /// Failures recorded since the last [`Backoff::reset`].
    pub fn attempt(&self) -> u32 {
        self.attempt
    }

    /// Refill the budget after a success.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

/// A clock-paced probe schedule for callers that must not block: each
/// recorded failure arms the next probe `policy.delay(failures)` in
/// the future, `due()` says whether that moment has passed, and
/// `exhausted()` reports a spent budget. Reads time through the
/// injected [`Clock`], so schedules test hermetically under a
/// [`crate::serve::MockClock`].
pub struct Pacer {
    policy: RetryPolicy,
    clock: Arc<dyn Clock>,
    failures: u32,
    next_due_us: u64,
}

impl Pacer {
    /// A pacer over `policy` reading time from `clock`. The first
    /// probe is due immediately.
    pub fn new(policy: RetryPolicy, clock: Arc<dyn Clock>) -> Pacer {
        let now = clock.now_us();
        Pacer { policy, clock, failures: 0, next_due_us: now }
    }

    /// A pacer on wall-clock time.
    pub fn system(policy: RetryPolicy) -> Pacer {
        Pacer::new(policy, Arc::new(SystemClock::new()))
    }

    /// Whether the next probe may run now (always `false` once
    /// exhausted).
    pub fn due(&self) -> bool {
        !self.exhausted() && self.clock.now_us() >= self.next_due_us
    }

    /// Whether `max_attempts` consecutive failures have been recorded.
    pub fn exhausted(&self) -> bool {
        self.failures >= self.policy.max_attempts
    }

    /// Consecutive failures since the last [`Pacer::reset`].
    pub fn failures(&self) -> u32 {
        self.failures
    }

    /// Record a failed probe: arms the next one `delay(failures)` from
    /// now.
    pub fn note_failure(&mut self) {
        let d = self.policy.delay(self.failures);
        self.failures = self.failures.saturating_add(1);
        self.next_due_us =
            self.clock.now_us().saturating_add(d.as_micros() as u64);
    }

    /// Record a success: the failure streak and pacing reset, so a
    /// later outage gets the full budget again.
    pub fn reset(&mut self) {
        self.failures = 0;
        self.next_due_us = self.clock.now_us();
    }
}

/// Sleep `d` in [`crate::net::frame::POLL_INTERVAL`] slices, returning
/// early (with `false`) as soon as `halt` reports true — the shared
/// helper keeping blocking retry loops responsive to shutdown.
pub fn sleep_interruptible(
    d: Duration,
    halt: &mut dyn FnMut() -> bool,
) -> bool {
    let mut left = d;
    while !left.is_zero() {
        if halt() {
            return false;
        }
        let step = left.min(crate::net::frame::POLL_INTERVAL);
        std::thread::sleep(step);
        left -= step;
    }
    !halt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::MockClock;

    #[test]
    fn delay_doubles_and_caps() {
        let p = RetryPolicy::new(50, 2_000, 8);
        let ms: Vec<u64> =
            (0..8).map(|a| p.delay(a).as_millis() as u64).collect();
        assert_eq!(ms, vec![50, 100, 200, 400, 800, 1_600, 2_000, 2_000]);
        // huge attempt indices saturate instead of overflowing
        assert_eq!(p.delay(u32::MAX), Duration::from_millis(2_000));
        assert_eq!(
            p.total_delay(),
            Duration::from_millis(50 + 100 + 200 + 400 + 800 + 1_600 + 2_000 + 2_000)
        );
    }

    #[test]
    fn backoff_hands_out_budget_then_none() {
        let mut b = Backoff::new(RetryPolicy::new(10, 40, 3));
        assert_eq!(b.next_delay(), Some(Duration::from_millis(10)));
        assert_eq!(b.next_delay(), Some(Duration::from_millis(20)));
        assert_eq!(b.next_delay(), Some(Duration::from_millis(40)));
        assert_eq!(b.next_delay(), None, "budget spent");
        assert_eq!(b.attempt(), 3);
        b.reset();
        assert_eq!(b.next_delay(), Some(Duration::from_millis(10)));
    }

    #[test]
    fn zero_attempts_never_retries() {
        let mut b = Backoff::new(RetryPolicy::new(10, 40, 0));
        assert_eq!(b.next_delay(), None);
        let clock = Arc::new(MockClock::new(0));
        let p = Pacer::new(RetryPolicy::new(10, 40, 0), clock);
        assert!(p.exhausted());
        assert!(!p.due());
    }

    #[test]
    fn pacer_schedules_on_the_injected_clock() {
        let clock = Arc::new(MockClock::new(0));
        let mut p =
            Pacer::new(RetryPolicy::new(10, 40, 3), clock.clone());
        assert!(p.due(), "first probe immediate");
        p.note_failure();
        assert!(!p.due(), "armed 10ms out");
        clock.advance_us(9_999);
        assert!(!p.due());
        clock.advance_us(1);
        assert!(p.due());
        p.note_failure(); // next at +20ms
        clock.advance_us(20_000);
        assert!(p.due());
        p.note_failure();
        assert!(p.exhausted(), "3 failures spend the budget");
        clock.advance_us(1_000_000);
        assert!(!p.due(), "exhausted pacers never come due");
        assert_eq!(p.failures(), 3);
        p.reset();
        assert!(!p.exhausted());
        assert!(p.due(), "success refills the budget immediately");
    }

    #[test]
    fn sleep_interruptible_halts_early() {
        let t0 = std::time::Instant::now();
        let done =
            sleep_interruptible(Duration::from_secs(30), &mut || true);
        assert!(!done);
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert!(sleep_interruptible(
            Duration::from_millis(1),
            &mut || false
        ));
    }
}
