//! The launch driver's control channel: node registration (`Hello`)
//! and cooperative shutdown (`Stop`) over one long-lived TCP
//! connection per node.
//!
//! The [`ControlServer`] lives in the `mava launch` driver process.
//! Every spawned node connects a [`ControlClient`] at startup, sends
//! one `Hello` frame carrying its name, role and advertised service
//! address (empty for pure workers), then holds the connection open
//! and beats a periodic `Heartbeat` frame on it
//! ([`ControlClient::start_heartbeat`]). That gives the driver three
//! things from one socket: address discovery
//! ([`ControlServer::wait_for`]), a broadcast stop channel
//! ([`ControlServer::stop_all`] → [`ControlClient::watch_stop`]), and
//! *liveness* — a node that dies drops its connection and is marked
//! lost at EOF, while a node that wedges (alive but silent) is caught
//! by its heartbeat going stale ([`ControlServer::seen_within`])
//! within a few `heartbeat_interval_ms`.
//!
//! What a loss *does* is the binder's choice: under [`ControlServer::bind`]
//! (fail-fast, the pre-supervision behaviour the in-process launcher
//! mirrors) a lost node trips the driver's [`StopSignal`] so siblings
//! wind down; under [`ControlServer::bind_supervised`] losses are only
//! recorded, and the supervisor in [`crate::launch::supervise`]
//! decides between restart, degrade and fail-stop (DESIGN.md §13). A
//! restarted node re-registers under the same name: the entry is
//! replaced, its loss flag clears and
//! [`ControlServer::hello_count`] increments so the supervisor can
//! tell incarnations apart.

use std::collections::HashMap;
use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::launch::StopSignal;
use crate::net::frame::{encode_frame, read_frame_polled, FrameKind};
use crate::net::param::{spawn_accept_loop, POLL};
use crate::net::wire;

/// What the control server knows about one registered node.
#[derive(Clone, Debug)]
pub struct NodeEntry {
    /// Role string from the node's `Hello` (e.g. `"executor:0"`).
    pub role: String,
    /// Service address the node advertised; empty for pure workers.
    pub addr: String,
    /// Whether the node's control connection dropped before shutdown
    /// was requested.
    pub lost: bool,
    /// How many times this name has registered — a supervised restart
    /// re-registers under the same name and increments this.
    pub hellos: u64,
    /// When the last frame (Hello or Heartbeat) arrived from this
    /// node's current connection.
    pub last_seen: Instant,
}

#[derive(Default)]
struct Registry {
    nodes: HashMap<String, NodeEntry>,
    writers: Vec<(String, TcpStream)>,
}

/// Driver-side registration + stop channel (one per `mava launch`).
pub struct ControlServer {
    addr: String,
    halt: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    registry: Arc<Mutex<Registry>>,
}

impl ControlServer {
    /// Bind on `host` (ephemeral port). A node connection that drops
    /// before `stop` is tripped marks the node lost and trips `stop`
    /// (fail-fast — every death ends the run).
    pub fn bind(host: &str, stop: StopSignal) -> Result<Self> {
        Self::bind_with(host, stop, true)
    }

    /// Bind like [`ControlServer::bind`], but a lost node is only
    /// *recorded*, never trips `stop`: the supervisor reads
    /// [`ControlServer::lost`] / [`ControlServer::seen_within`] and
    /// applies its restart policy instead.
    pub fn bind_supervised(host: &str, stop: StopSignal) -> Result<Self> {
        Self::bind_with(host, stop, false)
    }

    fn bind_with(
        host: &str,
        stop: StopSignal,
        fail_fast: bool,
    ) -> Result<Self> {
        let listener = std::net::TcpListener::bind((host, 0))
            .with_context(|| format!("bind control server on {host}"))?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let halt = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let registry = Arc::new(Mutex::new(Registry::default()));
        let conn_halt = halt.clone();
        let conn_registry = registry.clone();
        let accept = spawn_accept_loop(
            listener,
            halt.clone(),
            conns.clone(),
            "mava-ctl-srv",
            move |stream| {
                serve_conn(
                    stream,
                    &conn_registry,
                    &stop,
                    &conn_halt,
                    fail_fast,
                );
            },
        );
        Ok(ControlServer {
            addr,
            halt,
            accept: Some(accept),
            conns,
            registry,
        })
    }

    /// The bound `host:port` nodes connect back to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Block until the node `name` has sent its `Hello`, returning the
    /// address it advertised. Errors after `timeout`.
    pub fn wait_for(&self, name: &str, timeout: Duration) -> Result<String> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(entry) = self.registry.lock().unwrap().nodes.get(name)
            {
                return Ok(entry.addr.clone());
            }
            if Instant::now() >= deadline {
                bail!(
                    "node {name} did not register with the control server \
                     within {timeout:?}"
                );
            }
            std::thread::sleep(crate::net::frame::POLL_INTERVAL);
        }
    }

    /// Whether `name`'s control connection dropped before shutdown was
    /// requested (i.e. the node died rather than being stopped).
    pub fn lost(&self, name: &str) -> bool {
        self.registry
            .lock()
            .unwrap()
            .nodes
            .get(name)
            .is_some_and(|e| e.lost)
    }

    /// How many times `name` has registered (0 = never). A supervised
    /// restart re-registers under the same name and increments this,
    /// so a caller can wait for incarnation N+1's `Hello`.
    pub fn hello_count(&self, name: &str) -> u64 {
        self.registry
            .lock()
            .unwrap()
            .nodes
            .get(name)
            .map_or(0, |e| e.hellos)
    }

    /// Whether `name`'s connection produced a frame (Hello or
    /// Heartbeat) within the last `window`. `false` for unknown or
    /// lost nodes — a stale-but-connected node here is *wedged*, alive
    /// but not making progress, and the supervisor treats it as dead.
    pub fn seen_within(&self, name: &str, window: Duration) -> bool {
        self.registry
            .lock()
            .unwrap()
            .nodes
            .get(name)
            .is_some_and(|e| !e.lost && e.last_seen.elapsed() <= window)
    }

    /// Names of nodes whose connections dropped unexpectedly.
    pub fn lost_nodes(&self) -> Vec<String> {
        let reg = self.registry.lock().unwrap();
        let mut names: Vec<String> = reg
            .nodes
            .iter()
            .filter(|(_, e)| e.lost)
            .map(|(n, _)| n.clone())
            .collect();
        names.sort();
        names
    }

    /// Broadcast a `Stop` frame to every registered node.
    pub fn stop_all(&self) {
        let mut frame = Vec::new();
        encode_frame(FrameKind::Stop, &[], &mut frame);
        let mut reg = self.registry.lock().unwrap();
        for (_, stream) in reg.writers.iter_mut() {
            // a dead peer's write failing is fine: its reader thread
            // already marked it lost
            let _ = stream.write_all(&frame);
        }
    }

    /// Stop accepting and join every connection thread.
    pub fn shutdown(&mut self) {
        self.halt.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in conns {
            let _ = h.join();
        }
    }
}

impl Drop for ControlServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve one node's control connection: read the `Hello`, register,
/// then consume heartbeats (refreshing `last_seen`) and watch for EOF
/// (node death) until halted.
fn serve_conn(
    mut stream: TcpStream,
    registry: &Mutex<Registry>,
    stop: &StopSignal,
    halt: &AtomicBool,
    fail_fast: bool,
) {
    let mut payload = Vec::new();
    let hello = read_frame_polled(&mut stream, &mut payload, &mut || {
        halt.load(Ordering::Acquire)
    });
    let (name, incarnation) = match hello {
        Ok(Some(FrameKind::Hello)) => {
            let Ok((name, role, addr)) = wire::decode_hello(&payload) else {
                return;
            };
            let mut reg = registry.lock().unwrap();
            // a restarted node re-registers under its old name: drop
            // the dead incarnation's writer so stop_all and the
            // writers list don't grow across restarts
            reg.writers.retain(|(n, _)| n != &name);
            if let Ok(writer) = stream.try_clone() {
                reg.writers.push((name.clone(), writer));
            }
            let hellos =
                reg.nodes.get(&name).map_or(0, |e| e.hellos) + 1;
            reg.nodes.insert(
                name.clone(),
                NodeEntry {
                    role,
                    addr,
                    lost: false,
                    hellos,
                    last_seen: Instant::now(),
                },
            );
            (name, hellos)
        }
        // anything else before a Hello is not a node: drop it
        _ => return,
    };
    // only this connection's incarnation may touch the entry: a stale
    // thread from a replaced connection must not mark the restarted
    // node lost (or refresh its liveness)
    let entry_is_mine = |e: &NodeEntry| e.hellos == incarnation;
    loop {
        match read_frame_polled(&mut stream, &mut payload, &mut || {
            halt.load(Ordering::Acquire)
        }) {
            Ok(Some(_)) => {
                // Heartbeat (or any frame): the node is alive
                if let Some(e) =
                    registry.lock().unwrap().nodes.get_mut(&name)
                {
                    if entry_is_mine(e) {
                        e.last_seen = Instant::now();
                    }
                }
            }
            Ok(None) => return, // halted: clean driver shutdown
            Err(_) => {
                // EOF or socket error: the node is gone. If shutdown
                // was not already requested this is a *death* — record
                // it, and in fail-fast mode wind the program down (a
                // supervised driver decides restart/degrade itself).
                if !halt.load(Ordering::Acquire) && !stop.is_stopped() {
                    let mut lost_current = false;
                    if let Some(e) =
                        registry.lock().unwrap().nodes.get_mut(&name)
                    {
                        if entry_is_mine(e) {
                            e.lost = true;
                            lost_current = true;
                        }
                    }
                    if fail_fast && lost_current {
                        stop.stop();
                    }
                }
                return;
            }
        }
    }
}

/// Node-side end of the control channel.
pub struct ControlClient {
    stream: TcpStream,
}

impl ControlClient {
    /// Connect to the driver at `addr` and register as `name` with
    /// `role`, advertising `advertise` (a service address, or `""`).
    pub fn connect(
        addr: &str,
        name: &str,
        role: &str,
        advertise: &str,
    ) -> Result<Self> {
        let mut stream = TcpStream::connect(addr)
            .with_context(|| format!("connect control server {addr}"))?;
        stream.set_read_timeout(Some(POLL))?;
        stream.set_nodelay(true)?;
        let mut pay = Vec::new();
        wire::encode_hello(name, role, advertise, &mut pay);
        let mut frame = Vec::new();
        encode_frame(FrameKind::Hello, &pay, &mut frame);
        stream.write_all(&frame).context("send hello")?;
        Ok(ControlClient { stream })
    }

    /// Spawn a sender thread beating a `Heartbeat` frame every
    /// `interval` until `stop` trips or the connection dies. The
    /// driver reads the beats into the node's `last_seen`
    /// ([`ControlServer::seen_within`]): a node that keeps its
    /// connection open but stops beating is *wedged* and gets killed
    /// and restarted by the supervisor instead of hanging the run.
    pub fn start_heartbeat(
        &self,
        interval: Duration,
        stop: StopSignal,
    ) -> Result<JoinHandle<()>> {
        let mut stream =
            self.stream.try_clone().context("clone control")?;
        let mut frame = Vec::new();
        encode_frame(FrameKind::Heartbeat, &[], &mut frame);
        Ok(std::thread::Builder::new()
            .name("mava-ctl-beat".into())
            .spawn(move || loop {
                if !crate::net::retry::sleep_interruptible(
                    interval,
                    &mut || stop.is_stopped(),
                ) {
                    return;
                }
                if stream.write_all(&frame).is_err() {
                    // driver gone: watch_stop trips the node's stop
                    return;
                }
            })
            .expect("spawn heartbeat sender"))
    }

    /// Spawn a watcher thread that trips `stop` when the driver sends
    /// `Stop` — or when the driver's connection drops, so an orphaned
    /// node winds down instead of running forever.
    pub fn watch_stop(&self, stop: StopSignal) -> Result<JoinHandle<()>> {
        let mut stream = self.stream.try_clone().context("clone control")?;
        Ok(std::thread::Builder::new()
            .name("mava-ctl-watch".into())
            .spawn(move || {
                let mut payload = Vec::new();
                loop {
                    match read_frame_polled(
                        &mut stream,
                        &mut payload,
                        &mut || stop.is_stopped(),
                    ) {
                        Ok(Some(FrameKind::Stop)) | Ok(None) | Err(_) => {
                            stop.stop();
                            return;
                        }
                        Ok(Some(_)) => {}
                    }
                }
            })
            .expect("spawn control watcher"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_registers_and_stop_broadcasts() {
        let stop = StopSignal::new();
        let mut srv = ControlServer::bind("127.0.0.1", stop.clone()).unwrap();
        let client = ControlClient::connect(
            srv.addr(),
            "trainer",
            "trainer",
            "10.0.0.1:5000",
        )
        .unwrap();
        let addr = srv.wait_for("trainer", Duration::from_secs(5)).unwrap();
        assert_eq!(addr, "10.0.0.1:5000");
        assert!(!srv.lost("trainer"));

        let node_stop = StopSignal::new();
        let watcher = client.watch_stop(node_stop.clone()).unwrap();
        srv.stop_all();
        watcher.join().unwrap();
        assert!(node_stop.is_stopped(), "Stop frame reached the node");
        // an orderly stop is not a loss
        assert!(!srv.lost("trainer"));
        srv.shutdown();
    }

    #[test]
    fn dropped_connection_marks_lost_and_trips_stop() {
        let stop = StopSignal::new();
        let srv = ControlServer::bind("127.0.0.1", stop.clone()).unwrap();
        let client = ControlClient::connect(
            srv.addr(),
            "executor_0",
            "executor:0",
            "",
        )
        .unwrap();
        srv.wait_for("executor_0", Duration::from_secs(5)).unwrap();
        drop(client); // the node "dies"
        let deadline = Instant::now() + Duration::from_secs(5);
        while !stop.is_stopped() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(stop.is_stopped(), "node death trips the stop signal");
        assert!(srv.lost("executor_0"));
        assert_eq!(srv.lost_nodes(), vec!["executor_0".to_string()]);
    }

    #[test]
    fn heartbeats_refresh_liveness_and_silence_goes_stale() {
        let stop = StopSignal::new();
        let srv =
            ControlServer::bind_supervised("127.0.0.1", stop.clone())
                .unwrap();
        let client =
            ControlClient::connect(srv.addr(), "exec", "executor:0", "")
                .unwrap();
        srv.wait_for("exec", Duration::from_secs(5)).unwrap();
        assert_eq!(srv.hello_count("exec"), 1);
        // fresh Hello counts as seen
        assert!(srv.seen_within("exec", Duration::from_secs(5)));

        let hb_stop = StopSignal::new();
        let beat = client
            .start_heartbeat(Duration::from_millis(10), hb_stop.clone())
            .unwrap();
        // poll until a beat lands inside a tight window
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            std::thread::sleep(Duration::from_millis(15));
            if srv.seen_within("exec", Duration::from_millis(60)) {
                break;
            }
            assert!(Instant::now() < deadline, "no heartbeat arrived");
        }
        // stop beating (node still connected = wedged): liveness decays
        hb_stop.stop();
        beat.join().unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while srv.seen_within("exec", Duration::from_millis(60)) {
            assert!(Instant::now() < deadline, "liveness never decayed");
            std::thread::sleep(Duration::from_millis(10));
        }
        // a wedged node is stale but NOT lost (its socket is open)
        assert!(!srv.lost("exec"));
        drop(client);
    }

    #[test]
    fn supervised_loss_is_recorded_but_does_not_trip_stop() {
        let stop = StopSignal::new();
        let srv =
            ControlServer::bind_supervised("127.0.0.1", stop.clone())
                .unwrap();
        let client =
            ControlClient::connect(srv.addr(), "exec", "executor:0", "")
                .unwrap();
        srv.wait_for("exec", Duration::from_secs(5)).unwrap();
        drop(client); // the node "dies"
        let deadline = Instant::now() + Duration::from_secs(5);
        while !srv.lost("exec") && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(srv.lost("exec"), "loss recorded");
        assert!(
            !stop.is_stopped(),
            "supervised mode leaves the decision to the supervisor"
        );

        // a restarted node re-registers under the same name: the loss
        // clears and the incarnation count increments
        let client2 =
            ControlClient::connect(srv.addr(), "exec", "executor:0", "")
                .unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while srv.hello_count("exec") < 2 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(srv.hello_count("exec"), 2);
        assert!(!srv.lost("exec"), "re-registration clears the loss");
        assert!(srv.seen_within("exec", Duration::from_secs(5)));
        drop(client2);
    }

    #[test]
    fn wait_for_times_out_with_name() {
        let srv =
            ControlServer::bind("127.0.0.1", StopSignal::new()).unwrap();
        let err = srv
            .wait_for("ghost", Duration::from_millis(50))
            .unwrap_err();
        assert!(err.to_string().contains("ghost"), "{err}");
    }
}
