//! The multi-process wire layer (DESIGN.md §10): a length-prefixed,
//! versioned frame codec ([`frame`]), payload codecs ([`wire`]) and
//! three TCP protocols built on them —
//!
//! * [`param`] — publish/fetch of the flat `MAVATRN1` parameter blob
//!   with a monotone version counter, so executors poll a *remote*
//!   parameter server exactly like the in-process one;
//! * [`replay`] — adder row inserts streaming to a remote replay
//!   shard, and trainer sampling via request/response with receive
//!   buffers reused across batches;
//! * [`control`] — the launch driver's registration + stop channel
//!   (`Hello` / `Stop`), which detects lost nodes by connection EOF
//!   and — via periodic `Heartbeat` frames — by silence longer than
//!   the configured `heartbeat_interval_ms` (DESIGN.md §13).
//!
//! Transient transport failures are retried under the deterministic
//! capped-exponential-backoff schedule in [`retry`]: every client
//! reconnects a bounded number of times before surfacing the error,
//! and a success refills the budget, so a network blip never latches
//! a node into a failed state.
//!
//! The `mava serve` inference protocol (session open/close +
//! `ActRequest`/`ActResponse`, DESIGN.md §12) rides the same frame
//! codec; its service lives in [`crate::serve::service`].
//!
//! Everything here is transport only: the services wrap the existing
//! [`crate::params::ParameterServer`] and [`crate::replay::Table`]
//! unchanged, and the clients implement the same traits
//! ([`crate::params::ParamStore`], [`crate::replay::ItemSink`],
//! [`crate::replay::ItemSource`]) the in-process handles do, so node
//! loops cannot tell whether their peers share the process.

#![warn(missing_docs)]

pub mod control;
pub mod frame;
pub mod param;
pub mod replay;
pub mod retry;
pub mod wire;
