//! The length-prefixed, versioned frame codec every mava wire protocol
//! speaks (DESIGN.md §10).
//!
//! A frame is a fixed 12-byte header followed by the payload:
//!
//! ```text
//! offset  size  field
//! 0       2     magic  b"MV"
//! 2       1     wire version (WIRE_VERSION = 1)
//! 3       1     frame kind (FrameKind as u8)
//! 4       4     payload length, u32 little-endian (<= MAX_PAYLOAD)
//! 8       4     CRC32 (IEEE) of the payload, u32 little-endian
//! 12      len   payload bytes
//! ```
//!
//! Decoding is total: truncated, corrupted or wrong-version input is
//! rejected with a typed [`FrameError`] — never a panic, and never a
//! read past the declared payload (the length field is validated
//! against [`MAX_PAYLOAD`] *before* any allocation, so a corrupt
//! length cannot trigger an abort-on-alloc).

use std::io::Read;
use std::time::Duration;

/// Wire protocol version; bumped on any incompatible frame or payload
/// layout change. Peers reject frames from other versions.
pub const WIRE_VERSION: u8 = 1;

/// THE socket poll cadence of every polled read in the crate: services
/// and clients set their socket read timeout to this value so the
/// `halt` probe of [`read_frame_polled`] fires at this period while a
/// peer is idle. It bounds shutdown latency (a blocked read notices a
/// halt within one interval), so every accept loop, connection thread
/// and driver wind-down wait must use this ONE constant — a private
/// copy that drifts from it silently changes how fast `mava launch`
/// and `mava serve` wind down. 25 ms is far above a loopback RTT and
/// far below human-visible shutdown lag.
pub const POLL_INTERVAL: Duration = Duration::from_millis(25);

/// Frame magic: the first two bytes of every frame.
pub const MAGIC: [u8; 2] = *b"MV";

/// Fixed frame header size in bytes.
pub const HEADER_LEN: usize = 12;

/// Upper bound on a frame payload (256 MiB). Large enough for any
/// realistic parameter blob; small enough that a corrupt length field
/// is rejected instead of driving a huge allocation.
pub const MAX_PAYLOAD: usize = 256 * 1024 * 1024;

/// Every message type of the parameter-server, replay and control
/// protocols (DESIGN.md §10 wire tables).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Control: node → driver registration (name, role, advertised
    /// service address).
    Hello = 0,
    /// Control: driver → node shutdown request (empty payload).
    Stop = 1,
    /// Param: client → server "send params newer than version V".
    FetchParams = 2,
    /// Param: server → client versioned parameter blob.
    Params = 3,
    /// Param: server → client "nothing newer than your version".
    ParamsCurrent = 4,
    /// Param: trainer → server new parameter blob.
    PublishParams = 5,
    /// Param: server → trainer publish acknowledgement (new version).
    PublishAck = 6,
    /// Replay: adder → shard one item insert (priority + item).
    InsertItem = 7,
    /// Replay: shard → adder insert acknowledgement (accepted flag).
    InsertAck = 8,
    /// Replay: trainer → shard "sample a batch of N items".
    SampleRequest = 9,
    /// Replay: shard → trainer a sampled batch of items.
    SampleBatch = 10,
    /// Replay: shard → trainer "not admissible yet, retry" (the remote
    /// mirror of a rate-limited shard probe).
    SampleRetry = 11,
    /// Replay: shard → trainer "this shard is closed" (shutdown).
    SourceClosed = 12,
    /// Either direction: a rendered error message.
    Error = 13,
    /// Serve: client → service "open an inference session" (empty
    /// payload).
    SessionOpen = 14,
    /// Serve: service → client the new session id.
    SessionOpened = 15,
    /// Serve: client → service "close session N" (frees its carry
    /// slot).
    SessionClose = 16,
    /// Serve: service → client session-close acknowledgement.
    SessionClosed = 17,
    /// Serve: client → service one observation to act on (session id +
    /// flat obs).
    ActRequest = 18,
    /// Serve: service → client the selected joint action (session id +
    /// params version + per-agent actions).
    ActResponse = 19,
    /// Control: node → driver liveness beacon (empty payload), sent
    /// every `heartbeat_interval_ms` so a wedged node is detected
    /// within the interval instead of only at connection EOF.
    Heartbeat = 20,
}

impl FrameKind {
    /// Every frame kind, for exhaustive round-trip tests.
    pub const ALL: [FrameKind; 21] = [
        FrameKind::Hello,
        FrameKind::Stop,
        FrameKind::FetchParams,
        FrameKind::Params,
        FrameKind::ParamsCurrent,
        FrameKind::PublishParams,
        FrameKind::PublishAck,
        FrameKind::InsertItem,
        FrameKind::InsertAck,
        FrameKind::SampleRequest,
        FrameKind::SampleBatch,
        FrameKind::SampleRetry,
        FrameKind::SourceClosed,
        FrameKind::Error,
        FrameKind::SessionOpen,
        FrameKind::SessionOpened,
        FrameKind::SessionClose,
        FrameKind::SessionClosed,
        FrameKind::ActRequest,
        FrameKind::ActResponse,
        FrameKind::Heartbeat,
    ];

    /// Parse a kind byte; `None` for unknown kinds.
    pub fn from_byte(b: u8) -> Option<FrameKind> {
        Self::ALL.get(b as usize).copied()
    }
}

/// Typed decode/IO failure of the frame codec. Every malformed input
/// maps to one of these — the codec never panics.
#[derive(Debug)]
pub enum FrameError {
    /// An underlying I/O error (excluding clean EOF, which is
    /// [`FrameError::Truncated`]).
    Io(std::io::Error),
    /// Input ended (EOF or short slice) before the frame completed.
    Truncated,
    /// First two bytes were not [`MAGIC`].
    BadMagic([u8; 2]),
    /// Frame from an incompatible [`WIRE_VERSION`].
    BadVersion(u8),
    /// Unknown [`FrameKind`] byte.
    UnknownKind(u8),
    /// Declared payload length exceeds [`MAX_PAYLOAD`].
    Oversized(u32),
    /// Payload CRC32 mismatch.
    Corrupt {
        /// CRC the header declared.
        expected: u32,
        /// CRC of the payload actually read.
        got: u32,
    },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame io error: {e}"),
            FrameError::Truncated => write!(f, "truncated frame"),
            FrameError::BadMagic(m) => {
                write!(f, "bad frame magic {m:02x?}")
            }
            FrameError::BadVersion(v) => write!(
                f,
                "unsupported wire version {v} (expected {WIRE_VERSION})"
            ),
            FrameError::UnknownKind(k) => {
                write!(f, "unknown frame kind {k}")
            }
            FrameError::Oversized(n) => write!(
                f,
                "frame payload of {n} bytes exceeds the {MAX_PAYLOAD} \
                 byte cap"
            ),
            FrameError::Corrupt { expected, got } => write!(
                f,
                "corrupt frame payload: crc {got:#010x}, header says \
                 {expected:#010x}"
            ),
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC32 (IEEE 802.3) of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xffff_ffffu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

/// Append one encoded frame (header + payload) to `out`.
///
/// Panics only if `payload` exceeds [`MAX_PAYLOAD`] — encoders own
/// their payload sizes; the decode path never panics.
pub fn encode_frame(kind: FrameKind, payload: &[u8], out: &mut Vec<u8>) {
    assert!(
        payload.len() <= MAX_PAYLOAD,
        "frame payload {} exceeds MAX_PAYLOAD",
        payload.len()
    );
    out.extend_from_slice(&MAGIC);
    out.push(WIRE_VERSION);
    out.push(kind as u8);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
}

/// One encoded frame as a fresh vector ([`encode_frame`] convenience).
pub fn frame_bytes(kind: FrameKind, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    encode_frame(kind, payload, &mut out);
    out
}

/// Validate a 12-byte header; returns `(kind, payload_len, crc)`.
fn parse_header(h: &[u8; HEADER_LEN]) -> Result<(FrameKind, usize, u32), FrameError> {
    if h[0..2] != MAGIC {
        return Err(FrameError::BadMagic([h[0], h[1]]));
    }
    if h[2] != WIRE_VERSION {
        return Err(FrameError::BadVersion(h[2]));
    }
    let kind = FrameKind::from_byte(h[3]).ok_or(FrameError::UnknownKind(h[3]))?;
    let len = u32::from_le_bytes([h[4], h[5], h[6], h[7]]);
    if len as usize > MAX_PAYLOAD {
        return Err(FrameError::Oversized(len));
    }
    let crc = u32::from_le_bytes([h[8], h[9], h[10], h[11]]);
    Ok((kind, len as usize, crc))
}

/// Decode one frame from the front of `bytes` without consuming more
/// than the frame itself: returns `(kind, payload, consumed)`. A slice
/// shorter than the declared frame is [`FrameError::Truncated`] — the
/// decoder never reads past `consumed` bytes.
pub fn decode_slice(bytes: &[u8]) -> Result<(FrameKind, &[u8], usize), FrameError> {
    if bytes.len() < HEADER_LEN {
        return Err(FrameError::Truncated);
    }
    let mut h = [0u8; HEADER_LEN];
    h.copy_from_slice(&bytes[..HEADER_LEN]);
    let (kind, len, crc) = parse_header(&h)?;
    let end = HEADER_LEN + len;
    if bytes.len() < end {
        return Err(FrameError::Truncated);
    }
    let payload = &bytes[HEADER_LEN..end];
    let got = crc32(payload);
    if got != crc {
        return Err(FrameError::Corrupt { expected: crc, got });
    }
    Ok((kind, payload, end))
}

/// Read exactly `buf.len()` bytes from `r`, retrying reads that time
/// out (`WouldBlock` / `TimedOut`, as produced by socket read
/// timeouts). Between retries `halt` is consulted: once it returns
/// true and **no** byte of `buf` has been read yet, the wait is
/// abandoned with `Ok(false)` (a clean between-frames stop); halting
/// mid-buffer is [`FrameError::Truncated`] since the stream is no
/// longer framed. Clean EOF is also `Truncated`.
pub fn read_full<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    halt: &mut dyn FnMut() -> bool,
) -> Result<bool, FrameError> {
    let mut off = 0;
    while off < buf.len() {
        match r.read(&mut buf[off..]) {
            Ok(0) => return Err(FrameError::Truncated),
            Ok(n) => off += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                if halt() {
                    if off == 0 {
                        return Ok(false);
                    }
                    return Err(FrameError::Truncated);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(true)
}

/// Read one frame from `r` into the reusable `payload` buffer
/// (cleared and refilled — the steady-state receive path allocates
/// only when a payload outgrows every previous one). `halt` is polled
/// while waiting between frames (pair it with a socket read timeout);
/// `Ok(None)` means it halted before a frame started.
pub fn read_frame_polled<R: Read>(
    r: &mut R,
    payload: &mut Vec<u8>,
    halt: &mut dyn FnMut() -> bool,
) -> Result<Option<FrameKind>, FrameError> {
    let mut header = [0u8; HEADER_LEN];
    if !read_full(r, &mut header, halt)? {
        return Ok(None);
    }
    let (kind, len, crc) = parse_header(&header)?;
    payload.clear();
    payload.resize(len, 0);
    // the constant-false halt makes `Ok(false)` impossible, but decode
    // paths are panic-free (R4): map it to Truncated instead of proving
    // the impossibility with an abort
    if !read_full(r, payload, &mut || false)? {
        return Err(FrameError::Truncated);
    }
    let got = crc32(payload);
    if got != crc {
        return Err(FrameError::Corrupt { expected: crc, got });
    }
    Ok(Some(kind))
}

/// Blocking [`read_frame_polled`]: reads one frame or fails.
pub fn read_frame_into<R: Read>(
    r: &mut R,
    payload: &mut Vec<u8>,
) -> Result<FrameKind, FrameError> {
    match read_frame_polled(r, payload, &mut || false)? {
        Some(kind) => Ok(kind),
        // impossible with a constant-false halt; decode paths stay
        // panic-free (R4) so the dead arm maps to Truncated
        None => Err(FrameError::Truncated),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_slice_and_reader() {
        let payload = b"hello wire".as_slice();
        let bytes = frame_bytes(FrameKind::Hello, payload);
        assert_eq!(bytes.len(), HEADER_LEN + payload.len());
        let (kind, got, consumed) = decode_slice(&bytes).unwrap();
        assert_eq!(kind, FrameKind::Hello);
        assert_eq!(got, payload);
        assert_eq!(consumed, bytes.len());

        let mut cursor = std::io::Cursor::new(&bytes);
        let mut buf = Vec::new();
        let kind = read_frame_into(&mut cursor, &mut buf).unwrap();
        assert_eq!(kind, FrameKind::Hello);
        assert_eq!(buf, payload);
    }

    #[test]
    fn two_frames_back_to_back() {
        let mut bytes = frame_bytes(FrameKind::Stop, b"");
        encode_frame(FrameKind::Error, b"boom", &mut bytes);
        let (k1, p1, used) = decode_slice(&bytes).unwrap();
        assert_eq!((k1, p1), (FrameKind::Stop, b"".as_slice()));
        let (k2, p2, _) = decode_slice(&bytes[used..]).unwrap();
        assert_eq!((k2, p2), (FrameKind::Error, b"boom".as_slice()));
    }

    #[test]
    fn bad_magic_version_kind_size_are_typed() {
        let good = frame_bytes(FrameKind::Stop, b"x");
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(
            decode_slice(&bad),
            Err(FrameError::BadMagic(_))
        ));
        let mut bad = good.clone();
        bad[2] = WIRE_VERSION + 1;
        assert!(matches!(
            decode_slice(&bad),
            Err(FrameError::BadVersion(_))
        ));
        let mut bad = good.clone();
        bad[3] = 200;
        assert!(matches!(
            decode_slice(&bad),
            Err(FrameError::UnknownKind(200))
        ));
        let mut bad = good.clone();
        bad[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            decode_slice(&bad),
            Err(FrameError::Oversized(_))
        ));
        let mut bad = good;
        *bad.last_mut().unwrap() ^= 0xff;
        assert!(matches!(
            decode_slice(&bad),
            Err(FrameError::Corrupt { .. })
        ));
    }

    #[test]
    fn eof_is_truncated_not_io() {
        let bytes = frame_bytes(FrameKind::Params, &[1, 2, 3, 4]);
        let mut cursor = std::io::Cursor::new(&bytes[..bytes.len() - 1]);
        let mut buf = Vec::new();
        assert!(matches!(
            read_frame_into(&mut cursor, &mut buf),
            Err(FrameError::Truncated)
        ));
    }

    // R4 regressions for the two converted `unreachable!` sites: a
    // stream that dies mid-frame must yield Truncated from both the
    // polled and the blocking reader, never a panic.

    #[test]
    fn read_frame_polled_truncated_payload_is_typed() {
        let bytes = frame_bytes(FrameKind::Params, &[1, 2, 3, 4]);
        let mut cursor =
            std::io::Cursor::new(&bytes[..HEADER_LEN + 2]);
        let mut buf = Vec::new();
        assert!(matches!(
            read_frame_polled(&mut cursor, &mut buf, &mut || false),
            Err(FrameError::Truncated)
        ));
    }

    #[test]
    fn read_frame_into_empty_stream_is_typed() {
        let mut cursor = std::io::Cursor::new(Vec::<u8>::new());
        let mut buf = Vec::new();
        assert!(matches!(
            read_frame_into(&mut cursor, &mut buf),
            Err(FrameError::Truncated)
        ));
    }

    #[test]
    fn crc32_matches_known_vector() {
        // IEEE CRC32 of "123456789"
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
