//! Payload codecs for the frames of [`crate::net::frame`]: little-
//! endian, length-prefixed field layouts for params, replay items and
//! control messages (DESIGN.md §10 wire tables).
//!
//! Reading goes through [`WireReader`], a bounds-checked cursor that
//! validates every length prefix against the bytes actually present
//! *before* allocating — a corrupt prefix yields a typed error, never
//! a panic, over-read or giant allocation.

use anyhow::{bail, Result};

use crate::replay::{Item, Sequence, Transition};

/// Bounds-checked little-endian cursor over one frame payload.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    /// A reader over `buf` starting at offset 0.
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!(
                "wire payload truncated: need {n} bytes at offset {}, \
                 have {}",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// `N` bytes as a fixed array. The length check lives in `take`,
    /// so the copy is infallible — keeping every primitive below free
    /// of `unwrap` on the decode path (R4).
    fn arr<const N: usize>(&mut self) -> Result<[u8; N]> {
        let s = self.take(N)?;
        let mut a = [0u8; N];
        a.copy_from_slice(s);
        Ok(a)
    }

    /// One byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Little-endian u32.
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.arr()?))
    }

    /// Little-endian u64.
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.arr()?))
    }

    /// Little-endian f32.
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.arr()?))
    }

    /// Little-endian f64.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.arr()?))
    }

    /// A u16-length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String> {
        let n = u16::from_le_bytes(self.arr()?);
        let bytes = self.take(n as usize)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("wire string not utf-8: {e}"))?
            .to_string())
    }

    /// A u32-count-prefixed f32 array, appended to `dst` (cleared
    /// first). The count is validated against the remaining bytes
    /// before any allocation.
    pub fn f32_vec_into(&mut self, dst: &mut Vec<f32>) -> Result<()> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        dst.clear();
        dst.reserve(n);
        for c in bytes.chunks_exact(4) {
            // chunks_exact(4) guarantees the width; spell the array out
            // so the decode path carries no unwrap (R4)
            dst.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(())
    }

    /// A u32-count-prefixed f32 array as a fresh vector.
    pub fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let mut v = Vec::new();
        self.f32_vec_into(&mut v)?;
        Ok(v)
    }

    /// A u32-count-prefixed i32 array as a fresh vector.
    pub fn i32_vec(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let bytes = self.take(n.checked_mul(4).unwrap_or(usize::MAX))?;
        let mut v = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            v.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(v)
    }

    /// Fail unless every byte was consumed (layout drift guard).
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!(
                "wire payload has {} trailing bytes",
                self.remaining()
            );
        }
        Ok(())
    }
}

/// Append a u16-length-prefixed UTF-8 string.
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    let n = u16::try_from(s.len()).expect("wire string over 64 KiB");
    out.extend_from_slice(&n.to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Append a u32-count-prefixed f32 array.
pub fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append a u32-count-prefixed i32 array.
pub fn put_i32s(out: &mut Vec<u8>, v: &[i32]) {
    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Encode a `Params` payload: version u64 + f32 blob.
pub fn encode_params(version: u64, params: &[f32], out: &mut Vec<u8>) {
    out.extend_from_slice(&version.to_le_bytes());
    put_f32s(out, params);
}

/// Decode a `Params` payload into a reusable destination vector;
/// returns the version.
pub fn decode_params_into(
    payload: &[u8],
    dst: &mut Vec<f32>,
) -> Result<u64> {
    let mut r = WireReader::new(payload);
    let version = r.u64()?;
    r.f32_vec_into(dst)?;
    r.finish()?;
    Ok(version)
}

/// Encode a `Hello` payload: node name, role tag, advertised address.
pub fn encode_hello(name: &str, role: &str, addr: &str, out: &mut Vec<u8>) {
    put_str(out, name);
    put_str(out, role);
    put_str(out, addr);
}

/// Decode a `Hello` payload: `(name, role, addr)`.
pub fn decode_hello(payload: &[u8]) -> Result<(String, String, String)> {
    let mut r = WireReader::new(payload);
    let name = r.str()?;
    let role = r.str()?;
    let addr = r.str()?;
    r.finish()?;
    Ok((name, role, addr))
}

const ITEM_TRANSITION: u8 = 0;
const ITEM_SEQUENCE: u8 = 1;

/// Encode one replay [`Item`]: a kind tag then the field arrays.
pub fn encode_item(item: &Item, out: &mut Vec<u8>) {
    match item {
        Item::Transition(t) => {
            out.push(ITEM_TRANSITION);
            put_f32s(out, &t.obs);
            put_f32s(out, &t.state);
            put_i32s(out, &t.actions_disc);
            put_f32s(out, &t.actions_cont);
            put_f32s(out, &t.rewards);
            out.extend_from_slice(&t.discount.to_le_bytes());
            put_f32s(out, &t.next_obs);
            put_f32s(out, &t.next_state);
        }
        Item::Sequence(s) => {
            out.push(ITEM_SEQUENCE);
            out.extend_from_slice(&(s.t as u32).to_le_bytes());
            put_f32s(out, &s.obs);
            put_i32s(out, &s.actions);
            put_f32s(out, &s.rewards);
            put_f32s(out, &s.discounts);
            put_f32s(out, &s.mask);
        }
    }
}

/// Decode one replay [`Item`] from the reader.
pub fn decode_item(r: &mut WireReader<'_>) -> Result<Item> {
    match r.u8()? {
        ITEM_TRANSITION => Ok(Item::Transition(Transition {
            obs: r.f32_vec()?,
            state: r.f32_vec()?,
            actions_disc: r.i32_vec()?,
            actions_cont: r.f32_vec()?,
            rewards: r.f32_vec()?,
            discount: r.f32()?,
            next_obs: r.f32_vec()?,
            next_state: r.f32_vec()?,
        })),
        ITEM_SEQUENCE => Ok(Item::Sequence(Sequence {
            t: r.u32()? as usize,
            obs: r.f32_vec()?,
            actions: r.i32_vec()?,
            rewards: r.f32_vec()?,
            discounts: r.f32_vec()?,
            mask: r.f32_vec()?,
        })),
        tag => bail!("unknown wire item tag {tag}"),
    }
}

/// Encode an `InsertItem` payload: priority f64 + item.
pub fn encode_insert(item: &Item, priority: f64, out: &mut Vec<u8>) {
    out.extend_from_slice(&priority.to_le_bytes());
    encode_item(item, out);
}

/// Decode an `InsertItem` payload: `(item, priority)`.
pub fn decode_insert(payload: &[u8]) -> Result<(Item, f64)> {
    let mut r = WireReader::new(payload);
    let priority = r.f64()?;
    let item = decode_item(&mut r)?;
    r.finish()?;
    Ok((item, priority))
}

/// Encode a `SampleBatch` payload: count u32 + items.
pub fn encode_batch(items: &[Item], out: &mut Vec<u8>) {
    out.extend_from_slice(&(items.len() as u32).to_le_bytes());
    for item in items {
        encode_item(item, out);
    }
}

/// Decode a `SampleBatch` payload.
pub fn decode_batch(payload: &[u8]) -> Result<Vec<Item>> {
    let mut r = WireReader::new(payload);
    let n = r.u32()? as usize;
    // Each item is at least 1 tag byte; reject counts the payload
    // cannot possibly hold before allocating.
    if n > r.remaining() {
        bail!("wire batch count {n} exceeds payload size");
    }
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        items.push(decode_item(&mut r)?);
    }
    r.finish()?;
    Ok(items)
}

/// Encode a `u64` payload (PublishAck version, SampleRequest count…).
pub fn encode_u64(v: u64, out: &mut Vec<u8>) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Decode a `u64` payload.
pub fn decode_u64(payload: &[u8]) -> Result<u64> {
    let mut r = WireReader::new(payload);
    let v = r.u64()?;
    r.finish()?;
    Ok(v)
}

/// Encode an `ActRequest` payload: session id u64 + flat `[N*O]`
/// observation.
pub fn encode_act_request(session: u64, obs: &[f32], out: &mut Vec<u8>) {
    out.extend_from_slice(&session.to_le_bytes());
    put_f32s(out, obs);
}

/// Decode an `ActRequest` payload into a reusable observation vector;
/// returns the session id.
pub fn decode_act_request(
    payload: &[u8],
    obs: &mut Vec<f32>,
) -> Result<u64> {
    let mut r = WireReader::new(payload);
    let session = r.u64()?;
    r.f32_vec_into(obs)?;
    r.finish()?;
    Ok(session)
}

/// Encode an `ActResponse` payload: session id u64 + parameter version
/// u64 + per-agent discrete actions.
pub fn encode_act_response(
    session: u64,
    version: u64,
    actions: &[i32],
    out: &mut Vec<u8>,
) {
    out.extend_from_slice(&session.to_le_bytes());
    out.extend_from_slice(&version.to_le_bytes());
    put_i32s(out, actions);
}

/// Decode an `ActResponse` payload: `(session, version, actions)`.
pub fn decode_act_response(payload: &[u8]) -> Result<(u64, u64, Vec<i32>)> {
    let mut r = WireReader::new(payload);
    let session = r.u64()?;
    let version = r.u64()?;
    let actions = r.i32_vec()?;
    r.finish()?;
    Ok((session, version, actions))
}

/// Encode an `Error` payload: a rendered message string.
pub fn encode_error(msg: &str, out: &mut Vec<u8>) {
    let clipped = if msg.len() > u16::MAX as usize {
        &msg[..u16::MAX as usize]
    } else {
        msg
    };
    put_str(out, clipped);
}

/// Decode an `Error` payload.
pub fn decode_error(payload: &[u8]) -> Result<String> {
    let mut r = WireReader::new(payload);
    let msg = r.str()?;
    r.finish()?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_transition() -> Item {
        Item::Transition(Transition {
            obs: vec![1.0, 2.0, 3.0],
            state: vec![],
            actions_disc: vec![0, 4],
            actions_cont: vec![],
            rewards: vec![0.5, -0.5],
            discount: 0.99,
            next_obs: vec![4.0, 5.0, 6.0],
            next_state: vec![],
        })
    }

    fn sample_sequence() -> Item {
        Item::Sequence(Sequence {
            t: 3,
            obs: vec![0.0; 8],
            actions: vec![1, 2, 3, 4, 5, 6],
            rewards: vec![1.0; 6],
            discounts: vec![0.99, 0.99, 0.0],
            mask: vec![1.0, 1.0, 0.0],
        })
    }

    #[test]
    fn item_roundtrip_both_kinds() {
        for item in [sample_transition(), sample_sequence()] {
            let mut out = Vec::new();
            encode_insert(&item, 2.5, &mut out);
            let (got, pri) = decode_insert(&out).unwrap();
            assert_eq!(got, item);
            assert_eq!(pri, 2.5);
        }
    }

    #[test]
    fn batch_roundtrip() {
        let items = vec![sample_transition(), sample_sequence()];
        let mut out = Vec::new();
        encode_batch(&items, &mut out);
        assert_eq!(decode_batch(&out).unwrap(), items);
    }

    #[test]
    fn params_roundtrip_reuses_dst() {
        let mut out = Vec::new();
        encode_params(7, &[1.0, 2.0, 3.0], &mut out);
        let mut dst = vec![9.0; 100];
        let v = decode_params_into(&out, &mut dst).unwrap();
        assert_eq!(v, 7);
        assert_eq!(dst, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn hello_roundtrip() {
        let mut out = Vec::new();
        encode_hello("executor_1", "executor:1", "127.0.0.1:9", &mut out);
        let (name, role, addr) = decode_hello(&out).unwrap();
        assert_eq!(name, "executor_1");
        assert_eq!(role, "executor:1");
        assert_eq!(addr, "127.0.0.1:9");
    }

    #[test]
    fn corrupt_counts_error_without_allocating() {
        // A params payload whose array count is absurdly larger than
        // the bytes present must fail cleanly.
        let mut out = Vec::new();
        encode_params(1, &[1.0], &mut out);
        let len = out.len();
        out[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut dst = Vec::new();
        assert!(decode_params_into(&out, &mut dst).is_err());
        assert_eq!(out.len(), len);

        let mut out = Vec::new();
        encode_batch(&[sample_transition()], &mut out);
        out[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_batch(&out).is_err());
    }

    #[test]
    fn act_request_roundtrip_reuses_obs() {
        let mut out = Vec::new();
        encode_act_request(42, &[0.25, -1.0, 3.5], &mut out);
        let mut obs = vec![9.0; 64];
        let session = decode_act_request(&out, &mut obs).unwrap();
        assert_eq!(session, 42);
        assert_eq!(obs, vec![0.25, -1.0, 3.5]);
    }

    #[test]
    fn act_response_roundtrip() {
        let mut out = Vec::new();
        encode_act_response(7, 12, &[3, 0, 4], &mut out);
        let (session, version, actions) =
            decode_act_response(&out).unwrap();
        assert_eq!(session, 7);
        assert_eq!(version, 12);
        assert_eq!(actions, vec![3, 0, 4]);
    }

    #[test]
    fn corrupt_act_request_count_errors() {
        let mut out = Vec::new();
        encode_act_request(1, &[1.0], &mut out);
        out[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut obs = Vec::new();
        assert!(decode_act_request(&out, &mut obs).is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut out = Vec::new();
        encode_u64(3, &mut out);
        out.push(0);
        assert!(decode_u64(&out).is_err());
    }

    // R4 regressions: every fixed-width primitive used to convert via
    // `try_into().unwrap()`; each must now surface truncation as a
    // typed error, never a panic, when the buffer is short.

    #[test]
    fn truncated_u32_errors() {
        assert!(WireReader::new(&[1, 2, 3]).u32().is_err());
    }

    #[test]
    fn truncated_u64_errors() {
        assert!(WireReader::new(&[1, 2, 3, 4, 5, 6, 7]).u64().is_err());
    }

    #[test]
    fn truncated_f32_errors() {
        assert!(WireReader::new(&[0x40]).f32().is_err());
    }

    #[test]
    fn truncated_f64_errors() {
        assert!(WireReader::new(&[0x40, 0x09]).f64().is_err());
    }

    #[test]
    fn truncated_str_prefix_and_body_error() {
        // one byte cannot hold the u16 length prefix
        assert!(WireReader::new(&[5]).str().is_err());
        // prefix says 5 bytes, only 2 present
        assert!(WireReader::new(&[5, 0, b'h', b'i']).str().is_err());
    }

    #[test]
    fn truncated_f32_vec_errors() {
        // count says 2 floats (8 bytes), only 4 present
        let mut buf = Vec::new();
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&1.0f32.to_le_bytes());
        let mut dst = Vec::new();
        assert!(WireReader::new(&buf).f32_vec_into(&mut dst).is_err());
    }

    #[test]
    fn truncated_i32_vec_errors() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&3u32.to_le_bytes());
        buf.extend_from_slice(&7i32.to_le_bytes());
        assert!(WireReader::new(&buf).i32_vec().is_err());
    }
}
