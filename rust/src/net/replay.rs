//! The replay wire protocol (DESIGN.md §10): adder inserts streaming
//! to a remote [`Table`] shard, and trainer sampling via
//! request/response.
//!
//! [`ReplayService`] exposes one shard over TCP. [`RemoteShardClient`]
//! implements [`ItemSink`] (what executors' adders insert through) and
//! [`RemoteReplaySampler`] implements [`ItemSource`] (what the trainer
//! prefetches from, round-robin over every shard service — the remote
//! mirror of [`crate::replay::ShardedTable`]'s skip-ahead sampling).
//! Both reuse their receive/send buffers across calls, and both
//! degrade on a lost connection instead of panicking: a dead sink
//! reports through [`ItemSink::check`], a dead sampler shard is
//! dropped from the rotation and sampling continues on the survivors.

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::net::frame::{encode_frame, read_frame_polled, FrameKind};
use crate::net::param::{frame_err, spawn_accept_loop, POLL};
use crate::net::wire;
use crate::replay::{Item, ItemSink, ItemSource, Table};

/// A TCP front-end for one replay [`Table`] shard.
///
/// Shutdown order matters: [`Table::close`] the shard *first* (that
/// unblocks rate-limited inserts and samplers, and makes the service
/// answer `SourceClosed`), then [`ReplayService::shutdown`].
pub struct ReplayService {
    addr: String,
    halt: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ReplayService {
    /// Bind on `host` (ephemeral port) and serve `table`.
    pub fn bind(table: Arc<Table>, host: &str) -> Result<Self> {
        let listener = std::net::TcpListener::bind((host, 0))
            .with_context(|| format!("bind replay service on {host}"))?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let halt = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let conn_halt = halt.clone();
        let accept = spawn_accept_loop(
            listener,
            halt.clone(),
            conns.clone(),
            "mava-replay-srv",
            move |stream| {
                serve_conn(stream, &table, &conn_halt);
            },
        );
        Ok(ReplayService { addr, halt, accept: Some(accept), conns })
    }

    /// The bound `host:port` address clients connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Stop accepting and join every connection thread. Close the
    /// served table *before* calling this, or in-flight blocking
    /// inserts can delay the join by one rate-limiter wait.
    pub fn shutdown(&mut self) {
        self.halt.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in conns {
            let _ = h.join();
        }
    }
}

impl Drop for ReplayService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve one replay connection until EOF, protocol error or halt.
fn serve_conn(mut stream: TcpStream, table: &Table, halt: &AtomicBool) {
    let mut payload = Vec::new();
    let mut reply = Vec::new();
    let mut pay = Vec::new();
    loop {
        let kind = match read_frame_polled(&mut stream, &mut payload, &mut || {
            halt.load(Ordering::Acquire)
        }) {
            Ok(Some(kind)) => kind,
            Ok(None) | Err(_) => break,
        };
        reply.clear();
        pay.clear();
        let ok = match kind {
            FrameKind::InsertItem => {
                let (item, priority) = match wire::decode_insert(&payload)
                {
                    Ok(x) => x,
                    Err(_) => break,
                };
                // blocks under the shard's rate limiter: socket
                // backpressure is exactly Reverb's insert blocking,
                // stretched over TCP. Unblocked by Table::close.
                let (accepted, _evicted) =
                    table.insert_reuse(item, priority);
                wire::encode_u64(accepted as u64, &mut pay);
                encode_frame(FrameKind::InsertAck, &pay, &mut reply);
                true
            }
            FrameKind::SampleRequest => {
                let n = match wire::decode_u64(&payload) {
                    Ok(n) => n as usize,
                    Err(_) => break,
                };
                if table.can_sample() {
                    // may briefly block if a racing sampler drained
                    // the shard; returns None only once closed
                    match table.sample(n) {
                        Some(items) => {
                            wire::encode_batch(&items, &mut pay);
                            encode_frame(
                                FrameKind::SampleBatch,
                                &pay,
                                &mut reply,
                            );
                        }
                        None => encode_frame(
                            FrameKind::SourceClosed,
                            &[],
                            &mut reply,
                        ),
                    }
                } else if table.is_closed() {
                    encode_frame(FrameKind::SourceClosed, &[], &mut reply);
                } else {
                    // not admissible yet (warm-up / rate limiter):
                    // the non-blocking retry keeps the client free to
                    // round-robin other shards
                    encode_frame(FrameKind::SampleRetry, &[], &mut reply);
                }
                true
            }
            FrameKind::Stop => false,
            other => {
                wire::encode_error(
                    &format!("unexpected frame {other:?} on replay port"),
                    &mut pay,
                );
                encode_frame(FrameKind::Error, &pay, &mut reply);
                false
            }
        };
        if stream.write_all(&reply).is_err() || !ok {
            break;
        }
    }
}

/// An [`ItemSink`] streaming inserts to one remote [`ReplayService`]
/// shard — the executor-side end of the replay wire protocol.
///
/// Inserts block until the shard acknowledges (mirroring the
/// in-process rate-limiter blocking); the serialized item is always
/// handed back for buffer recycling, so the adders' free lists work
/// unchanged. A connection failure marks the sink dead: subsequent
/// inserts are rejected and [`ItemSink::check`] reports the stored
/// error so the executor node fails by name.
pub struct RemoteShardClient {
    conn: Mutex<ShardConn>,
    dead: AtomicBool,
}

struct ShardConn {
    stream: TcpStream,
    payload: Vec<u8>,
    out: Vec<u8>,
    pay: Vec<u8>,
    error: Option<String>,
}

impl RemoteShardClient {
    /// Connect to a [`ReplayService`] at `addr`.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect replay shard {addr}"))?;
        stream.set_read_timeout(Some(POLL))?;
        stream.set_nodelay(true)?;
        Ok(RemoteShardClient {
            conn: Mutex::new(ShardConn {
                stream,
                payload: Vec::new(),
                out: Vec::new(),
                pay: Vec::new(),
                error: None,
            }),
            dead: AtomicBool::new(false),
        })
    }

    fn fail(&self, conn: &mut ShardConn, msg: String) {
        conn.error.get_or_insert(msg);
        self.dead.store(true, Ordering::Release);
    }
}

impl ItemSink for RemoteShardClient {
    fn insert_item_reuse(
        &self,
        item: Item,
        priority: f64,
    ) -> (bool, Option<Item>) {
        if self.dead.load(Ordering::Acquire) {
            return (false, Some(item));
        }
        let mut conn = self.conn.lock().unwrap();
        conn.pay.clear();
        wire::encode_insert(&item, priority, &mut conn.pay);
        let mut out = std::mem::take(&mut conn.out);
        encode_frame(FrameKind::InsertItem, &conn.pay, &mut out);
        let sent = conn.stream.write_all(&out);
        out.clear();
        conn.out = out;
        if let Err(e) = sent {
            self.fail(&mut conn, format!("replay insert send: {e}"));
            return (false, Some(item));
        }
        // Wait for the ack without a deadline: the shard's rate
        // limiter may legitimately hold the insert (the in-process
        // adder blocks identically); a closed table acks
        // accepted=false, a dead service surfaces as an IO error.
        let mut payload = std::mem::take(&mut conn.payload);
        let got = read_frame_polled(
            &mut conn.stream,
            &mut payload,
            &mut || false,
        );
        conn.payload = payload;
        match got {
            Ok(Some(FrameKind::InsertAck)) => {
                let accepted = wire::decode_u64(&conn.payload)
                    .map(|v| v != 0)
                    .unwrap_or(false);
                (accepted, Some(item))
            }
            Ok(Some(other)) => {
                self.fail(
                    &mut conn,
                    format!("unexpected insert reply {other:?}"),
                );
                (false, Some(item))
            }
            Ok(None) => unreachable!("halt closure is constant false"),
            Err(e) => {
                self.fail(&mut conn, format!("replay insert: {e}"));
                (false, Some(item))
            }
        }
    }

    fn check(&self) -> Result<()> {
        if !self.dead.load(Ordering::Acquire) {
            return Ok(());
        }
        let conn = self.conn.lock().unwrap();
        match &conn.error {
            Some(msg) => bail!("replay shard connection lost: {msg}"),
            None => bail!("replay shard connection lost"),
        }
    }
}

/// An [`ItemSource`] drawing batches from several remote shard
/// services round-robin — the trainer-side end of the replay wire
/// protocol, mirroring [`crate::replay::ShardedTable::sample`]'s
/// skip-ahead rotation. A shard that answers `SourceClosed`, times
/// out or drops its connection is removed from the rotation
/// (degrading to the survivors); only when every shard is gone does
/// [`ItemSource::sample_batch`] return `None`.
pub struct RemoteReplaySampler {
    shards: Vec<Mutex<Option<SamplerConn>>>,
    cursor: AtomicUsize,
    timeout: Duration,
}

struct SamplerConn {
    addr: String,
    stream: TcpStream,
    payload: Vec<u8>,
    out: Vec<u8>,
    pay: Vec<u8>,
}

impl RemoteReplaySampler {
    /// Connect to every shard service in `addrs`. `timeout` bounds
    /// each sample round trip (a healthy shard answers `SampleRetry`
    /// immediately when not admissible, so replies are always fast —
    /// a timeout means the shard is wedged and it is dropped).
    pub fn connect(addrs: &[String], timeout: Duration) -> Result<Self> {
        anyhow::ensure!(!addrs.is_empty(), "no replay shard addresses");
        let mut shards = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let stream = TcpStream::connect(addr.as_str())
                .with_context(|| format!("connect replay shard {addr}"))?;
            stream.set_read_timeout(Some(POLL))?;
            stream.set_nodelay(true)?;
            shards.push(Mutex::new(Some(SamplerConn {
                addr: addr.clone(),
                stream,
                payload: Vec::new(),
                out: Vec::new(),
                pay: Vec::new(),
            })));
        }
        Ok(RemoteReplaySampler {
            shards,
            cursor: AtomicUsize::new(0),
            timeout,
        })
    }

    /// Number of shards still in the rotation.
    pub fn live_shards(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.lock().unwrap().is_some())
            .count()
    }

    /// One sample request against one shard. `Ok(Some)` is a batch,
    /// `Ok(None)` means "retry later" (rate limiter), `Err` means the
    /// shard is gone (closed, wedged or disconnected).
    fn try_shard(
        conn: &mut SamplerConn,
        n: usize,
        timeout: Duration,
    ) -> Result<Option<Vec<Item>>> {
        conn.pay.clear();
        wire::encode_u64(n as u64, &mut conn.pay);
        let mut out = std::mem::take(&mut conn.out);
        encode_frame(FrameKind::SampleRequest, &conn.pay, &mut out);
        let sent = conn.stream.write_all(&out);
        out.clear();
        conn.out = out;
        sent.with_context(|| format!("sample request to {}", conn.addr))?;
        let deadline = Instant::now() + timeout;
        let mut payload = std::mem::take(&mut conn.payload);
        let got = read_frame_polled(
            &mut conn.stream,
            &mut payload,
            &mut || Instant::now() >= deadline,
        );
        conn.payload = payload;
        match got {
            Ok(Some(FrameKind::SampleBatch)) => {
                Ok(Some(wire::decode_batch(&conn.payload)?))
            }
            Ok(Some(FrameKind::SampleRetry)) => Ok(None),
            Ok(Some(FrameKind::SourceClosed)) => {
                bail!("shard {} closed", conn.addr)
            }
            Ok(Some(other)) => {
                bail!("unexpected sample reply {other:?} from {}", conn.addr)
            }
            Ok(None) => bail!(
                "shard {} sample timed out after {timeout:?}",
                conn.addr
            ),
            Err(e) => {
                Err(frame_err(e, "sample reply").context(conn.addr.clone()))
            }
        }
    }
}

impl ItemSource for RemoteReplaySampler {
    fn sample_batch(&self, n: usize) -> Option<Vec<Item>> {
        let k = self.shards.len();
        loop {
            let start = self.cursor.load(Ordering::Relaxed);
            let mut live = 0usize;
            for off in 0..k {
                let idx = (start + off) % k;
                let mut slot = self.shards[idx].lock().unwrap();
                let Some(conn) = slot.as_mut() else {
                    continue;
                };
                match Self::try_shard(conn, n, self.timeout) {
                    Ok(Some(items)) => {
                        self.cursor.store((idx + 1) % k, Ordering::Relaxed);
                        return Some(items);
                    }
                    Ok(None) => live += 1,
                    Err(_) => {
                        // closed / wedged / disconnected: drop the
                        // shard from the rotation, keep the survivors
                        *slot = None;
                    }
                }
            }
            if live == 0 {
                // every shard gone: the source has ended
                return None;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::Transition;

    fn item(v: f32) -> Item {
        Item::Transition(Transition { obs: vec![v], ..Default::default() })
    }

    fn val(i: &Item) -> f32 {
        i.as_transition().obs[0]
    }

    #[test]
    fn remote_insert_then_remote_sample() {
        let table = Arc::new(Table::uniform(16, 2, 0));
        let mut svc = ReplayService::bind(table.clone(), "127.0.0.1")
            .unwrap();
        let sink = RemoteShardClient::connect(svc.addr()).unwrap();
        for i in 0..4 {
            let (accepted, recycled) =
                sink.insert_item_reuse(item(i as f32), 1.0);
            assert!(accepted);
            assert!(recycled.is_some(), "item handed back for reuse");
        }
        assert!(sink.check().is_ok());
        assert_eq!(table.stats().inserts, 4);

        let sampler = RemoteReplaySampler::connect(
            &[svc.addr().to_string()],
            Duration::from_secs(5),
        )
        .unwrap();
        let batch = sampler.sample_batch(8).expect("batch");
        assert_eq!(batch.len(), 8);
        for it in &batch {
            assert!((0.0..4.0).contains(&val(it)));
        }
        table.close();
        assert!(sampler.sample_batch(1).is_none(), "closed source ends");
        svc.shutdown();
    }

    #[test]
    fn closed_table_rejects_inserts_via_ack() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let svc = ReplayService::bind(table.clone(), "127.0.0.1").unwrap();
        let sink = RemoteShardClient::connect(svc.addr()).unwrap();
        table.close();
        let (accepted, recycled) = sink.insert_item_reuse(item(1.0), 1.0);
        assert!(!accepted);
        assert!(recycled.is_some());
        // a rejected insert is NOT a dead connection
        assert!(sink.check().is_ok());
    }

    #[test]
    fn dead_service_fails_sink_check() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut svc = ReplayService::bind(table.clone(), "127.0.0.1")
            .unwrap();
        let sink = RemoteShardClient::connect(svc.addr()).unwrap();
        assert!(sink.insert_item_reuse(item(1.0), 1.0).0);
        table.close();
        svc.shutdown();
        drop(svc);
        // the service is gone: the next insert must fail and latch
        let (accepted, recycled) = sink.insert_item_reuse(item(2.0), 1.0);
        assert!(!accepted);
        assert!(recycled.is_some());
        let err = sink.check().unwrap_err();
        assert!(
            err.to_string().contains("connection lost"),
            "typed sink failure: {err}"
        );
    }
}
