//! The replay wire protocol (DESIGN.md §10): adder inserts streaming
//! to a remote [`Table`] shard, and trainer sampling via
//! request/response.
//!
//! [`ReplayService`] exposes one shard over TCP. [`RemoteShardClient`]
//! implements [`ItemSink`] (what executors' adders insert through) and
//! [`RemoteReplaySampler`] implements [`ItemSource`] (what the trainer
//! prefetches from, round-robin over every shard service — the remote
//! mirror of [`crate::replay::ShardedTable`]'s skip-ahead sampling).
//! Both reuse their receive/send buffers across calls, and both
//! survive transport failures under the bounded
//! [`crate::net::retry::RetryPolicy`] (DESIGN.md §13): the sink
//! reconnects and resends inside the insert call (only a spent budget
//! marks it dead, and a later successful reconnect *clears* that
//! state), while the sampler parks a disconnected shard and re-probes
//! it on a backoff schedule — a restarted shard service rejoins the
//! rotation, a shard that answers `SourceClosed` (or exhausts its
//! probe budget) is gone for good, and only when every shard is gone
//! does sampling end.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::net::frame::{encode_frame, read_frame_polled, FrameKind};
use crate::net::param::{frame_err, spawn_accept_loop, POLL};
use crate::net::retry::{Backoff, Pacer, RetryPolicy};
use crate::net::wire;
use crate::replay::{Item, ItemSink, ItemSource, Table};

/// A TCP front-end for one replay [`Table`] shard.
///
/// Shutdown order matters: [`Table::close`] the shard *first* (that
/// unblocks rate-limited inserts and samplers, and makes the service
/// answer `SourceClosed`), then [`ReplayService::shutdown`].
pub struct ReplayService {
    addr: String,
    halt: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ReplayService {
    /// Bind on `host` (ephemeral port) and serve `table`.
    pub fn bind(table: Arc<Table>, host: &str) -> Result<Self> {
        let listener = TcpListener::bind((host, 0))
            .with_context(|| format!("bind replay service on {host}"))?;
        Self::serve(table, listener)
    }

    /// Bind an exact `host:port` and serve `table` — how a restarted
    /// shard process reclaims its advertised address so parked clients
    /// re-probing it can rejoin (`SO_REUSEADDR` makes the rebind
    /// immediate on Unix).
    pub fn bind_at(table: Arc<Table>, addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind replay service at {addr}"))?;
        Self::serve(table, listener)
    }

    fn serve(table: Arc<Table>, listener: TcpListener) -> Result<Self> {
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let halt = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let conn_halt = halt.clone();
        let accept = spawn_accept_loop(
            listener,
            halt.clone(),
            conns.clone(),
            "mava-replay-srv",
            move |stream| {
                serve_conn(stream, &table, &conn_halt);
            },
        );
        Ok(ReplayService { addr, halt, accept: Some(accept), conns })
    }

    /// The bound `host:port` address clients connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Stop accepting and join every connection thread. Close the
    /// served table *before* calling this, or in-flight blocking
    /// inserts can delay the join by one rate-limiter wait.
    pub fn shutdown(&mut self) {
        self.halt.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in conns {
            let _ = h.join();
        }
    }
}

impl Drop for ReplayService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve one replay connection until EOF, protocol error or halt.
fn serve_conn(mut stream: TcpStream, table: &Table, halt: &AtomicBool) {
    let mut payload = Vec::new();
    let mut reply = Vec::new();
    let mut pay = Vec::new();
    loop {
        let kind = match read_frame_polled(&mut stream, &mut payload, &mut || {
            halt.load(Ordering::Acquire)
        }) {
            Ok(Some(kind)) => kind,
            Ok(None) | Err(_) => break,
        };
        reply.clear();
        pay.clear();
        let ok = match kind {
            FrameKind::InsertItem => {
                let (item, priority) = match wire::decode_insert(&payload)
                {
                    Ok(x) => x,
                    Err(_) => break,
                };
                // blocks under the shard's rate limiter: socket
                // backpressure is exactly Reverb's insert blocking,
                // stretched over TCP. Unblocked by Table::close.
                let (accepted, _evicted) =
                    table.insert_reuse(item, priority);
                wire::encode_u64(accepted as u64, &mut pay);
                encode_frame(FrameKind::InsertAck, &pay, &mut reply);
                true
            }
            FrameKind::SampleRequest => {
                let n = match wire::decode_u64(&payload) {
                    Ok(n) => n as usize,
                    Err(_) => break,
                };
                if table.can_sample() {
                    // may briefly block if a racing sampler drained
                    // the shard; returns None only once closed
                    match table.sample(n) {
                        Some(items) => {
                            wire::encode_batch(&items, &mut pay);
                            encode_frame(
                                FrameKind::SampleBatch,
                                &pay,
                                &mut reply,
                            );
                        }
                        None => encode_frame(
                            FrameKind::SourceClosed,
                            &[],
                            &mut reply,
                        ),
                    }
                } else if table.is_closed() {
                    encode_frame(FrameKind::SourceClosed, &[], &mut reply);
                } else {
                    // not admissible yet (warm-up / rate limiter):
                    // the non-blocking retry keeps the client free to
                    // round-robin other shards
                    encode_frame(FrameKind::SampleRetry, &[], &mut reply);
                }
                true
            }
            FrameKind::Stop => false,
            other => {
                wire::encode_error(
                    &format!("unexpected frame {other:?} on replay port"),
                    &mut pay,
                );
                encode_frame(FrameKind::Error, &pay, &mut reply);
                false
            }
        };
        if stream.write_all(&reply).is_err() || !ok {
            break;
        }
    }
}

/// An [`ItemSink`] streaming inserts to one remote [`ReplayService`]
/// shard — the executor-side end of the replay wire protocol.
///
/// Inserts block until the shard acknowledges (mirroring the
/// in-process rate-limiter blocking); the serialized item is always
/// handed back for buffer recycling, so the adders' free lists work
/// unchanged. A transport failure reconnects and resends under the
/// client's [`RetryPolicy`] (a duplicated insert after a lost ack is
/// harmless replay data); only a spent budget marks the sink dead, at
/// which point [`ItemSink::check`] reports the stored error so the
/// executor node fails by name — and a later *successful* reconnect
/// (the shard came back) clears the dead state rather than poisoning
/// the executor forever.
pub struct RemoteShardClient {
    conn: Mutex<ShardConn>,
    dead: AtomicBool,
}

struct ShardConn {
    addr: String,
    stream: Option<TcpStream>,
    backoff: Backoff,
    payload: Vec<u8>,
    out: Vec<u8>,
    pay: Vec<u8>,
    error: Option<String>,
}

impl RemoteShardClient {
    /// Connect to a [`ReplayService`] at `addr` under
    /// [`RetryPolicy::net_default`].
    pub fn connect(addr: &str) -> Result<Self> {
        Self::connect_with(addr, RetryPolicy::net_default())
    }

    /// [`RemoteShardClient::connect`] with an explicit reconnect
    /// policy. The initial connect is eager and fail-fast (a node that
    /// cannot reach its shard at startup should die and be restarted).
    pub fn connect_with(addr: &str, policy: RetryPolicy) -> Result<Self> {
        let stream = Self::dial(addr)?;
        Ok(RemoteShardClient {
            conn: Mutex::new(ShardConn {
                addr: addr.to_string(),
                stream: Some(stream),
                backoff: Backoff::new(policy),
                payload: Vec::new(),
                out: Vec::new(),
                pay: Vec::new(),
                error: None,
            }),
            dead: AtomicBool::new(false),
        })
    }

    fn dial(addr: &str) -> Result<TcpStream> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect replay shard {addr}"))?;
        stream.set_read_timeout(Some(POLL))?;
        stream.set_nodelay(true)?;
        Ok(stream)
    }

    /// One insert attempt on the current (or freshly dialed)
    /// connection. The request bytes are already in `conn.pay`.
    fn insert_once(conn: &mut ShardConn) -> Result<bool> {
        if conn.stream.is_none() {
            conn.stream = Some(Self::dial(&conn.addr)?);
        }
        let stream = conn.stream.as_mut().expect("dialed above");
        let mut out = std::mem::take(&mut conn.out);
        encode_frame(FrameKind::InsertItem, &conn.pay, &mut out);
        let sent = stream.write_all(&out);
        out.clear();
        conn.out = out;
        sent.context("replay insert send")?;
        // Wait for the ack without a deadline: the shard's rate
        // limiter may legitimately hold the insert (the in-process
        // adder blocks identically); a closed table acks
        // accepted=false, a dead service surfaces as an IO error.
        let mut payload = std::mem::take(&mut conn.payload);
        let got =
            read_frame_polled(stream, &mut payload, &mut || false);
        conn.payload = payload;
        match got {
            Ok(Some(FrameKind::InsertAck)) => Ok(wire::decode_u64(
                &conn.payload,
            )
            .map(|v| v != 0)
            .unwrap_or(false)),
            Ok(Some(other)) => {
                bail!("unexpected insert reply {other:?}")
            }
            Ok(None) => unreachable!("halt closure is constant false"),
            Err(e) => Err(frame_err(e, "replay insert")),
        }
    }
}

impl ItemSink for RemoteShardClient {
    fn insert_item_reuse(
        &self,
        item: Item,
        priority: f64,
    ) -> (bool, Option<Item>) {
        let mut conn = self.conn.lock().unwrap();
        conn.pay.clear();
        wire::encode_insert(&item, priority, &mut conn.pay);
        loop {
            match Self::insert_once(&mut conn) {
                Ok(accepted) => {
                    // success clears the failure streak AND the dead
                    // latch: a shard that came back un-poisons the
                    // executor
                    conn.backoff.reset();
                    conn.error = None;
                    self.dead.store(false, Ordering::Release);
                    return (accepted, Some(item));
                }
                Err(e) => {
                    // drop the (possibly desynced) connection; retry
                    // redials and resends until the budget is spent
                    conn.stream = None;
                    match conn.backoff.next_delay() {
                        // POLL_INTERVAL-sliced sleep keeps the computed
                        // backoff on the sanctioned pacing seam (R3)
                        Some(delay) => {
                            crate::net::retry::sleep_interruptible(delay, &mut || false);
                        }
                        None => {
                            conn.error.get_or_insert(format!("{e:#}"));
                            self.dead.store(true, Ordering::Release);
                            return (false, Some(item));
                        }
                    }
                }
            }
        }
    }

    fn check(&self) -> Result<()> {
        if !self.dead.load(Ordering::Acquire) {
            return Ok(());
        }
        let conn = self.conn.lock().unwrap();
        match &conn.error {
            Some(msg) => bail!("replay shard connection lost: {msg}"),
            None => bail!("replay shard connection lost"),
        }
    }
}

/// An [`ItemSource`] drawing batches from several remote shard
/// services round-robin — the trainer-side end of the replay wire
/// protocol, mirroring [`crate::replay::ShardedTable::sample`]'s
/// skip-ahead rotation.
///
/// Shard loss is two-tier. A shard that answers `SourceClosed` shut
/// down deliberately and leaves the rotation permanently. A shard
/// that times out or drops its connection is *parked* instead: the
/// rotation keeps serving from the survivors while a [`Pacer`]
/// re-probes the parked address on the backoff schedule, so a
/// restarted shard rejoins the rotation without the trainer
/// restarting. Only when the probe budget is spent is the shard
/// evicted for good; [`ItemSource::sample_batch`] returns `None` only
/// once every shard is gone.
pub struct RemoteReplaySampler {
    shards: Vec<Mutex<Slot>>,
    cursor: AtomicUsize,
    timeout: Duration,
    policy: RetryPolicy,
}

/// One shard's place in the rotation.
enum Slot {
    /// Connected and serving.
    Live(SamplerConn),
    /// Transport lost: parked, re-probed when the pacer says so.
    Down { addr: String, pacer: Pacer },
    /// Deliberately closed, or the probe budget is spent.
    Gone,
}

/// Outcome of one sample request against one live shard.
enum ShardPoll {
    /// A batch of items.
    Batch(Vec<Item>),
    /// Healthy but not admissible yet (rate limiter).
    NotReady,
    /// The shard's table closed: leave the rotation permanently.
    Closed,
    /// Transport failure (timeout, disconnect, bad frame): park.
    Lost(anyhow::Error),
}

struct SamplerConn {
    addr: String,
    stream: TcpStream,
    payload: Vec<u8>,
    out: Vec<u8>,
    pay: Vec<u8>,
}

impl RemoteReplaySampler {
    /// Connect to every shard service in `addrs` under
    /// [`RetryPolicy::net_default`]. `timeout` bounds each sample
    /// round trip (a healthy shard answers `SampleRetry` immediately
    /// when not admissible, so replies are always fast — a timeout
    /// means the shard is wedged and it is parked).
    pub fn connect(addrs: &[String], timeout: Duration) -> Result<Self> {
        Self::connect_with(addrs, timeout, RetryPolicy::net_default())
    }

    /// [`RemoteReplaySampler::connect`] with an explicit re-probe
    /// policy for parked shards. The initial connects are eager and
    /// fail-fast (a trainer that cannot reach replay at startup should
    /// die and be restarted).
    pub fn connect_with(
        addrs: &[String],
        timeout: Duration,
        policy: RetryPolicy,
    ) -> Result<Self> {
        anyhow::ensure!(!addrs.is_empty(), "no replay shard addresses");
        let mut shards = Vec::with_capacity(addrs.len());
        for addr in addrs {
            shards.push(Mutex::new(Slot::Live(Self::dial(addr)?)));
        }
        Ok(RemoteReplaySampler {
            shards,
            cursor: AtomicUsize::new(0),
            timeout,
            policy,
        })
    }

    fn dial(addr: &str) -> Result<SamplerConn> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect replay shard {addr}"))?;
        stream.set_read_timeout(Some(POLL))?;
        stream.set_nodelay(true)?;
        Ok(SamplerConn {
            addr: addr.to_string(),
            stream,
            payload: Vec::new(),
            out: Vec::new(),
            pay: Vec::new(),
        })
    }

    /// Number of shards currently connected and serving.
    pub fn live_shards(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| matches!(*s.lock().unwrap(), Slot::Live(_)))
            .count()
    }

    /// One sample request against one shard.
    fn try_shard(
        conn: &mut SamplerConn,
        n: usize,
        timeout: Duration,
    ) -> ShardPoll {
        conn.pay.clear();
        wire::encode_u64(n as u64, &mut conn.pay);
        let mut out = std::mem::take(&mut conn.out);
        encode_frame(FrameKind::SampleRequest, &conn.pay, &mut out);
        let sent = conn.stream.write_all(&out);
        out.clear();
        conn.out = out;
        if let Err(e) = sent {
            return ShardPoll::Lost(anyhow::Error::new(e).context(
                format!("sample request to {}", conn.addr),
            ));
        }
        let deadline = Instant::now() + timeout;
        let mut payload = std::mem::take(&mut conn.payload);
        let got = read_frame_polled(
            &mut conn.stream,
            &mut payload,
            &mut || Instant::now() >= deadline,
        );
        conn.payload = payload;
        match got {
            Ok(Some(FrameKind::SampleBatch)) => {
                match wire::decode_batch(&conn.payload) {
                    Ok(items) => ShardPoll::Batch(items),
                    Err(e) => ShardPoll::Lost(e),
                }
            }
            Ok(Some(FrameKind::SampleRetry)) => ShardPoll::NotReady,
            Ok(Some(FrameKind::SourceClosed)) => ShardPoll::Closed,
            Ok(Some(other)) => ShardPoll::Lost(anyhow::anyhow!(
                "unexpected sample reply {other:?} from {}",
                conn.addr
            )),
            Ok(None) => ShardPoll::Lost(anyhow::anyhow!(
                "shard {} sample timed out after {timeout:?}",
                conn.addr
            )),
            Err(e) => ShardPoll::Lost(
                frame_err(e, "sample reply").context(conn.addr.clone()),
            ),
        }
    }
}

impl ItemSource for RemoteReplaySampler {
    fn sample_batch(&self, n: usize) -> Option<Vec<Item>> {
        let k = self.shards.len();
        loop {
            let start = self.cursor.load(Ordering::Relaxed);
            let mut waiting = 0usize;
            for off in 0..k {
                let idx = (start + off) % k;
                let mut slot = self.shards[idx].lock().unwrap();
                // parked shards: evict on spent budget, redial when due
                if let Slot::Down { addr, pacer } = &mut *slot {
                    if pacer.exhausted() {
                        *slot = Slot::Gone;
                    } else if pacer.due() {
                        match Self::dial(addr) {
                            Ok(conn) => *slot = Slot::Live(conn),
                            Err(_) => pacer.note_failure(),
                        }
                    }
                }
                match &mut *slot {
                    Slot::Live(conn) => {
                        match Self::try_shard(conn, n, self.timeout) {
                            ShardPoll::Batch(items) => {
                                self.cursor.store(
                                    (idx + 1) % k,
                                    Ordering::Relaxed,
                                );
                                return Some(items);
                            }
                            ShardPoll::NotReady => waiting += 1,
                            ShardPoll::Closed => *slot = Slot::Gone,
                            ShardPoll::Lost(_) => {
                                // park: the restart supervisor may
                                // bring the shard back at this address
                                let addr = conn.addr.clone();
                                let mut pacer =
                                    Pacer::system(self.policy);
                                pacer.note_failure();
                                *slot = Slot::Down { addr, pacer };
                                waiting += 1;
                            }
                        }
                    }
                    Slot::Down { .. } => waiting += 1,
                    Slot::Gone => {}
                }
            }
            if waiting == 0 {
                // every shard gone for good: the source has ended
                return None;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::Transition;

    fn item(v: f32) -> Item {
        Item::Transition(Transition { obs: vec![v], ..Default::default() })
    }

    fn val(i: &Item) -> f32 {
        i.as_transition().obs[0]
    }

    #[test]
    fn remote_insert_then_remote_sample() {
        let table = Arc::new(Table::uniform(16, 2, 0));
        let mut svc = ReplayService::bind(table.clone(), "127.0.0.1")
            .unwrap();
        let sink = RemoteShardClient::connect(svc.addr()).unwrap();
        for i in 0..4 {
            let (accepted, recycled) =
                sink.insert_item_reuse(item(i as f32), 1.0);
            assert!(accepted);
            assert!(recycled.is_some(), "item handed back for reuse");
        }
        assert!(sink.check().is_ok());
        assert_eq!(table.stats().inserts, 4);

        let sampler = RemoteReplaySampler::connect(
            &[svc.addr().to_string()],
            Duration::from_secs(5),
        )
        .unwrap();
        let batch = sampler.sample_batch(8).expect("batch");
        assert_eq!(batch.len(), 8);
        for it in &batch {
            assert!((0.0..4.0).contains(&val(it)));
        }
        table.close();
        assert!(sampler.sample_batch(1).is_none(), "closed source ends");
        svc.shutdown();
    }

    #[test]
    fn closed_table_rejects_inserts_via_ack() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let svc = ReplayService::bind(table.clone(), "127.0.0.1").unwrap();
        let sink = RemoteShardClient::connect(svc.addr()).unwrap();
        table.close();
        let (accepted, recycled) = sink.insert_item_reuse(item(1.0), 1.0);
        assert!(!accepted);
        assert!(recycled.is_some());
        // a rejected insert is NOT a dead connection
        assert!(sink.check().is_ok());
    }

    #[test]
    fn dead_service_fails_sink_check() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut svc = ReplayService::bind(table.clone(), "127.0.0.1")
            .unwrap();
        // tiny reconnect budget so exhaustion is fast
        let sink = RemoteShardClient::connect_with(
            svc.addr(),
            RetryPolicy::new(1, 2, 2),
        )
        .unwrap();
        assert!(sink.insert_item_reuse(item(1.0), 1.0).0);
        table.close();
        svc.shutdown();
        drop(svc);
        // the service is gone: the insert spends its reconnect budget,
        // then fails and latches
        let (accepted, recycled) = sink.insert_item_reuse(item(2.0), 1.0);
        assert!(!accepted);
        assert!(recycled.is_some());
        let err = sink.check().unwrap_err();
        assert!(
            err.to_string().contains("connection lost"),
            "typed sink failure: {err}"
        );
    }

    #[test]
    fn sink_reconnects_to_restarted_shard_and_unlatches() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut svc = ReplayService::bind(table.clone(), "127.0.0.1")
            .unwrap();
        let addr = svc.addr().to_string();
        let sink = RemoteShardClient::connect_with(
            &addr,
            RetryPolicy::new(1, 2, 2),
        )
        .unwrap();
        assert!(sink.insert_item_reuse(item(1.0), 1.0).0);

        // kill the service (table stays open — a crash, not a close)
        svc.shutdown();
        drop(svc);
        let (accepted, _) = sink.insert_item_reuse(item(2.0), 1.0);
        assert!(!accepted, "budget spent against a dead service");
        assert!(sink.check().is_err(), "failure latched");

        // restart at the same address: the next insert redials,
        // succeeds, and clears the latch
        let mut svc2 =
            ReplayService::bind_at(table.clone(), &addr).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let (accepted, _) = sink.insert_item_reuse(item(3.0), 1.0);
            if accepted {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "sink never recovered after shard restart"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(sink.check().is_ok(), "success un-latches the sink");
        table.close();
        svc2.shutdown();
    }

    #[test]
    fn sampler_reprobes_parked_shard_after_restart() {
        let table = Arc::new(Table::uniform(64, 2, 0));
        let mut svc = ReplayService::bind(table.clone(), "127.0.0.1")
            .unwrap();
        let addr = svc.addr().to_string();
        let sink = RemoteShardClient::connect(&addr).unwrap();
        for i in 0..4 {
            assert!(sink.insert_item_reuse(item(i as f32), 1.0).0);
        }
        // generous probe budget: the shard must still be parked (not
        // evicted) while it is down
        let sampler = RemoteReplaySampler::connect_with(
            &[addr.clone()],
            Duration::from_secs(2),
            RetryPolicy::new(5, 50, 100),
        )
        .unwrap();
        assert_eq!(sampler.sample_batch(4).expect("batch").len(), 4);
        assert_eq!(sampler.live_shards(), 1);

        // crash the shard service; the sampler parks it
        svc.shutdown();
        drop(svc);
        // restart at the same address in the background while the
        // sampler is already blocked inside sample_batch re-probing
        let t_addr = addr.clone();
        let t_table = table.clone();
        let restarter = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(150));
            ReplayService::bind_at(t_table, &t_addr).unwrap()
        });
        let batch = sampler
            .sample_batch(4)
            .expect("sampler rejoined the restarted shard");
        assert_eq!(batch.len(), 4);
        assert_eq!(sampler.live_shards(), 1);
        let mut svc2 = restarter.join().unwrap();
        table.close();
        assert!(
            sampler.sample_batch(1).is_none(),
            "deliberate close still ends the source"
        );
        svc2.shutdown();
    }
}
