//! The parameter-server wire protocol (DESIGN.md §10): publish/fetch
//! of the flat parameter blob with a monotone version counter.
//!
//! [`ParamService`] exposes an in-process
//! [`crate::params::ParameterServer`] over TCP; [`RemoteParamClient`]
//! implements [`ParamStore`] against such a service, so a
//! [`crate::systems::TrainerNode`] publishes to — and executors poll —
//! a remote server through the exact trait surface the in-process
//! handle provides. Fetches are version-gated (`FetchParams` carries
//! the client's known version, the server answers `ParamsCurrent` when
//! nothing newer exists), so steady-state polling moves 12-byte
//! frames, not parameter blobs.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::net::frame::{
    encode_frame, read_frame_polled, FrameError, FrameKind,
};
use crate::net::retry::{Backoff, RetryPolicy};
use crate::net::wire;
use crate::params::{ParamStore, ParameterServer};

/// Poll cadence of the accept loop and the per-connection reads — the
/// crate-wide [`crate::net::frame::POLL_INTERVAL`] (the constant used
/// to live here as a private copy; it is load-bearing for shutdown
/// latency, so there is exactly one).
pub(crate) use crate::net::frame::POLL_INTERVAL as POLL;

/// Convert a frame-codec error into an `anyhow` error with context.
pub(crate) fn frame_err(e: FrameError, what: &str) -> anyhow::Error {
    anyhow::Error::new(e).context(what.to_string())
}

/// Spawn the shared accept loop every service in this module uses: a
/// non-blocking listener polled against `halt`, each accepted
/// connection handed to `handler` on its own thread (collected in
/// `conns` so shutdown can join them).
pub(crate) fn spawn_accept_loop(
    listener: TcpListener,
    halt: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    thread_name: &str,
    handler: impl Fn(TcpStream) + Send + Sync + Clone + 'static,
) -> JoinHandle<()> {
    let name = thread_name.to_string();
    std::thread::Builder::new()
        .name(name.clone())
        .spawn(move || loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let _ = stream.set_read_timeout(Some(POLL));
                    let _ = stream.set_nodelay(true);
                    let handler = handler.clone();
                    let h = std::thread::Builder::new()
                        .name(format!("{name}-conn"))
                        .spawn(move || handler(stream))
                        .expect("spawn service connection thread");
                    conns.lock().unwrap().push(h);
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    if halt.load(Ordering::Acquire) {
                        break;
                    }
                    std::thread::sleep(POLL);
                }
                Err(_) => break,
            }
        })
        .expect("spawn service accept thread")
}

/// A TCP front-end for one [`ParameterServer`]: accepts any number of
/// publisher/fetcher connections and serves them until shutdown.
pub struct ParamService {
    addr: String,
    halt: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ParamService {
    /// Bind on `host` (ephemeral port) and serve `server`.
    pub fn bind(server: Arc<ParameterServer>, host: &str) -> Result<Self> {
        let listener = TcpListener::bind((host, 0))
            .with_context(|| format!("bind param service on {host}"))?;
        Self::serve(server, listener)
    }

    /// Bind an exact `host:port` and serve `server` — how a restarted
    /// service reclaims its advertised address so reconnecting clients
    /// find it again (`SO_REUSEADDR` makes the rebind immediate on
    /// Unix).
    pub fn bind_at(
        server: Arc<ParameterServer>,
        addr: &str,
    ) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind param service at {addr}"))?;
        Self::serve(server, listener)
    }

    fn serve(
        server: Arc<ParameterServer>,
        listener: TcpListener,
    ) -> Result<Self> {
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let halt = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let conn_halt = halt.clone();
        let accept = spawn_accept_loop(
            listener,
            halt.clone(),
            conns.clone(),
            "mava-param-srv",
            move |stream| {
                serve_conn(stream, &server, &conn_halt);
            },
        );
        Ok(ParamService { addr, halt, accept: Some(accept), conns })
    }

    /// The bound `host:port` address clients connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Stop accepting, drain every connection thread and join them.
    pub fn shutdown(&mut self) {
        self.halt.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in conns {
            let _ = h.join();
        }
    }
}

impl Drop for ParamService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve one param connection until EOF, protocol error or halt.
fn serve_conn(
    mut stream: TcpStream,
    server: &ParameterServer,
    halt: &AtomicBool,
) {
    let mut payload = Vec::new();
    let mut reply = Vec::new();
    let mut pay = Vec::new();
    loop {
        let kind = match read_frame_polled(&mut stream, &mut payload, &mut || {
            halt.load(Ordering::Acquire)
        }) {
            Ok(Some(kind)) => kind,
            // halted between frames, or the peer went away / sent
            // garbage: either way this connection is done
            Ok(None) | Err(_) => break,
        };
        reply.clear();
        pay.clear();
        let ok = match kind {
            FrameKind::FetchParams => {
                let known = match wire::decode_u64(&payload) {
                    Ok(v) => v,
                    Err(_) => break,
                };
                let (v, blob) = server.get();
                // an empty blob means nothing was published yet (a
                // fresh distributed server): clients keep their init
                // params until the first publish
                if v > known && !blob.is_empty() {
                    wire::encode_params(v, &blob, &mut pay);
                    encode_frame(FrameKind::Params, &pay, &mut reply);
                } else {
                    encode_frame(
                        FrameKind::ParamsCurrent,
                        &[],
                        &mut reply,
                    );
                }
                true
            }
            FrameKind::PublishParams => {
                let mut r = wire::WireReader::new(&payload);
                let mut blob = Vec::new();
                if r.f32_vec_into(&mut blob).is_err()
                    || r.finish().is_err()
                {
                    break;
                }
                server.push(&blob);
                wire::encode_u64(server.version(), &mut pay);
                encode_frame(FrameKind::PublishAck, &pay, &mut reply);
                true
            }
            FrameKind::Stop => false,
            other => {
                wire::encode_error(
                    &format!("unexpected frame {other:?} on param port"),
                    &mut pay,
                );
                encode_frame(FrameKind::Error, &pay, &mut reply);
                false
            }
        };
        if stream.write_all(&reply).is_err() || !ok {
            break;
        }
    }
}

/// A [`ParamStore`] speaking the wire protocol to a remote
/// [`ParamService`]. One connection, serialized behind a mutex (each
/// node holds its own client, so there is no contention to shard);
/// receive buffers are reused across calls.
///
/// A transport failure mid-call (send error, reply timeout, torn
/// frame) drops the connection and retries under the client's
/// [`RetryPolicy`]: reconnect, resend, capped-exponential sleeps in
/// between. The protocol is stateless request/response, so a resend
/// after a lost reply is safe (a duplicated publish re-pushes the
/// identical blob). Only a spent retry budget surfaces as an error —
/// and a later success refills the budget, so a transient outage
/// never latches the client dead.
pub struct RemoteParamClient {
    conn: Mutex<ClientConn>,
    timeout: Duration,
}

struct ClientConn {
    addr: String,
    stream: Option<TcpStream>,
    backoff: Backoff,
    payload: Vec<u8>,
    out: Vec<u8>,
    pay: Vec<u8>,
}

impl RemoteParamClient {
    /// Connect to a [`ParamService`] at `addr` under
    /// [`RetryPolicy::net_default`]. `timeout` bounds every
    /// request/response round trip.
    pub fn connect(addr: &str, timeout: Duration) -> Result<Self> {
        Self::connect_with(addr, timeout, RetryPolicy::net_default())
    }

    /// [`RemoteParamClient::connect`] with an explicit reconnect
    /// policy. The *initial* connect is still eager and fail-fast —
    /// a node that cannot reach its services at startup should die
    /// (and be restarted by the supervisor) rather than spin.
    pub fn connect_with(
        addr: &str,
        timeout: Duration,
        policy: RetryPolicy,
    ) -> Result<Self> {
        let stream = Self::dial(addr)?;
        Ok(RemoteParamClient {
            conn: Mutex::new(ClientConn {
                addr: addr.to_string(),
                stream: Some(stream),
                backoff: Backoff::new(policy),
                payload: Vec::new(),
                out: Vec::new(),
                pay: Vec::new(),
            }),
            timeout,
        })
    }

    fn dial(addr: &str) -> Result<TcpStream> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect param server {addr}"))?;
        stream.set_read_timeout(Some(POLL))?;
        stream.set_nodelay(true)?;
        Ok(stream)
    }

    /// One request/response round trip with bounded
    /// reconnect-with-backoff; returns the reply kind, with the
    /// payload left in `conn.payload`.
    fn rpc(
        conn: &mut ClientConn,
        kind: FrameKind,
        timeout: Duration,
    ) -> Result<FrameKind> {
        loop {
            match Self::rpc_once(conn, kind, timeout) {
                Ok(reply) => {
                    conn.backoff.reset();
                    return Ok(reply);
                }
                Err(e) => {
                    // drop the (possibly desynced) connection; the
                    // next attempt redials and resends
                    conn.stream = None;
                    let Some(delay) = conn.backoff.next_delay() else {
                        return Err(e.context(format!(
                            "param server {}: reconnect budget \
                             exhausted",
                            conn.addr
                        )));
                    };
                    // POLL_INTERVAL-sliced sleep: the computed backoff
                    // delay stays on the sanctioned pacing seam (R3)
                    crate::net::retry::sleep_interruptible(delay, &mut || false);
                }
            }
        }
    }

    /// One attempt at a round trip on the current (or a freshly
    /// dialed) connection.
    fn rpc_once(
        conn: &mut ClientConn,
        kind: FrameKind,
        timeout: Duration,
    ) -> Result<FrameKind> {
        if conn.stream.is_none() {
            conn.stream = Some(Self::dial(&conn.addr)?);
        }
        let stream = conn.stream.as_mut().expect("dialed above");
        let mut out = std::mem::take(&mut conn.out);
        encode_frame(kind, &conn.pay, &mut out);
        let sent = stream.write_all(&out);
        out.clear();
        conn.out = out;
        sent.context("param request send")?;
        let deadline = Instant::now() + timeout;
        match read_frame_polled(stream, &mut conn.payload, &mut || {
            Instant::now() >= deadline
        }) {
            Ok(Some(reply)) => Ok(reply),
            Ok(None) => bail!(
                "param server reply timed out after {timeout:?}"
            ),
            Err(e) => Err(frame_err(e, "param reply")),
        }
    }

    /// Fail on any reply kind other than the expected ones, decoding a
    /// server-side [`FrameKind::Error`] frame into the message.
    fn unexpected(conn: &ClientConn, got: FrameKind) -> anyhow::Error {
        if got == FrameKind::Error {
            if let Ok(msg) = wire::decode_error(&conn.payload) {
                return anyhow::anyhow!("param server error: {msg}");
            }
        }
        anyhow::anyhow!("unexpected param server reply {got:?}")
    }
}

impl ParamStore for RemoteParamClient {
    fn push(&self, params: &[f32]) -> Result<u64> {
        let mut conn = self.conn.lock().unwrap();
        conn.pay.clear();
        wire::put_f32s(&mut conn.pay, params);
        match Self::rpc(&mut conn, FrameKind::PublishParams, self.timeout)? {
            FrameKind::PublishAck => wire::decode_u64(&conn.payload),
            other => Err(Self::unexpected(&conn, other)),
        }
    }

    fn get(&self) -> Result<(u64, Vec<f32>)> {
        let mut blob = Vec::new();
        let version = self.sync(0, &mut blob)?.unwrap_or(0);
        Ok((version, blob))
    }

    fn sync(
        &self,
        known_version: u64,
        dst: &mut Vec<f32>,
    ) -> Result<Option<u64>> {
        let mut conn = self.conn.lock().unwrap();
        conn.pay.clear();
        wire::encode_u64(known_version, &mut conn.pay);
        match Self::rpc(&mut conn, FrameKind::FetchParams, self.timeout)? {
            FrameKind::Params => {
                let v = wire::decode_params_into(&conn.payload, dst)?;
                Ok(Some(v))
            }
            FrameKind::ParamsCurrent => Ok(None),
            other => Err(Self::unexpected(&conn, other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn client(svc: &ParamService) -> RemoteParamClient {
        RemoteParamClient::connect(svc.addr(), Duration::from_secs(5))
            .unwrap()
    }

    #[test]
    fn publish_fetch_roundtrip() {
        let server = Arc::new(ParameterServer::new(vec![0.0; 4]));
        let mut svc =
            ParamService::bind(server.clone(), "127.0.0.1").unwrap();
        let c = client(&svc);
        // fetch the initial blob
        let mut buf = Vec::new();
        let v = c.sync(0, &mut buf).unwrap().expect("initial fetch");
        assert_eq!(v, 1);
        assert_eq!(buf, vec![0.0; 4]);
        // current version -> no new blob
        assert!(c.sync(v, &mut buf).unwrap().is_none());
        // remote publish bumps the version for everyone
        let v2 = ParamStore::push(&c, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(v2, 2);
        assert_eq!(server.get(), (2, vec![1.0, 2.0, 3.0, 4.0]));
        let v3 = c.sync(v, &mut buf).unwrap().expect("new version");
        assert_eq!(v3, 2);
        assert_eq!(buf, vec![1.0, 2.0, 3.0, 4.0]);
        svc.shutdown();
    }

    #[test]
    fn empty_blob_reads_as_current() {
        // a fresh distributed param server holds no params until the
        // trainer's first publish; fetchers must keep their init blob
        let server = Arc::new(ParameterServer::new(Vec::new()));
        let mut svc =
            ParamService::bind(server.clone(), "127.0.0.1").unwrap();
        let c = client(&svc);
        let mut buf = vec![7.0];
        assert!(c.sync(0, &mut buf).unwrap().is_none());
        assert_eq!(buf, vec![7.0], "scratch untouched");
        ParamStore::push(&c, &[5.0]).unwrap();
        assert_eq!(c.sync(0, &mut buf).unwrap(), Some(2));
        assert_eq!(buf, vec![5.0]);
        svc.shutdown();
    }

    #[test]
    fn dead_server_spends_reconnect_budget_then_errors() {
        let server = Arc::new(ParameterServer::new(Vec::new()));
        let mut svc =
            ParamService::bind(server.clone(), "127.0.0.1").unwrap();
        let c = RemoteParamClient::connect_with(
            svc.addr(),
            Duration::from_secs(5),
            RetryPolicy::new(1, 2, 2),
        )
        .unwrap();
        ParamStore::push(&c, &[1.0]).unwrap();
        svc.shutdown();
        drop(svc);
        // every reconnect refuses: the bounded budget (2 attempts at
        // 1-2ms) spends quickly and surfaces a typed error
        let err = ParamStore::push(&c, &[2.0]).unwrap_err();
        assert!(
            err.to_string().contains("reconnect budget exhausted"),
            "typed exhaustion: {err:#}"
        );
    }

    #[test]
    fn get_on_fresh_server_is_empty() {
        let server = Arc::new(ParameterServer::new(Vec::new()));
        let svc = ParamService::bind(server, "127.0.0.1").unwrap();
        let c = client(&svc);
        let (v, blob) = ParamStore::get(&c).unwrap();
        assert_eq!(v, 0);
        assert!(blob.is_empty());
    }
}
