//! The serve core: sessions + batcher + backend + hot-reload, wired
//! into one single-threaded state machine (DESIGN.md §12).
//!
//! [`ServeCore`] owns everything stateful about serving and exposes
//! exactly four operations: open/close a session, submit an
//! observation, and [`ServeCore::step`] — which flushes every batch
//! the batcher deems due and returns the finished responses. It has no
//! threads, no sockets and no real clock: the TCP service drives it
//! from one ticker thread, and the hermetic suites drive it directly
//! with a [`crate::serve::clock::MockClock`] and a
//! [`crate::serve::backend::MockBackend`].
//!
//! Hot-reload ordering: the [`ParamStore`] is sync'd at most once per
//! flushed batch, *before* that batch infers. A trainer publish
//! therefore lands between batches, never mid-batch — every response
//! in a batch reports the one version its actions were computed with,
//! and the version sequence across responses is monotone.

#![warn(missing_docs)]

use std::sync::Arc;

use crate::params::ParamStore;
use crate::serve::backend::PolicyBackend;
use crate::serve::batcher::{Batcher, PendingRequest};
use crate::serve::clock::Clock;
use crate::serve::session::{ServeError, SessionTable};

/// One finished inference: the actions for one request, stamped with
/// the parameter version that produced them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ActResponse {
    /// The session that asked.
    pub session: u64,
    /// Parameter version the actions were computed with.
    pub version: u64,
    /// One discrete action per agent.
    pub actions: Vec<i32>,
}

/// The single-threaded serving state machine over an injected clock,
/// backend and (optional) parameter store.
pub struct ServeCore<B: PolicyBackend> {
    clock: Arc<dyn Clock>,
    backend: B,
    sessions: SessionTable,
    batcher: Batcher,
    store: Option<Arc<dyn ParamStore>>,
    known_version: u64,
    param_scratch: Vec<f32>,
    obs_scratch: Vec<f32>,
    carry_scratch: Vec<f32>,
    act_scratch: Vec<i32>,
}

impl<B: PolicyBackend> ServeCore<B> {
    /// A core serving `backend` with `max_sessions` carry slots and a
    /// `deadline_us` coalescing window.
    pub fn new(
        backend: B,
        clock: Arc<dyn Clock>,
        max_sessions: usize,
        deadline_us: u64,
    ) -> ServeCore<B> {
        let sessions = SessionTable::new(max_sessions, backend.carry_width());
        let batcher = Batcher::new(backend.buckets(), deadline_us);
        ServeCore {
            clock,
            backend,
            sessions,
            batcher,
            store: None,
            known_version: 0,
            param_scratch: Vec::new(),
            obs_scratch: Vec::new(),
            carry_scratch: Vec::new(),
            act_scratch: Vec::new(),
        }
    }

    /// Attach a checkpoint source: each batch checks it (version-
    /// gated) before inferring, so trainer publishes hot-reload
    /// without dropping requests.
    pub fn with_store(mut self, store: Arc<dyn ParamStore>) -> ServeCore<B> {
        self.store = Some(store);
        self
    }

    /// Open a session (a carry slot for one client episode).
    pub fn open_session(&mut self) -> Result<u64, ServeError> {
        self.sessions.open()
    }

    /// Close a session: drops its queued-but-unflushed requests (their
    /// responses must never be emitted) and zeroes its carry slot.
    /// Returns how many pending requests were dropped.
    pub fn close_session(
        &mut self,
        session: u64,
    ) -> Result<usize, ServeError> {
        self.sessions.close(session)?;
        Ok(self.batcher.drop_session(session))
    }

    /// Queue one observation for `session`. The response comes out of
    /// a later [`ServeCore::step`].
    pub fn submit(
        &mut self,
        session: u64,
        obs: Vec<f32>,
    ) -> Result<(), ServeError> {
        if obs.len() != self.backend.obs_width() {
            return Err(ServeError::BadRequest(format!(
                "observation has {} floats, the policy expects {}",
                obs.len(),
                self.backend.obs_width()
            )));
        }
        let slot = self.sessions.slot(session)?;
        self.batcher.submit(PendingRequest {
            session,
            slot,
            obs,
            enqueued_us: self.clock.now_us(),
        });
        Ok(())
    }

    /// Number of queued (unflushed) requests.
    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    /// Number of open sessions.
    pub fn open_sessions(&self) -> usize {
        self.sessions.open_count()
    }

    /// Absolute clock time of the next forced flush (`None`: idle).
    pub fn next_deadline_us(&self) -> Option<u64> {
        self.batcher.next_deadline_us()
    }

    /// The parameter version responses are currently stamped with.
    pub fn known_version(&self) -> u64 {
        self.known_version
    }

    /// The backend (tests inspect mock call logs through this).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Mutable backend access (tests arrange fault injection).
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Version-gated checkpoint sync. Called between batches only —
    /// never mid-batch — so a concurrent trainer publish can delay a
    /// batch's parameters but never tear them.
    fn maybe_reload(&mut self) -> Result<(), ServeError> {
        let Some(store) = &self.store else {
            return Ok(());
        };
        match store.sync(self.known_version, &mut self.param_scratch) {
            // an empty blob is a fresh store nothing was published to
            // yet: keep the init params (mirrors the param service)
            Ok(Some(v)) if !self.param_scratch.is_empty() => {
                self.backend.set_params(v, &self.param_scratch)?;
                self.known_version = v;
            }
            Ok(_) => {}
            Err(e) => {
                return Err(ServeError::Backend(format!(
                    "checkpoint sync failed: {e:#}"
                )));
            }
        }
        Ok(())
    }

    /// Flush every due batch: reload params if the store moved, gather
    /// each batch's obs + per-session carry rows, infer with padding
    /// rows masked, scatter the carry back and emit one response per
    /// real request. Requests submitted after a flush decision simply
    /// stay queued for the next one — nothing is lost or answered
    /// twice.
    pub fn step(&mut self) -> Result<Vec<ActResponse>, ServeError> {
        let mut out = Vec::new();
        loop {
            let now = self.clock.now_us();
            let Some(batch) = self.batcher.poll(now) else {
                break;
            };
            self.maybe_reload()?;
            let ow = self.backend.obs_width();
            let aw = self.backend.act_width();
            let cw = self.backend.carry_width();
            let bucket = batch.bucket;
            self.obs_scratch.clear();
            self.obs_scratch.resize(bucket * ow, 0.0);
            self.carry_scratch.clear();
            self.carry_scratch.resize(bucket * cw, 0.0);
            self.act_scratch.clear();
            self.act_scratch.resize(bucket * aw, 0);
            for (row, req) in batch.requests.iter().enumerate() {
                self.obs_scratch[row * ow..(row + 1) * ow]
                    .copy_from_slice(&req.obs);
                self.carry_scratch[row * cw..(row + 1) * cw]
                    .copy_from_slice(self.sessions.carry_row(req.slot));
            }
            self.backend.infer(
                bucket,
                batch.active(),
                &self.obs_scratch,
                &mut self.carry_scratch,
                &mut self.act_scratch,
            )?;
            for (row, req) in batch.requests.iter().enumerate() {
                self.sessions.carry_row_mut(req.slot).copy_from_slice(
                    &self.carry_scratch[row * cw..(row + 1) * cw],
                );
                out.push(ActResponse {
                    session: req.session,
                    version: self.known_version,
                    actions: self.act_scratch[row * aw..(row + 1) * aw]
                        .to_vec(),
                });
            }
        }
        Ok(out)
    }
}
