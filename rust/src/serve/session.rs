//! Per-session slot allocation: session ids → rows of the recurrent
//! carry (DESIGN.md §12).
//!
//! A serving session owns one row of a `[max_sessions, carry_width]`
//! host-side carry table for as long as it is open. Session ids are
//! monotone and never reused, so a late frame for a closed session is
//! a typed [`ServeError::UnknownSession`] — it can never alias a new
//! session that happens to occupy the same slot. Closing a session
//! zeroes its carry row *before* the slot returns to the free list, so
//! the next session to land on that row starts from the exact state a
//! fresh recurrent episode starts from.

#![warn(missing_docs)]

/// Typed failure of the serve layer. Every client-visible error maps
/// to one of these — the service never panics on bad input.
#[derive(Debug, PartialEq, Eq)]
pub enum ServeError {
    /// All `serve_max_sessions` carry slots are in use.
    SlotsExhausted {
        /// The configured session cap that was hit.
        max: usize,
    },
    /// The session id is not open (never existed, or already closed).
    UnknownSession(u64),
    /// The request itself is malformed (wrong observation width…).
    BadRequest(String),
    /// The policy backend failed (artifact call, parameter reload…).
    Backend(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::SlotsExhausted { max } => write!(
                f,
                "all {max} serve sessions in use (raise \
                 serve_max_sessions)"
            ),
            ServeError::UnknownSession(id) => {
                write!(f, "unknown serve session {id}")
            }
            ServeError::BadRequest(msg) => {
                write!(f, "bad serve request: {msg}")
            }
            ServeError::Backend(msg) => {
                write!(f, "serve backend error: {msg}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// The session ↔ carry-row map: a fixed pool of `max_sessions` slots,
/// each backing one open session's recurrent carry row.
pub struct SessionTable {
    carry_width: usize,
    /// slot → open session id (`None` = free).
    slots: Vec<Option<u64>>,
    /// Free slot indices (stack; order is irrelevant because carry
    /// rows are zeroed on close).
    free: Vec<usize>,
    /// Next session id to hand out; ids are never reused.
    next_id: u64,
    /// Row-major `[max_sessions, carry_width]` recurrent carry.
    carry: Vec<f32>,
}

impl SessionTable {
    /// A table of `max_sessions` slots, each carrying `carry_width`
    /// f32s (0 for feedforward systems).
    pub fn new(max_sessions: usize, carry_width: usize) -> SessionTable {
        assert!(max_sessions >= 1, "serve needs at least one session");
        SessionTable {
            carry_width,
            slots: vec![None; max_sessions],
            free: (0..max_sessions).rev().collect(),
            next_id: 1,
            carry: vec![0.0; max_sessions * carry_width],
        }
    }

    /// The configured session cap.
    pub fn max_sessions(&self) -> usize {
        self.slots.len()
    }

    /// Per-session carry row width in f32s.
    pub fn carry_width(&self) -> usize {
        self.carry_width
    }

    /// Number of currently open sessions.
    pub fn open_count(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Open a session: allocate a slot and a fresh id. The slot's
    /// carry row is already zero (zeroed at close time).
    pub fn open(&mut self) -> Result<u64, ServeError> {
        let slot = self.free.pop().ok_or(ServeError::SlotsExhausted {
            max: self.slots.len(),
        })?;
        let id = self.next_id;
        self.next_id += 1;
        self.slots[slot] = Some(id);
        debug_assert!(
            self.carry_row(slot).iter().all(|&x| x == 0.0),
            "slot {slot} reused with a dirty carry row"
        );
        Ok(id)
    }

    /// The slot of an open session.
    pub fn slot(&self, session: u64) -> Result<usize, ServeError> {
        self.slots
            .iter()
            .position(|s| *s == Some(session))
            .ok_or(ServeError::UnknownSession(session))
    }

    /// Close a session: zero its carry row, then free the slot.
    /// Returns the freed slot index.
    pub fn close(&mut self, session: u64) -> Result<usize, ServeError> {
        let slot = self.slot(session)?;
        self.slots[slot] = None;
        self.carry_row_mut_raw(slot).fill(0.0);
        self.free.push(slot);
        Ok(slot)
    }

    /// Carry row of `slot` (length [`Self::carry_width`]).
    pub fn carry_row(&self, slot: usize) -> &[f32] {
        let w = self.carry_width;
        &self.carry[slot * w..(slot + 1) * w]
    }

    /// Mutable carry row of an *open* slot.
    pub fn carry_row_mut(&mut self, slot: usize) -> &mut [f32] {
        debug_assert!(
            self.slots[slot].is_some(),
            "writing the carry of a free slot"
        );
        self.carry_row_mut_raw(slot)
    }

    fn carry_row_mut_raw(&mut self, slot: usize) -> &mut [f32] {
        let w = self.carry_width;
        &mut self.carry[slot * w..(slot + 1) * w]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_monotone_and_never_reused() {
        let mut t = SessionTable::new(2, 3);
        let a = t.open().unwrap();
        let b = t.open().unwrap();
        assert_ne!(a, b);
        t.close(a).unwrap();
        let c = t.open().unwrap();
        assert!(c > b, "ids must never be reused");
        assert_eq!(t.slot(a), Err(ServeError::UnknownSession(a)));
    }

    #[test]
    fn exhaustion_is_typed_not_a_panic() {
        let mut t = SessionTable::new(1, 0);
        t.open().unwrap();
        assert_eq!(t.open(), Err(ServeError::SlotsExhausted { max: 1 }));
        assert_eq!(t.open_count(), 1);
    }

    #[test]
    fn close_zeroes_the_row_before_reuse() {
        let mut t = SessionTable::new(1, 4);
        let a = t.open().unwrap();
        let slot = t.slot(a).unwrap();
        t.carry_row_mut(slot).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        t.close(a).unwrap();
        let b = t.open().unwrap();
        let slot_b = t.slot(b).unwrap();
        assert_eq!(slot_b, slot, "single slot must be recycled");
        assert_eq!(t.carry_row(slot_b), &[0.0; 4]);
    }

    #[test]
    fn close_unknown_session_errors() {
        let mut t = SessionTable::new(2, 1);
        assert_eq!(t.close(99), Err(ServeError::UnknownSession(99)));
    }
}
