//! The TCP front-end of `mava serve` (DESIGN.md §12): frames in,
//! frames out, with all inference on one core thread.
//!
//! Threading model — three roles per the engine-per-thread rule
//! (PJRT artifacts are `Rc`-based, so the backend must be *built and
//! used* on a single thread):
//!
//! - **core ticker** (one): owns the [`ServeCore`] + backend
//!   (constructed on-thread via the factory passed to
//!   [`ServeService::bind`]). Waits on a command channel with a
//!   timeout bounded by the next batch deadline, applies commands,
//!   steps the core and routes responses to connection writers.
//! - **reader** (one per connection): parses frames and forwards
//!   typed commands to the ticker. A corrupt payload gets a typed
//!   error frame and the connection *survives* (the stream is still
//!   frame-aligned after a CRC failure); EOF/desync tears the
//!   connection down, closing its sessions so their carry slots free.
//! - **writer** (one per connection): drains an mpsc of pre-encoded
//!   frames into the socket, so responses and error replies from the
//!   ticker and the reader serialize without locking the stream.
//!
//! A response for a vanished connection is simply dropped — the rest
//! of its batch completes untouched.

#![warn(missing_docs)]

use std::collections::HashMap;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::net::frame::{
    frame_bytes, read_frame_polled, FrameError, FrameKind, POLL_INTERVAL,
};
use crate::net::param::{frame_err, spawn_accept_loop};
use crate::net::wire;
use crate::params::ParamStore;
use crate::serve::backend::PolicyBackend;
use crate::serve::clock::Clock;
use crate::serve::core::{ActResponse, ServeCore};

/// Commands connection readers send the core ticker.
enum ServeCmd {
    /// A new connection: register its writer channel.
    Register {
        conn: u64,
        tx: Sender<Vec<u8>>,
    },
    /// `SessionOpen` frame.
    Open { conn: u64 },
    /// `SessionClose` frame.
    Close { conn: u64, session: u64 },
    /// `ActRequest` frame.
    Act {
        conn: u64,
        session: u64,
        obs: Vec<f32>,
    },
    /// The connection died: close its sessions, drop its writer.
    Disconnect { conn: u64 },
}

/// A running serve listener. Dropping it (or calling
/// [`ServeService::shutdown`]) stops the accept loop, the core ticker
/// and every connection thread.
pub struct ServeService {
    addr: String,
    halt: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    ticker: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ServeService {
    /// Bind on `host` (ephemeral port) and serve the backend
    /// `make_backend` constructs **on the core thread** (the factory
    /// crosses the thread boundary; the backend never does). A factory
    /// error surfaces here, from `bind`, not as a dead service.
    pub fn bind<B, F>(
        host: &str,
        make_backend: F,
        clock: Arc<dyn Clock>,
        store: Option<Arc<dyn ParamStore>>,
        max_sessions: usize,
        deadline_us: u64,
    ) -> Result<ServeService>
    where
        B: PolicyBackend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let listener = TcpListener::bind((host, 0))
            .with_context(|| format!("bind serve service on {host}"))?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let halt = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));

        let (cmd_tx, cmd_rx) = mpsc::channel::<ServeCmd>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let ticker_clock = clock.clone();
        let ticker_halt = halt.clone();
        let ticker = std::thread::Builder::new()
            .name("mava-serve-core".into())
            .spawn(move || {
                let backend = match make_backend() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut core = ServeCore::new(
                    backend,
                    ticker_clock.clone(),
                    max_sessions,
                    deadline_us,
                );
                if let Some(store) = store {
                    core = core.with_store(store);
                }
                ticker_loop(core, cmd_rx, &ticker_clock, &ticker_halt);
            })
            .expect("spawn serve core thread");
        ready_rx
            .recv()
            .context("serve core thread died before reporting ready")??;

        // Sender<ServeCmd> is Clone + Send; the Mutex wrapper is only
        // there to hand each accepted connection its own clone from
        // the shared accept-loop closure.
        let cmd_tx = Arc::new(Mutex::new(cmd_tx));
        let conn_ids = Arc::new(AtomicU64::new(1));
        let conn_halt = halt.clone();
        let accept = spawn_accept_loop(
            listener,
            halt.clone(),
            conns.clone(),
            "mava-serve",
            move |stream| {
                let conn = conn_ids.fetch_add(1, Ordering::AcqRel);
                let tx = cmd_tx.lock().unwrap().clone();
                serve_conn(stream, conn, tx, &conn_halt);
            },
        );

        Ok(ServeService {
            addr,
            halt,
            accept: Some(accept),
            ticker: Some(ticker),
            conns,
        })
    }

    /// The bound `host:port` address clients connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Stop accepting, stop the core ticker, join every thread. The
    /// ticker is joined before the connection threads: each reader's
    /// writer drains only once the ticker has dropped its sender.
    pub fn shutdown(&mut self) {
        self.halt.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.ticker.take() {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in conns {
            let _ = h.join();
        }
    }
}

impl Drop for ServeService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Encode + enqueue one frame for a connection's writer. A failed
/// send means the connection is gone: the frame is dropped, nothing
/// else is affected.
fn send_frame(tx: &Sender<Vec<u8>>, kind: FrameKind, payload: &[u8]) {
    let _ = tx.send(frame_bytes(kind, payload));
}

fn send_error(tx: &Sender<Vec<u8>>, msg: &str) {
    let mut pay = Vec::new();
    wire::encode_error(msg, &mut pay);
    send_frame(tx, FrameKind::Error, &pay);
}

/// The core ticker: commands in, responses out, batches stepped in
/// between. Wakes at least every [`POLL_INTERVAL`] (to notice halt)
/// and exactly at the next batch deadline when one is pending.
fn ticker_loop<B: PolicyBackend>(
    mut core: ServeCore<B>,
    cmd_rx: Receiver<ServeCmd>,
    clock: &Arc<dyn Clock>,
    halt: &AtomicBool,
) {
    let mut conn_tx: HashMap<u64, Sender<Vec<u8>>> = HashMap::new();
    let mut session_conn: HashMap<u64, u64> = HashMap::new();
    let mut conn_sessions: HashMap<u64, Vec<u64>> = HashMap::new();
    let mut pay = Vec::new();
    loop {
        if halt.load(Ordering::Acquire) {
            break;
        }
        let timeout = core
            .next_deadline_us()
            .map(|d| {
                Duration::from_micros(d.saturating_sub(clock.now_us()))
            })
            .unwrap_or(POLL_INTERVAL)
            .min(POLL_INTERVAL);
        match cmd_rx.recv_timeout(timeout) {
            Ok(cmd) => {
                handle_cmd(
                    cmd,
                    &mut core,
                    &mut conn_tx,
                    &mut session_conn,
                    &mut conn_sessions,
                    &mut pay,
                );
                // drain whatever else arrived without blocking, so one
                // wake-up coalesces a burst into one batch decision
                while let Ok(cmd) = cmd_rx.try_recv() {
                    handle_cmd(
                        cmd,
                        &mut core,
                        &mut conn_tx,
                        &mut session_conn,
                        &mut conn_sessions,
                        &mut pay,
                    );
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        match core.step() {
            Ok(responses) => {
                for r in responses {
                    route_response(&r, &conn_tx, &session_conn, &mut pay);
                }
            }
            Err(e) => {
                // a failed batch consumed its requests: tell every
                // live client rather than leaving them waiting
                for tx in conn_tx.values() {
                    send_error(tx, &format!("inference step failed: {e}"));
                }
            }
        }
    }
}

fn handle_cmd<B: PolicyBackend>(
    cmd: ServeCmd,
    core: &mut ServeCore<B>,
    conn_tx: &mut HashMap<u64, Sender<Vec<u8>>>,
    session_conn: &mut HashMap<u64, u64>,
    conn_sessions: &mut HashMap<u64, Vec<u64>>,
    pay: &mut Vec<u8>,
) {
    match cmd {
        ServeCmd::Register { conn, tx } => {
            conn_tx.insert(conn, tx);
        }
        ServeCmd::Open { conn } => {
            let Some(tx) = conn_tx.get(&conn) else { return };
            match core.open_session() {
                Ok(id) => {
                    session_conn.insert(id, conn);
                    conn_sessions.entry(conn).or_default().push(id);
                    pay.clear();
                    wire::encode_u64(id, pay);
                    send_frame(tx, FrameKind::SessionOpened, pay);
                }
                Err(e) => send_error(tx, &e.to_string()),
            }
        }
        ServeCmd::Close { conn, session } => {
            let Some(tx) = conn_tx.get(&conn) else { return };
            if session_conn.get(&session) != Some(&conn) {
                send_error(
                    tx,
                    &format!("session {session} is not yours to close"),
                );
                return;
            }
            match core.close_session(session) {
                Ok(_dropped) => {
                    session_conn.remove(&session);
                    if let Some(s) = conn_sessions.get_mut(&conn) {
                        s.retain(|&id| id != session);
                    }
                    pay.clear();
                    wire::encode_u64(session, pay);
                    send_frame(tx, FrameKind::SessionClosed, pay);
                }
                Err(e) => send_error(tx, &e.to_string()),
            }
        }
        ServeCmd::Act { conn, session, obs } => {
            let Some(tx) = conn_tx.get(&conn) else { return };
            if session_conn.get(&session) != Some(&conn) {
                send_error(
                    tx,
                    &format!("session {session} is not yours to act in"),
                );
                return;
            }
            if let Err(e) = core.submit(session, obs) {
                send_error(tx, &e.to_string());
            }
        }
        ServeCmd::Disconnect { conn } => {
            conn_tx.remove(&conn);
            for session in conn_sessions.remove(&conn).unwrap_or_default() {
                session_conn.remove(&session);
                // closing drops the session's queued requests, so a
                // dead client's rows never reach the backend
                let _ = core.close_session(session);
            }
        }
    }
}

/// Deliver one response to the connection owning its session; both
/// lookups can fail (the client vanished mid-batch) and then this one
/// row is dropped while the rest of the batch delivers.
fn route_response(
    r: &ActResponse,
    conn_tx: &HashMap<u64, Sender<Vec<u8>>>,
    session_conn: &HashMap<u64, u64>,
    pay: &mut Vec<u8>,
) {
    let Some(conn) = session_conn.get(&r.session) else { return };
    let Some(tx) = conn_tx.get(conn) else { return };
    pay.clear();
    wire::encode_act_response(r.session, r.version, &r.actions, pay);
    send_frame(tx, FrameKind::ActResponse, pay);
}

/// One connection: spawn the writer, then parse frames until the
/// stream dies or the service halts.
fn serve_conn(
    mut stream: TcpStream,
    conn: u64,
    cmd_tx: Sender<ServeCmd>,
    halt: &AtomicBool,
) {
    let (wtx, wrx) = mpsc::channel::<Vec<u8>>();
    let mut wstream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let writer = std::thread::Builder::new()
        .name("mava-serve-writer".into())
        .spawn(move || {
            // exits when every sender (reader + ticker map entry) is
            // gone, or on the first failed write
            for buf in wrx {
                if wstream.write_all(&buf).is_err() {
                    break;
                }
            }
        })
        .expect("spawn serve writer thread");
    if cmd_tx
        .send(ServeCmd::Register { conn, tx: wtx.clone() })
        .is_err()
    {
        drop(wtx);
        let _ = writer.join();
        return;
    }

    let mut payload = Vec::new();
    loop {
        let kind = match read_frame_polled(&mut stream, &mut payload, &mut || {
            halt.load(Ordering::Acquire)
        }) {
            Ok(Some(kind)) => kind,
            // halted between frames, EOF, or a desynced stream: done
            Ok(None) => break,
            // a CRC failure leaves the stream frame-aligned (header +
            // declared payload were fully consumed): reply with a
            // typed error and keep serving this connection
            Err(e @ FrameError::Corrupt { .. }) => {
                send_error(&wtx, &e.to_string());
                continue;
            }
            Err(_) => break,
        };
        match kind {
            FrameKind::SessionOpen => {
                if cmd_tx.send(ServeCmd::Open { conn }).is_err() {
                    break;
                }
            }
            FrameKind::SessionClose => match wire::decode_u64(&payload) {
                Ok(session) => {
                    if cmd_tx
                        .send(ServeCmd::Close { conn, session })
                        .is_err()
                    {
                        break;
                    }
                }
                Err(e) => send_error(&wtx, &format!("bad close: {e:#}")),
            },
            FrameKind::ActRequest => {
                let mut obs = Vec::new();
                match wire::decode_act_request(&payload, &mut obs) {
                    Ok(session) => {
                        if cmd_tx
                            .send(ServeCmd::Act { conn, session, obs })
                            .is_err()
                        {
                            break;
                        }
                    }
                    Err(e) => send_error(
                        &wtx,
                        &format!("bad act request: {e:#}"),
                    ),
                }
            }
            FrameKind::Stop => break,
            other => send_error(
                &wtx,
                &format!("unexpected frame {other:?} on serve port"),
            ),
        }
    }
    let _ = cmd_tx.send(ServeCmd::Disconnect { conn });
    drop(cmd_tx);
    drop(wtx);
    let _ = writer.join();
}

/// A blocking client for the serve protocol — the test harness and
/// the `examples`-grade consumer of `mava serve`.
pub struct ServeClient {
    stream: TcpStream,
    payload: Vec<u8>,
}

impl ServeClient {
    /// Connect to a [`ServeService`] at `addr`.
    pub fn connect(addr: &str) -> Result<ServeClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect serve service {addr}"))?;
        stream.set_read_timeout(Some(POLL_INTERVAL))?;
        stream.set_nodelay(true)?;
        Ok(ServeClient { stream, payload: Vec::new() })
    }

    /// Send pre-encoded bytes as-is (fault-injection tests tear
    /// frames with this).
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.stream.write_all(bytes).context("serve client send")
    }

    /// Send one frame.
    pub fn send(&mut self, kind: FrameKind, payload: &[u8]) -> Result<()> {
        let buf = frame_bytes(kind, payload);
        self.send_raw(&buf)
    }

    /// Receive one frame within `timeout`; returns the kind, with the
    /// payload left in `self.payload`.
    pub fn recv(&mut self, timeout: Duration) -> Result<FrameKind> {
        let deadline = Instant::now() + timeout;
        match read_frame_polled(&mut self.stream, &mut self.payload, &mut || {
            Instant::now() >= deadline
        }) {
            Ok(Some(kind)) => Ok(kind),
            Ok(None) => bail!("serve reply timed out after {timeout:?}"),
            Err(e) => Err(frame_err(e, "serve reply")),
        }
    }

    /// The payload of the last received frame.
    pub fn last_payload(&self) -> &[u8] {
        &self.payload
    }

    fn bail_error(&self, got: FrameKind) -> anyhow::Error {
        if got == FrameKind::Error {
            if let Ok(msg) = wire::decode_error(&self.payload) {
                return anyhow::anyhow!("serve error: {msg}");
            }
        }
        anyhow::anyhow!("unexpected serve reply {got:?}")
    }

    /// Open a session; returns its id.
    pub fn open_session(&mut self, timeout: Duration) -> Result<u64> {
        self.send(FrameKind::SessionOpen, &[])?;
        match self.recv(timeout)? {
            FrameKind::SessionOpened => wire::decode_u64(&self.payload),
            other => Err(self.bail_error(other)),
        }
    }

    /// Close a session (acknowledged).
    pub fn close_session(
        &mut self,
        session: u64,
        timeout: Duration,
    ) -> Result<()> {
        let mut pay = Vec::new();
        wire::encode_u64(session, &mut pay);
        self.send(FrameKind::SessionClose, &pay)?;
        match self.recv(timeout)? {
            FrameKind::SessionClosed => Ok(()),
            other => Err(self.bail_error(other)),
        }
    }

    /// Fire an act request without waiting for the response.
    pub fn send_act(&mut self, session: u64, obs: &[f32]) -> Result<()> {
        let mut pay = Vec::new();
        wire::encode_act_request(session, obs, &mut pay);
        self.send(FrameKind::ActRequest, &pay)
    }

    /// One observation in, `(version, actions)` out.
    pub fn act(
        &mut self,
        session: u64,
        obs: &[f32],
        timeout: Duration,
    ) -> Result<(u64, Vec<i32>)> {
        self.send_act(session, obs)?;
        match self.recv(timeout)? {
            FrameKind::ActResponse => {
                let (got, version, actions) =
                    wire::decode_act_response(&self.payload)?;
                anyhow::ensure!(
                    got == session,
                    "response for session {got}, expected {session}"
                );
                Ok((version, actions))
            }
            other => Err(self.bail_error(other)),
        }
    }
}
