//! Deadline-based dynamic batching: the serve coalescing state machine
//! (DESIGN.md §12).
//!
//! Requests queue in arrival order. A batch flushes the moment either
//! trigger fires:
//!
//! - **full bucket** — the queue reaches the largest lowered bucket:
//!   flush `max_bucket` rows immediately (zero padding, zero added
//!   latency under load), or
//! - **deadline** — the *oldest* queued request has waited
//!   `deadline_us`: flush everything queued into the smallest lowered
//!   bucket that covers it (the `bucket - n` trailing rows are padding
//!   the executor masks out).
//!
//! The batcher is pure state + arithmetic over caller-supplied clock
//! readings — it never reads a real clock and never sleeps, which is
//! what makes every coalescing decision hermetically testable.

#![warn(missing_docs)]

use std::collections::VecDeque;

/// One queued act request, tagged with the carry slot its session owns
/// and the clock reading at enqueue time.
#[derive(Debug)]
pub struct PendingRequest {
    /// The session that sent it.
    pub session: u64,
    /// The session's carry slot (resolved at submit time).
    pub slot: usize,
    /// Flat `[N*O]` observation.
    pub obs: Vec<f32>,
    /// [`crate::serve::clock::Clock::now_us`] when the request queued;
    /// its deadline is `enqueued_us + deadline_us`.
    pub enqueued_us: u64,
}

/// One flushed batch: `requests.len()` real rows padded up to a
/// lowered `bucket` width.
#[derive(Debug)]
pub struct Batch {
    /// The lowered bucket width this batch executes at.
    pub bucket: usize,
    /// The real requests, in arrival order (rows `0..active()`).
    pub requests: Vec<PendingRequest>,
}

impl Batch {
    /// Number of real (non-padding) rows.
    pub fn active(&self) -> usize {
        self.requests.len()
    }

    /// Number of trailing padding rows the executor must mask.
    pub fn pad(&self) -> usize {
        self.bucket - self.requests.len()
    }
}

/// The coalescing queue. [`Batcher::poll`] is the whole state machine:
/// called with "now", it either returns the next batch to execute or
/// tells the caller (via [`Batcher::next_deadline_us`]) how long it
/// may sleep.
pub struct Batcher {
    /// Lowered bucket widths, ascending (from the artifact ladder).
    buckets: Vec<usize>,
    deadline_us: u64,
    queue: VecDeque<PendingRequest>,
}

impl Batcher {
    /// A batcher over the ascending lowered `buckets` with a
    /// `deadline_us` coalescing window.
    pub fn new(buckets: &[usize], deadline_us: u64) -> Batcher {
        assert!(!buckets.is_empty(), "serve needs a non-empty ladder");
        assert!(
            buckets.windows(2).all(|w| w[0] < w[1]),
            "bucket ladder must be strictly ascending"
        );
        Batcher {
            buckets: buckets.to_vec(),
            deadline_us,
            queue: VecDeque::new(),
        }
    }

    /// Largest lowered bucket (the full-batch flush trigger).
    pub fn max_bucket(&self) -> usize {
        *self.buckets.last().expect("ladder is never empty")
    }

    /// Number of queued requests.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Queue one request (arrival order is preserved end to end).
    pub fn submit(&mut self, req: PendingRequest) {
        self.queue.push_back(req);
    }

    /// Drop every queued request of `session` (the session closed or
    /// its connection died); returns how many were dropped. Their
    /// responses must never be emitted.
    pub fn drop_session(&mut self, session: u64) -> usize {
        let before = self.queue.len();
        self.queue.retain(|r| r.session != session);
        before - self.queue.len()
    }

    /// Absolute clock time at which the oldest queued request must
    /// flush, or `None` when the queue is empty. The caller sleeps at
    /// most until this instant.
    pub fn next_deadline_us(&self) -> Option<u64> {
        self.queue
            .front()
            .map(|r| r.enqueued_us.saturating_add(self.deadline_us))
    }

    /// Flush decision at clock reading `now_us`. Returns at most one
    /// batch; callers loop until `None` so a backlog of several full
    /// buckets drains in order.
    pub fn poll(&mut self, now_us: u64) -> Option<Batch> {
        let max = self.max_bucket();
        if self.queue.len() >= max {
            return Some(self.drain(max, max));
        }
        let deadline = self.next_deadline_us()?;
        if now_us >= deadline {
            let n = self.queue.len();
            let bucket = *self
                .buckets
                .iter()
                .find(|&&b| b >= n)
                .expect("n < max_bucket is always coverable");
            return Some(self.drain(n, bucket));
        }
        None
    }

    fn drain(&mut self, n: usize, bucket: usize) -> Batch {
        Batch {
            bucket,
            requests: self.queue.drain(..n).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(session: u64, at_us: u64) -> PendingRequest {
        PendingRequest {
            session,
            slot: session as usize,
            obs: vec![session as f32],
            enqueued_us: at_us,
        }
    }

    #[test]
    fn full_bucket_flushes_immediately() {
        let mut b = Batcher::new(&[1, 2, 4], 1_000);
        for i in 0..4 {
            b.submit(req(i, 0));
            if i < 3 {
                assert!(b.poll(0).is_none(), "partial must wait");
            }
        }
        let batch = b.poll(0).expect("full bucket flushes at once");
        assert_eq!(batch.bucket, 4);
        assert_eq!(batch.active(), 4);
        assert_eq!(batch.pad(), 0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_flush_picks_smallest_covering_bucket() {
        let mut b = Batcher::new(&[1, 2, 4, 8], 1_000);
        b.submit(req(0, 100));
        b.submit(req(1, 400));
        b.submit(req(2, 900));
        // deadline runs off the OLDEST request
        assert_eq!(b.next_deadline_us(), Some(1_100));
        assert!(b.poll(1_099).is_none());
        let batch = b.poll(1_100).expect("deadline reached");
        assert_eq!(batch.bucket, 4, "3 rows round up to bucket 4");
        assert_eq!(batch.active(), 3);
        assert_eq!(batch.pad(), 1);
        let order: Vec<u64> =
            batch.requests.iter().map(|r| r.session).collect();
        assert_eq!(order, vec![0, 1, 2], "arrival order preserved");
    }

    #[test]
    fn overflow_drains_in_bucket_sized_batches() {
        let mut b = Batcher::new(&[1, 2], 500);
        for i in 0..5 {
            b.submit(req(i, 0));
        }
        // two full buckets drain immediately, the odd request waits
        // for its deadline
        assert_eq!(b.poll(0).unwrap().active(), 2);
        assert_eq!(b.poll(0).unwrap().active(), 2);
        assert!(b.poll(0).is_none());
        assert_eq!(b.pending(), 1);
        let last = b.poll(500).unwrap();
        assert_eq!((last.active(), last.bucket), (1, 1));
    }

    #[test]
    fn drop_session_removes_only_that_sessions_rows() {
        let mut b = Batcher::new(&[8], 500);
        b.submit(req(1, 0));
        b.submit(req(2, 0));
        b.submit(req(1, 10));
        assert_eq!(b.drop_session(1), 2);
        assert_eq!(b.pending(), 1);
        let batch = b.poll(500).unwrap();
        assert_eq!(batch.requests[0].session, 2);
    }

    #[test]
    fn empty_queue_has_no_deadline() {
        let mut b = Batcher::new(&[4], 100);
        assert_eq!(b.next_deadline_us(), None);
        assert!(b.poll(u64::MAX).is_none());
    }
}
