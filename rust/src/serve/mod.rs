//! `mava serve`: a policy inference service with deadline-based
//! dynamic batching (DESIGN.md §12).
//!
//! The request-facing consumer of everything the training stack
//! produces: checkpoints (through the version-gated
//! [`crate::params::ParamStore`] seam), the lowered `_b{B}` policy
//! ladder ([`crate::runtime::BucketLadder`]) and the padding-masked
//! batched executor ([`crate::systems::VecExecutor`]). Concurrent
//! observation requests coalesce into the largest bucket reachable
//! within `serve_deadline_us`; each open session owns one row of the
//! recurrent carry for the lifetime of its episode.
//!
//! Layering (bottom-up), built so every batching/deadline/reload
//! decision tests hermetically — no artifacts, no sockets, no sleeps:
//!
//! - [`clock`] — the injected time source ([`MockClock`] in tests)
//! - [`session`] — session-id ↔ carry-slot allocation, typed
//!   [`ServeError`]
//! - [`batcher`] — the pure coalescing state machine
//! - [`backend`] — the [`PolicyBackend`] seam: [`MockBackend`]
//!   (hermetic) and [`EngineBackend`] (real artifacts)
//! - [`core`] — sessions + batcher + backend + hot-reload in one
//!   single-threaded [`ServeCore`]
//! - [`service`] — the TCP front-end on the [`crate::net`] frame
//!   codec, plus [`ServeClient`]

#![warn(missing_docs)]

pub mod backend;
pub mod batcher;
pub mod clock;
pub mod core;
pub mod service;
pub mod session;

pub use backend::{EngineBackend, MockBackend, MockCall, PolicyBackend};
pub use batcher::{Batch, Batcher, PendingRequest};
pub use clock::{Clock, MockClock, SystemClock};
pub use core::{ActResponse, ServeCore};
pub use service::{ServeClient, ServeService};
pub use session::{ServeError, SessionTable};
