//! The injected time source of the serve batcher (DESIGN.md §12).
//!
//! Every deadline decision in [`crate::serve`] reads time through
//! [`Clock`], so the whole coalescing state machine runs hermetically
//! under a [`MockClock`] in tests — deadline expiry is a `set_us`
//! call, never a real sleep. Production uses [`SystemClock`], a
//! monotonic microsecond counter anchored at service start.

#![warn(missing_docs)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic microsecond time source. Implementations must never go
/// backwards; the absolute epoch is arbitrary (only differences are
/// compared against `serve_deadline_us`).
pub trait Clock: Send + Sync {
    /// Microseconds since the clock's (arbitrary) epoch.
    fn now_us(&self) -> u64;
}

/// Wall-clock [`Clock`]: microseconds since construction, backed by
/// [`Instant`] so it is monotone under NTP step adjustments.
pub struct SystemClock {
    start: Instant,
}

impl SystemClock {
    /// A clock anchored at "now".
    pub fn new() -> SystemClock {
        SystemClock { start: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }
}

/// Manually advanced [`Clock`] for hermetic tests: time moves only
/// when the test says so.
pub struct MockClock {
    now_us: AtomicU64,
}

impl MockClock {
    /// A mock clock reading `start_us`.
    pub fn new(start_us: u64) -> MockClock {
        MockClock { now_us: AtomicU64::new(start_us) }
    }

    /// Jump to the absolute time `us` (must not move backwards).
    pub fn set_us(&self, us: u64) {
        debug_assert!(us >= self.now_us.load(Ordering::Acquire));
        self.now_us.store(us, Ordering::Release);
    }

    /// Advance by `us` microseconds.
    pub fn advance_us(&self, us: u64) {
        self.now_us.fetch_add(us, Ordering::AcqRel);
    }
}

impl Clock for MockClock {
    fn now_us(&self) -> u64 {
        self.now_us.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_clock_moves_only_on_command() {
        let c = MockClock::new(100);
        assert_eq!(c.now_us(), 100);
        assert_eq!(c.now_us(), 100);
        c.advance_us(50);
        assert_eq!(c.now_us(), 150);
        c.set_us(1_000);
        assert_eq!(c.now_us(), 1_000);
    }

    #[test]
    fn system_clock_is_monotone() {
        let c = SystemClock::new();
        let a = c.now_us();
        let b = c.now_us();
        assert!(b >= a);
    }
}
