//! The policy surface the batching core drives (DESIGN.md §12).
//!
//! [`PolicyBackend`] is the seam that makes the whole serve state
//! machine hermetically testable: [`crate::serve::core::ServeCore`]
//! only ever sees this trait, so the deadline/coalescing/reload suites
//! run against the deterministic [`MockBackend`] — no artifacts, no
//! PJRT. Production uses [`EngineBackend`], one
//! [`VecExecutor`] per lowered `_b{B}` bucket of the artifact ladder,
//! with the per-session recurrent carry gathered/scattered through
//! [`VecExecutor::import_carry`] / [`VecExecutor::export_carry`] and
//! padding rows masked by [`VecExecutor::set_active_rows`].

#![warn(missing_docs)]

use std::collections::HashMap;

use anyhow::Result;

use crate::core::{ActionSpec, EnvSpec};
use crate::env::{ActionBuf, VecStepBuf};
use crate::runtime::{BucketLadder, Engine};
use crate::serve::session::ServeError;
use crate::systems::{SystemKind, VecExecutor};

/// A batched, recurrent-carry-aware policy: the only thing the serve
/// core knows how to call.
///
/// Contract of [`PolicyBackend::infer`]: `obs` is `[bucket *
/// obs_width]` with padding rows zeroed, `carry` is `[bucket *
/// carry_width]` in/out (row `r` is the carry of the request in row
/// `r`), `actions` is `[bucket * act_width]` and the backend must
/// write **only** rows `0..active` — padding rows consume no RNG and
/// produce no actions.
pub trait PolicyBackend {
    /// Flat per-request observation width (`n_agents * obs_dim`).
    fn obs_width(&self) -> usize;

    /// Per-request action count (`n_agents`, one discrete action per
    /// agent).
    fn act_width(&self) -> usize;

    /// Per-session recurrent carry width in f32s (0 = feedforward).
    fn carry_width(&self) -> usize;

    /// Lowered bucket widths, ascending — the batcher's ladder.
    fn buckets(&self) -> &[usize];

    /// Run the policy for one padded batch (see trait docs for the
    /// buffer contract).
    fn infer(
        &mut self,
        bucket: usize,
        active: usize,
        obs: &[f32],
        carry: &mut [f32],
        actions: &mut [i32],
    ) -> Result<(), ServeError>;

    /// Swap in a new parameter blob (checkpoint hot-reload). Called
    /// only *between* batches, never mid-inference.
    fn set_params(
        &mut self,
        version: u64,
        params: &[f32],
    ) -> Result<(), ServeError>;
}

/// One recorded [`MockBackend::infer`] call, for asserting coalescing
/// decisions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MockCall {
    /// Bucket the batch executed at.
    pub bucket: usize,
    /// Real rows in the batch.
    pub active: usize,
    /// Parameter version the backend held during the call.
    pub version: u64,
}

/// Deterministic in-memory [`PolicyBackend`] for the hermetic suites.
///
/// Behaviour is arranged so tests can *prove* routing and masking:
/// every agent's action is the first observation element of its row
/// (so a response is traceable to the request that produced it), and
/// each call adds 1.0 to every active carry element (so the carry of a
/// session counts exactly how many times *that session* was inferred).
/// Padding rows are asserted untouched.
pub struct MockBackend {
    obs_width: usize,
    act_width: usize,
    carry_width: usize,
    buckets: Vec<usize>,
    version: u64,
    /// Last parameter blob installed via `set_params` (tests inspect
    /// it for torn reads).
    pub params: Vec<f32>,
    /// Every `infer` call in order.
    pub calls: Vec<MockCall>,
    /// When true, the next `infer` fails with a typed backend error
    /// (and clears the flag).
    pub fail_next: bool,
}

impl MockBackend {
    /// A mock policy with the given widths and bucket ladder.
    pub fn new(
        obs_width: usize,
        act_width: usize,
        carry_width: usize,
        buckets: &[usize],
    ) -> MockBackend {
        MockBackend {
            obs_width,
            act_width,
            carry_width,
            buckets: buckets.to_vec(),
            version: 0,
            params: Vec::new(),
            calls: Vec::new(),
            fail_next: false,
        }
    }
}

impl PolicyBackend for MockBackend {
    fn obs_width(&self) -> usize {
        self.obs_width
    }

    fn act_width(&self) -> usize {
        self.act_width
    }

    fn carry_width(&self) -> usize {
        self.carry_width
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn infer(
        &mut self,
        bucket: usize,
        active: usize,
        obs: &[f32],
        carry: &mut [f32],
        actions: &mut [i32],
    ) -> Result<(), ServeError> {
        if self.fail_next {
            self.fail_next = false;
            return Err(ServeError::Backend("injected mock failure".into()));
        }
        assert_eq!(obs.len(), bucket * self.obs_width);
        assert_eq!(carry.len(), bucket * self.carry_width);
        assert_eq!(actions.len(), bucket * self.act_width);
        assert!(active >= 1 && active <= bucket);
        assert!(
            obs[active * self.obs_width..].iter().all(|&x| x == 0.0),
            "padding observation rows must be zero"
        );
        self.calls.push(MockCall {
            bucket,
            active,
            version: self.version,
        });
        for row in 0..active {
            let a = obs[row * self.obs_width] as i32;
            actions[row * self.act_width..(row + 1) * self.act_width]
                .fill(a);
            for c in &mut carry
                [row * self.carry_width..(row + 1) * self.carry_width]
            {
                *c += 1.0;
            }
        }
        Ok(())
    }

    fn set_params(
        &mut self,
        version: u64,
        params: &[f32],
    ) -> Result<(), ServeError> {
        assert!(
            version > self.version,
            "hot-reload must be version-monotone ({} -> {version})",
            self.version
        );
        self.version = version;
        self.params.clear();
        self.params.extend_from_slice(params);
        Ok(())
    }
}

/// The real-engine [`PolicyBackend`]: one [`VecExecutor`] per lowered
/// bucket, all sharing one parameter blob, driven at `eps = 0`
/// (serving is greedy — exploration belongs to training executors).
///
/// Lives on the serve core thread (PJRT artifacts are
/// single-threaded `Rc`s), which is why [`PolicyBackend`] does not
/// require `Send` and the service constructs its backend *on* that
/// thread via a factory.
pub struct EngineBackend {
    buckets: Vec<usize>,
    execs: HashMap<usize, VecExecutor>,
    /// Reusable per-bucket obs/action staging buffers.
    bufs: HashMap<usize, (VecStepBuf, ActionBuf)>,
    obs_width: usize,
    act_width: usize,
    carry_width: usize,
    param_len: usize,
}

impl EngineBackend {
    /// Build an executor for every bucket of `ladder`, starting from
    /// `initial_params` (the artifact's `params0` blob or a
    /// checkpoint). Continuous-action systems are rejected: the serve
    /// wire format carries one discrete action per agent.
    pub fn new(
        engine: &mut Engine,
        kind: SystemKind,
        ladder: &BucketLadder,
        initial_params: Vec<f32>,
        seed: u64,
    ) -> Result<EngineBackend> {
        anyhow::ensure!(
            kind.discrete(),
            "mava serve only serves discrete-action systems \
             (the ActResponse wire format is one discrete action per \
             agent)"
        );
        let buckets = ladder.buckets().to_vec();
        let mut execs = HashMap::new();
        let mut bufs = HashMap::new();
        let mut dims = None;
        let mut carry_width = 0;
        for &b in &buckets {
            let artifact = engine.artifact(&ladder.artifact_name(b))?;
            let ex = VecExecutor::new(
                kind,
                artifact,
                initial_params.clone(),
                seed ^ (b as u64),
            )?;
            anyhow::ensure!(
                ex.num_envs() == b,
                "artifact {} lowered for batch {}, ladder says {b}",
                ladder.artifact_name(b),
                ex.num_envs()
            );
            carry_width = ex.carry_width();
            dims.get_or_insert((ex.n_agents(), ex.obs_dim(), ex.n_actions()));
            let (n, o, a) = dims.unwrap();
            let spec = EnvSpec {
                name: "serve".into(),
                n_agents: n,
                obs_dim: o,
                action: ActionSpec::Discrete { n: a },
                state_dim: 0,
                episode_limit: 0,
            };
            bufs.insert(
                b,
                (VecStepBuf::new(&spec, b, false), ActionBuf::new(&spec, b)),
            );
            execs.insert(b, ex);
        }
        let (n, o, _) = dims.expect("ladder is never empty");
        Ok(EngineBackend {
            buckets,
            execs,
            bufs,
            obs_width: n * o,
            act_width: n,
            carry_width,
            param_len: initial_params.len(),
        })
    }
}

impl PolicyBackend for EngineBackend {
    fn obs_width(&self) -> usize {
        self.obs_width
    }

    fn act_width(&self) -> usize {
        self.act_width
    }

    fn carry_width(&self) -> usize {
        self.carry_width
    }

    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn infer(
        &mut self,
        bucket: usize,
        active: usize,
        obs: &[f32],
        carry: &mut [f32],
        actions: &mut [i32],
    ) -> Result<(), ServeError> {
        let ex = self
            .execs
            .get_mut(&bucket)
            .ok_or_else(|| {
                ServeError::Backend(format!("no executor for bucket {bucket}"))
            })?;
        let (buf, abuf) = self.bufs.get_mut(&bucket).expect("bufs match execs");
        let run = || -> Result<()> {
            ex.set_active_rows(active)?;
            ex.import_carry(carry)?;
            buf.obs.as_f32_mut().copy_from_slice(obs);
            ex.select_actions_into(buf, 0.0, 0.0, abuf)?;
            ex.export_carry(carry)?;
            Ok(())
        };
        run().map_err(|e| ServeError::Backend(format!("{e:#}")))?;
        for row in 0..active {
            let w = self.act_width;
            actions[row * w..(row + 1) * w]
                .copy_from_slice(abuf.row(row).as_discrete());
        }
        Ok(())
    }

    fn set_params(
        &mut self,
        version: u64,
        params: &[f32],
    ) -> Result<(), ServeError> {
        if params.len() != self.param_len {
            return Err(ServeError::Backend(format!(
                "hot-reload blob has {} params, artifacts expect {}",
                params.len(),
                self.param_len
            )));
        }
        for ex in self.execs.values_mut() {
            ex.set_params(version, params);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_actions_trace_back_to_their_row() {
        let mut m = MockBackend::new(2, 3, 1, &[4]);
        let obs = [5.0, 0.5, 7.0, 0.5, 0.0, 0.0, 0.0, 0.0];
        let mut carry = [0.0; 4];
        let mut actions = [0; 12];
        m.infer(4, 2, &obs, &mut carry, &mut actions).unwrap();
        assert_eq!(&actions[..6], &[5, 5, 5, 7, 7, 7]);
        assert_eq!(&actions[6..], &[0; 6], "padding rows untouched");
        assert_eq!(carry, [1.0, 1.0, 0.0, 0.0]);
        assert_eq!(
            m.calls,
            vec![MockCall { bucket: 4, active: 2, version: 0 }]
        );
    }

    #[test]
    fn mock_fail_next_is_one_shot() {
        let mut m = MockBackend::new(1, 1, 0, &[1]);
        m.fail_next = true;
        let err = m
            .infer(1, 1, &[1.0], &mut [], &mut [0])
            .unwrap_err();
        assert!(matches!(err, ServeError::Backend(_)));
        m.infer(1, 1, &[1.0], &mut [], &mut [0]).unwrap();
        assert_eq!(m.calls.len(), 1);
    }
}
