//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Provides timed closures with warmup + simple statistics, a table
//! printer used by the figure-reproduction benches to emit the paper's
//! rows/series in a uniform format, and — in [`mod@report`] — the
//! versioned `BENCH_*.json` writer/validator that records the repo's
//! perf/quality trajectory (EXPERIMENTS.md).

#![warn(missing_docs)]

pub mod report;

use std::time::Instant;

/// Timing statistics in nanoseconds per iteration.
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    /// Timed iterations (warmup excluded).
    pub iters: u64,
    /// Mean ns per iteration.
    pub mean_ns: f64,
    /// Fastest iteration, ns.
    pub min_ns: f64,
    /// Slowest iteration, ns.
    pub max_ns: f64,
}

impl BenchStats {
    /// Mean iterations per second.
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn time<F: FnMut()>(warmup: u64, iters: u64, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut min = f64::INFINITY;
    let mut max = 0.0f64;
    let mut total = 0.0f64;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        let ns = t0.elapsed().as_nanos() as f64;
        total += ns;
        min = min.min(ns);
        max = max.max(ns);
    }
    BenchStats { iters: iters.max(1), mean_ns: total / iters.max(1) as f64, min_ns: min, max_ns: max }
}

/// Report one benchmark line in a stable grep-able format.
pub fn report(name: &str, stats: &BenchStats) {
    println!(
        "bench {name:<44} {:>12.0} ns/iter  ({:.1}/s, min {:.0}, max {:.0})",
        stats.mean_ns,
        stats.per_sec(),
        stats.min_ns,
        stats.max_ns
    );
}

/// Print a labelled table row of (x, series values) — the benches emit
/// the paper's figures as these rows.
pub fn curve_row(fig: &str, series: &str, x: f64, y: f64) {
    println!("curve {fig} {series} {x} {y}");
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Scale factor for bench workloads: `MAVA_BENCH_SCALE=4 cargo bench`
/// runs 4x longer curves (the EXPERIMENTS.md runs use larger scales).
pub fn scale() -> f64 {
    std::env::var("MAVA_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Run one training configuration and emit its learning curve as
/// `curve <fig> <series> <env_steps> <return>` rows (plus a walltime
/// variant `curvet` keyed on seconds) — the figure-reproduction benches
/// are built from these.
pub fn figure_run(
    fig: &str,
    series: &str,
    cfg: &crate::config::TrainConfig,
    deadline_s: u64,
) -> anyhow::Result<crate::systems::TrainResult> {
    let result = crate::systems::train(
        cfg,
        Some(std::time::Duration::from_secs(deadline_s)),
    )?;
    for e in &result.evals {
        curve_row(fig, series, e.env_steps as f64, e.mean_return as f64);
    }
    for e in &result.evals {
        println!(
            "curvet {fig} {series} {:.2} {:.4}",
            e.wall_s, e.mean_return
        );
    }
    println!(
        "summary {fig} {series} best={:.3} final_train={:.3} steps={} \
         train_steps={} wall_s={:.1}",
        // NaN marks "never evaluated" in the grep-able summary row
        result.best_return().unwrap_or(f32::NAN),
        result.train_return,
        result.env_steps,
        result.train_steps,
        result.wall_s
    );
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_sane() {
        let s = time(1, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.iters, 10);
        assert!(s.min_ns <= s.mean_ns && s.mean_ns <= s.max_ns);
        assert!(s.per_sec() > 0.0);
    }
}
