//! Machine-readable benchmark artifacts: the versioned `BENCH_*.json`
//! schema, its writer and its validator (EXPERIMENTS.md §2).
//!
//! Every experiment-harness run and throughput bench can serialise its
//! result as `BENCH_<scenario>.json` so the repo's perf/quality
//! trajectory is recorded in a greppable, diffable form. The offline
//! crate set has no serde, so this module carries a deliberately small
//! JSON value type ([`Json`]), a renderer, a recursive-descent parser
//! ([`parse`]) and a schema check ([`validate`]) — the same code path
//! the `mava check-bench` CLI subcommand and CI's
//! `make check-bench-schema` gate run.
//!
//! Schema v[`BENCH_SCHEMA_VERSION`], three report kinds sharing a
//! header:
//!
//! ```text
//! { "schema_version": 1,
//!   "kind": "experiment" | "throughput" | "latency",
//!   "scenario": "<file tag>", ... }
//! ```
//!
//! `experiment` reports add per-seed episode returns and the robust
//! aggregates of [`crate::eval::stats`]; `throughput` reports add a
//! flat `series` of named rates; `latency` reports (the `mava serve`
//! request-latency axis) add a `series` of named distributions with
//! request counts and p50/p99/mean microseconds. See EXPERIMENTS.md
//! for the full field tables.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::eval::stats::Aggregates;

/// Version stamped into (and required from) every `BENCH_*.json`.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// A JSON value (minimal, insertion-ordered objects).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (rendered via f64; non-finite becomes `null`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; key order is preserved as inserted.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Numeric view of this value.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// String view of this value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array view of this value.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Render as pretty-printed JSON text (2-space indent, stable field
    /// order — the files are meant to be diffed).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_into(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                out.push_str(if *b { "true" } else { "false" })
            }
            Json::Num(x) => {
                if x.is_finite() {
                    // `{}` on f64 prints integers without a fraction and
                    // round-trips doubles — both valid JSON numbers
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    item.write_into(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_into(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }
}

fn push_indent(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse JSON text into a [`Json`] value (full value; trailing
/// non-whitespace is an error).
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    ensure!(pos == bytes.len(), "trailing data at byte {pos}");
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    ensure!(*pos < b.len(), "unexpected end of input");
    match b[*pos] {
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                ensure!(*pos < b.len(), "unterminated array");
                match b[*pos] {
                    b',' => *pos += 1,
                    b']' => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    c => bail!("expected ',' or ']', got {:?}", c as char),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                ensure!(
                    *pos < b.len() && b[*pos] == b':',
                    "expected ':' after object key"
                );
                *pos += 1;
                fields.push((key, parse_value(b, pos)?));
                skip_ws(b, pos);
                ensure!(*pos < b.len(), "unterminated object");
                match b[*pos] {
                    b',' => *pos += 1,
                    b'}' => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    c => bail!("expected ',' or '}}', got {:?}", c as char),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    ensure!(
        b[*pos..].starts_with(lit.as_bytes()),
        "invalid literal at byte {pos}"
    );
    *pos += lit.len();
    Ok(v)
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    ensure!(
        *pos < b.len() && b[*pos] == b'"',
        "expected string at byte {pos}"
    );
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                ensure!(*pos < b.len(), "dangling escape");
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        ensure!(*pos + 4 < b.len(), "short \\u escape");
                        let hex =
                            std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)
                            .context("bad \\u escape")?;
                        // surrogate pairs are not needed by our writer;
                        // map unpaired surrogates to the replacement char
                        out.push(
                            char::from_u32(code).unwrap_or('\u{fffd}'),
                        );
                        *pos += 4;
                    }
                    c => bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let rest = std::str::from_utf8(&b[*pos..])?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    bail!("unterminated string")
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    let x: f64 = text
        .parse()
        .with_context(|| format!("bad number {text:?} at byte {start}"))?;
    Ok(Json::Num(x))
}

/// One seed's contribution to an experiment report.
#[derive(Clone, Debug)]
pub struct SeedRecord {
    /// RNG seed the run used.
    pub seed: u64,
    /// Greedy evaluation episode returns of the final policy.
    pub returns: Vec<f32>,
    /// Environment steps the run executed.
    pub env_steps: u64,
    /// Trainer steps the run executed.
    pub train_steps: u64,
    /// Wall-clock seconds of the run.
    pub wall_s: f64,
}

impl SeedRecord {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("seed".into(), Json::Num(self.seed as f64)),
            (
                "mean_return".into(),
                Json::Num(crate::eval::stats::mean(&self.returns)),
            ),
            (
                "returns".into(),
                Json::Arr(
                    self.returns
                        .iter()
                        .map(|&r| Json::Num(r as f64))
                        .collect(),
                ),
            ),
            ("env_steps".into(), Json::Num(self.env_steps as f64)),
            ("train_steps".into(), Json::Num(self.train_steps as f64)),
            ("wall_s".into(), Json::Num(self.wall_s)),
            (
                "env_steps_per_s".into(),
                Json::Num(self.env_steps as f64 / self.wall_s.max(1e-9)),
            ),
        ])
    }
}

fn ci_json(lo: f64, hi: f64) -> Json {
    Json::Arr(vec![Json::Num(lo), Json::Num(hi)])
}

fn header(kind: &str, scenario: &str) -> Vec<(String, Json)> {
    vec![
        (
            "schema_version".into(),
            Json::Num(BENCH_SCHEMA_VERSION as f64),
        ),
        ("kind".into(), Json::Str(kind.into())),
        ("scenario".into(), Json::Str(scenario.into())),
    ]
}

/// Build a schema-valid `experiment` report (the multi-seed harness
/// output for one scenario).
#[allow(clippy::too_many_arguments)] // mirrors the schema field list
pub fn experiment_report(
    scenario: &str,
    system: &str,
    preset: &str,
    eval_episodes: usize,
    max_env_steps: u64,
    seeds: &[SeedRecord],
    agg: &Aggregates,
) -> Json {
    let mut fields = header("experiment", scenario);
    fields.push(("system".into(), Json::Str(system.into())));
    fields.push(("preset".into(), Json::Str(preset.into())));
    fields.push((
        "eval_episodes".into(),
        Json::Num(eval_episodes as f64),
    ));
    fields.push((
        "max_env_steps".into(),
        Json::Num(max_env_steps as f64),
    ));
    fields.push((
        "seeds".into(),
        Json::Arr(seeds.iter().map(SeedRecord::to_json).collect()),
    ));
    fields.push((
        "aggregate".into(),
        Json::Obj(vec![
            (
                "per_seed_means".into(),
                Json::Arr(
                    agg.per_seed_means
                        .iter()
                        .map(|&m| Json::Num(m))
                        .collect(),
                ),
            ),
            ("mean".into(), Json::Num(agg.mean)),
            ("iqm".into(), Json::Num(agg.iqm)),
            ("mean_ci".into(), ci_json(agg.mean_ci.lo, agg.mean_ci.hi)),
            ("iqm_ci".into(), ci_json(agg.iqm_ci.lo, agg.iqm_ci.hi)),
            ("confidence".into(), Json::Num(agg.mean_ci.confidence)),
            (
                "bootstrap_resamples".into(),
                Json::Num(agg.mean_ci.resamples as f64),
            ),
        ]),
    ));
    Json::Obj(fields)
}

/// One named rate in a `throughput` report, with the optional
/// data-parallel / bucketed-lowering axes (DESIGN.md §11): `devices`
/// is the device-lane count the rate was measured at, `bucket` the
/// lowered policy-batch bucket serving the run. Both are omitted from
/// the JSON when `None`, so reports without the axes stay byte-stable.
#[derive(Clone, Debug)]
pub struct ThroughputRow {
    /// Series entry name (unique within the report by convention).
    pub name: String,
    /// The measured rate.
    pub value: f64,
    /// Unit string, e.g. `"env_steps/s"`.
    pub unit: String,
    /// Device-lane count axis (`num_devices`), when measured.
    pub devices: Option<u64>,
    /// Policy bucket-size axis, when measured.
    pub bucket: Option<u64>,
}

impl ThroughputRow {
    /// Row without the optional axes.
    pub fn new(
        name: impl Into<String>,
        value: f64,
        unit: impl Into<String>,
    ) -> ThroughputRow {
        ThroughputRow {
            name: name.into(),
            value,
            unit: unit.into(),
            devices: None,
            bucket: None,
        }
    }

    /// Attach the device-count axis.
    pub fn with_devices(mut self, d: u64) -> ThroughputRow {
        self.devices = Some(d);
        self
    }

    /// Attach the bucket-size axis.
    pub fn with_bucket(mut self, b: u64) -> ThroughputRow {
        self.bucket = Some(b);
        self
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("value".into(), Json::Num(self.value)),
            ("unit".into(), Json::Str(self.unit.clone())),
        ];
        if let Some(d) = self.devices {
            fields.push(("devices".into(), Json::Num(d as f64)));
        }
        if let Some(b) = self.bucket {
            fields.push(("bucket".into(), Json::Num(b as f64)));
        }
        Json::Obj(fields)
    }
}

/// Build a schema-valid `throughput` report from named `(name, value,
/// unit)` series rows — the writer the steps/s benches share with the
/// experiment harness. Use [`throughput_report_rows`] to also record
/// the `devices` / `bucket` axes.
pub fn throughput_report(
    scenario: &str,
    series: &[(String, f64, String)],
) -> Json {
    let rows: Vec<ThroughputRow> = series
        .iter()
        .map(|(n, v, u)| ThroughputRow::new(n.clone(), *v, u.clone()))
        .collect();
    throughput_report_rows(scenario, &rows)
}

/// [`throughput_report`] over full [`ThroughputRow`]s (optional
/// `devices` / `bucket` axes included).
pub fn throughput_report_rows(
    scenario: &str,
    series: &[ThroughputRow],
) -> Json {
    let mut fields = header("throughput", scenario);
    fields.push((
        "series".into(),
        Json::Arr(series.iter().map(ThroughputRow::to_json).collect()),
    ));
    Json::Obj(fields)
}

/// One named latency distribution in a `latency` report (`mava
/// serve`'s request-latency axis): `count` requests measured, with
/// the p50/p99/mean of their end-to-end latency in microseconds.
#[derive(Clone, Debug)]
pub struct LatencyRow {
    /// Series entry name, e.g. `"load_4_clients"`.
    pub name: String,
    /// Number of requests the distribution summarises.
    pub count: u64,
    /// Median latency in microseconds.
    pub p50_us: f64,
    /// 99th-percentile latency in microseconds.
    pub p99_us: f64,
    /// Mean latency in microseconds.
    pub mean_us: f64,
}

impl LatencyRow {
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("count".into(), Json::Num(self.count as f64)),
            ("p50_us".into(), Json::Num(self.p50_us)),
            ("p99_us".into(), Json::Num(self.p99_us)),
            ("mean_us".into(), Json::Num(self.mean_us)),
        ])
    }
}

/// Build a schema-valid `latency` report from per-load-level
/// distributions — the writer `benches/serve_latency.rs` uses.
pub fn latency_report(scenario: &str, series: &[LatencyRow]) -> Json {
    let mut fields = header("latency", scenario);
    fields.push((
        "series".into(),
        Json::Arr(series.iter().map(LatencyRow::to_json).collect()),
    ));
    Json::Obj(fields)
}

/// Write a validated report as `<dir>/BENCH_<scenario>.json`; returns
/// the path. Refuses to write a report that fails [`validate`] — the
/// schema gate runs at write time, not just in CI.
pub fn write_report(dir: &Path, scenario: &str, report: &Json) -> Result<PathBuf> {
    validate(report).with_context(|| {
        format!("refusing to write schema-invalid report for {scenario:?}")
    })?;
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create {}", dir.display()))?;
    let path = dir.join(format!("BENCH_{scenario}.json"));
    std::fs::write(&path, report.render())
        .with_context(|| format!("write {}", path.display()))?;
    Ok(path)
}

fn require<'a>(v: &'a Json, key: &str) -> Result<&'a Json> {
    v.get(key).with_context(|| format!("missing field {key:?}"))
}

fn require_num(v: &Json, key: &str) -> Result<f64> {
    require(v, key)?
        .as_num()
        .with_context(|| format!("field {key:?} must be a number"))
}

fn require_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    require(v, key)?
        .as_str()
        .with_context(|| format!("field {key:?} must be a string"))
}

fn require_arr<'a>(v: &'a Json, key: &str) -> Result<&'a [Json]> {
    require(v, key)?
        .as_arr()
        .with_context(|| format!("field {key:?} must be an array"))
}

fn check_ci_pair(agg: &Json, key: &str) -> Result<()> {
    let ci = require_arr(agg, key)?;
    ensure!(ci.len() == 2, "{key} must be [lo, hi]");
    let (lo, hi) = (
        ci[0].as_num().with_context(|| format!("{key}[0] not a number"))?,
        ci[1].as_num().with_context(|| format!("{key}[1] not a number"))?,
    );
    ensure!(lo <= hi, "{key}: lo {lo} > hi {hi}");
    Ok(())
}

/// Validate a parsed `BENCH_*.json` value against the schema.
pub fn validate(report: &Json) -> Result<()> {
    let version = require_num(report, "schema_version")?;
    ensure!(
        version == BENCH_SCHEMA_VERSION as f64,
        "schema_version {version} != supported {BENCH_SCHEMA_VERSION}"
    );
    require_str(report, "scenario")?;
    match require_str(report, "kind")? {
        "experiment" => {
            require_str(report, "system")?;
            require_str(report, "preset")?;
            require_num(report, "eval_episodes")?;
            require_num(report, "max_env_steps")?;
            let seeds = require_arr(report, "seeds")?;
            ensure!(!seeds.is_empty(), "seeds must be non-empty");
            for (i, s) in seeds.iter().enumerate() {
                let ctx = || format!("seeds[{i}]");
                require_num(s, "seed").with_context(ctx)?;
                require_num(s, "mean_return").with_context(ctx)?;
                let returns = require_arr(s, "returns").with_context(ctx)?;
                ensure!(
                    !returns.is_empty()
                        && returns.iter().all(|r| r.as_num().is_some()),
                    "seeds[{i}].returns must be non-empty numbers"
                );
                require_num(s, "env_steps").with_context(ctx)?;
                require_num(s, "train_steps").with_context(ctx)?;
                require_num(s, "wall_s").with_context(ctx)?;
                require_num(s, "env_steps_per_s").with_context(ctx)?;
            }
            let agg = require(report, "aggregate")?;
            let per_seed = require_arr(agg, "per_seed_means")?;
            ensure!(
                per_seed.len() == seeds.len(),
                "per_seed_means length {} != seeds length {}",
                per_seed.len(),
                seeds.len()
            );
            require_num(agg, "mean")?;
            require_num(agg, "iqm")?;
            check_ci_pair(agg, "mean_ci")?;
            check_ci_pair(agg, "iqm_ci")?;
            let conf = require_num(agg, "confidence")?;
            ensure!(
                (0.0..1.0).contains(&conf),
                "confidence {conf} outside (0, 1)"
            );
            require_num(agg, "bootstrap_resamples")?;
        }
        "throughput" => {
            let series = require_arr(report, "series")?;
            ensure!(!series.is_empty(), "series must be non-empty");
            for (i, row) in series.iter().enumerate() {
                let ctx = || format!("series[{i}]");
                require_str(row, "name").with_context(ctx)?;
                require_num(row, "value").with_context(ctx)?;
                require_str(row, "unit").with_context(ctx)?;
                // optional axes: device-lane count and bucket size
                // must be whole numbers >= 1 when present
                for axis in ["devices", "bucket"] {
                    if let Some(v) = row.get(axis) {
                        let x = v.as_num().with_context(|| {
                            format!("series[{i}].{axis} must be a number")
                        })?;
                        ensure!(
                            x >= 1.0 && x.fract() == 0.0,
                            "series[{i}].{axis} must be a whole number \
                             >= 1, got {x}"
                        );
                    }
                }
            }
        }
        "latency" => {
            let series = require_arr(report, "series")?;
            ensure!(!series.is_empty(), "series must be non-empty");
            for (i, row) in series.iter().enumerate() {
                let ctx = || format!("series[{i}]");
                require_str(row, "name").with_context(ctx)?;
                let count = require_num(row, "count").with_context(ctx)?;
                ensure!(
                    count >= 1.0 && count.fract() == 0.0,
                    "series[{i}].count must be a whole number >= 1, \
                     got {count}"
                );
                let p50 = require_num(row, "p50_us").with_context(ctx)?;
                let p99 = require_num(row, "p99_us").with_context(ctx)?;
                require_num(row, "mean_us").with_context(ctx)?;
                ensure!(
                    p50 >= 0.0 && p50 <= p99,
                    "series[{i}]: need 0 <= p50 ({p50}) <= p99 ({p99})"
                );
            }
        }
        other => bail!("unknown report kind {other:?}"),
    }
    Ok(())
}

/// Parse and validate a `BENCH_*.json` file on disk.
pub fn validate_file(path: &Path) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let json =
        parse(&text).with_context(|| format!("parse {}", path.display()))?;
    validate(&json).with_context(|| format!("validate {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::stats;

    fn sample_seeds() -> Vec<SeedRecord> {
        vec![
            SeedRecord {
                seed: 42,
                returns: vec![1.0, 2.0, 3.0],
                env_steps: 1000,
                train_steps: 200,
                wall_s: 2.0,
            },
            SeedRecord {
                seed: 1042,
                returns: vec![2.0, 2.5, 3.5],
                env_steps: 1000,
                train_steps: 190,
                wall_s: 2.1,
            },
        ]
    }

    fn sample_report() -> Json {
        let seeds = sample_seeds();
        let per_seed: Vec<Vec<f32>> =
            seeds.iter().map(|s| s.returns.clone()).collect();
        let agg = stats::aggregate(&per_seed, 0.95, 200, 9);
        experiment_report(
            "matrix2_madqn",
            "madqn",
            "matrix2",
            3,
            1000,
            &seeds,
            &agg,
        )
    }

    #[test]
    fn render_parse_roundtrip() {
        let report = sample_report();
        let text = report.render();
        let back = parse(&text).unwrap();
        assert_eq!(report, back);
        // escaping round-trips too
        let tricky = Json::Obj(vec![(
            "k\"ey\n".into(),
            Json::Str("a\\b\t\u{1}ü".into()),
        )]);
        assert_eq!(parse(&tricky.render()).unwrap(), tricky);
    }

    #[test]
    fn writer_output_is_schema_valid() {
        validate(&sample_report()).unwrap();
        let tp = throughput_report(
            "trainer_throughput",
            &[("host".into(), 120.0, "steps/s".into())],
        );
        validate(&tp).unwrap();
    }

    #[test]
    fn throughput_axes_roundtrip_and_validate() {
        let rows = [
            ThroughputRow::new("train_dp2", 900.0, "train_steps/s")
                .with_devices(2),
            ThroughputRow::new("acting_n3", 5000.0, "env_steps/s")
                .with_bucket(4),
            ThroughputRow::new("plain", 1.0, "steps/s"),
        ];
        let json = throughput_report_rows("axes", &rows);
        validate(&json).unwrap();
        let back = parse(&json.render()).unwrap();
        let series = back.get("series").unwrap().as_arr().unwrap();
        assert_eq!(series[0].get("devices").unwrap().as_num(), Some(2.0));
        assert_eq!(series[1].get("bucket").unwrap().as_num(), Some(4.0));
        assert!(series[2].get("devices").is_none());
        // a zero or fractional axis is rejected
        for bad_axis in ["\"devices\": 0", "\"devices\": 1.5"] {
            let bad = parse(
                &json
                    .render()
                    .replace("\"devices\": 2", bad_axis),
            )
            .unwrap();
            assert!(validate(&bad).is_err(), "{bad_axis} must fail");
        }
    }

    #[test]
    fn latency_report_validates_and_gates() {
        let rows = [
            LatencyRow {
                name: "load_1".into(),
                count: 100,
                p50_us: 250.0,
                p99_us: 900.0,
                mean_us: 300.0,
            },
            LatencyRow {
                name: "load_8".into(),
                count: 800,
                p50_us: 400.0,
                p99_us: 2_000.0,
                mean_us: 520.0,
            },
        ];
        let json = latency_report("serve_latency", &rows);
        validate(&json).unwrap();
        let back = parse(&json.render()).unwrap();
        assert_eq!(back.get("kind").unwrap().as_str(), Some("latency"));
        let series = back.get("series").unwrap().as_arr().unwrap();
        assert_eq!(series[0].get("count").unwrap().as_num(), Some(100.0));
        // p50 > p99 is rejected
        let bad = parse(
            &json.render().replace("\"p50_us\": 250", "\"p50_us\": 9999"),
        )
        .unwrap();
        assert!(validate(&bad).is_err(), "inverted percentiles must fail");
        // fractional request count is rejected
        let bad = parse(
            &json.render().replace("\"count\": 100", "\"count\": 1.5"),
        )
        .unwrap();
        assert!(validate(&bad).is_err(), "fractional count must fail");
        // empty series is rejected
        assert!(validate(&latency_report("empty", &[])).is_err());
    }

    #[test]
    fn validation_rejects_malformed_reports() {
        // wrong version
        let mut bad = sample_report();
        if let Json::Obj(fields) = &mut bad {
            fields[0].1 = Json::Num(999.0);
        }
        assert!(validate(&bad).is_err());
        // missing aggregate
        let mut bad = sample_report();
        if let Json::Obj(fields) = &mut bad {
            fields.retain(|(k, _)| k != "aggregate");
        }
        assert!(validate(&bad).is_err());
        // unknown kind
        let mut bad = sample_report();
        if let Json::Obj(fields) = &mut bad {
            fields[1].1 = Json::Str("bogus".into());
        }
        assert!(validate(&bad).is_err());
        // inverted CI
        let bad = parse(
            &sample_report()
                .render()
                .replace("\"mean_ci\": [", "\"mean_ci\": [9999999,"),
        );
        // the replace yields a 3-element array -> must fail validation
        assert!(validate(&bad.unwrap()).is_err());
        // not an object at all
        assert!(validate(&Json::Num(1.0)).is_err());
    }

    #[test]
    fn write_report_emits_and_gates() {
        let dir = std::env::temp_dir().join("mava_test_bench_report");
        let path = write_report(&dir, "unit_test", &sample_report()).unwrap();
        assert!(path.ends_with("BENCH_unit_test.json"));
        validate_file(&path).unwrap();
        // schema-invalid reports never reach disk
        let err = write_report(&dir, "bad", &Json::Obj(vec![]));
        assert!(err.is_err());
        assert!(!dir.join("BENCH_bad.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parser_rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("nule").is_err());
    }

    #[test]
    fn numbers_render_as_valid_json() {
        assert_eq!(Json::Num(3.0).render().trim(), "3");
        assert_eq!(Json::Num(f64::NAN).render().trim(), "null");
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
    }
}
