//! The replay table: a bounded, thread-safe item store with pluggable
//! sampling, FIFO eviction and blocking flow control.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::replay::{RateLimiter, Selector, SumTree};
use crate::rng::Rng;

/// A single environment transition, flattened for batch assembly.
/// `obs`/`next_obs` are `[N*O]`; exactly one of the action fields is
/// non-empty depending on the action space.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Transition {
    /// Stacked per-agent observations `[N*O]`.
    pub obs: Vec<f32>,
    /// Global state (empty when the preset has none).
    pub state: Vec<f32>,
    /// Discrete joint action `[N]` (empty for continuous systems).
    pub actions_disc: Vec<i32>,
    /// Continuous joint action `[N*A]` (empty for discrete systems).
    pub actions_cont: Vec<f32>,
    /// Per-agent (n-step) rewards `[N]`.
    pub rewards: Vec<f32>,
    /// Bootstrap discount (0.0 at terminal steps).
    pub discount: f32,
    /// Stacked next observations `[N*O]`.
    pub next_obs: Vec<f32>,
    /// Next global state.
    pub next_state: Vec<f32>,
}

/// A fixed-length (padded) trajectory slice for recurrent training.
/// `obs` holds T+1 steps (`[(T+1)*N*O]`), the rest T steps; `mask[t]`
/// is 1.0 for valid steps.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Sequence {
    /// Window length `T` (steps, excluding the trailing observation).
    pub t: usize,
    /// Stacked observations `[(T+1)*N*O]`.
    pub obs: Vec<f32>,
    /// Discrete joint actions `[T*N]`.
    pub actions: Vec<i32>,
    /// Per-agent rewards `[T*N]` (team rewards replicated).
    pub rewards: Vec<f32>,
    /// Per-step discounts `[T]`.
    pub discounts: Vec<f32>,
    /// 1.0 for valid steps, 0.0 for padding `[T]`.
    pub mask: Vec<f32>,
}

/// A stored replay item: one transition or one sequence window.
#[derive(Clone, Debug, PartialEq)]
pub enum Item {
    /// A flattened (n-step) transition.
    Transition(Transition),
    /// A fixed-length padded trajectory window.
    Sequence(Sequence),
}

impl Item {
    /// Borrow as a transition; panics on sequence items.
    pub fn as_transition(&self) -> &Transition {
        match self {
            Item::Transition(t) => t,
            _ => panic!("expected transition item"),
        }
    }

    /// Borrow as a sequence; panics on transition items.
    pub fn as_sequence(&self) -> &Sequence {
        match self {
            Item::Sequence(s) => s,
            _ => panic!("expected sequence item"),
        }
    }
}

/// Lifetime counters of one table (or the aggregate over shards).
#[derive(Clone, Copy, Debug, Default)]
pub struct TableStats {
    /// Items currently stored.
    pub size: usize,
    /// Lifetime inserts.
    pub inserts: u64,
    /// Lifetime sample *calls* (a call may return many items).
    pub samples: u64,
    /// Lifetime FIFO evictions.
    pub evictions: u64,
}

struct Inner {
    items: VecDeque<Item>,
    /// ring slot of items[0] within the sum-tree
    head_slot: usize,
    tree: SumTree,
    rng: Rng,
    stats: TableStats,
}

/// Thread-safe replay table (one Reverb table).
pub struct Table {
    max_size: usize,
    selector: Selector,
    limiter: RateLimiter,
    priority_exponent: f64,
    inner: Mutex<Inner>,
    cv: Condvar,
    closed: AtomicBool,
}

impl Table {
    /// A table holding at most `max_size` items with the given
    /// selector and rate limiter.
    pub fn new(
        max_size: usize,
        selector: Selector,
        limiter: RateLimiter,
        seed: u64,
    ) -> Self {
        assert!(max_size > 0);
        Table {
            max_size,
            selector,
            limiter,
            priority_exponent: 0.6,
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(max_size),
                head_slot: 0,
                tree: SumTree::new(max_size),
                rng: Rng::new(seed),
                stats: TableStats::default(),
            }),
            cv: Condvar::new(),
            closed: AtomicBool::new(false),
        }
    }

    /// Uniform table with a min-size limiter (the common configuration).
    pub fn uniform(max_size: usize, min_size: usize, seed: u64) -> Self {
        Table::new(
            max_size,
            Selector::Uniform,
            RateLimiter::min_size(min_size),
            seed,
        )
    }

    /// Current counters (size, inserts, samples, evictions).
    pub fn stats(&self) -> TableStats {
        let mut inner = self.inner.lock().unwrap();
        inner.stats.size = inner.items.len();
        inner.stats
    }

    /// Whether [`Table::close`] was called.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Unblock all waiters; subsequent blocking calls return None/false.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    fn slot_of(&self, inner: &Inner, index: usize) -> usize {
        (inner.head_slot + index) % self.max_size
    }

    /// Insert with priority, blocking while the rate limiter forbids it.
    /// Returns false if the table was closed while waiting.
    pub fn insert(&self, item: Item, priority: f64) -> bool {
        self.insert_reuse(item, priority).0
    }

    /// [`Table::insert`] that additionally hands the FIFO-evicted item
    /// (if the table was at capacity) back to the caller, so adders can
    /// recycle its buffers instead of allocating fresh ones — the
    /// steady-state insert path of the allocation-free vector step
    /// (DESIGN.md §6). Returns `(accepted, evicted)`; `accepted` is
    /// false (and the evicted slot `None`) when the table closed while
    /// waiting.
    pub fn insert_reuse(
        &self,
        item: Item,
        priority: f64,
    ) -> (bool, Option<Item>) {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if self.is_closed() {
                return (false, None);
            }
            let st = inner.stats;
            if self.limiter.can_insert(st.inserts, st.samples) {
                break;
            }
            let (guard, timeout) = self
                .cv
                .wait_timeout(inner, Duration::from_millis(50))
                .unwrap();
            inner = guard;
            let _ = timeout;
        }
        let mut evicted = None;
        if inner.items.len() == self.max_size {
            evicted = inner.items.pop_front();
            let slot = inner.head_slot;
            inner.tree.set(slot, 0.0);
            inner.head_slot = (inner.head_slot + 1) % self.max_size;
            inner.stats.evictions += 1;
        }
        let index = inner.items.len();
        let slot = self.slot_of(&inner, index);
        inner.items.push_back(item);
        let pri = priority.max(1e-6).powf(self.priority_exponent);
        inner.tree.set(slot, pri);
        inner.stats.inserts += 1;
        drop(inner);
        self.cv.notify_all();
        (true, evicted)
    }

    /// Copy of every stored item, oldest first (checkpointing).
    pub fn snapshot(&self) -> Vec<Item> {
        let inner = self.inner.lock().unwrap();
        inner.items.iter().cloned().collect()
    }

    /// Non-blocking: true when a sample would currently be admitted.
    pub fn can_sample(&self) -> bool {
        let inner = self.inner.lock().unwrap();
        let st = inner.stats;
        !inner.items.is_empty()
            && self.limiter.can_sample(st.inserts, st.samples)
    }

    /// Sample `n` items (with replacement), blocking until the limiter
    /// admits it. Returns None if the table is closed.
    pub fn sample(&self, n: usize) -> Option<Vec<Item>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if self.is_closed() {
                return None;
            }
            let st = inner.stats;
            if !inner.items.is_empty()
                && self.limiter.can_sample(st.inserts, st.samples)
            {
                break;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(inner, Duration::from_millis(50))
                .unwrap();
            inner = guard;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let len = inner.items.len();
            let index = match self.selector {
                Selector::Uniform => inner.rng.below(len),
                Selector::Fifo => 0,
                Selector::Lifo => len - 1,
                Selector::Prioritized => {
                    let inner = &mut *inner;
                    let slot = inner.tree.sample(&mut inner.rng);
                    (slot + self.max_size - inner.head_slot) % self.max_size
                }
            };
            out.push(inner.items[index].clone());
            if self.selector == Selector::Fifo {
                // queue semantics: consume the item
                inner.items.pop_front();
                let slot = inner.head_slot;
                inner.tree.set(slot, 0.0);
                inner.head_slot = (inner.head_slot + 1) % self.max_size;
                if inner.items.is_empty() {
                    inner.stats.samples += 1;
                    break;
                }
            }
        }
        inner.stats.samples += 1;
        drop(inner);
        self.cv.notify_all();
        Some(out)
    }
}

/// Where adders put finished items: a local [`Table`] or a remote
/// replay shard ([`crate::net::replay::RemoteShardClient`]). Mirrors
/// the insert half of the table API, including the evicted-item
/// recycling of [`Table::insert_reuse`].
pub trait ItemSink: Send + Sync {
    /// Insert one item; returns `(accepted, recyclable)` exactly like
    /// [`Table::insert_reuse`] — `recyclable` is an item whose buffers
    /// the caller may reuse for the next insert.
    fn insert_item_reuse(
        &self,
        item: Item,
        priority: f64,
    ) -> (bool, Option<Item>);

    /// Non-blocking health probe: `Err` when the sink is permanently
    /// gone (e.g. a remote shard disconnected) and the writing node
    /// should fail rather than spin on rejected inserts.
    fn check(&self) -> anyhow::Result<()> {
        Ok(())
    }
}

impl ItemSink for Table {
    fn insert_item_reuse(
        &self,
        item: Item,
        priority: f64,
    ) -> (bool, Option<Item>) {
        self.insert_reuse(item, priority)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn item(v: f32) -> Item {
        Item::Transition(Transition { obs: vec![v], ..Default::default() })
    }

    fn val(i: &Item) -> f32 {
        i.as_transition().obs[0]
    }

    #[test]
    fn insert_and_uniform_sample() {
        let t = Table::uniform(8, 1, 0);
        for i in 0..5 {
            assert!(t.insert(item(i as f32), 1.0));
        }
        let s = t.sample(16).unwrap();
        assert_eq!(s.len(), 16);
        for it in &s {
            assert!((0.0..5.0).contains(&val(it)));
        }
        assert_eq!(t.stats().inserts, 5);
    }

    #[test]
    fn insert_reuse_returns_evicted_item() {
        let t = Table::uniform(2, 1, 0);
        assert_eq!(t.insert_reuse(item(0.0), 1.0), (true, None));
        assert_eq!(t.insert_reuse(item(1.0), 1.0).1.map(|i| val(&i)), None);
        let (ok, ev) = t.insert_reuse(item(2.0), 1.0);
        assert!(ok);
        assert_eq!(ev.map(|i| val(&i)), Some(0.0), "oldest item recycled");
        assert_eq!(t.stats().evictions, 1);
    }

    #[test]
    fn fifo_eviction_at_capacity() {
        let t = Table::uniform(3, 1, 0);
        for i in 0..5 {
            t.insert(item(i as f32), 1.0);
        }
        let st = t.stats();
        assert_eq!(st.size, 3);
        assert_eq!(st.evictions, 2);
        // only items 2,3,4 remain
        for it in t.sample(32).unwrap() {
            assert!(val(&it) >= 2.0);
        }
    }

    #[test]
    fn lifo_returns_newest() {
        let t = Table::new(
            8,
            Selector::Lifo,
            RateLimiter::min_size(1),
            0,
        );
        for i in 0..4 {
            t.insert(item(i as f32), 1.0);
        }
        let s = t.sample(1).unwrap();
        assert_eq!(val(&s[0]), 3.0);
    }

    #[test]
    fn fifo_consumes_like_a_queue() {
        let t = Table::new(8, Selector::Fifo, RateLimiter::min_size(1), 0);
        for i in 0..3 {
            t.insert(item(i as f32), 1.0);
        }
        let a = t.sample(1).unwrap();
        let b = t.sample(1).unwrap();
        assert_eq!(val(&a[0]), 0.0);
        assert_eq!(val(&b[0]), 1.0);
        assert_eq!(t.stats().size, 1);
    }

    #[test]
    fn prioritized_prefers_high_priority() {
        let t = Table::new(
            64,
            Selector::Prioritized,
            RateLimiter::min_size(1),
            7,
        );
        t.insert(item(0.0), 0.01);
        t.insert(item(1.0), 100.0);
        let s = t.sample(200).unwrap();
        let high = s.iter().filter(|i| val(i) == 1.0).count();
        assert!(high > 150, "high-priority sampled {high}/200");
    }

    #[test]
    fn prioritized_survives_eviction_wraparound() {
        let t = Table::new(
            4,
            Selector::Prioritized,
            RateLimiter::min_size(1),
            9,
        );
        for i in 0..11 {
            t.insert(item(i as f32), 1.0);
        }
        // slots wrapped nearly three times; samples must come from 7..=10
        for it in t.sample(64).unwrap() {
            assert!(val(&it) >= 7.0, "stale item {:?}", val(&it));
        }
    }

    #[test]
    fn sample_blocks_until_min_size() {
        let t = Arc::new(Table::uniform(16, 4, 0));
        let t2 = t.clone();
        let h = std::thread::spawn(move || t2.sample(2));
        std::thread::sleep(Duration::from_millis(30));
        for i in 0..4 {
            t.insert(item(i as f32), 1.0);
        }
        let got = h.join().unwrap();
        assert_eq!(got.unwrap().len(), 2);
    }

    #[test]
    fn close_unblocks_sampler() {
        let t = Arc::new(Table::uniform(16, 100, 0));
        let t2 = t.clone();
        let h = std::thread::spawn(move || t2.sample(1));
        std::thread::sleep(Duration::from_millis(20));
        t.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn ratio_limiter_throttles_sampler() {
        // 1 sample per insert, tight buffer: a sampler thread must
        // interleave with the inserter rather than running ahead.
        let t = Arc::new(Table::new(
            1024,
            Selector::Uniform,
            RateLimiter::SampleToInsertRatio {
                ratio: 1.0,
                min_size: 1,
                error_buffer: 2.0,
            },
            0,
        ));
        let t2 = t.clone();
        let sampler = std::thread::spawn(move || {
            let mut n = 0;
            while t2.sample(1).is_some() {
                n += 1;
            }
            n
        });
        for i in 0..50 {
            assert!(t.insert(item(i as f32), 1.0));
            std::thread::sleep(Duration::from_micros(200));
        }
        std::thread::sleep(Duration::from_millis(50));
        let st = t.stats();
        t.close();
        let sampled: u64 = sampler.join().unwrap();
        assert!(sampled >= st.inserts - 2, "sampler starved: {sampled}");
        assert!(
            (sampled as f64) <= st.inserts as f64 + 3.0,
            "sampler ran ahead: {sampled} vs {}",
            st.inserts
        );
    }
}
