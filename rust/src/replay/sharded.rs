//! Sharded replay: one [`Table`] shard per executor so the insert hot
//! path never contends on a shared lock (DESIGN.md §5).
//!
//! The seed design funnelled every executor through a single
//! `Mutex<Inner>`; with `num_executors × num_envs_per_executor` inserts
//! per vector step that mutex serialises the whole acting fleet. A
//! [`ShardedTable`] gives each executor its own shard (its own mutex,
//! condvar and rate limiter) and the trainer samples the shards
//! round-robin — each [`ShardedTable::sample`] call draws a full batch
//! from the next ready shard, so batches stay shard-coherent and the
//! trainer still consumes every executor's data at the pinned
//! samples-per-insert rate.
//!
//! Rate limiting aggregates across shards by construction: each shard
//! runs the global limiter scaled by [`RateLimiter::per_shard`]
//! (min-size and error-buffer divided by the shard count, ratio
//! unchanged). Round-robin sampling sends each shard `1/K` of the sample
//! calls while each shard receives `1/K` of the inserts, so every
//! shard-local `samples/inserts` ratio — and therefore the aggregate
//! ratio — stays pinned to the configured value. The min-size warm-up
//! is additionally enforced on the *aggregate* insert count (per-shard
//! scaling alone would let training start on `min_size/K` experiences
//! when startup insert rates are skewed toward one executor).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::replay::{Item, RateLimiter, Selector, Table, TableStats};

/// Anything a [`crate::systems::Trainer`] can draw sample batches from:
/// a single [`Table`] or a [`ShardedTable`].
pub trait ItemSource {
    /// Draw `n` items, blocking until flow control admits the sample.
    /// Returns `None` once the source is closed (shutdown).
    fn sample_batch(&self, n: usize) -> Option<Vec<Item>>;
}

impl ItemSource for Table {
    fn sample_batch(&self, n: usize) -> Option<Vec<Item>> {
        self.sample(n)
    }
}

impl<S: ItemSource + ?Sized> ItemSource for Arc<S> {
    fn sample_batch(&self, n: usize) -> Option<Vec<Item>> {
        (**self).sample_batch(n)
    }
}

impl ItemSource for ShardedTable {
    fn sample_batch(&self, n: usize) -> Option<Vec<Item>> {
        self.sample(n)
    }
}

impl RateLimiter {
    /// Scale a table-global limiter down to one of `k` shards: min-size
    /// is split (ceiling) across shards, the sample:insert ratio is
    /// unchanged (it is a per-shard *and* aggregate invariant under
    /// round-robin), and the error buffer is split with a floor of two
    /// sample calls so shards never wedge on rounding.
    pub fn per_shard(self, k: usize) -> RateLimiter {
        let k = k.max(1);
        match self {
            RateLimiter::MinSize { min_size } => {
                RateLimiter::MinSize { min_size: min_size.div_ceil(k) }
            }
            RateLimiter::SampleToInsertRatio {
                ratio,
                min_size,
                error_buffer,
            } => RateLimiter::SampleToInsertRatio {
                ratio,
                min_size: min_size.div_ceil(k),
                error_buffer: (error_buffer / k as f64).max(2.0),
            },
        }
    }
}

/// A replay table split into `K` independently locked shards.
///
/// Executor `k` inserts through its own shard handle ([`Self::shard`]),
/// so the acting-path insert never blocks on other executors. The
/// trainer samples the aggregate via [`Self::sample`]. All shards share
/// the selector/limiter configuration (limiter scaled per shard) and
/// split the total capacity evenly.
pub struct ShardedTable {
    shards: Vec<Arc<Table>>,
    /// next shard the round-robin sampler prefers
    cursor: AtomicUsize,
    /// next shard a convenience [`Self::insert`] targets
    insert_cursor: AtomicUsize,
    /// aggregate warm-up gate: no sample is admitted before this many
    /// total inserts across all shards (the *global* limiter min-size,
    /// which per-shard scaling alone cannot guarantee under skewed
    /// startup insert rates)
    min_inserts: u64,
    /// latched once the warm-up gate opens — inserts only grow, so
    /// after opening, samplers skip the cross-shard stats() scan
    warmed: AtomicBool,
}

impl ShardedTable {
    /// Build `num_shards` shards splitting `total_capacity` evenly.
    /// `limiter` is the *global* flow-control policy; it is scaled with
    /// [`RateLimiter::per_shard`] internally.
    pub fn new(
        num_shards: usize,
        total_capacity: usize,
        selector: Selector,
        limiter: RateLimiter,
        seed: u64,
    ) -> Self {
        let k = num_shards.max(1);
        let per_shard = (total_capacity / k).max(1);
        let shard_limiter = limiter.per_shard(k);
        let min_inserts = match limiter {
            RateLimiter::MinSize { min_size } => min_size as u64,
            RateLimiter::SampleToInsertRatio { min_size, .. } => {
                min_size as u64
            }
        };
        let shards = (0..k)
            .map(|i| {
                Arc::new(Table::new(
                    per_shard,
                    selector,
                    shard_limiter,
                    seed.wrapping_add(0x9e37_79b9 * i as u64),
                ))
            })
            .collect();
        ShardedTable {
            shards,
            cursor: AtomicUsize::new(0),
            insert_cursor: AtomicUsize::new(0),
            min_inserts,
            warmed: AtomicBool::new(min_inserts == 0),
        }
    }

    /// Wrap one existing table as a single-shard view (benches/tests).
    /// The wrapped table's own limiter governs; no aggregate gate.
    pub fn single(table: Arc<Table>) -> Self {
        ShardedTable {
            shards: vec![table],
            cursor: AtomicUsize::new(0),
            insert_cursor: AtomicUsize::new(0),
            min_inserts: 0,
            warmed: AtomicBool::new(true),
        }
    }

    /// One-way warm-up gate: false until `min_inserts` total inserts
    /// were observed, then latched true (so steady-state samplers
    /// never pay the cross-shard stats() scan again).
    fn warmed_up(&self) -> bool {
        if self.warmed.load(Ordering::Relaxed) {
            return true;
        }
        if self.stats().inserts >= self.min_inserts {
            self.warmed.store(true, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard `i`'s insert handle — hand one to each executor's adder.
    pub fn shard(&self, i: usize) -> Arc<Table> {
        self.shards[i % self.shards.len()].clone()
    }

    /// Aggregate statistics summed over every shard.
    pub fn stats(&self) -> TableStats {
        let mut agg = TableStats::default();
        for s in &self.shards {
            let st = s.stats();
            agg.size += st.size;
            agg.inserts += st.inserts;
            agg.samples += st.samples;
            agg.evictions += st.evictions;
        }
        agg
    }

    /// Close every shard, unblocking all waiters.
    pub fn close(&self) {
        for s in &self.shards {
            s.close();
        }
    }

    /// True once every shard is closed.
    pub fn is_closed(&self) -> bool {
        self.shards.iter().all(|s| s.is_closed())
    }

    /// True when the aggregate warm-up gate is open and some *live*
    /// shard would currently admit a sample (closed shards — e.g. a
    /// remote shard that disconnected — no longer count).
    pub fn can_sample(&self) -> bool {
        self.warmed_up()
            && self
                .shards
                .iter()
                .any(|s| !s.is_closed() && s.can_sample())
    }

    /// Round-robin convenience insert (tests, checkpoint restore);
    /// executors should insert through their own [`Self::shard`] handle
    /// instead.
    pub fn insert(&self, item: Item, priority: f64) -> bool {
        let i = self.insert_cursor.fetch_add(1, Ordering::Relaxed)
            % self.shards.len();
        self.shards[i].insert(item, priority)
    }

    /// Draw one batch of `n` items from the next ready shard
    /// (round-robin with skip-ahead: a stalled shard never blocks the
    /// trainer while another shard has admissible data). No sample is
    /// admitted before `min_size` *total* inserts across shards, so the
    /// configured warm-up holds even under skewed startup insert rates.
    /// Blocks until some shard admits the sample; returns `None` after
    /// [`Self::close`].
    ///
    /// Waiting is a 2 ms poll rather than a cross-shard condvar: each
    /// probe takes K uncontended shard locks for ~ns each, and in the
    /// steady state the ratio limiter paces the trainer anyway, so the
    /// poll costs well under a percent of a core — the trade for
    /// keeping shards fully independent on the insert hot path.
    pub fn sample(&self, n: usize) -> Option<Vec<Item>> {
        loop {
            if self.warmed_up() {
                let start = self.cursor.load(Ordering::Relaxed);
                for k in 0..self.shards.len() {
                    let idx = (start + k) % self.shards.len();
                    let shard = &self.shards[idx];
                    // A shard that went away mid-run (closed, e.g. a
                    // remote disconnect) is skipped: the aggregate
                    // degrades to the survivors instead of ending the
                    // whole source.
                    if shard.is_closed() || !shard.can_sample() {
                        continue;
                    }
                    self.cursor.store(
                        (idx + 1) % self.shards.len(),
                        Ordering::Relaxed,
                    );
                    // the shard may still block briefly if a racing
                    // sampler drained it (its own limiter arbitrates),
                    // or close under us — fall through to survivors.
                    match shard.sample(n) {
                        Some(batch) => return Some(batch),
                        None => continue,
                    }
                }
            }
            if self.is_closed() {
                return None;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::Transition;

    fn item(v: f32) -> Item {
        Item::Transition(Transition { obs: vec![v], ..Default::default() })
    }

    fn val(i: &Item) -> f32 {
        i.as_transition().obs[0]
    }

    #[test]
    fn per_shard_limiter_scaling() {
        let l = RateLimiter::MinSize { min_size: 10 }.per_shard(4);
        match l {
            RateLimiter::MinSize { min_size } => assert_eq!(min_size, 3),
            _ => panic!(),
        }
        let l = RateLimiter::SampleToInsertRatio {
            ratio: 2.0,
            min_size: 100,
            error_buffer: 40.0,
        }
        .per_shard(4);
        match l {
            RateLimiter::SampleToInsertRatio { ratio, min_size, error_buffer } => {
                assert_eq!(ratio, 2.0);
                assert_eq!(min_size, 25);
                assert_eq!(error_buffer, 10.0);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn round_robin_visits_every_ready_shard() {
        let t = ShardedTable::new(
            3,
            30,
            Selector::Uniform,
            RateLimiter::min_size(3),
            0,
        );
        // shard k holds values k*10..k*10+3 (inserted via shard handles,
        // as executors do)
        for k in 0..3 {
            let shard = t.shard(k);
            for j in 0..3 {
                assert!(shard.insert(item((k * 10 + j) as f32), 1.0));
            }
        }
        assert_eq!(t.stats().inserts, 9);
        // each sample call draws a shard-coherent batch; three calls
        // visit three distinct shards
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            let batch = t.sample(4).unwrap();
            let shard_of = (val(&batch[0]) / 10.0) as i32;
            for it in &batch {
                assert_eq!((val(it) / 10.0) as i32, shard_of);
            }
            seen.insert(shard_of);
        }
        assert_eq!(seen.len(), 3, "round-robin skipped a shard: {seen:?}");
    }

    #[test]
    fn skip_ahead_bypasses_starved_shard() {
        // shard 0 stays empty; sampling must not deadlock on it
        let t = ShardedTable::new(
            2,
            16,
            Selector::Uniform,
            RateLimiter::min_size(2),
            1,
        );
        let shard1 = t.shard(1);
        shard1.insert(item(1.0), 1.0);
        shard1.insert(item(2.0), 1.0);
        for _ in 0..4 {
            let batch = t.sample(2).unwrap();
            assert!(batch.iter().all(|i| val(i) >= 1.0));
        }
    }

    #[test]
    fn aggregate_min_size_gates_skewed_startup() {
        // global min 8 over 4 shards (per-shard min 2): one shard
        // racing ahead must NOT open sampling before 8 TOTAL inserts.
        let t = ShardedTable::new(
            4,
            64,
            Selector::Uniform,
            RateLimiter::min_size(8),
            5,
        );
        let fast = t.shard(0);
        for j in 0..4 {
            fast.insert(item(j as f32), 1.0);
        }
        assert!(
            !t.can_sample(),
            "sampling opened on 4/8 aggregate inserts"
        );
        // spread the remaining warm-up across other shards
        for k in 1..=4 {
            t.shard(k % 4).insert(item((10 + k) as f32), 1.0);
        }
        assert!(t.can_sample());
        assert_eq!(t.sample(2).unwrap().len(), 2);
    }

    #[test]
    fn close_unblocks_sampler() {
        let t = Arc::new(ShardedTable::new(
            2,
            16,
            Selector::Uniform,
            RateLimiter::min_size(100),
            2,
        ));
        let t2 = t.clone();
        let h = std::thread::spawn(move || t2.sample(1));
        std::thread::sleep(Duration::from_millis(20));
        t.close();
        assert!(h.join().unwrap().is_none());
        assert!(t.is_closed());
    }

    #[test]
    fn lost_shard_degrades_to_survivors() {
        // A shard going away mid-run (remote disconnect → close) must
        // not end the aggregate source: sampling continues from the
        // survivors, and only once every shard is gone does sample()
        // return None.
        let t = ShardedTable::new(
            3,
            48,
            Selector::Uniform,
            RateLimiter::min_size(2),
            4,
        );
        for k in 0..3 {
            let shard = t.shard(k);
            for j in 0..4 {
                assert!(shard.insert(item((k * 10 + j) as f32), 1.0));
            }
        }
        // lose shard 1 while it still holds items
        t.shard(1).close();
        assert!(t.can_sample(), "survivors should still admit samples");
        for _ in 0..8 {
            let batch = t.sample(2).expect("survivors must keep serving");
            for it in &batch {
                let shard_of = (val(it) / 10.0) as i32;
                assert_ne!(shard_of, 1, "sampled from a closed shard");
            }
        }
        assert!(!t.is_closed(), "aggregate not closed while shards live");
        // losing the rest ends the source
        t.close();
        assert!(t.sample(1).is_none());
        assert!(!t.can_sample());
    }

    #[test]
    fn concurrent_shard_inserts_do_not_contend_on_sampling() {
        // 4 inserter threads (one per shard) + 1 round-robin sampler;
        // ratio limiter pins aggregate samples ~ inserts.
        let t = Arc::new(ShardedTable::new(
            4,
            4096,
            Selector::Uniform,
            RateLimiter::SampleToInsertRatio {
                ratio: 1.0,
                min_size: 4,
                error_buffer: 8.0,
            },
            3,
        ));
        let sampler = {
            let t = t.clone();
            std::thread::spawn(move || {
                let mut n = 0u64;
                while t.sample(1).is_some() {
                    n += 1;
                }
                n
            })
        };
        let writers: Vec<_> = (0..4)
            .map(|k| {
                let shard = t.shard(k);
                std::thread::spawn(move || {
                    for j in 0..100 {
                        if !shard.insert(item((k * 1000 + j) as f32), 1.0) {
                            break;
                        }
                    }
                })
            })
            .collect();
        for w in writers {
            w.join().unwrap();
        }
        // let the sampler catch up to the limiter bound, then shut down
        std::thread::sleep(Duration::from_millis(100));
        let st = t.stats();
        t.close();
        let sampled = sampler.join().unwrap();
        assert_eq!(st.inserts, 400);
        assert!(
            sampled as f64 >= st.inserts as f64 - 8.0 * 4.0,
            "sampler starved: {sampled} of {}",
            st.inserts
        );
        assert!(
            sampled as f64 <= st.inserts as f64 + 8.0 * 4.0,
            "sampler overran: {sampled} of {}",
            st.inserts
        );
    }
}
