//! Rate limiters: Reverb's insert/sample flow control.
//!
//! `SampleToInsertRatio` is the one that matters for distributed training
//! (paper Fig 6 bottom-right): it pins the number of times each item is
//! sampled on average, so adding executors genuinely increases data
//! throughput instead of letting the trainer oversample a small buffer.

/// Decides whether an insert/sample may proceed given table statistics.
#[derive(Clone, Copy, Debug)]
pub enum RateLimiter {
    /// Sampling allowed once at least `min_size` items were inserted;
    /// inserts are never blocked.
    MinSize { min_size: usize },
    /// Keep `samples / inserts` near `ratio` once `min_size` is reached,
    /// within a tolerance of `error_buffer` samples.
    SampleToInsertRatio {
        ratio: f64,
        min_size: usize,
        error_buffer: f64,
    },
}

impl RateLimiter {
    /// Sampling gated only on a minimum table size.
    pub fn min_size(min_size: usize) -> Self {
        RateLimiter::MinSize { min_size }
    }

    /// Pin samples/inserts to `ratio` with a Reverb-style slack
    /// buffer derived from `min_size`.
    pub fn sample_to_insert(ratio: f64, min_size: usize) -> Self {
        RateLimiter::SampleToInsertRatio {
            ratio,
            min_size,
            // Reverb default-ish: allow a couple of batches of slack
            error_buffer: (ratio * min_size as f64).max(2.0 * ratio),
        }
    }

    /// May a sample proceed given lifetime (inserts, samples)?
    pub fn can_sample(&self, inserts: u64, samples: u64) -> bool {
        match *self {
            RateLimiter::MinSize { min_size } => inserts >= min_size as u64,
            RateLimiter::SampleToInsertRatio { ratio, min_size, error_buffer } => {
                if inserts < min_size as u64 {
                    return false;
                }
                // samples may run ahead of ratio*inserts by error_buffer
                (samples as f64) < ratio * inserts as f64 + error_buffer
            }
        }
    }

    /// May an insert proceed given lifetime (inserts, samples)?
    pub fn can_insert(&self, inserts: u64, samples: u64) -> bool {
        match *self {
            RateLimiter::MinSize { .. } => true,
            RateLimiter::SampleToInsertRatio { ratio, min_size, error_buffer } => {
                if inserts < min_size as u64 {
                    return true;
                }
                // inserts may run ahead of samples/ratio by error_buffer/ratio
                ratio * (inserts as f64)
                    < samples as f64 + error_buffer.max(1.0) * ratio
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_size_blocks_until_filled() {
        let l = RateLimiter::min_size(10);
        assert!(!l.can_sample(9, 0));
        assert!(l.can_sample(10, 0));
        assert!(l.can_insert(0, 0));
        assert!(l.can_insert(1_000_000, 0));
    }

    #[test]
    fn ratio_blocks_oversampling() {
        let l = RateLimiter::SampleToInsertRatio {
            ratio: 2.0,
            min_size: 10,
            error_buffer: 4.0,
        };
        assert!(!l.can_sample(5, 0), "below min size");
        assert!(l.can_sample(10, 0));
        // at 10 inserts, sampling allowed up to 2*10+4 = 24 samples
        assert!(l.can_sample(10, 23));
        assert!(!l.can_sample(10, 24));
        // more inserts unblock sampling
        assert!(l.can_sample(20, 24));
    }

    #[test]
    fn ratio_blocks_overinserting() {
        let l = RateLimiter::SampleToInsertRatio {
            ratio: 2.0,
            min_size: 4,
            error_buffer: 4.0,
        };
        // before min_size inserts always allowed
        assert!(l.can_insert(3, 0));
        // 2*inserts must stay below samples + 4*2
        assert!(l.can_insert(4, 1)); // 8 < 1+8
        assert!(!l.can_insert(5, 1)); // 10 !< 9
        assert!(l.can_insert(5, 4)); // 10 < 12
    }
}
