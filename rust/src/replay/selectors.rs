//! Item-sampling strategies (Reverb "selectors").

use crate::rng::Rng;

/// How a table picks the next item to sample.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selector {
    /// Uniform over stored items (default experience replay).
    Uniform,
    /// Proportional to priority^alpha via a sum-tree.
    Prioritized, // alpha applied at insert time
    /// Oldest stored item (queue semantics).
    Fifo,
    /// Newest stored item (stack semantics).
    Lifo,
}

/// A classic sum-tree over item priorities for O(log n) proportional
/// sampling; capacity is fixed at construction and slots are reused
/// ring-buffer style in step with the table's FIFO eviction.
#[derive(Clone, Debug)]
pub struct SumTree {
    capacity: usize,
    tree: Vec<f64>, // 1-indexed binary heap layout, len = 2*capacity
}

impl SumTree {
    /// A zeroed tree over `capacity` slots (rounded up to a power
    /// of two).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        let cap = capacity.next_power_of_two();
        SumTree { capacity: cap, tree: vec![0.0; 2 * cap] }
    }

    /// Sum of all slot priorities.
    pub fn total(&self) -> f64 {
        self.tree[1]
    }

    /// Set the priority of `slot`.
    pub fn set(&mut self, slot: usize, priority: f64) {
        debug_assert!(slot < self.capacity);
        debug_assert!(priority >= 0.0);
        let mut i = self.capacity + slot;
        let delta = priority - self.tree[i];
        while i >= 1 {
            self.tree[i] += delta;
            i /= 2;
        }
    }

    /// Priority currently stored at `slot`.
    pub fn get(&self, slot: usize) -> f64 {
        self.tree[self.capacity + slot]
    }

    /// Sample a slot proportional to priority. Total must be > 0.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        debug_assert!(self.total() > 0.0);
        let mut mass = rng.f64() * self.total();
        let mut i = 1usize;
        while i < self.capacity {
            let left = 2 * i;
            if mass < self.tree[left] {
                i = left;
            } else {
                mass -= self.tree[left];
                i = left + 1;
            }
        }
        i - self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_tree_total_tracks_sets() {
        let mut t = SumTree::new(5);
        t.set(0, 1.0);
        t.set(3, 2.0);
        assert!((t.total() - 3.0).abs() < 1e-12);
        t.set(0, 0.5);
        assert!((t.total() - 2.5).abs() < 1e-12);
        assert_eq!(t.get(3), 2.0);
    }

    #[test]
    fn sum_tree_sampling_is_proportional() {
        let mut t = SumTree::new(4);
        t.set(0, 1.0);
        t.set(1, 3.0);
        let mut rng = Rng::new(0);
        let mut counts = [0usize; 4];
        for _ in 0..20_000 {
            counts[t.sample(&mut rng)] += 1;
        }
        assert_eq!(counts[2] + counts[3], 0);
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn sum_tree_zero_slots_never_sampled() {
        let mut t = SumTree::new(8);
        t.set(5, 1.0);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert_eq!(t.sample(&mut rng), 5);
        }
    }
}
