//! Reverb-style replay (Cassirer et al., 2021) — the data-flow substrate.
//!
//! The paper routes all executor→trainer data through Reverb tables. This
//! module reimplements the semantics mava-rs needs, in-process:
//!
//! * [`Table`] — bounded item store with a pluggable [`Selector`]
//!   (uniform / prioritized / FIFO / LIFO, paper §4 "dataset") and FIFO
//!   eviction;
//! * [`RateLimiter`] — Reverb's insert/sample flow control
//!   (`MinSize`, `SampleToInsertRatio`), blocking on condvars;
//! * adders ([`TransitionAdder`], [`SequenceAdder`]) — the Acme/Mava
//!   client-side classes that turn executor timesteps into table items.
//!
//! Being in-process removes only the RPC hop; insertion blocking,
//! sampling blocking and eviction order match Reverb's behaviour, which
//! is what the distribution experiment (Fig 6, bottom-right) exercises.
//!
//! For multi-executor runs the store is a [`ShardedTable`] — one
//! independently locked [`Table`] shard per executor with round-robin
//! trainer sampling (DESIGN.md §5) — so the insert hot path never
//! serialises executors on one mutex.

#![warn(missing_docs)]

mod adders;
mod checkpoint;
mod limiter;
mod selectors;
mod sharded;
mod table;

pub use adders::{SequenceAdder, TransitionAdder};
pub use limiter::RateLimiter;
pub use selectors::{Selector, SumTree};
pub use sharded::{ItemSource, ShardedTable};
pub use table::{Item, ItemSink, Sequence, Table, TableStats, Transition};
