//! Replay-table checkpointing (Reverb ships table checkpoints; mava-rs
//! mirrors the capability so long runs survive restarts).
//!
//! Format: a little-endian binary stream, one record per item:
//! ```text
//! magic "MAVARPL1"
//! u64 item_count
//! per item: u8 kind (0 transition, 1 sequence), then per-field
//!           u64 length + payload (f32/i32 arrays as raw LE bytes)
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::replay::{Item, Sequence, Table, Transition};

const MAGIC: &[u8; 8] = b"MAVARPL1";

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    w.write_all(&(xs.len() as u64).to_le_bytes())?;
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn write_i32s(w: &mut impl Write, xs: &[i32]) -> Result<()> {
    w.write_all(&(xs.len() as u64).to_le_bytes())?;
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = read_u64(r)? as usize;
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_i32s(r: &mut impl Read) -> Result<Vec<i32>> {
    let n = read_u64(r)? as usize;
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn write_item(w: &mut impl Write, item: &Item) -> Result<()> {
    match item {
        Item::Transition(t) => {
            w.write_all(&[0u8])?;
            write_f32s(w, &t.obs)?;
            write_f32s(w, &t.state)?;
            write_i32s(w, &t.actions_disc)?;
            write_f32s(w, &t.actions_cont)?;
            write_f32s(w, &t.rewards)?;
            write_f32s(w, &[t.discount])?;
            write_f32s(w, &t.next_obs)?;
            write_f32s(w, &t.next_state)?;
        }
        Item::Sequence(s) => {
            w.write_all(&[1u8])?;
            w.write_all(&(s.t as u64).to_le_bytes())?;
            write_f32s(w, &s.obs)?;
            write_i32s(w, &s.actions)?;
            write_f32s(w, &s.rewards)?;
            write_f32s(w, &s.discounts)?;
            write_f32s(w, &s.mask)?;
        }
    }
    Ok(())
}

fn read_item(r: &mut impl Read) -> Result<Item> {
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind)?;
    Ok(match kind[0] {
        0 => Item::Transition(Transition {
            obs: read_f32s(r)?,
            state: read_f32s(r)?,
            actions_disc: read_i32s(r)?,
            actions_cont: read_f32s(r)?,
            rewards: read_f32s(r)?,
            discount: {
                let d = read_f32s(r)?;
                anyhow::ensure!(d.len() == 1, "bad discount record");
                d[0]
            },
            next_obs: read_f32s(r)?,
            next_state: read_f32s(r)?,
        }),
        1 => Item::Sequence(Sequence {
            t: read_u64(r)? as usize,
            obs: read_f32s(r)?,
            actions: read_i32s(r)?,
            rewards: read_f32s(r)?,
            discounts: read_f32s(r)?,
            mask: read_f32s(r)?,
        }),
        k => bail!("unknown item kind {k}"),
    })
}

impl Table {
    /// Write every stored item to `path` (oldest first).
    pub fn checkpoint<P: AsRef<Path>>(&self, path: P) -> Result<usize> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let items = self.snapshot();
        let mut w = BufWriter::new(File::create(&path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(items.len() as u64).to_le_bytes())?;
        for item in &items {
            write_item(&mut w, item)?;
        }
        w.flush()?;
        Ok(items.len())
    }

    /// Insert every item from a checkpoint file (appended in order, so a
    /// fresh table reproduces the captured contents up to capacity).
    pub fn restore<P: AsRef<Path>>(&self, path: P) -> Result<usize> {
        let mut r = BufReader::new(
            File::open(&path).context("open replay checkpoint")?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a mava replay checkpoint");
        }
        let count = read_u64(&mut r)? as usize;
        for _ in 0..count {
            let item = read_item(&mut r)?;
            if !self.insert(item, 1.0) {
                bail!("table closed during restore");
            }
        }
        Ok(count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{RateLimiter, Selector};

    fn tr(v: f32) -> Item {
        Item::Transition(Transition {
            obs: vec![v, v + 1.0],
            state: vec![v],
            actions_disc: vec![1, 2],
            actions_cont: vec![],
            rewards: vec![0.5, 0.5],
            discount: 0.9,
            next_obs: vec![v + 2.0, v + 3.0],
            next_state: vec![v + 1.0],
        })
    }

    fn sq(v: f32) -> Item {
        Item::Sequence(Sequence {
            t: 4,
            obs: vec![v; 10],
            actions: vec![0, 1, 2, 3],
            rewards: vec![v; 4],
            discounts: vec![1.0, 1.0, 0.0, 0.0],
            mask: vec![1.0, 1.0, 0.0, 0.0],
        })
    }

    #[test]
    fn transition_roundtrip() {
        let dir = std::env::temp_dir().join("mava_ckpt_t");
        let path = dir.join("replay.ckpt");
        let table = Table::uniform(64, 1, 0);
        for i in 0..10 {
            table.insert(tr(i as f32), 1.0);
        }
        assert_eq!(table.checkpoint(&path).unwrap(), 10);

        let restored = Table::uniform(64, 1, 1);
        assert_eq!(restored.restore(&path).unwrap(), 10);
        assert_eq!(restored.stats().size, 10);
        let got = restored.sample(32).unwrap();
        for item in got {
            let t = item.as_transition();
            let v = t.obs[0];
            assert_eq!(t.obs, vec![v, v + 1.0]);
            assert_eq!(t.actions_disc, vec![1, 2]);
            assert_eq!(t.discount, 0.9);
            assert_eq!(t.next_state, vec![v + 1.0]);
        }
    }

    #[test]
    fn sequence_roundtrip() {
        let dir = std::env::temp_dir().join("mava_ckpt_s");
        let path = dir.join("replay.ckpt");
        let table = Table::new(
            32,
            Selector::Uniform,
            RateLimiter::min_size(1),
            0,
        );
        for i in 0..5 {
            table.insert(sq(i as f32), 1.0);
        }
        table.checkpoint(&path).unwrap();
        let restored = Table::uniform(32, 1, 2);
        assert_eq!(restored.restore(&path).unwrap(), 5);
        let got = restored.sample(8).unwrap();
        for item in got {
            let s = item.as_sequence();
            assert_eq!(s.t, 4);
            assert_eq!(s.mask, vec![1.0, 1.0, 0.0, 0.0]);
        }
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("mava_ckpt_g");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bogus.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        let table = Table::uniform(8, 1, 0);
        assert!(table.restore(&path).is_err());
    }
}
