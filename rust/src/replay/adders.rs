//! Adders: the client-side classes that turn executor timesteps into
//! replay items (Acme/Mava's `adders` package; paper: "an internal adder
//! class interfaces with a reverb replay table").
//!
//! Two APIs feed the same accumulation logic:
//!
//! * the legacy `observe_first`/`observe` pair over owned
//!   [`TimeStep`]s (serial executors, tests);
//! * the hot-path `observe_first_row`/`observe_row` pair over one row
//!   of a struct-of-arrays [`VecStepBuf`]/[`ActionBuf`]
//!   (DESIGN.md §6).
//!
//! The row path is **allocation-free at steady state**: step records
//! and emitted items are recycled through internal free lists, refilled
//! by [`Table::insert_reuse`] handing evicted items' buffers back, so
//! after the table reaches capacity (and one episode has warmed the
//! accumulation buffers) inserting a transition or sequence touches
//! the heap zero times.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::core::{Actions, ActionsRef, TimeStep};
use crate::env::{ActionBuf, VecStepBuf};
use crate::replay::{Item, ItemSink, Sequence, Transition};

#[derive(Clone, Debug, Default)]
struct StepRecord {
    obs: Vec<f32>,
    state: Vec<f32>,
    a_disc: Vec<i32>,
    a_cont: Vec<f32>,
    rewards: Vec<f32>,
    discount: f32,
}

impl StepRecord {
    fn clear(&mut self) {
        self.obs.clear();
        self.state.clear();
        self.a_disc.clear();
        self.a_cont.clear();
        self.rewards.clear();
    }
}

fn clear_transition(t: &mut Transition) {
    t.obs.clear();
    t.state.clear();
    t.actions_disc.clear();
    t.actions_cont.clear();
    t.rewards.clear();
    t.next_obs.clear();
    t.next_state.clear();
}

fn clear_sequence(s: &mut Sequence) {
    s.obs.clear();
    s.actions.clear();
    s.rewards.clear();
    s.discounts.clear();
    s.mask.clear();
}

/// Builds (n-step) transitions — feedforward systems (MADQN, VDN, QMIX,
/// MADDPG) and, with `n_step > 1`, MAD4PG's n-step targets: the emitted
/// `rewards` are the discounted n-step sums and `discount` is
/// `gamma^(n-1) * prod(discounts)`, so the train artifact's single
/// `y = r + gamma * disc * Q(next)` stays correct for any n.
pub struct TransitionAdder {
    sink: Arc<dyn ItemSink>,
    n_step: usize,
    gamma: f32,
    has_pending: bool,
    /// flat `[N*O]` observation awaiting its action
    pending_obs: Vec<f32>,
    pending_state: Vec<f32>,
    buf: VecDeque<StepRecord>,
    free_records: Vec<StepRecord>,
    free_items: Vec<Transition>,
    // legacy-API staging for the flattened next obs/state
    scratch_obs: Vec<f32>,
    scratch_state: Vec<f32>,
}

impl TransitionAdder {
    /// An adder emitting `n_step` transitions into `sink` (a local
    /// [`crate::replay::Table`] or a remote shard client).
    pub fn new(sink: Arc<dyn ItemSink>, n_step: usize, gamma: f32) -> Self {
        assert!(n_step >= 1);
        TransitionAdder {
            sink,
            n_step,
            gamma,
            has_pending: false,
            pending_obs: Vec::new(),
            pending_state: Vec::new(),
            buf: VecDeque::new(),
            free_records: Vec::new(),
            free_items: Vec::new(),
            scratch_obs: Vec::new(),
            scratch_state: Vec::new(),
        }
    }

    /// Begin a new episode from its `First` timestep.
    pub fn observe_first(&mut self, ts: &TimeStep) {
        self.scratch_obs.clear();
        for o in &ts.observations {
            self.scratch_obs.extend_from_slice(o);
        }
        let obs = std::mem::take(&mut self.scratch_obs);
        self.begin(&obs, &ts.state);
        self.scratch_obs = obs;
    }

    /// Begin a new episode from row `row` of a `First` vector step.
    pub fn observe_first_row(&mut self, next: &VecStepBuf, row: usize) {
        debug_assert!(next.step_type(row) == crate::core::StepType::First);
        // the SoA row is already flat: no staging needed
        let (obs, state) = (next.obs_row(row), next.state_row(row));
        self.begin(obs, state);
    }

    fn begin(&mut self, obs: &[f32], state: &[f32]) {
        while let Some(mut rec) = self.buf.pop_front() {
            rec.clear();
            self.free_records.push(rec);
        }
        self.pending_obs.clear();
        self.pending_obs.extend_from_slice(obs);
        self.pending_state.clear();
        self.pending_state.extend_from_slice(state);
        self.has_pending = true;
    }

    /// Record one `(action, next timestep)` pair; emits items once
    /// `n_step` steps accumulated (and flushes at episode end).
    pub fn observe(&mut self, actions: &Actions, next: &TimeStep) {
        self.scratch_obs.clear();
        for o in &next.observations {
            self.scratch_obs.extend_from_slice(o);
        }
        self.scratch_state.clear();
        self.scratch_state.extend_from_slice(&next.state);
        let obs = std::mem::take(&mut self.scratch_obs);
        let state = std::mem::take(&mut self.scratch_state);
        self.step_flat(
            &ActionsRef::from_actions(actions),
            &next.rewards,
            next.discount,
            &obs,
            &state,
            next.is_last(),
        );
        self.scratch_obs = obs;
        self.scratch_state = state;
    }

    /// Record one `(action row, next vector-step row)` pair from the
    /// SoA buffers (allocation-free at steady state).
    pub fn observe_row(
        &mut self,
        actions: &ActionBuf,
        row: usize,
        next: &VecStepBuf,
    ) {
        self.step_flat(
            &actions.row(row),
            next.rewards_row(row),
            next.discount(row),
            next.obs_row(row),
            next.state_row(row),
            next.is_last(row),
        );
    }

    fn step_flat(
        &mut self,
        actions: &ActionsRef,
        rewards: &[f32],
        discount: f32,
        next_obs: &[f32],
        next_state: &[f32],
        is_last: bool,
    ) {
        assert!(self.has_pending, "observe() before observe_first()");
        let mut rec = self.free_records.pop().unwrap_or_default();
        rec.clear();
        // the pending obs/state become this record's; swap keeps both
        // buffers' capacity alive
        std::mem::swap(&mut rec.obs, &mut self.pending_obs);
        std::mem::swap(&mut rec.state, &mut self.pending_state);
        match actions {
            ActionsRef::Discrete(a) => rec.a_disc.extend_from_slice(a),
            ActionsRef::Continuous { data, .. } => {
                rec.a_cont.extend_from_slice(data)
            }
            ActionsRef::ContinuousRows(rows) => {
                for r in rows.iter() {
                    rec.a_cont.extend_from_slice(r);
                }
            }
        }
        rec.rewards.extend_from_slice(rewards);
        rec.discount = discount;
        self.buf.push_back(rec);
        if self.buf.len() == self.n_step {
            self.emit_front(next_obs, next_state);
        }
        if is_last {
            while !self.buf.is_empty() {
                self.emit_front(next_obs, next_state);
            }
            self.has_pending = false;
            self.pending_obs.clear();
            self.pending_state.clear();
        } else {
            self.pending_obs.clear();
            self.pending_obs.extend_from_slice(next_obs);
            self.pending_state.clear();
            self.pending_state.extend_from_slice(next_state);
        }
    }

    fn emit_front(&mut self, next_obs: &[f32], next_state: &[f32]) {
        let n_agents = self.buf[0].rewards.len();
        let mut t = self.free_items.pop().unwrap_or_default();
        clear_transition(&mut t);
        t.rewards.resize(n_agents, 0.0);
        let mut disc = 1.0f32;
        let mut g = 1.0f32;
        for (k, rec) in self.buf.iter().enumerate() {
            for (r, &x) in t.rewards.iter_mut().zip(&rec.rewards) {
                *r += g * x;
            }
            disc *= rec.discount;
            if k + 1 < self.buf.len() {
                g *= self.gamma;
            }
        }
        // gamma^(n-1): `g` already equals that after the loop
        disc *= g;
        let mut front = self.buf.pop_front().unwrap();
        t.obs.extend_from_slice(&front.obs);
        t.state.extend_from_slice(&front.state);
        t.actions_disc.extend_from_slice(&front.a_disc);
        t.actions_cont.extend_from_slice(&front.a_cont);
        t.discount = disc;
        t.next_obs.extend_from_slice(next_obs);
        t.next_state.extend_from_slice(next_state);
        front.clear();
        self.free_records.push(front);
        let (_, evicted) =
            self.sink.insert_item_reuse(Item::Transition(t), 1.0);
        if let Some(Item::Transition(mut old)) = evicted {
            clear_transition(&mut old);
            self.free_items.push(old);
        }
    }
}

/// Builds fixed-length (padded, possibly overlapping) sequences for
/// recurrent systems (recurrent MADQN, DIAL).
pub struct SequenceAdder {
    sink: Arc<dyn ItemSink>,
    seq_len: usize,
    period: usize,
    /// per-step layout, learned from the first observation of an episode
    n_agents: usize,
    obs_row: usize,
    /// flat episode accumulation: `obs` holds `steps+1` rows of
    /// `obs_row` floats, the rest `steps` entries
    steps: usize,
    active: bool,
    obs: Vec<f32>,
    acts: Vec<i32>,
    rewards: Vec<f32>,
    discounts: Vec<f32>,
    free_items: Vec<Sequence>,
}

impl SequenceAdder {
    /// An adder emitting `seq_len` windows every `period` steps.
    pub fn new(
        sink: Arc<dyn ItemSink>,
        seq_len: usize,
        period: usize,
    ) -> Self {
        assert!(seq_len >= 1 && period >= 1);
        SequenceAdder {
            sink,
            seq_len,
            period,
            n_agents: 0,
            obs_row: 0,
            steps: 0,
            active: false,
            obs: Vec::new(),
            acts: Vec::new(),
            rewards: Vec::new(),
            discounts: Vec::new(),
            free_items: Vec::new(),
        }
    }

    /// Begin a new episode from its `First` timestep.
    pub fn observe_first(&mut self, ts: &TimeStep) {
        self.begin();
        self.n_agents = ts.observations.len();
        for o in &ts.observations {
            self.obs.extend_from_slice(o);
        }
        self.obs_row = self.obs.len();
    }

    /// Begin a new episode from row `row` of a `First` vector step.
    pub fn observe_first_row(&mut self, next: &VecStepBuf, row: usize) {
        self.begin();
        self.n_agents = next.n_agents();
        let obs = next.obs_row(row);
        self.obs.extend_from_slice(obs);
        self.obs_row = obs.len();
    }

    fn begin(&mut self) {
        self.obs.clear();
        self.acts.clear();
        self.rewards.clear();
        self.discounts.clear();
        self.steps = 0;
        self.active = true;
    }

    /// Record one step; windows flush when the episode ends.
    pub fn observe(&mut self, actions: &Actions, next: &TimeStep) {
        assert!(self.active, "observe() before observe_first()");
        self.acts.extend_from_slice(actions.as_discrete());
        self.rewards.extend_from_slice(&next.rewards);
        self.discounts.push(next.discount);
        for o in &next.observations {
            self.obs.extend_from_slice(o);
        }
        self.steps += 1;
        if next.is_last() {
            self.flush();
        }
    }

    /// Record one `(action row, next vector-step row)` pair from the
    /// SoA buffers (allocation-free at steady state).
    pub fn observe_row(
        &mut self,
        actions: &ActionBuf,
        row: usize,
        next: &VecStepBuf,
    ) {
        assert!(self.active, "observe_row() before observe_first_row()");
        self.acts.extend_from_slice(actions.row(row).as_discrete());
        self.rewards.extend_from_slice(next.rewards_row(row));
        self.discounts.push(next.discount(row));
        self.obs.extend_from_slice(next.obs_row(row));
        self.steps += 1;
        if next.is_last(row) {
            self.flush();
        }
    }

    fn flush(&mut self) {
        let steps = self.steps;
        if steps == 0 {
            self.active = false;
            return;
        }
        let t_len = self.seq_len;
        let obs_row = self.obs_row;
        let n_agents = self.n_agents;
        let mut start = 0;
        loop {
            let valid = (steps - start).min(t_len);
            let mut seq = self.free_items.pop().unwrap_or_default();
            clear_sequence(&mut seq);
            seq.t = t_len;
            for t in 0..=t_len {
                let idx = (start + t).min(steps); // repeat last obs as pad
                seq.obs.extend_from_slice(
                    &self.obs[idx * obs_row..(idx + 1) * obs_row],
                );
            }
            for t in 0..t_len {
                if t < valid {
                    let idx = start + t;
                    seq.actions.extend_from_slice(
                        &self.acts[idx * n_agents..(idx + 1) * n_agents],
                    );
                    seq.rewards.extend_from_slice(
                        &self.rewards[idx * n_agents..(idx + 1) * n_agents],
                    );
                    seq.discounts.push(self.discounts[idx]);
                    seq.mask.push(1.0);
                } else {
                    seq.actions
                        .extend(std::iter::repeat(0).take(n_agents));
                    seq.rewards
                        .extend(std::iter::repeat(0.0).take(n_agents));
                    seq.discounts.push(0.0);
                    seq.mask.push(0.0);
                }
            }
            let (_, evicted) =
                self.sink.insert_item_reuse(Item::Sequence(seq), 1.0);
            if let Some(Item::Sequence(mut old)) = evicted {
                clear_sequence(&mut old);
                self.free_items.push(old);
            }
            start += self.period;
            if start >= steps {
                break;
            }
        }
        self.begin();
        self.active = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::StepType;
    use crate::replay::Table;

    fn ts(step_type: StepType, obs: f32, rew: f32, disc: f32) -> TimeStep {
        TimeStep {
            step_type,
            observations: vec![vec![obs; 2]; 2], // 2 agents, obs_dim 2
            rewards: vec![rew; 2],
            discount: disc,
            state: vec![obs; 3],
            legal_actions: None,
        }
    }

    fn acts(a: i32) -> Actions {
        Actions::Discrete(vec![a; 2])
    }

    #[test]
    fn one_step_transition_fields() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = TransitionAdder::new(table.clone(), 1, 0.99);
        adder.observe_first(&ts(StepType::First, 1.0, 0.0, 1.0));
        adder.observe(&acts(3), &ts(StepType::Mid, 2.0, 0.5, 1.0));
        let items = table.sample(1).unwrap();
        let tr = items[0].as_transition();
        assert_eq!(tr.obs, vec![1.0; 4]);
        assert_eq!(tr.next_obs, vec![2.0; 4]);
        assert_eq!(tr.actions_disc, vec![3, 3]);
        assert_eq!(tr.rewards, vec![0.5; 2]);
        assert_eq!(tr.discount, 1.0);
        assert_eq!(tr.state, vec![1.0; 3]);
    }

    #[test]
    fn n_step_accumulates_discounted_rewards() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = TransitionAdder::new(table.clone(), 3, 0.5);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        adder.observe(&acts(0), &ts(StepType::Mid, 1.0, 1.0, 1.0));
        adder.observe(&acts(0), &ts(StepType::Mid, 2.0, 2.0, 1.0));
        assert_eq!(table.stats().inserts, 0, "no item before n steps");
        adder.observe(&acts(0), &ts(StepType::Mid, 3.0, 4.0, 1.0));
        let tr_items = table.sample(1).unwrap();
        let tr = tr_items[0].as_transition();
        // R = 1 + 0.5*2 + 0.25*4 = 3 ; disc = 0.5^2 = 0.25
        assert_eq!(tr.rewards, vec![3.0; 2]);
        assert!((tr.discount - 0.25).abs() < 1e-6);
        assert_eq!(tr.obs, vec![0.0; 4]);
        assert_eq!(tr.next_obs, vec![3.0; 4]);
    }

    #[test]
    fn episode_end_flushes_short_transitions() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = TransitionAdder::new(table.clone(), 3, 0.5);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        adder.observe(&acts(0), &ts(StepType::Mid, 1.0, 1.0, 1.0));
        adder.observe(&acts(0), &ts(StepType::Last, 2.0, 2.0, 0.0));
        // two transitions: horizons 2 and 1, both terminal -> disc 0
        assert_eq!(table.stats().inserts, 2);
        for it in table.sample(8).unwrap() {
            assert_eq!(it.as_transition().discount, 0.0);
            assert_eq!(it.as_transition().next_obs, vec![2.0; 4]);
        }
    }

    #[test]
    fn terminal_discount_zero_propagates() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = TransitionAdder::new(table.clone(), 1, 0.99);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        adder.observe(&acts(1), &ts(StepType::Last, 1.0, 1.0, 0.0));
        let items = table.sample(1).unwrap();
        assert_eq!(items[0].as_transition().discount, 0.0);
    }

    #[test]
    fn sequence_pads_and_masks() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = SequenceAdder::new(table.clone(), 4, 4);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        adder.observe(&acts(1), &ts(StepType::Mid, 1.0, 0.1, 1.0));
        adder.observe(&acts(2), &ts(StepType::Last, 2.0, 1.0, 0.0));
        let seq_items = table.sample(1).unwrap();
        let s = seq_items[0].as_sequence();
        assert_eq!(s.mask, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(s.obs.len(), 5 * 4); // (T+1) * N*O
        assert_eq!(s.actions[0..2], [1, 1]);
        assert_eq!(s.actions[2..4], [2, 2]);
        assert_eq!(s.discounts, vec![1.0, 0.0, 0.0, 0.0]);
        // padded obs repeat the final observation
        assert_eq!(&s.obs[3 * 4..4 * 4], &[2.0; 4]);
        assert_eq!(&s.obs[4 * 4..5 * 4], &[2.0; 4]);
    }

    #[test]
    fn long_episode_emits_overlapping_windows() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = SequenceAdder::new(table.clone(), 4, 2);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        for t in 0..6 {
            let st = if t == 5 { StepType::Last } else { StepType::Mid };
            adder.observe(&acts(t), &ts(st, t as f32, 0.0, 1.0));
        }
        // windows at start 0, 2, 4 -> 3 items
        assert_eq!(table.stats().inserts, 3);
    }

    #[test]
    fn new_episode_resets_accumulation() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = SequenceAdder::new(table.clone(), 4, 4);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        adder.observe(&acts(0), &ts(StepType::Mid, 1.0, 0.0, 1.0));
        // abandoned episode (e.g. executor restart): observe_first again
        adder.observe_first(&ts(StepType::First, 5.0, 0.0, 1.0));
        adder.observe(&acts(1), &ts(StepType::Last, 6.0, 1.0, 0.0));
        let items = table.sample(1).unwrap();
        let s = items[0].as_sequence();
        assert_eq!(&s.obs[0..4], &[5.0; 4], "stale episode leaked");
    }

    /// The SoA row API must produce bit-identical table contents to the
    /// legacy timestep API for the same trajectory.
    #[test]
    fn row_api_matches_legacy_api() {
        use crate::core::{ActionSpec, EnvSpec};

        let spec = EnvSpec {
            name: "fixture".into(),
            n_agents: 2,
            obs_dim: 2,
            action: ActionSpec::Discrete { n: 4 },
            state_dim: 3,
            episode_limit: 8,
        };
        // a 2-row buffer: the adder under test reads row 1
        let mut buf = VecStepBuf::new(&spec, 2, false);
        let mut abuf = ActionBuf::new(&spec, 2);

        for (n_step, gamma) in [(1usize, 0.9f32), (3, 0.5)] {
            let t_legacy = Arc::new(Table::uniform(64, 1, 0));
            let t_row = Arc::new(Table::uniform(64, 1, 0));
            let mut legacy =
                TransitionAdder::new(t_legacy.clone(), n_step, gamma);
            let mut row = TransitionAdder::new(t_row.clone(), n_step, gamma);

            for episode in 0..3 {
                let first = ts(StepType::First, episode as f32, 0.0, 1.0);
                legacy.observe_first(&first);
                buf.scatter(1, &first);
                row.observe_first_row(&buf, 1);
                for t in 0..5 {
                    let last = t == 4;
                    let step = ts(
                        if last { StepType::Last } else { StepType::Mid },
                        t as f32,
                        t as f32 * 0.5,
                        if last { 0.0 } else { 1.0 },
                    );
                    let a = acts(t);
                    legacy.observe(&a, &step);
                    buf.scatter(1, &step);
                    abuf.set_row(1, &a);
                    row.observe_row(&abuf, 1, &buf);
                }
            }
            let a = t_legacy.snapshot();
            let b = t_row.snapshot();
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                let (x, y) = (x.as_transition(), y.as_transition());
                assert_eq!(x.obs, y.obs);
                assert_eq!(x.state, y.state);
                assert_eq!(x.actions_disc, y.actions_disc);
                assert_eq!(x.rewards, y.rewards);
                assert_eq!(x.discount, y.discount);
                assert_eq!(x.next_obs, y.next_obs);
                assert_eq!(x.next_state, y.next_state);
            }
        }

        // sequence adders over the same trajectory
        let t_legacy = Arc::new(Table::uniform(64, 1, 0));
        let t_row = Arc::new(Table::uniform(64, 1, 0));
        let mut legacy = SequenceAdder::new(t_legacy.clone(), 4, 2);
        let mut row = SequenceAdder::new(t_row.clone(), 4, 2);
        for episode in 0..2 {
            let first = ts(StepType::First, episode as f32, 0.0, 1.0);
            legacy.observe_first(&first);
            buf.scatter(0, &first);
            row.observe_first_row(&buf, 0);
            for t in 0..6 {
                let last = t == 5;
                let step = ts(
                    if last { StepType::Last } else { StepType::Mid },
                    t as f32 + 10.0 * episode as f32,
                    0.25,
                    if last { 0.0 } else { 1.0 },
                );
                let a = acts(t);
                legacy.observe(&a, &step);
                buf.scatter(0, &step);
                abuf.set_row(0, &a);
                row.observe_row(&abuf, 0, &buf);
            }
        }
        let a = t_legacy.snapshot();
        let b = t_row.snapshot();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            let (x, y) = (x.as_sequence(), y.as_sequence());
            assert_eq!(x.t, y.t);
            assert_eq!(x.obs, y.obs);
            assert_eq!(x.actions, y.actions);
            assert_eq!(x.rewards, y.rewards);
            assert_eq!(x.discounts, y.discounts);
            assert_eq!(x.mask, y.mask);
        }
    }

    /// Continuous joint actions flatten identically through both APIs.
    #[test]
    fn continuous_actions_flatten() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = TransitionAdder::new(table.clone(), 1, 0.99);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        let a = Actions::Continuous(vec![vec![0.1, 0.2], vec![0.3, 0.4]]);
        adder.observe(&a, &ts(StepType::Mid, 1.0, 0.0, 1.0));
        let items = table.sample(1).unwrap();
        let tr = items[0].as_transition();
        assert_eq!(tr.actions_cont, vec![0.1, 0.2, 0.3, 0.4]);
        assert!(tr.actions_disc.is_empty());
    }
}
