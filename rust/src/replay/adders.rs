//! Adders: the client-side classes that turn executor timesteps into
//! replay items (Acme/Mava's `adders` package; paper: "an internal adder
//! class interfaces with a reverb replay table").

use std::collections::VecDeque;
use std::sync::Arc;

use crate::core::{Actions, TimeStep};
use crate::replay::{Item, Sequence, Table, Transition};

#[derive(Clone, Debug)]
struct StepRecord {
    obs: Vec<f32>,
    state: Vec<f32>,
    a_disc: Vec<i32>,
    a_cont: Vec<f32>,
    rewards: Vec<f32>,
    discount: f32,
}

/// Builds (n-step) transitions — feedforward systems (MADQN, VDN, QMIX,
/// MADDPG) and, with `n_step > 1`, MAD4PG's n-step targets: the emitted
/// `rewards` are the discounted n-step sums and `discount` is
/// `gamma^(n-1) * prod(discounts)`, so the train artifact's single
/// `y = r + gamma * disc * Q(next)` stays correct for any n.
pub struct TransitionAdder {
    table: Arc<Table>,
    n_step: usize,
    gamma: f32,
    pending: Option<(Vec<f32>, Vec<f32>)>, // (obs, state) awaiting action
    buf: VecDeque<StepRecord>,
}

impl TransitionAdder {
    /// An adder emitting `n_step` transitions into `table`.
    pub fn new(table: Arc<Table>, n_step: usize, gamma: f32) -> Self {
        assert!(n_step >= 1);
        TransitionAdder { table, n_step, gamma, pending: None, buf: VecDeque::new() }
    }

    /// Begin a new episode from its `First` timestep.
    pub fn observe_first(&mut self, ts: &TimeStep) {
        self.buf.clear();
        self.pending = Some((ts.observations.concat(), ts.state.clone()));
    }

    /// Record one `(action, next timestep)` pair; emits items once
    /// `n_step` steps accumulated (and flushes at episode end).
    pub fn observe(&mut self, actions: &Actions, next: &TimeStep) {
        let (obs, state) = self
            .pending
            .take()
            .expect("observe() before observe_first()");
        let (a_disc, a_cont) = match actions {
            Actions::Discrete(a) => (a.clone(), vec![]),
            Actions::Continuous(a) => (vec![], a.concat()),
        };
        self.buf.push_back(StepRecord {
            obs,
            state,
            a_disc,
            a_cont,
            rewards: next.rewards.clone(),
            discount: next.discount,
        });
        let next_obs = next.observations.concat();
        let next_state = next.state.clone();
        if self.buf.len() == self.n_step {
            self.emit_front(&next_obs, &next_state);
        }
        if next.is_last() {
            while !self.buf.is_empty() {
                self.emit_front(&next_obs, &next_state);
            }
            self.pending = None;
        } else {
            self.pending = Some((next_obs, next_state));
        }
    }

    fn emit_front(&mut self, next_obs: &[f32], next_state: &[f32]) {
        let n_agents = self.buf[0].rewards.len();
        let mut rewards = vec![0.0f32; n_agents];
        let mut disc = 1.0f32;
        let mut g = 1.0f32;
        for (k, rec) in self.buf.iter().enumerate() {
            for (r, &x) in rewards.iter_mut().zip(&rec.rewards) {
                *r += g * x;
            }
            disc *= rec.discount;
            if k + 1 < self.buf.len() {
                g *= self.gamma;
            }
        }
        // gamma^(n-1): `g` already equals that after the loop
        disc *= g;
        let front = self.buf.pop_front().unwrap();
        let t = Transition {
            obs: front.obs,
            state: front.state,
            actions_disc: front.a_disc,
            actions_cont: front.a_cont,
            rewards,
            discount: disc,
            next_obs: next_obs.to_vec(),
            next_state: next_state.to_vec(),
        };
        self.table.insert(Item::Transition(t), 1.0);
    }
}

/// Builds fixed-length (padded, possibly overlapping) sequences for
/// recurrent systems (recurrent MADQN, DIAL).
pub struct SequenceAdder {
    table: Arc<Table>,
    seq_len: usize,
    period: usize,
    // episode accumulation
    obs: Vec<Vec<f32>>, // length L+1 once episode ends
    acts: Vec<Vec<i32>>,
    rewards: Vec<Vec<f32>>,
    discounts: Vec<f32>,
}

impl SequenceAdder {
    /// An adder emitting `seq_len` windows every `period` steps.
    pub fn new(table: Arc<Table>, seq_len: usize, period: usize) -> Self {
        assert!(seq_len >= 1 && period >= 1);
        SequenceAdder {
            table,
            seq_len,
            period,
            obs: vec![],
            acts: vec![],
            rewards: vec![],
            discounts: vec![],
        }
    }

    /// Begin a new episode from its `First` timestep.
    pub fn observe_first(&mut self, ts: &TimeStep) {
        self.obs = vec![ts.observations.concat()];
        self.acts.clear();
        self.rewards.clear();
        self.discounts.clear();
    }

    /// Record one step; windows flush when the episode ends.
    pub fn observe(&mut self, actions: &Actions, next: &TimeStep) {
        assert!(!self.obs.is_empty(), "observe() before observe_first()");
        self.acts.push(actions.as_discrete().to_vec());
        self.rewards.push(next.rewards.clone());
        self.discounts.push(next.discount);
        self.obs.push(next.observations.concat());
        if next.is_last() {
            self.flush();
        }
    }

    fn flush(&mut self) {
        let steps = self.acts.len();
        if steps == 0 {
            return;
        }
        let t_len = self.seq_len;
        let obs_dim = self.obs[0].len();
        let n_agents = self.acts[0].len();
        let mut start = 0;
        loop {
            let valid = (steps - start).min(t_len);
            let mut seq = Sequence {
                t: t_len,
                obs: Vec::with_capacity((t_len + 1) * obs_dim),
                actions: Vec::with_capacity(t_len * n_agents),
                rewards: Vec::with_capacity(t_len * n_agents),
                discounts: Vec::with_capacity(t_len),
                mask: Vec::with_capacity(t_len),
            };
            for t in 0..=t_len {
                let idx = (start + t).min(steps); // repeat last obs as pad
                seq.obs.extend_from_slice(&self.obs[idx]);
            }
            for t in 0..t_len {
                if t < valid {
                    let idx = start + t;
                    seq.actions.extend_from_slice(&self.acts[idx]);
                    seq.rewards.extend_from_slice(&self.rewards[idx]);
                    seq.discounts.push(self.discounts[idx]);
                    seq.mask.push(1.0);
                } else {
                    seq.actions.extend(std::iter::repeat(0).take(n_agents));
                    seq.rewards
                        .extend(std::iter::repeat(0.0).take(n_agents));
                    seq.discounts.push(0.0);
                    seq.mask.push(0.0);
                }
            }
            self.table.insert(Item::Sequence(seq), 1.0);
            start += self.period;
            if start >= steps {
                break;
            }
        }
        self.obs.clear();
        self.acts.clear();
        self.rewards.clear();
        self.discounts.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::StepType;

    fn ts(step_type: StepType, obs: f32, rew: f32, disc: f32) -> TimeStep {
        TimeStep {
            step_type,
            observations: vec![vec![obs; 2]; 2], // 2 agents, obs_dim 2
            rewards: vec![rew; 2],
            discount: disc,
            state: vec![obs; 3],
            legal_actions: None,
        }
    }

    fn acts(a: i32) -> Actions {
        Actions::Discrete(vec![a; 2])
    }

    #[test]
    fn one_step_transition_fields() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = TransitionAdder::new(table.clone(), 1, 0.99);
        adder.observe_first(&ts(StepType::First, 1.0, 0.0, 1.0));
        adder.observe(&acts(3), &ts(StepType::Mid, 2.0, 0.5, 1.0));
        let items = table.sample(1).unwrap();
        let tr = items[0].as_transition();
        assert_eq!(tr.obs, vec![1.0; 4]);
        assert_eq!(tr.next_obs, vec![2.0; 4]);
        assert_eq!(tr.actions_disc, vec![3, 3]);
        assert_eq!(tr.rewards, vec![0.5; 2]);
        assert_eq!(tr.discount, 1.0);
        assert_eq!(tr.state, vec![1.0; 3]);
    }

    #[test]
    fn n_step_accumulates_discounted_rewards() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = TransitionAdder::new(table.clone(), 3, 0.5);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        adder.observe(&acts(0), &ts(StepType::Mid, 1.0, 1.0, 1.0));
        adder.observe(&acts(0), &ts(StepType::Mid, 2.0, 2.0, 1.0));
        assert_eq!(table.stats().inserts, 0, "no item before n steps");
        adder.observe(&acts(0), &ts(StepType::Mid, 3.0, 4.0, 1.0));
        let tr_items = table.sample(1).unwrap();
        let tr = tr_items[0].as_transition();
        // R = 1 + 0.5*2 + 0.25*4 = 3 ; disc = 0.5^2 = 0.25
        assert_eq!(tr.rewards, vec![3.0; 2]);
        assert!((tr.discount - 0.25).abs() < 1e-6);
        assert_eq!(tr.obs, vec![0.0; 4]);
        assert_eq!(tr.next_obs, vec![3.0; 4]);
    }

    #[test]
    fn episode_end_flushes_short_transitions() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = TransitionAdder::new(table.clone(), 3, 0.5);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        adder.observe(&acts(0), &ts(StepType::Mid, 1.0, 1.0, 1.0));
        adder.observe(&acts(0), &ts(StepType::Last, 2.0, 2.0, 0.0));
        // two transitions: horizons 2 and 1, both terminal -> disc 0
        assert_eq!(table.stats().inserts, 2);
        for it in table.sample(8).unwrap() {
            assert_eq!(it.as_transition().discount, 0.0);
            assert_eq!(it.as_transition().next_obs, vec![2.0; 4]);
        }
    }

    #[test]
    fn terminal_discount_zero_propagates() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = TransitionAdder::new(table.clone(), 1, 0.99);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        adder.observe(&acts(1), &ts(StepType::Last, 1.0, 1.0, 0.0));
        let items = table.sample(1).unwrap();
        assert_eq!(items[0].as_transition().discount, 0.0);
    }

    #[test]
    fn sequence_pads_and_masks() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = SequenceAdder::new(table.clone(), 4, 4);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        adder.observe(&acts(1), &ts(StepType::Mid, 1.0, 0.1, 1.0));
        adder.observe(&acts(2), &ts(StepType::Last, 2.0, 1.0, 0.0));
        let seq_items = table.sample(1).unwrap();
        let s = seq_items[0].as_sequence();
        assert_eq!(s.mask, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(s.obs.len(), 5 * 4); // (T+1) * N*O
        assert_eq!(s.actions[0..2], [1, 1]);
        assert_eq!(s.actions[2..4], [2, 2]);
        assert_eq!(s.discounts, vec![1.0, 0.0, 0.0, 0.0]);
        // padded obs repeat the final observation
        assert_eq!(&s.obs[3 * 4..4 * 4], &[2.0; 4]);
        assert_eq!(&s.obs[4 * 4..5 * 4], &[2.0; 4]);
    }

    #[test]
    fn long_episode_emits_overlapping_windows() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = SequenceAdder::new(table.clone(), 4, 2);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        for t in 0..6 {
            let st = if t == 5 { StepType::Last } else { StepType::Mid };
            adder.observe(&acts(t), &ts(st, t as f32, 0.0, 1.0));
        }
        // windows at start 0, 2, 4 -> 3 items
        assert_eq!(table.stats().inserts, 3);
    }

    #[test]
    fn new_episode_resets_accumulation() {
        let table = Arc::new(Table::uniform(16, 1, 0));
        let mut adder = SequenceAdder::new(table.clone(), 4, 4);
        adder.observe_first(&ts(StepType::First, 0.0, 0.0, 1.0));
        adder.observe(&acts(0), &ts(StepType::Mid, 1.0, 0.0, 1.0));
        // abandoned episode (e.g. executor restart): observe_first again
        adder.observe_first(&ts(StepType::First, 5.0, 0.0, 1.0));
        adder.observe(&acts(1), &ts(StepType::Last, 6.0, 1.0, 0.0));
        let items = table.sample(1).unwrap();
        let s = items[0].as_sequence();
        assert_eq!(&s.obs[0..4], &[5.0; 4], "stale episode leaked");
    }
}
