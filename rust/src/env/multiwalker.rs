//! Simplified Multi-Walker (Gupta et al., 2017) — Fig 6 mid/bottom-right.
//!
//! The original is a Box2D bipedal-walker swarm jointly carrying a
//! package. A full rigid-body port is orthogonal to the *systems*
//! contribution the figure tests (cooperative continuous control where
//! every agent's failure ends the episode), so walkers are modelled as
//! force-controlled leg-carts: each walker has a horizontal position and
//! a leg extension, the package rests across the walkers, and it falls if
//! the walkers spread apart or the package tilts past a threshold.
//! Reward: shared forward progress of the package, a control cost, and a
//! large penalty on dropping it — the same learning signal structure
//! (dense progress + catastrophic cooperative failure) as the original.
//!
//! Actions per walker: 4 torques in [-1,1] mapped to horizontal force
//! (front+back hip) and leg extension force (front+back knee), mirroring
//! the original's 4-dim joint-torque interface.

use crate::core::{
    ActionSpec, Actions, ActionsRef, EnvSpec, StepMeta, StepType, TimeStep,
};
use crate::env::MultiAgentEnv;
use crate::rng::Rng;

const DT: f32 = 0.05;
const SPACING: f32 = 1.0;
const DRAG: f32 = 1.0;
const LEG_K: f32 = 8.0; // leg spring toward nominal extension
const G_EFF: f32 = 2.0; // effective load on the legs
const FX_SCALE: f32 = 4.0;
const FH_SCALE: f32 = 6.0;
const TILT_LIMIT: f32 = 0.35;
const SPREAD_LIMIT: f32 = 0.6;
const H_MIN: f32 = 0.5;
const H_MAX: f32 = 1.5;
const EPISODE: usize = 100;
const PROGRESS_SCALE: f32 = 10.0;
const CTRL_COST: f32 = 0.02;
const FALL_PENALTY: f32 = -10.0;

#[derive(Clone, Debug)]
struct Walker {
    x: f32,
    vx: f32,
    h: f32,
    vh: f32,
}

/// Simplified multi-walker: `n` coupled walkers carrying a shared
/// package; continuous control with shared package-progress reward.
pub struct MultiWalker {
    spec: EnvSpec,
    rng: Rng,
    n: usize,
    walkers: Vec<Walker>,
    package_x: f32,
    prev_tilt: f32,
    t: usize,
    done: bool,
    last_reward: f32,
}

impl MultiWalker {
    /// An `n`-walker instance (the paper uses 3).
    pub fn new(n: usize, seed: u64) -> Self {
        MultiWalker {
            spec: EnvSpec {
                name: "multiwalker".into(),
                n_agents: n,
                obs_dim: 20,
                action: ActionSpec::Continuous { dim: 4 },
                state_dim: 20 * n,
                episode_limit: EPISODE,
            },
            rng: Rng::new(seed),
            n,
            walkers: vec![],
            package_x: 0.0,
            prev_tilt: 0.0,
            t: 0,
            done: true,
            last_reward: 0.0,
        }
    }

    fn tilt(&self) -> f32 {
        let h0 = self.walkers.first().unwrap().h;
        let h1 = self.walkers.last().unwrap().h;
        ((h1 - h0) / ((self.n - 1) as f32 * SPACING)).atan()
    }

    fn spread_violation(&self) -> bool {
        self.walkers.windows(2).any(|w| {
            ((w[1].x - w[0].x) - SPACING).abs() > SPREAD_LIMIT
        })
    }

}

impl MultiAgentEnv for MultiWalker {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        let meta = self.reset_soa();
        self.materialize(meta)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        let meta = self.step_soa(&ActionsRef::from_actions(actions));
        self.materialize(meta)
    }

    fn writes_soa(&self) -> bool {
        true
    }

    fn reset_soa(&mut self) -> StepMeta {
        self.t = 0;
        self.done = false;
        self.prev_tilt = 0.0;
        self.package_x = 0.0;
        self.last_reward = 0.0;
        // clear+extend keeps the Vec capacity across auto-resets
        self.walkers.clear();
        let n = self.n;
        let rng = &mut self.rng;
        self.walkers.extend((0..n).map(|i| Walker {
            x: (i as f32 - (n - 1) as f32 / 2.0) * SPACING
                + rng.range_f32(-0.05, 0.05),
            vx: 0.0,
            h: 1.0 + rng.range_f32(-0.05, 0.05),
            vh: 0.0,
        }));
        StepMeta { step_type: StepType::First, discount: 1.0 }
    }

    fn step_soa(&mut self, actions: &ActionsRef) -> StepMeta {
        assert!(!self.done, "step() after episode end");
        self.t += 1;
        self.prev_tilt = self.tilt();

        let mut ctrl = 0.0;
        for (i, w) in self.walkers.iter_mut().enumerate() {
            let raw = actions.cont(i);
            let a = [
                raw[0].clamp(-1.0, 1.0),
                raw[1].clamp(-1.0, 1.0),
                raw[2].clamp(-1.0, 1.0),
                raw[3].clamp(-1.0, 1.0),
            ];
            ctrl += a.iter().map(|x| x * x).sum::<f32>();
            let fx = FX_SCALE * 0.5 * (a[0] + a[2]);
            let fh = FH_SCALE * 0.5 * (a[1] + a[3]);
            w.vx += (fx - DRAG * w.vx) * DT;
            w.x += w.vx * DT;
            w.vh += (fh - LEG_K * (w.h - 1.0) - G_EFF) * DT;
            w.h += w.vh * DT;
            if w.h < H_MIN {
                w.h = H_MIN;
                w.vh = 0.0;
            } else if w.h > H_MAX {
                w.h = H_MAX;
                w.vh = 0.0;
            }
        }

        // the package rides the walkers
        let old_pkg = self.package_x;
        self.package_x =
            self.walkers.iter().map(|w| w.x).sum::<f32>() / self.n as f32;
        let progress = self.package_x - old_pkg;

        let fell = self.tilt().abs() > TILT_LIMIT || self.spread_violation();
        let truncated = !fell && self.t >= EPISODE;
        self.done = fell || truncated;

        self.last_reward = if fell {
            FALL_PENALTY
        } else {
            PROGRESS_SCALE * progress - CTRL_COST * ctrl / self.n as f32
        };
        StepMeta {
            step_type: if self.done { StepType::Last } else { StepType::Mid },
            discount: if fell { 0.0 } else { 1.0 },
        }
    }

    fn write_obs(&mut self, out: &mut [f32]) {
        let od = self.spec.obs_dim;
        let tilt = self.tilt();
        let vtilt = tilt - self.prev_tilt;
        let pkg_vx =
            self.walkers.iter().map(|w| w.vx).sum::<f32>() / self.n as f32;
        for i in 0..self.n {
            let w = &self.walkers[i];
            let nominal = self.package_x
                + (i as f32 - (self.n - 1) as f32 / 2.0) * SPACING;
            let left = if i > 0 {
                let l = &self.walkers[i - 1];
                [(w.x - l.x) - SPACING, l.h - w.h, l.vx - w.vx]
            } else {
                [0.0; 3]
            };
            let right = if i + 1 < self.n {
                let r = &self.walkers[i + 1];
                [(r.x - w.x) - SPACING, r.h - w.h, r.vx - w.vx]
            } else {
                [0.0; 3]
            };
            let o = &mut out[i * od..(i + 1) * od];
            o.fill(0.0); // zero-pad the tail up to obs_dim
            o[0] = w.h - 1.0;
            o[1] = w.vh;
            o[2] = w.vx;
            o[3] = w.x - nominal;
            o[4] = tilt;
            o[5] = vtilt;
            o[6] = pkg_vx;
            o[7..10].copy_from_slice(&left);
            o[10..13].copy_from_slice(&right);
            o[13] = (i > 0) as u8 as f32;
            o[14] = (i + 1 < self.n) as u8 as f32;
            o[15] = self.t as f32 / EPISODE as f32;
            o[16] = 1.0;
        }
    }

    fn write_rewards(&mut self, out: &mut [f32]) {
        out.fill(self.last_reward);
    }

    fn write_state(&mut self, out: &mut [f32]) {
        // state = stacked observations (state_dim == n * obs_dim)
        self.write_obs(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle(n: usize) -> Actions {
        Actions::Continuous(vec![vec![0.0; 4]; n])
    }

    /// Legs sag under load without lift force, but uniformly: no tilt.
    #[test]
    fn idle_walkers_survive_briefly() {
        let mut env = MultiWalker::new(3, 0);
        let mut ts = env.reset();
        for _ in 0..10 {
            assert!(!ts.is_last());
            ts = env.step(&idle(3));
        }
    }

    #[test]
    fn forward_force_earns_progress_reward() {
        let mut env = MultiWalker::new(3, 1);
        env.reset();
        let fwd = Actions::Continuous(vec![vec![1.0, 0.3, 1.0, 0.3]; 3]);
        let mut total = 0.0;
        let mut ts;
        for _ in 0..30 {
            ts = env.step(&fwd);
            total += ts.rewards[0];
            if ts.is_last() {
                break;
            }
        }
        assert!(total > 0.0, "synchronised push must progress: {total}");
    }

    #[test]
    fn uneven_legs_drop_the_package() {
        let mut env = MultiWalker::new(3, 2);
        env.reset();
        // walker 0 pushes its legs all the way up, walker 2 down
        let acts = Actions::Continuous(vec![
            vec![0.0, 1.0, 0.0, 1.0],
            vec![0.0, 0.0, 0.0, 0.0],
            vec![0.0, -1.0, 0.0, -1.0],
        ]);
        let mut fell = false;
        for _ in 0..EPISODE {
            let ts = env.step(&acts);
            if ts.is_last() {
                fell = ts.rewards[0] == FALL_PENALTY;
                break;
            }
        }
        assert!(fell, "tilting legs must drop the package");
    }

    #[test]
    fn spreading_apart_fails() {
        let mut env = MultiWalker::new(3, 3);
        env.reset();
        let acts = Actions::Continuous(vec![
            vec![-1.0, 0.0, -1.0, 0.0],
            vec![0.0; 4],
            vec![1.0, 0.0, 1.0, 0.0],
        ]);
        let mut fell = false;
        for _ in 0..EPISODE {
            let ts = env.step(&acts);
            if ts.is_last() {
                fell = ts.rewards[0] == FALL_PENALTY;
                break;
            }
        }
        assert!(fell, "walkers pulling apart must drop the package");
    }

    #[test]
    fn spec_and_random_play() {
        let mut env = MultiWalker::new(3, 4);
        assert_eq!(env.spec().obs_dim, 20);
        assert_eq!(env.spec().state_dim, 60);
        let mut rng = Rng::new(5);
        for _ in 0..5 {
            crate::env::random_episode(&mut env, &mut rng);
        }
    }
}
