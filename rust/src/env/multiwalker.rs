//! Simplified Multi-Walker (Gupta et al., 2017) — Fig 6 mid/bottom-right.
//!
//! The original is a Box2D bipedal-walker swarm jointly carrying a
//! package. A full rigid-body port is orthogonal to the *systems*
//! contribution the figure tests (cooperative continuous control where
//! every agent's failure ends the episode), so walkers are modelled as
//! force-controlled leg-carts: each walker has a horizontal position and
//! a leg extension, the package rests across the walkers, and it falls if
//! the walkers spread apart or the package tilts past a threshold.
//! Reward: shared forward progress of the package, a control cost, and a
//! large penalty on dropping it — the same learning signal structure
//! (dense progress + catastrophic cooperative failure) as the original.
//!
//! Actions per walker: 4 torques in [-1,1] mapped to horizontal force
//! (front+back hip) and leg extension force (front+back knee), mirroring
//! the original's 4-dim joint-torque interface.

use crate::core::{ActionSpec, Actions, EnvSpec, StepType, TimeStep};
use crate::env::MultiAgentEnv;
use crate::rng::Rng;

const DT: f32 = 0.05;
const SPACING: f32 = 1.0;
const DRAG: f32 = 1.0;
const LEG_K: f32 = 8.0; // leg spring toward nominal extension
const G_EFF: f32 = 2.0; // effective load on the legs
const FX_SCALE: f32 = 4.0;
const FH_SCALE: f32 = 6.0;
const TILT_LIMIT: f32 = 0.35;
const SPREAD_LIMIT: f32 = 0.6;
const H_MIN: f32 = 0.5;
const H_MAX: f32 = 1.5;
const EPISODE: usize = 100;
const PROGRESS_SCALE: f32 = 10.0;
const CTRL_COST: f32 = 0.02;
const FALL_PENALTY: f32 = -10.0;

#[derive(Clone, Debug)]
struct Walker {
    x: f32,
    vx: f32,
    h: f32,
    vh: f32,
}

/// Simplified multi-walker: `n` coupled walkers carrying a shared
/// package; continuous control with shared package-progress reward.
pub struct MultiWalker {
    spec: EnvSpec,
    rng: Rng,
    n: usize,
    walkers: Vec<Walker>,
    package_x: f32,
    prev_tilt: f32,
    t: usize,
    done: bool,
}

impl MultiWalker {
    /// An `n`-walker instance (the paper uses 3).
    pub fn new(n: usize, seed: u64) -> Self {
        MultiWalker {
            spec: EnvSpec {
                name: "multiwalker".into(),
                n_agents: n,
                obs_dim: 20,
                action: ActionSpec::Continuous { dim: 4 },
                state_dim: 20 * n,
                episode_limit: EPISODE,
            },
            rng: Rng::new(seed),
            n,
            walkers: vec![],
            package_x: 0.0,
            prev_tilt: 0.0,
            t: 0,
            done: true,
        }
    }

    fn tilt(&self) -> f32 {
        let h0 = self.walkers.first().unwrap().h;
        let h1 = self.walkers.last().unwrap().h;
        ((h1 - h0) / ((self.n - 1) as f32 * SPACING)).atan()
    }

    fn spread_violation(&self) -> bool {
        self.walkers.windows(2).any(|w| {
            ((w[1].x - w[0].x) - SPACING).abs() > SPREAD_LIMIT
        })
    }

    fn observe(&self) -> Vec<Vec<f32>> {
        let tilt = self.tilt();
        let vtilt = tilt - self.prev_tilt;
        let pkg_vx =
            self.walkers.iter().map(|w| w.vx).sum::<f32>() / self.n as f32;
        (0..self.n)
            .map(|i| {
                let w = &self.walkers[i];
                let nominal = self.package_x + (i as f32 - (self.n - 1) as f32 / 2.0) * SPACING;
                let left = if i > 0 {
                    let l = &self.walkers[i - 1];
                    [(w.x - l.x) - SPACING, l.h - w.h, l.vx - w.vx]
                } else {
                    [0.0; 3]
                };
                let right = if i + 1 < self.n {
                    let r = &self.walkers[i + 1];
                    [(r.x - w.x) - SPACING, r.h - w.h, r.vx - w.vx]
                } else {
                    [0.0; 3]
                };
                let mut o = vec![
                    w.h - 1.0,
                    w.vh,
                    w.vx,
                    w.x - nominal,
                    tilt,
                    vtilt,
                    pkg_vx,
                    left[0],
                    left[1],
                    left[2],
                    right[0],
                    right[1],
                    right[2],
                    (i > 0) as u8 as f32,
                    (i + 1 < self.n) as u8 as f32,
                    self.t as f32 / EPISODE as f32,
                    1.0,
                ];
                o.resize(self.spec.obs_dim, 0.0);
                o
            })
            .collect()
    }

    fn timestep(&self, st: StepType, reward: f32, discount: f32) -> TimeStep {
        let observations = self.observe();
        let state = observations.concat();
        TimeStep {
            step_type: st,
            observations,
            rewards: vec![reward; self.n],
            discount,
            state,
            legal_actions: None,
        }
    }
}

impl MultiAgentEnv for MultiWalker {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        self.t = 0;
        self.done = false;
        self.prev_tilt = 0.0;
        self.package_x = 0.0;
        self.walkers = (0..self.n)
            .map(|i| Walker {
                x: (i as f32 - (self.n - 1) as f32 / 2.0) * SPACING
                    + self.rng.range_f32(-0.05, 0.05),
                vx: 0.0,
                h: 1.0 + self.rng.range_f32(-0.05, 0.05),
                vh: 0.0,
            })
            .collect();
        self.timestep(StepType::First, 0.0, 1.0)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        assert!(!self.done, "step() after episode end");
        let acts = actions.as_continuous();
        self.t += 1;
        self.prev_tilt = self.tilt();

        let mut ctrl = 0.0;
        for (w, a) in self.walkers.iter_mut().zip(acts) {
            let a: Vec<f32> = a.iter().map(|x| x.clamp(-1.0, 1.0)).collect();
            ctrl += a.iter().map(|x| x * x).sum::<f32>();
            let fx = FX_SCALE * 0.5 * (a[0] + a[2]);
            let fh = FH_SCALE * 0.5 * (a[1] + a[3]);
            w.vx += (fx - DRAG * w.vx) * DT;
            w.x += w.vx * DT;
            w.vh += (fh - LEG_K * (w.h - 1.0) - G_EFF) * DT;
            w.h += w.vh * DT;
            if w.h < H_MIN {
                w.h = H_MIN;
                w.vh = 0.0;
            } else if w.h > H_MAX {
                w.h = H_MAX;
                w.vh = 0.0;
            }
        }

        // the package rides the walkers
        let old_pkg = self.package_x;
        self.package_x =
            self.walkers.iter().map(|w| w.x).sum::<f32>() / self.n as f32;
        let progress = self.package_x - old_pkg;

        let fell = self.tilt().abs() > TILT_LIMIT || self.spread_violation();
        let truncated = !fell && self.t >= EPISODE;
        self.done = fell || truncated;

        let reward = if fell {
            FALL_PENALTY
        } else {
            PROGRESS_SCALE * progress - CTRL_COST * ctrl / self.n as f32
        };
        let st = if self.done { StepType::Last } else { StepType::Mid };
        self.timestep(st, reward, if fell { 0.0 } else { 1.0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle(n: usize) -> Actions {
        Actions::Continuous(vec![vec![0.0; 4]; n])
    }

    /// Legs sag under load without lift force, but uniformly: no tilt.
    #[test]
    fn idle_walkers_survive_briefly() {
        let mut env = MultiWalker::new(3, 0);
        let mut ts = env.reset();
        for _ in 0..10 {
            assert!(!ts.is_last());
            ts = env.step(&idle(3));
        }
    }

    #[test]
    fn forward_force_earns_progress_reward() {
        let mut env = MultiWalker::new(3, 1);
        env.reset();
        let fwd = Actions::Continuous(vec![vec![1.0, 0.3, 1.0, 0.3]; 3]);
        let mut total = 0.0;
        let mut ts;
        for _ in 0..30 {
            ts = env.step(&fwd);
            total += ts.rewards[0];
            if ts.is_last() {
                break;
            }
        }
        assert!(total > 0.0, "synchronised push must progress: {total}");
    }

    #[test]
    fn uneven_legs_drop_the_package() {
        let mut env = MultiWalker::new(3, 2);
        env.reset();
        // walker 0 pushes its legs all the way up, walker 2 down
        let acts = Actions::Continuous(vec![
            vec![0.0, 1.0, 0.0, 1.0],
            vec![0.0, 0.0, 0.0, 0.0],
            vec![0.0, -1.0, 0.0, -1.0],
        ]);
        let mut fell = false;
        for _ in 0..EPISODE {
            let ts = env.step(&acts);
            if ts.is_last() {
                fell = ts.rewards[0] == FALL_PENALTY;
                break;
            }
        }
        assert!(fell, "tilting legs must drop the package");
    }

    #[test]
    fn spreading_apart_fails() {
        let mut env = MultiWalker::new(3, 3);
        env.reset();
        let acts = Actions::Continuous(vec![
            vec![-1.0, 0.0, -1.0, 0.0],
            vec![0.0; 4],
            vec![1.0, 0.0, 1.0, 0.0],
        ]);
        let mut fell = false;
        for _ in 0..EPISODE {
            let ts = env.step(&acts);
            if ts.is_last() {
                fell = ts.rewards[0] == FALL_PENALTY;
                break;
            }
        }
        assert!(fell, "walkers pulling apart must drop the package");
    }

    #[test]
    fn spec_and_random_play() {
        let mut env = MultiWalker::new(3, 4);
        assert_eq!(env.spec().obs_dim, 20);
        assert_eq!(env.spec().state_dim, 60);
        let mut rng = Rng::new(5);
        for _ in 0..5 {
            crate::env::random_episode(&mut env, &mut rng);
        }
    }
}
