//! MPE `simple_speaker_listener` — Fig 6 top-right.
//!
//! A static speaker observes which of three landmarks is the goal and
//! emits a 3-dim continuous communication vector; a mobile listener
//! observes the landmarks and the speaker's utterance and must navigate
//! to the goal. Shared reward: minus the squared listener-goal distance.
//!
//! Heterogeneous specs are padded to the preset maxima (obs 11, act 3):
//! the speaker's observation is its 3-dim goal one-hot + zeros; the
//! listener's action uses only the first two dims (acceleration).

use crate::core::{
    ActionSpec, Actions, ActionsRef, EnvSpec, StepMeta, StepType, TimeStep,
};
use crate::env::mpe::core::{Entity, World};
use crate::env::MultiAgentEnv;
use crate::rng::Rng;

const ACCEL: f32 = 5.0;
const EPISODE: usize = 25;
/// Agent index of the (immobile) speaker.
pub const SPEAKER: usize = 0;
/// Agent index of the (colour-blind) listener.
pub const LISTENER: usize = 1;

/// MPE simple_speaker_listener: the speaker sees the goal colour,
/// the listener moves; heterogeneous specs padded to a shared dim.
pub struct SpeakerListener {
    spec: EnvSpec,
    rng: Rng,
    world: World, // agents[0] = listener body (speaker has no body)
    goal: usize,
    comm: [f32; 3], // last utterance (heard with one-step delay)
    t: usize,
    last_reward: f32,
}

impl SpeakerListener {
    /// The standard 2-agent, 3-landmark instance.
    pub fn new(seed: u64) -> Self {
        SpeakerListener {
            spec: EnvSpec {
                name: "mpe_speaker_listener".into(),
                n_agents: 2,
                obs_dim: 11,
                action: ActionSpec::Continuous { dim: 3 },
                state_dim: 22,
                episode_limit: EPISODE,
            },
            rng: Rng::new(seed),
            world: World::default(),
            goal: 0,
            comm: [0.0; 3],
            t: 0,
            last_reward: 0.0,
        }
    }

    fn reward(&self) -> f32 {
        let d = self.world.agents[0].dist(&self.world.landmarks[self.goal]);
        -(d * d)
    }
}

impl MultiAgentEnv for SpeakerListener {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        let meta = self.reset_soa();
        self.materialize(meta)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        let meta = self.step_soa(&ActionsRef::from_actions(actions));
        self.materialize(meta)
    }

    fn writes_soa(&self) -> bool {
        true
    }

    fn reset_soa(&mut self) -> StepMeta {
        self.t = 0;
        self.comm = [0.0; 3];
        self.last_reward = 0.0;
        self.goal = self.rng.below(3);
        self.world.clear();
        let mut body = Entity::new(0.075, true, false);
        body.pos = [
            self.rng.range_f32(-1.0, 1.0),
            self.rng.range_f32(-1.0, 1.0),
        ];
        self.world.agents.push(body);
        for _ in 0..3 {
            let mut l = Entity::new(0.04, false, false);
            l.pos = [
                self.rng.range_f32(-1.0, 1.0),
                self.rng.range_f32(-1.0, 1.0),
            ];
            self.world.landmarks.push(l);
        }
        StepMeta { step_type: StepType::First, discount: 1.0 }
    }

    fn step_soa(&mut self, actions: &ActionsRef) -> StepMeta {
        self.t += 1;
        let sp = actions.cont(SPEAKER);
        let li = actions.cont(LISTENER);
        // speaker utterance: heard on the NEXT step (MPE comm delay)
        self.comm = [
            sp[0].clamp(-1.0, 1.0),
            sp[1].clamp(-1.0, 1.0),
            sp[2].clamp(-1.0, 1.0),
        ];
        // listener motion: first two action dims
        let f = [
            li[0].clamp(-1.0, 1.0) * ACCEL,
            li[1].clamp(-1.0, 1.0) * ACCEL,
        ];
        self.world.step(&[f]);
        self.last_reward = self.reward();
        StepMeta {
            step_type: if self.t >= EPISODE {
                StepType::Last
            } else {
                StepType::Mid
            },
            discount: 1.0,
        }
    }

    fn write_obs(&mut self, out: &mut [f32]) {
        let od = self.spec.obs_dim;
        // speaker: goal one-hot, padded to obs_dim
        let sp = &mut out[0..od];
        sp.fill(0.0);
        sp[self.goal] = 1.0;
        // listener: vel(2) + rel landmarks(6) + comm(3)
        let li_body = &self.world.agents[0];
        let li = &mut out[od..2 * od];
        li[0] = li_body.vel[0];
        li[1] = li_body.vel[1];
        let mut k = 2;
        for lm in &self.world.landmarks {
            li[k] = lm.pos[0] - li_body.pos[0];
            li[k + 1] = lm.pos[1] - li_body.pos[1];
            k += 2;
        }
        li[k..k + 3].copy_from_slice(&self.comm);
        debug_assert_eq!(k + 3, od);
    }

    fn write_rewards(&mut self, out: &mut [f32]) {
        out.fill(self.last_reward);
    }

    fn write_state(&mut self, out: &mut [f32]) {
        // state = stacked observations (state_dim == n * obs_dim)
        self.write_obs(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_matches_preset() {
        let env = SpeakerListener::new(0);
        assert_eq!(env.spec().obs_dim, 11);
        assert_eq!(env.spec().state_dim, 22);
        assert_eq!(env.spec().n_actions(), 3);
    }

    #[test]
    fn speaker_obs_is_goal_onehot() {
        let mut env = SpeakerListener::new(1);
        let ts = env.reset();
        let sp = &ts.observations[SPEAKER];
        assert_eq!(sp.iter().sum::<f32>(), 1.0);
        assert_eq!(sp[env.goal], 1.0);
    }

    #[test]
    fn comm_delayed_one_step() {
        let mut env = SpeakerListener::new(2);
        let ts0 = env.reset();
        assert_eq!(&ts0.observations[LISTENER][8..11], &[0.0; 3]);
        let a = Actions::Continuous(vec![vec![0.5, -0.5, 1.0], vec![0.0; 3]]);
        let ts1 = env.step(&a);
        assert_eq!(&ts1.observations[LISTENER][8..11], &[0.5, -0.5, 1.0]);
    }

    #[test]
    fn moving_to_goal_improves_reward() {
        let mut env = SpeakerListener::new(3);
        env.reset();
        let far = env.reward();
        env.world.agents[0].pos = env.world.landmarks[env.goal].pos;
        assert!(env.reward() > far);
        assert!(env.reward().abs() < 1e-6);
    }

    #[test]
    fn random_episode_runs() {
        let mut env = SpeakerListener::new(4);
        let mut rng = Rng::new(5);
        let (_, steps) = crate::env::random_episode(&mut env, &mut rng);
        assert_eq!(steps, 25);
    }
}
