//! Multi-agent Particle Environments (MPE, Lowe et al. 2017 /
//! openai/multiagent-particle-envs) — paper Fig 6 (top-right).
//!
//! Faithful port of the point-mass physics core (dt = 0.1, velocity
//! damping 0.25, soft contact forces) plus the two scenarios the paper
//! benchmarks: `simple_spread` and `simple_speaker_listener`.

pub mod core;
pub mod speaker_listener;
pub mod spread;

pub use core::{Entity, World};
