//! MPE physics core: point-mass entities with damping and soft contacts.
//!
//! Matches openai/multiagent-particle-envs `core.py`:
//!   p_vel <- p_vel * (1 - damping)
//!   p_vel <- p_vel + (F / mass) * dt
//!   p_pos <- p_pos + p_vel * dt
//! with contact force between overlapping entities
//!   f = k * log(1 + exp((d_min - d) / margin)) * margin  (softplus)
//! where k = 100, margin = 1e-3.

#[derive(Clone, Debug)]
/// A physical body in the MPE world (agent or landmark).
pub struct Entity {
    /// Position in the 2D plane.
    pub pos: [f32; 2],
    /// Velocity.
    pub vel: [f32; 2],
    /// Collision radius.
    pub size: f32,
    /// Inertial mass.
    pub mass: f32,
    /// Whether forces move this entity (landmarks are static).
    pub movable: bool,
    /// Whether this entity takes part in contact forces.
    pub collide: bool,
}

impl Entity {
    /// An entity at the origin with the given physical properties.
    pub fn new(size: f32, movable: bool, collide: bool) -> Self {
        Entity {
            pos: [0.0; 2],
            vel: [0.0; 2],
            size,
            mass: 1.0,
            movable,
            collide,
        }
    }

    /// Euclidean centre distance to `other`.
    pub fn dist(&self, other: &Entity) -> f32 {
        let dx = self.pos[0] - other.pos[0];
        let dy = self.pos[1] - other.pos[1];
        (dx * dx + dy * dy).sqrt()
    }

    /// Whether the two collision radii intersect.
    pub fn overlaps(&self, other: &Entity) -> bool {
        self.dist(other) < self.size + other.size
    }
}

/// Physics integration timestep.
pub const DT: f32 = 0.1;
/// Per-step velocity damping factor.
pub const DAMPING: f32 = 0.25;
/// Contact (collision) force magnitude.
pub const CONTACT_FORCE: f32 = 100.0;
/// Softplus margin of the contact penetration response.
pub const CONTACT_MARGIN: f32 = 1e-3;

/// The physical world: `agents` move, `landmarks` are static scenery.
#[derive(Clone, Debug, Default)]
pub struct World {
    /// Controllable bodies (one per agent).
    pub agents: Vec<Entity>,
    /// Static reference points.
    pub landmarks: Vec<Entity>,
    /// Reused per-step force accumulator, so stepping is
    /// allocation-free after the first call (SoA hot path).
    force_scratch: Vec<[f32; 2]>,
}

impl World {
    /// Drop all entities, keeping buffer capacity (episode resets on
    /// the allocation-free hot path).
    pub fn clear(&mut self) {
        self.agents.clear();
        self.landmarks.clear();
    }

    /// Integrate one physics step given per-agent control forces.
    pub fn step(&mut self, forces: &[[f32; 2]]) {
        assert_eq!(forces.len(), self.agents.len());
        let n = self.agents.len();
        let total = &mut self.force_scratch;
        total.clear();
        total.extend_from_slice(forces);

        // pairwise contact forces between colliding agents
        for i in 0..n {
            for j in (i + 1)..n {
                if !(self.agents[i].collide && self.agents[j].collide) {
                    continue;
                }
                let (a, b) = (&self.agents[i], &self.agents[j]);
                let delta = [a.pos[0] - b.pos[0], a.pos[1] - b.pos[1]];
                let dist = (delta[0] * delta[0] + delta[1] * delta[1])
                    .sqrt()
                    .max(1e-6);
                let dist_min = a.size + b.size;
                // numerically stable softplus penetration:
                // softplus(u) = max(u, 0) + ln(1 + exp(-|u|))
                let k = CONTACT_MARGIN;
                let u = (dist_min - dist) / k;
                let pen = (u.max(0.0) + (-u.abs()).exp().ln_1p()) * k;
                let f = CONTACT_FORCE * pen;
                let fx = f * delta[0] / dist;
                let fy = f * delta[1] / dist;
                total[i][0] += fx;
                total[i][1] += fy;
                total[j][0] -= fx;
                total[j][1] -= fy;
            }
        }

        for (agent, f) in self.agents.iter_mut().zip(total.iter()) {
            if !agent.movable {
                continue;
            }
            agent.vel[0] *= 1.0 - DAMPING;
            agent.vel[1] *= 1.0 - DAMPING;
            agent.vel[0] += f[0] / agent.mass * DT;
            agent.vel[1] += f[1] / agent.mass * DT;
            agent.pos[0] += agent.vel[0] * DT;
            agent.pos[1] += agent.vel[1] * DT;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn free_particle_coasts_with_damping() {
        let mut w = World::default();
        let mut e = Entity::new(0.05, true, false);
        e.vel = [1.0, 0.0];
        w.agents.push(e);
        w.step(&[[0.0, 0.0]]);
        assert!((w.agents[0].vel[0] - 0.75).abs() < 1e-6);
        assert!((w.agents[0].pos[0] - 0.075).abs() < 1e-6);
    }

    #[test]
    fn force_accelerates() {
        let mut w = World::default();
        w.agents.push(Entity::new(0.05, true, false));
        w.step(&[[5.0, 0.0]]);
        assert!((w.agents[0].vel[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn immovable_entity_stays_put() {
        let mut w = World::default();
        let mut e = Entity::new(0.05, false, false);
        e.vel = [1.0, 1.0];
        w.agents.push(e);
        w.step(&[[10.0, 10.0]]);
        assert_eq!(w.agents[0].pos, [0.0, 0.0]);
    }

    #[test]
    fn overlapping_agents_repel() {
        let mut w = World::default();
        let mut a = Entity::new(0.15, true, true);
        let mut b = Entity::new(0.15, true, true);
        a.pos = [0.0, 0.0];
        b.pos = [0.1, 0.0]; // heavily overlapping
        w.agents.push(a);
        w.agents.push(b);
        w.step(&[[0.0, 0.0], [0.0, 0.0]]);
        assert!(w.agents[0].vel[0] < 0.0, "a pushed left");
        assert!(w.agents[1].vel[0] > 0.0, "b pushed right");
    }

    /// Regression: deep overlap must not overflow the softplus — forces
    /// (and hence velocities/positions) stay finite even when entities
    /// sit on top of each other (found as NaN replay data in MAD4PG).
    #[test]
    fn deep_overlap_force_is_finite() {
        let mut w = World::default();
        let mut a = Entity::new(0.15, true, true);
        let mut b = Entity::new(0.15, true, true);
        a.pos = [0.0, 0.0];
        b.pos = [1e-4, 0.0];
        w.agents.push(a);
        w.agents.push(b);
        for _ in 0..50 {
            w.step(&[[0.0, 0.0], [0.0, 0.0]]);
        }
        for e in &w.agents {
            assert!(e.pos[0].is_finite() && e.vel[0].is_finite());
        }
        // linear regime: penetration ~ dist_min - dist
        assert!(w.agents[0].vel[0] < 0.0 && w.agents[1].vel[0] > 0.0);
    }

    #[test]
    fn distant_agents_do_not_interact() {
        let mut w = World::default();
        let mut a = Entity::new(0.1, true, true);
        let mut b = Entity::new(0.1, true, true);
        a.pos = [0.0, 0.0];
        b.pos = [2.0, 0.0];
        w.agents.push(a);
        w.agents.push(b);
        w.step(&[[0.0, 0.0], [0.0, 0.0]]);
        assert!(w.agents[0].vel[0].abs() < 1e-4);
    }
}
