//! MPE `simple_spread`: N agents must cover N landmarks — Fig 6 top-right.
//!
//! Shared reward: minus the sum over landmarks of the distance to the
//! closest agent, minus 1 per colliding agent pair (original scenario).
//! Continuous actions: 2-D acceleration in [-1, 1], scaled by the MPE
//! sensitivity factor.

use crate::core::{
    ActionSpec, Actions, ActionsRef, EnvSpec, StepMeta, StepType, TimeStep,
};
use crate::env::mpe::core::{Entity, World};
use crate::env::MultiAgentEnv;
use crate::rng::Rng;

const ACCEL: f32 = 5.0; // MPE u_multiplier for spread-like scenarios
const EPISODE: usize = 25;

/// MPE simple_spread: `n` agents cover `n` landmarks, penalised for
/// collisions (continuous control, shared coverage reward).
pub struct Spread {
    spec: EnvSpec,
    rng: Rng,
    world: World,
    n: usize,
    t: usize,
    last_reward: f32,
    forces: Vec<[f32; 2]>, // reused per step (allocation-free hot path)
}

impl Spread {
    /// An `n`-agent, `n`-landmark instance (the paper uses 3).
    pub fn new(n: usize, seed: u64) -> Self {
        Spread {
            spec: EnvSpec {
                name: "mpe_spread".into(),
                n_agents: n,
                obs_dim: 4 + 2 * n + 2 * (n - 1),
                action: ActionSpec::Continuous { dim: 2 },
                state_dim: n * (4 + 2 * n + 2 * (n - 1)),
                episode_limit: EPISODE,
            },
            rng: Rng::new(seed),
            world: World::default(),
            n,
            t: 0,
            last_reward: 0.0,
            forces: Vec::new(),
        }
    }

    fn reward(&self) -> f32 {
        let mut r = 0.0;
        for lm in &self.world.landmarks {
            let min_d = self
                .world
                .agents
                .iter()
                .map(|a| a.dist(lm))
                .fold(f32::INFINITY, f32::min);
            r -= min_d;
        }
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                if self.world.agents[i].overlaps(&self.world.agents[j]) {
                    r -= 1.0;
                }
            }
        }
        r
    }

}

impl MultiAgentEnv for Spread {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self) -> TimeStep {
        let meta = self.reset_soa();
        self.materialize(meta)
    }

    fn step(&mut self, actions: &Actions) -> TimeStep {
        let meta = self.step_soa(&ActionsRef::from_actions(actions));
        self.materialize(meta)
    }

    fn writes_soa(&self) -> bool {
        true
    }

    fn reset_soa(&mut self) -> StepMeta {
        self.t = 0;
        self.last_reward = 0.0;
        self.world.clear();
        for _ in 0..self.n {
            let mut a = Entity::new(0.15, true, true);
            a.pos = [
                self.rng.range_f32(-1.0, 1.0),
                self.rng.range_f32(-1.0, 1.0),
            ];
            self.world.agents.push(a);
        }
        for _ in 0..self.n {
            let mut l = Entity::new(0.05, false, false);
            l.pos = [
                self.rng.range_f32(-1.0, 1.0),
                self.rng.range_f32(-1.0, 1.0),
            ];
            self.world.landmarks.push(l);
        }
        StepMeta { step_type: StepType::First, discount: 1.0 }
    }

    fn step_soa(&mut self, actions: &ActionsRef) -> StepMeta {
        self.t += 1;
        self.forces.clear();
        for i in 0..self.n {
            let a = actions.cont(i);
            self.forces.push([
                a[0].clamp(-1.0, 1.0) * ACCEL,
                a[1].clamp(-1.0, 1.0) * ACCEL,
            ]);
        }
        let forces = std::mem::take(&mut self.forces);
        self.world.step(&forces);
        self.forces = forces;
        self.last_reward = self.reward();
        StepMeta {
            step_type: if self.t >= EPISODE {
                StepType::Last
            } else {
                StepType::Mid
            },
            // spread truncates (time limit), never terminates
            discount: 1.0,
        }
    }

    fn write_obs(&mut self, out: &mut [f32]) {
        let od = self.spec.obs_dim;
        for i in 0..self.n {
            let me = &self.world.agents[i];
            let o = &mut out[i * od..(i + 1) * od];
            o[0] = me.vel[0];
            o[1] = me.vel[1];
            o[2] = me.pos[0];
            o[3] = me.pos[1];
            let mut k = 4;
            for lm in &self.world.landmarks {
                o[k] = lm.pos[0] - me.pos[0];
                o[k + 1] = lm.pos[1] - me.pos[1];
                k += 2;
            }
            for (j, other) in self.world.agents.iter().enumerate() {
                if j != i {
                    o[k] = other.pos[0] - me.pos[0];
                    o[k + 1] = other.pos[1] - me.pos[1];
                    k += 2;
                }
            }
            debug_assert_eq!(k, od);
        }
    }

    fn write_rewards(&mut self, out: &mut [f32]) {
        out.fill(self.last_reward);
    }

    fn write_state(&mut self, out: &mut [f32]) {
        // state = stacked observations (state_dim == n * obs_dim)
        self.write_obs(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_matches_preset() {
        let env = Spread::new(3, 0);
        assert_eq!(env.spec().obs_dim, 14);
        assert_eq!(env.spec().state_dim, 42);
    }

    #[test]
    fn reward_improves_when_agents_reach_landmarks() {
        let mut env = Spread::new(3, 1);
        env.reset();
        let r_far = env.reward();
        // teleport agents onto landmarks
        for i in 0..3 {
            env.world.agents[i].pos = env.world.landmarks[i].pos;
        }
        let r_on = env.reward();
        assert!(r_on > r_far, "{r_on} !> {r_far}");
        assert!(r_on > -0.5, "covering all landmarks ~0 distance cost");
    }

    #[test]
    fn collision_penalty_applies() {
        let mut env = Spread::new(3, 2);
        env.reset();
        for a in &mut env.world.agents {
            a.pos = [0.0, 0.0];
        }
        let r = env.reward();
        // 3 overlapping pairs -> at least -3 from collisions
        let dist_part: f32 = env
            .world
            .landmarks
            .iter()
            .map(|lm| {
                env.world.agents.iter().map(|a| a.dist(lm)).fold(f32::INFINITY, f32::min)
            })
            .sum();
        assert!((r + dist_part + 3.0).abs() < 1e-5);
    }

    #[test]
    fn episode_runs_25_steps() {
        let mut env = Spread::new(3, 3);
        let mut rng = Rng::new(4);
        let (_, steps) = crate::env::random_episode(&mut env, &mut rng);
        assert_eq!(steps, 25);
    }
}
